"""Regenerate the roofline tables inside EXPERIMENTS.md from
experiments/dryrun artifacts (idempotent; keeps everything else)."""
import re
import subprocess
import sys

sys.path.insert(0, "src")
from repro.roofline import analysis  # noqa: E402

MARK = "<!-- ROOFLINE TABLES -->"


def main():
    out = []
    for mesh, label in (("pod1", "single pod — 256 chips (baseline table)"),
                        ("pod2", "multi-pod — 512 chips")):
        recs = analysis.load("experiments/dryrun", mesh)
        if not recs:
            continue
        out.append(f"\n#### Roofline — {label}\n")
        out.append(analysis.table("experiments/dryrun", mesh))
        out.append("")
    text = open("EXPERIMENTS.md").read()
    assert MARK in text
    pre, post = text.split(MARK, 1)
    # drop any previously generated tables (up to the next "Reading of")
    post = post.split("Reading of the baseline table", 1)[-1]
    new = (pre + MARK + "\n" + "\n".join(out)
           + "\nReading of the baseline table" + post)
    open("EXPERIMENTS.md", "w").write(new)
    print("EXPERIMENTS.md roofline tables regenerated "
          f"({sum(1 for _ in analysis.load('experiments/dryrun', 'pod1'))} "
          "pod1 records)")


if __name__ == "__main__":
    main()
