#!/usr/bin/env bash
# Tier-1 CI: test suite + cutover-regression gate.
#
#   scripts/ci.sh            # run everything
#
# The cutover gate re-runs the tuning profiler (benchmarks.run --json) and
# fails if any emitted (tier, work_items) cutover point moved by more than
# 2x against the checked-in benchmarks/baseline_cutover.json.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# The --ignore list is the jax-version-drift set documented in ROADMAP.md
# ("Open items"): these modules fail on the pinned jax 0.4.37 for reasons
# unrelated to repo logic.  Drop entries as the toolchain catches up.
python -m pytest -x -q \
    --ignore=tests/test_comms_equiv.py \
    --ignore=tests/test_dryrun_small.py \
    --ignore=tests/test_ring_kernels.py \
    --deselect=tests/test_hlo_parser.py::test_scan_flops_scaled_by_trip_count \
    --deselect=tests/test_ishmem_api.py::test_hierarchical_psum_matches_flat \
    --deselect=tests/test_system.py::test_dp_gradient_allreduce_via_shmem_backend

echo "== cutover tuning profile =="
python -m benchmarks.run --only cutover --json BENCH_cutover.json

echo "== cutover regression gate =="
python scripts/check_cutover.py BENCH_cutover.json benchmarks/baseline_cutover.json
