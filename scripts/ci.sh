#!/usr/bin/env bash
# Tier-1 CI: test suite + cutover-regression gate + overlap smoke.
#
#   scripts/ci.sh            # run everything
#
# The cutover gate re-runs the tuning profiler (benchmarks.run --json) and
# fails if any emitted (tier, work_items) cutover point moved by more than
# 2x against the checked-in benchmarks/baseline_cutover.json.  The overlap
# smoke emits BENCH_overlap.json (modeled nbi overlap efficiency + the
# completion queue's write-combining ratio) alongside the cutover profile.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# jax-version drift is marked in-tree (version-keyed xfail/skip, see
# tests/conftest.py and ROADMAP.md "Open items"), so the plain suite is
# clean signal — no ignore/deselect lists to keep in sync here.
python -m pytest -q

echo "== cutover tuning profile =="
python -m benchmarks.run --only cutover --json BENCH_cutover.json

echo "== cutover regression gate =="
python scripts/check_cutover.py BENCH_cutover.json benchmarks/baseline_cutover.json

echo "== overlap smoke (completion engine) =="
python - <<'EOF'
from benchmarks import bench_overlap
doc = bench_overlap.smoke("BENCH_overlap.json")
eff = doc["ring_allreduce"]["overlap_efficiency"]
ratio = doc["write_combining"]["coalescing_ratio"]
assert eff > 1.0, f"nbi overlap efficiency regressed to {eff:.3f} (<= 1.0)"
assert ratio > 1.0, f"write combining inactive (ratio {ratio:.1f})"
print(f"overlap efficiency {eff:.3f}, coalescing ratio {ratio:.1f} -> OK")
EOF

echo "== paged decode / streaming / shared-prefix smoke =="
python -m benchmarks.bench_paged_decode --smoke BENCH_paged.json
python - <<'EOF'
import json, os
assert os.path.exists("BENCH_paged.json"), "BENCH_paged.json not emitted"
doc = json.load(open("BENCH_paged.json"))
whole = doc["ttfd"]["whole_prefill_s"]
stream = doc["ttfd"]["streaming_s"]
shared = doc["shared_prefix"]["blocks_shared"]
cow = doc["shared_prefix"]["cow_copies"]
assert stream < whole, \
    f"chunked streaming no longer beats whole-prefill TTFD " \
    f"({stream*1e6:.2f}us >= {whole*1e6:.2f}us)"
assert shared > 0, "shared-prefix policy mapped no blocks"
assert cow > 0, "boundary-block copy-on-write never fired"
print(f"streaming TTFD {whole/stream:.2f}x better, {shared} blocks shared, "
      f"{cow} COW copies -> OK")
EOF

echo "== cluster frontend smoke (SLO scheduling / shed / affinity) =="
python -m benchmarks.bench_fleet --smoke BENCH_fleet.json
python - <<'EOF'
import json
doc = json.load(open("BENCH_fleet.json"))
ab = doc["slo_vs_fcfs"]
fcfs_p99 = ab["fcfs"]["interactive_ttfd_p99_steps"]
slo_p99 = ab["slo"]["interactive_ttfd_p99_steps"]
assert slo_p99 < fcfs_p99, \
    f"SLO scheduling no longer beats FCFS on interactive p99 TTFD under " \
    f"overload ({slo_p99:.1f} >= {fcfs_p99:.1f} steps)"
assert ab["slo"]["preempts"] > 0, \
    "over-budget preemption never fired under overload"
pts = {round(p["rate"], 2): p for p in doc["goodput"]["points"]}
rates = sorted(pts)
cap, over = pts[rates[0]], pts[rates[-1]]
assert over["shed"] > 0, \
    f"no shedding past saturation (rate {rates[-1]}) — queues unbounded"
assert over["goodput_per_step"] >= 0.7 * cap["goodput_per_step"], \
    f"goodput collapsed past saturation: {over['goodput_per_step']:.3f}" \
    f"/step at {rates[-1]} vs {cap['goodput_per_step']:.3f}/step at " \
    f"{rates[0]}"
aff = doc["affinity"]
assert aff["random"]["bytes_cross_pod"] > 0, \
    "random routing produced no cross-pod wire bytes — the affinity " \
    "comparison is vacuous"
assert (aff["affinity"]["bytes_cross_pod"]
        < aff["random"]["bytes_cross_pod"]), \
    f"prefix-affinity routing stopped saving cross-pod wire bytes " \
    f"({aff['affinity']['bytes_cross_pod']} >= " \
    f"{aff['random']['bytes_cross_pod']})"
print(f"SLO p99 {slo_p99:.1f} vs FCFS {fcfs_p99:.1f} steps, "
      f"{over['shed']} shed at {rates[-1]}x with goodput "
      f"{over['goodput_per_step']:.3f}/step, affinity cross-pod "
      f"{aff['affinity']['bytes_cross_pod']} vs "
      f"{aff['random']['bytes_cross_pod']} B -> OK")
EOF

echo "== fault-tolerance smoke (chaos: pod loss mid-benchmark) =="
python -m benchmarks.bench_fault --smoke BENCH_fault.json
python - <<'EOF'
import json
doc = json.load(open("BENCH_fault.json"))
p = doc["pod_loss"]
assert p["wrong_tokens"] == 0, \
    f"{p['wrong_tokens']} surviving request(s) decoded WRONG tokens after " \
    f"the pod loss — recovery corrupted state"
assert p["recovered_requests"] >= 1, \
    "the fault hit no live work — the chaos gate is vacuous"
assert p["recovery_ratio"] >= 0.9, \
    f"goodput never recovered: post-fault plateau is " \
    f"{p['recovery_ratio']:.2f}x the pre-fault plateau (< 0.9x)"
assert p["recovery_ttfd_max_steps"] <= 15, \
    f"recovery TTFD unbounded: a recovered request took " \
    f"{p['recovery_ttfd_max_steps']} steps to re-admit (> 15)"
assert p["completed"] + p["casualties"] == p["offered"], \
    f"request accounting leaked: {p['completed']} completed + " \
    f"{p['casualties']} casualties != {p['offered']} offered"
print(f"pod loss at step 10: goodput {p['pre_fault_good_per_step']:.2f} -> "
      f"{p['dip_good_per_step']:.2f} -> "
      f"{p['post_recovery_good_per_step']:.2f}/step "
      f"({p['recovery_ratio']:.2f}x recovery), 0 wrong tokens, "
      f"{p['recovered_requests']} recovered (TTFD max "
      f"{p['recovery_ttfd_max_steps']} steps) -> OK")
EOF

echo "== KV migration smoke (disaggregated serving) =="
python -m benchmarks.bench_kvxfer --smoke BENCH_kvxfer.json
python - <<'EOF'
import json
doc = json.load(open("BENCH_kvxfer.json"))
ovl = doc["overlap"]["overlap_ratio"]
ratio = doc["migration"]["coalescing_ratio"]
bw = doc["migration"]["bw_GBs"]
profiles = doc["telemetry"]["fitted_profiles"]
assert ovl >= 1.2, f"MB-scale overlap below acceptance floor ({ovl:.3f} < 1.2)"
assert ratio > 1.0, f"block write-combining inactive (ratio {ratio:.1f})"
assert bw > 0.0, "migration moved no bytes"
assert profiles > 0, "kvxfer telemetry produced no fitted transport profiles"
print(f"migration overlap {ovl:.2f}x, coalescing {ratio:.1f}, "
      f"{bw:.1f} GB/s modeled, {profiles} fitted profiles -> OK")
EOF

echo "== observability smoke (tracer / critical path / audit / alerts) =="
python -m benchmarks.bench_obs --smoke BENCH_obs.json
python - <<'EOF'
import json
doc = json.load(open("BENCH_obs.json"))
ov = doc["overhead"]
assert ov["overhead_pct"] < 2.0, \
    f"observability work exceeds 2% of the fleet smoke wall clock " \
    f"({ov['overhead_pct']:.2f}% of {ov['off_best_s']:.2f}s)"
assert ov["outputs_bitwise_identical"], \
    "tracer-on outputs diverged from tracer-off (observer effect)"
tr = doc["trace"]
assert tr["validation_errors"] == [], \
    f"exported trace failed schema validation: {tr['validation_errors'][:5]}"
assert tr["chains"] == tr["requests"] > 0 and not tr["chains_missing"], \
    f"request lifelines missing from trace: {tr['chains_missing']}"
assert tr["chain_gaps"] == 0, \
    f"{tr['chain_gaps']} untraced holes in request lifelines"
assert tr["flow_events"] % 2 == 0 and tr["flow_events"] > 0, \
    "migration flow arrows missing or unpaired"
assert tr["paths"] > 0 and tr["paths_exact"] == tr["paths"], \
    f"critical-path attribution inexact: {tr['paths_exact']}/{tr['paths']} " \
    f"request paths are gap-free with segment sum == e2e"
rf = doc["refit"]
assert rf["refits"] > 0, "online re-fit never fired in the smoke run"
assert rf["decisions_changed"] >= 1, \
    "online re-fit corrected no cutover decisions against the stale " \
    "warm-start table"
au = doc["audit"]
assert au["checks"] > 0 and au["violations"] == 0, \
    f"invariant auditors flagged a clean run ({au['violations']} " \
    f"violation(s) over {au['checks']} passes)"
assert au["overhead_pct"] < 3.0, \
    f"audit+recorder work exceeds 3% of the audited smoke wall clock " \
    f"({au['overhead_pct']:.2f}%)"
for fam, rec in doc["faults"].items():
    assert rec["caught"], f"seeded {fam} corruption escaped the auditors"
    assert rec["caught_within_steps"] <= 1, \
        f"seeded {fam} corruption took {rec['caught_within_steps']} steps " \
        f"to surface (audit_period=1)"
    assert rec["dump_written"] and rec["dump_validation_errors"] == [], \
        f"{fam} postmortem dump missing or schema-invalid: " \
        f"{rec['dump_validation_errors'][:3]}"
al = doc["alerts"]
assert al["overload_fired"] and al["offender_verified"], \
    "burn-rate alert silent under overload, or its worst offender does " \
    "not match the scheduler's own ledger"
assert al["nominal_silent"], \
    f"burn-rate alert fired on a nominal run: {al['alerts'][:2]}"
# measured-time profiling layer (gate g): profiling-off bitwise, a
# populated calibration report, and a genuinely measured re-fit
ms = doc["measured"]
bw = ms["bitwise"]
assert bw["prof_samples"] > 0 and bw["prof_ops"], \
    "profiled arm collected no measured samples"
assert (bw["outputs_bitwise_identical"] and bw["trace_doc_identical"]
        and bw["audit_identical"]), \
    "wall-clock profiling perturbed a deterministic output (tokens, " \
    "trace document, or audit roll-up)"
assert bw["trace_validation_errors"] == [], \
    f"profiled arm's trace failed validation (wall-clock leak?): " \
    f"{bw['trace_validation_errors'][:3]}"
cal = ms["calibration"]
assert cal["populated_buckets"] >= 1, \
    "calibration report has no populated (op, tier, size, wi) bucket — " \
    "measured samples never paired with modeled time"
assert cal["track_doc_validation_errors"] == [] and cal["track_additive"], \
    "measured Chrome-trace track is invalid or not strictly additive"
wf = ms["refit"]
assert wf["refits"] > 0, "wallclock re-fit never fired"
assert wf["table_armed"] and wf["table_source"] == "wallclock", \
    f"re-fit did not hot-swap a measured table (source=" \
    f"{wf['table_source']!r})"
assert wf["profiles"] > 0 and wf["profile_sources"] == ["wallclock"], \
    f"fitted profiles lost wallclock provenance: {wf['profile_sources']}"
print(f"obs work {ov['overhead_pct']:.2f}% of wall clock, "
      f"{tr['events']} events / {tr['chains']} lifelines validate clean "
      f"({tr['paths_exact']}/{tr['paths']} paths exact), "
      f"{rf['refits']} re-fits flipped {rf['decisions_changed']} "
      f"decisions, audit {au['checks']} passes clean at "
      f"{au['overhead_pct']:.2f}%, {len(doc['faults'])} seeded faults "
      f"caught, alerts fire/stay-silent, profiler "
      f"{bw['prof_samples']} samples bitwise-clean, "
      f"{cal['populated_buckets']} calibration bucket(s), "
      f"{wf['refits']} wallclock re-fit(s) -> OK")
EOF

echo "== measured tuning loop (bench record= -> fit -> warm-start) =="
python -m benchmarks.run --measured

echo "== device-initiated smoke (fused admission / ring attention) =="
python -m benchmarks.bench_device --smoke BENCH_device.json
python - <<'EOF'
import json
doc = json.load(open("BENCH_device.json"))
ab = doc["fused_vs_barrier"]
assert ab["bitwise_identical"], \
    "fused paged-attention decode diverged from the barrier baseline"
f, b = ab["fused"], ab["barrier"]
assert f["ttfd_model_s"] < b["ttfd_model_s"], \
    f"fused admission no longer beats the barrier on the modeled comm " \
    f"clock ({f['ttfd_model_s']*1e6:.2f}us >= {b['ttfd_model_s']*1e6:.2f}us)"
assert f["ttfd_steps"] < b["ttfd_steps"], \
    f"fused admission no longer beats the barrier on step-level TTFD " \
    f"({f['ttfd_steps']} >= {b['ttfd_steps']} steps)"
assert f["first_block_steps"] < b["first_block_steps"], \
    f"time-to-first-resident-block regressed ({f['first_block_steps']} >= " \
    f"{b['first_block_steps']} steps)"
ring = doc["ring_attention"]
assert ring["overlap_ratio"] >= 1.2, \
    f"ring-attention overlap below acceptance floor at long context " \
    f"({ring['overlap_ratio']:.2f} < 1.2)"
assert ring["numeric_max_err"] < 1e-4, \
    f"ring attention diverged from flash ({ring['numeric_max_err']:.2e})"
fit = doc["cutover_fit"]
assert fit["all_widths_fitted"], \
    f"device-op telemetry missing fitted (tier, work_group) cutovers: " \
    f"{fit['fitted_cutovers']}"
tr = doc["trace"]
assert tr["device_events"] > 0, "no device_* spans in the exported trace"
print(f"fused TTFD {ab['ttfd_model_improvement']:.2f}x modeled "
      f"({f['ttfd_steps']} vs {b['ttfd_steps']} steps, bitwise ok), ring "
      f"overlap {ring['overlap_ratio']:.2f}x, "
      f"{len(fit['fitted_cutovers'])} fitted width cutovers, "
      f"{tr['device_events']} device trace events -> OK")
EOF
