"""CI gate: fail when measured cutover points regress >2x vs the baseline.

Usage: python scripts/check_cutover.py BENCH_cutover.json baseline.json

Compares the per-(tier, work_items) cutover bytes emitted by the tuning
profiler (``benchmarks.run --json``) against the checked-in baseline.  A
finite cutover moving by more than 2x in either direction, a flip between
finite and "never switch" (null), a key present on only one side, or a
learned/analytic agreement below 0.95 fails the gate — any of these means
the cost model, the estimator, or the sweep changed behaviour (if the change
is intentional, regenerate the baseline with ``benchmarks.run --json``).
"""
from __future__ import annotations

import json
import sys

MAX_RATIO = 2.0


def _cutovers(doc: dict) -> dict:
    # accept either a bare TuningTable dump or the full profiler document
    table = doc.get("table", doc)
    return table.get("cutovers", {})


def main(argv) -> int:
    if len(argv) != 3:
        print(__doc__.strip().splitlines()[2])
        return 2
    current = _cutovers(json.load(open(argv[1])))
    baseline = _cutovers(json.load(open(argv[2])))
    if not baseline:
        print("check_cutover: baseline has no cutovers — refusing to pass")
        return 2
    failures = []
    for key in sorted(set(baseline) | set(current)):
        base = baseline.get(key, "missing")
        cur = current.get(key, "missing")
        if cur == "missing":
            failures.append(f"{key}: missing from current profile")
        elif base == "missing":
            failures.append(f"{key}: new cutover key not in baseline "
                            "(regenerate the baseline if intentional)")
        elif (base is None) != (cur is None):
            failures.append(f"{key}: finite/infinite flip "
                            f"(baseline={base}, current={cur})")
        elif base is not None and cur is not None:
            lo, hi = sorted((max(1, base), max(1, cur)))
            if hi / lo > MAX_RATIO:
                failures.append(f"{key}: {base} -> {cur} "
                                f"({hi / lo:.2f}x > {MAX_RATIO}x)")
    agree = json.load(open(argv[1])).get("agreement_vs_analytic")
    if agree is not None and agree < 0.95:
        failures.append(f"learned/analytic agreement {agree:.3f} < 0.95")
    if failures:
        print("check_cutover: FAIL")
        for f in failures:
            print("  " + f)
        return 1
    print(f"check_cutover: OK ({len(baseline)} cutover points within "
          f"{MAX_RATIO}x of baseline"
          + (f", agreement={agree:.3f})" if agree is not None else ")"))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
