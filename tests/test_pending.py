"""Completion-engine ordering laws (paper §III-F: nbi ops complete at quiet).

Property tests over the deferred-op queue: no visibility before quiet, fence
epochs block coalescing/reordering, quiet idempotence, and convergence of
interleaved proxy + nbi drains under permuted schedules.
"""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean interpreter: deterministic fallback
    from _minihyp import given, settings, strategies as st

from repro.core import amo, context, proxy, rma, signal
from repro.core.heap import SymPtr


def _ctx(npes=4, node_size=2, **kw):
    return context.init(npes=npes, node_size=node_size, **kw)


# ---------------------------------------------------------------------------
# law 1: no visibility before quiet (the acceptance-criterion test)
# ---------------------------------------------------------------------------


def test_put_nbi_defers_until_quiet():
    ctx, heap = _ctx()
    p = heap.malloc((32,), "float32")
    heap = rma.put(ctx, heap, p, jnp.full(32, 7.0), 1)      # old value
    heap = rma.put_nbi(ctx, heap, p, jnp.full(32, 9.0), 1)
    # destination row is UNTOUCHED between put_nbi and quiet
    np.testing.assert_array_equal(np.asarray(heap.read(p, 1)),
                                  np.full(32, 7.0))
    assert len(ctx.pending) == 1
    heap = rma.quiet(ctx, heap)
    np.testing.assert_array_equal(np.asarray(heap.read(p, 1)),
                                  np.full(32, 9.0))
    assert len(ctx.pending) == 0


@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7), st.integers(1, 100)),
                min_size=1, max_size=12))
def test_deferred_queue_matches_sequential_oracle(writes):
    """Any mix of slotted nbi puts == the same stores applied in program
    order (write combining must be invisible to memory semantics)."""
    ctx, heap = _ctx()
    p = heap.malloc((8 * 16,), "float32")
    oracle = np.zeros(8 * 16, np.float32)
    for slot, val in writes:
        piece = SymPtr("float32", p.offset + slot * 16, (16,))
        heap = rma.put_nbi(ctx, heap, piece, jnp.full(16, float(val)), 2)
        oracle[slot * 16:(slot + 1) * 16] = val
    heap = rma.quiet(ctx, heap)
    np.testing.assert_array_equal(np.asarray(heap.read(p, 2)), oracle)


def test_contiguous_nbi_puts_coalesce_and_tuner_sees_wire_size():
    ctx, heap = _ctx()
    p = heap.malloc((128,), "float32")
    t0 = ctx.pending.stats.transfers
    for i in range(4):                                   # 4 x 128 B, abutting
        piece = SymPtr("float32", p.offset + i * 32, (32,))
        heap = rma.put_nbi(ctx, heap, piece, jnp.full(32, float(i)), 1)
    heap = rma.quiet(ctx, heap)
    assert ctx.pending.stats.transfers - t0 == 1          # one wire transfer
    done = [r for r in ctx.ledger if r.op == "put_nbi"]
    assert done and done[-1].nbytes == 4 * 32 * 4         # coalesced size
    np.testing.assert_array_equal(
        np.asarray(heap.read(p, 1)),
        np.repeat(np.arange(4, dtype=np.float32), 32))


def test_coalesce_knob_off_issues_per_call_transfers():
    from repro.core import cutover
    ctx, heap = _ctx(tuning=cutover.Tuning(nbi_coalesce=False))
    p = heap.malloc((128,), "float32")
    for i in range(4):
        piece = SymPtr("float32", p.offset + i * 32, (32,))
        heap = rma.put_nbi(ctx, heap, piece, jnp.ones(32), 1)
    heap = rma.quiet(ctx, heap)
    assert ctx.pending.stats.transfers == 4
    assert ctx.pending.stats.coalescing_ratio() == 1.0


# ---------------------------------------------------------------------------
# law 2: fence = ordering epoch (no cross-epoch coalescing/reordering)
# ---------------------------------------------------------------------------


def test_fence_prevents_cross_epoch_coalescing():
    ctx, heap = _ctx()
    p = heap.malloc((128,), "float32")
    a = SymPtr("float32", p.offset, (32,))
    b = SymPtr("float32", p.offset + 32, (32,))
    heap = rma.put_nbi(ctx, heap, a, jnp.ones(32), 1)
    heap = rma.fence(ctx, heap)                      # epoch boundary
    heap = rma.put_nbi(ctx, heap, b, jnp.full(32, 2.0), 1)
    heap = rma.quiet(ctx, heap)
    # contiguous ranges, but the fence forbids merging them
    assert ctx.pending.stats.transfers == 2
    assert ctx.pending.stats.coalescing_ratio() == 1.0


def test_fence_orders_same_target_overwrites():
    """put A; fence; put A' — A' must win even though within one epoch the
    squash would also pick the later value; across the fence the first write
    must still be *issued* (two transfers, last lands second)."""
    ctx, heap = _ctx()
    p = heap.malloc((32,), "float32")
    heap = rma.put_nbi(ctx, heap, p, jnp.full(32, 1.0), 1)
    heap = rma.fence(ctx, heap)
    heap = rma.put_nbi(ctx, heap, p, jnp.full(32, 2.0), 1)
    heap = rma.quiet(ctx, heap)
    assert ctx.pending.stats.transfers == 2
    np.testing.assert_array_equal(np.asarray(heap.read(p, 1)),
                                  np.full(32, 2.0))


def test_fence_without_pending_is_free():
    ctx, heap = _ctx()
    e0 = ctx.pending.epoch
    heap = rma.fence(ctx, heap)
    assert ctx.pending.epoch == e0              # no ops -> no new epoch


# ---------------------------------------------------------------------------
# law 3: quiet idempotence
# ---------------------------------------------------------------------------


def test_quiet_idempotent():
    ctx, heap = _ctx()
    p = heap.malloc((32,), "float32")
    heap = rma.put_nbi(ctx, heap, p, jnp.full(32, 3.0), 1)
    heap = rma.quiet(ctx, heap)
    snap = np.asarray(heap.read(p, 1)).copy()
    transfers = ctx.pending.stats.transfers
    heap = rma.quiet(ctx, heap)                 # second quiet: no-op
    heap = rma.quiet(ctx, heap)
    np.testing.assert_array_equal(np.asarray(heap.read(p, 1)), snap)
    assert ctx.pending.stats.transfers == transfers


# ---------------------------------------------------------------------------
# law 4: interleaved proxy + nbi drains converge under permuted schedules
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(["fd", "df", "fdf", "dfd"]),
       st.integers(1, 50))
def test_proxy_and_nbi_drain_order_converges(schedule, val):
    """The nbi queue and the reverse-offload ring are independent channels
    to disjoint targets: any order of (f)lush and (d)rain yields the same
    final heap."""
    results = []
    for order in (schedule, schedule[::-1]):
        ctx, heap = _ctx()
        a = heap.malloc((16,), "float32")
        b = heap.malloc((16,), "float32")
        px = proxy.HostProxy(ctx)
        heap = rma.put_nbi(ctx, heap, a, jnp.full(16, float(val)), 1)
        px.put(b, jnp.full(16, float(val + 1)), 3)
        for step in order:
            heap = (rma.quiet(ctx, heap) if step == "f"
                    else px.drain(heap))
        results.append(np.concatenate([
            np.asarray(heap.read(a, 1)), np.asarray(heap.read(b, 3))]))
    np.testing.assert_array_equal(results[0], results[1])


def test_proxy_put_nbi_rides_queue_and_completes_at_quiet():
    ctx, heap = _ctx()
    p = heap.malloc((16,), "float32")
    px = proxy.HostProxy(ctx)
    px.put_nbi(p, jnp.full(16, 5.0), 3)              # cross-pod, deferred
    assert float(heap.read(p, 3).sum()) == 0.0       # not yet on the ring
    assert len(px.ring.delivered) == 0
    heap = rma.quiet(ctx, heap, proxy=px)            # ring + drain at quiet
    assert float(heap.read(p, 3)[0]) == 5.0
    assert len(px.ring.delivered) == 1               # traveled the real ring


# ---------------------------------------------------------------------------
# blocking ops vs the queue
# ---------------------------------------------------------------------------


def test_blocking_put_supersedes_pending_nbi():
    ctx, heap = _ctx()
    p = heap.malloc((32,), "float32")
    heap = rma.put_nbi(ctx, heap, p, jnp.full(32, 1.0), 1)
    heap = rma.put(ctx, heap, p, jnp.full(32, 2.0), 1)   # program order wins
    heap = rma.quiet(ctx, heap)                          # stale op dropped
    np.testing.assert_array_equal(np.asarray(heap.read(p, 1)),
                                  np.full(32, 2.0))


def test_blocking_put_wins_over_covered_sub_range_nbi():
    """A pending nbi put to a SUB-range of the blocking put's target is
    fully covered -> dropped; the blocking value must survive quiet."""
    ctx, heap = _ctx()
    p = heap.malloc((32,), "float32")
    sub = SymPtr("float32", p.offset, (16,))
    heap = rma.put_nbi(ctx, heap, sub, jnp.full(16, 1.0), 1)
    heap = rma.put(ctx, heap, p, jnp.full(32, 2.0), 1)   # covers sub
    heap = rma.quiet(ctx, heap)
    np.testing.assert_array_equal(np.asarray(heap.read(p, 1)),
                                  np.full(32, 2.0))


def test_blocking_put_completes_partial_overlap_first():
    """A pending nbi put only partially overlapped by the blocking put
    completes BEFORE the blocking store (program order), so the overlap
    bytes read the blocking value and the rest the nbi value."""
    ctx, heap = _ctx()
    p = heap.malloc((64,), "float32")
    wide = SymPtr("float32", p.offset, (48,))            # 0..48 deferred
    head = SymPtr("float32", p.offset, (32,))            # 0..32 blocking
    heap = rma.put_nbi(ctx, heap, wide, jnp.full(48, 1.0), 1)
    heap = rma.put(ctx, heap, head, jnp.full(32, 2.0), 1)
    heap = rma.quiet(ctx, heap)
    got = np.asarray(heap.read(p, 1))
    np.testing.assert_array_equal(got[:32], np.full(32, 2.0))
    np.testing.assert_array_equal(got[32:48], np.full(16, 1.0))


def test_proxy_flush_orders_ring_puts_before_later_amos():
    """A dcn nbi put followed by a deferred AMO on the same element: the
    quiet-with-proxy flush must drain the ring BEFORE applying the AMO, so
    the AMO reads the put's value (FIFO program order)."""
    ctx, heap = _ctx()
    p = heap.malloc((), "int32")
    px = proxy.HostProxy(ctx)
    px.put_nbi(p, jnp.asarray(10, "int32"), 3)           # pe 3 = other pod
    heap = amo.add_nbi(ctx, heap, p, 5, 3)
    heap = rma.quiet(ctx, heap, proxy=px)
    assert int(heap.read(p, 3).reshape(())) == 15


def test_signal_wait_forces_dependent_completion():
    ctx, heap = _ctx()
    buf = heap.malloc((16,), "float32")
    sig = heap.malloc((), "uint32")
    heap = signal.put_signal_nbi(ctx, heap, buf, jnp.full(16, 4.0), sig, 1,
                                 signal.SIGNAL_ADD, 1)
    assert float(heap.read(buf, 1).sum()) == 0.0     # both halves deferred
    heap, cur, ok = signal.signal_wait_until(ctx, heap, sig, 1, "ge", 1)
    assert bool(ok) and int(cur) == 1
    # the data half landed BEFORE the observed signal (data-then-flag)
    np.testing.assert_array_equal(np.asarray(heap.read(buf, 1)),
                                  np.full(16, 4.0))


def test_blocking_amo_linearizes_after_pending_nbi_put():
    """put_nbi then a blocking fetch_add on the same element: the atomic
    must observe the deferred put (program order), not lose its increment
    to a stale flush."""
    ctx, heap = _ctx()
    p = heap.malloc((), "int32")
    heap = rma.put_nbi(ctx, heap, p, jnp.asarray(10, "int32"), 1)
    heap, old = amo.fetch_add(ctx, heap, p, 5, 1)
    assert int(old) == 10                            # saw the completed put
    heap = rma.quiet(ctx, heap)
    assert int(heap.read(p, 1).reshape(())) == 15


def test_blocking_put_signal_wins_over_pending_signal_set():
    """put_signal_nbi(SET 7) then blocking put_signal(SET 99): the later
    blocking flag write is the one a waiter observes after quiet."""
    ctx, heap = _ctx()
    buf = heap.malloc((8,), "float32")
    sig = heap.malloc((), "uint32")
    heap = signal.put_signal_nbi(ctx, heap, buf, jnp.ones(8), sig, 7,
                                 signal.SIGNAL_SET, 1)
    heap = signal.put_signal(ctx, heap, buf, jnp.ones(8), sig, 99,
                             signal.SIGNAL_SET, 1)
    heap = rma.quiet(ctx, heap)
    assert int(heap.read(sig, 1).reshape(())) == 99


def test_trace_markers_track_dropped_vs_done():
    """Superseded ops read "(dropped)", flushed ops "(done)" — the debug
    trace never claims a never-executed op completed."""
    ctx, heap = _ctx()
    p = heap.malloc((32,), "float32")
    q = heap.malloc((32,), "float32")
    heap = rma.put_nbi(ctx, heap, p, jnp.ones(32), 1)    # will be dropped
    heap = rma.put(ctx, heap, p, jnp.full(32, 2.0), 1)   # covers it
    heap = rma.put_nbi(ctx, heap, q, jnp.ones(32), 1)    # will flush
    heap = rma.quiet(ctx, heap)
    tags = [r.op for r in ctx.ledger if r.op.startswith("put_nbi(")]
    assert tags == ["put_nbi(dropped)", "put_nbi(done)"]


def test_amo_add_nbi_defers_and_merges():
    ctx, heap = _ctx()
    p = heap.malloc((), "int32")
    heap = amo.add_nbi(ctx, heap, p, 5, 1)
    heap = amo.add_nbi(ctx, heap, p, 7, 1)
    assert int(heap.read(p, 1).reshape(())) == 0     # deferred
    t0 = ctx.pending.stats.transfers
    heap = rma.quiet(ctx, heap)
    assert int(heap.read(p, 1).reshape(())) == 12
    assert ctx.pending.stats.transfers - t0 == 1     # adds merged


# ---------------------------------------------------------------------------
# fault handling: dead peers and dcn partitions vs the queue
# ---------------------------------------------------------------------------


def test_dead_pe_ops_cancel_instead_of_wedging_quiet():
    """The PR-9 wedge fix: pending ops whose destination died complete
    quiet() by cancel-with-error — a structured record on ctx.pending.errors
    — instead of wedging on undeliverable traffic or landing garbage."""
    ctx, heap = _ctx()
    p = heap.malloc((32,), "float32")
    heap = rma.put_nbi(ctx, heap, p, jnp.ones(32), 1)        # doomed
    heap = rma.put_nbi(ctx, heap, p, jnp.full(32, 2.0), 2)   # survives
    ctx.fault.kill(1)
    assert ctx.pending.cancel_pe(ctx, 1) == 1
    heap = rma.quiet(ctx, heap)                  # completes — no wedge
    assert len(ctx.pending) == 0
    assert ctx.pending.stats.cancelled == 1
    err = ctx.pending.errors[0]
    assert err["pe"] == 1 and "died" in err["reason"]
    np.testing.assert_array_equal(np.asarray(heap.read(p, 2)),
                                  np.full(32, 2.0))          # live op landed
    assert float(heap.read(p, 1).sum()) == 0.0   # nothing landed on the dead


def test_ops_queued_after_death_cancel_at_flush():
    """Traffic enqueued AFTER the kill (racing issuer that has not yet seen
    the death) is cancelled at the next flush, not delivered to a corpse."""
    ctx, heap = _ctx()
    p = heap.malloc((16,), "float32")
    ctx.fault.kill(3)
    heap = rma.put_nbi(ctx, heap, p, jnp.ones(16), 3)
    heap = rma.quiet(ctx, heap)
    assert len(ctx.pending) == 0
    assert ctx.pending.stats.cancelled == 1
    assert ctx.pending.errors[0]["reason"] == "peer died with op in flight"


def test_dead_source_pe_cancels_op():
    """Ops whose SOURCE died cancel too — a get/migration from a dead
    peer's garbage row must never complete as if it read real data."""
    ctx, heap = _ctx()
    p = heap.malloc((16,), "float32")
    heap = rma.put_nbi(ctx, heap, p, jnp.ones(16), 2, src_pe=1)
    ctx.fault.kill(1)
    assert ctx.pending.cancel_pe(ctx, 1) == 1
    heap = rma.quiet(ctx, heap)
    assert float(heap.read(p, 2).sum()) == 0.0
    assert ctx.pending.errors[0]["src_pe"] == 1


def test_partition_parks_dcn_ops_until_heal():
    """While the inter-pod fabric is partitioned, cross-pod (dcn) ops are
    neither delivered nor lost: quiet() completes the intra-pod prefix and
    keeps the dcn suffix queued; healing drains it in order."""
    ctx, heap = _ctx()                           # node_size=2: pe 3 is dcn
    near = heap.malloc((16,), "float32")
    far = heap.malloc((16,), "float32")
    ctx.fault.dcn_down = True
    heap = rma.put_nbi(ctx, heap, near, jnp.ones(16), 1)     # ici: flows
    heap = rma.put_nbi(ctx, heap, far, jnp.full(16, 9.0), 3)  # dcn: parks
    heap = rma.quiet(ctx, heap)                  # returns — no wedge
    assert float(heap.read(near, 1).sum()) == 16.0
    assert float(heap.read(far, 3).sum()) == 0.0
    assert len(ctx.pending) == 1                 # parked, not dropped
    ctx.fault.dcn_down = False
    heap = rma.quiet(ctx, heap)
    np.testing.assert_array_equal(np.asarray(heap.read(far, 3)),
                                  np.full(16, 9.0))
    assert len(ctx.pending) == 0
    assert ctx.pending.stats.cancelled == 0      # partition loses nothing


def test_get_nbi_costs_accrue_at_quiet():
    ctx, heap = _ctx()
    p = heap.malloc((32,), "float32")
    heap = rma.put(ctx, heap, p, jnp.arange(32.0), 1)
    out = rma.get_nbi(ctx, heap, p, 1)
    np.testing.assert_array_equal(np.asarray(out), np.arange(32.0))
    assert len(ctx.pending) == 1
    heap = rma.quiet(ctx, heap)
    assert any(r.op == "get_nbi" for r in ctx.ledger)
    assert len(ctx.pending) == 0


def test_flush_dependency_completes_exact_prefix():
    """The streamed-migration primitive: flushing the dependency of a signal
    word completes the chunks issued before it (data before each chunk's
    flag) and leaves everything submitted after it deferred."""
    ctx, heap = _ctx()
    data = heap.malloc((64,), "float32")
    sig = heap.malloc((), "int32")
    other = heap.malloc((32,), "float32")
    # chunk 1: data + flag on (sig, 1)
    heap = rma.put_nbi(ctx, heap, data, jnp.full(64, 3.0), 1)
    heap = signal.put_signal_nbi(ctx, heap, data, jnp.full(64, 3.0), sig,
                                 1, signal.SIGNAL_ADD, 1)
    # unrelated traffic submitted AFTER the flag
    heap = rma.put_nbi(ctx, heap, other, jnp.ones(32), 2)
    heap = ctx.pending.flush_dependency(ctx, heap, sig, 1)
    assert int(heap.read(sig, 1).reshape(())) == 1           # chunk landed
    np.testing.assert_array_equal(np.asarray(heap.read(data, 1)),
                                  np.full(64, 3.0))
    assert ctx.pending.pending_for(other, 2) is not None     # still deferred
    # chunk 2 on the same word: the signal keeps ramping monotonically
    heap = signal.put_signal_nbi(ctx, heap, data, jnp.full(64, 4.0), sig,
                                 1, signal.SIGNAL_ADD, 1)
    heap = ctx.pending.flush_dependency(ctx, heap, sig, 1)
    assert int(heap.read(sig, 1).reshape(())) == 2
    # flushing a word with no pending dependency is a no-op
    heap = ctx.pending.flush_dependency(ctx, heap, sig, 1)
    assert len(ctx.pending) == 0                 # 'other' flushed as prefix
