"""Disaggregated prefill/decode: migration protocol + end-to-end serving.

The two load-bearing guarantees (ISSUE acceptance criteria):

1. decode output after a paged-KV migration is bitwise-identical to the
   single-PE ``Engine.generate`` baseline, and
2. no block is readable decode-side before its signal lands — property-tested
   against the pending-queue oracle (the CompletionQueue holds every byte
   until a completion point, and the admission signal is queued last).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _minihyp import given, settings, strategies as st

from repro.configs import base as cfgbase
from repro.core import context, teams
from repro.core.proxy import HostProxy
from repro.models import model
from repro.serve import kvpool as kvpool_mod
from repro.serve.engine import Engine, ServeConfig, SlotBatch
from repro.serve.kvpool import KVPool
from repro.serve.kvxfer import KVMigrator, expected_signal
from repro.serve.scheduler import DisaggScheduler

MAXLEN = 24


def _setup(arch="qwen3_4b", npes=4, node_size=None, num_blocks=32,
           max_slots=3, block_tokens=8):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    params = model.init_params(jax.random.key(0), cfg)
    ctx, heap = context.init(npes=npes, node_size=node_size or npes)
    eng = Engine(cfg, params, max_len=MAXLEN)
    pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=num_blocks,
                         max_slots=max_slots, block_tokens=block_tokens)
    return cfg, params, ctx, heap, eng, pool


def _prompts(cfg, n, S=10, key=1):
    return [jax.random.randint(jax.random.fold_in(jax.random.key(key), i),
                               (1, S), 0, cfg.vocab_size) for i in range(n)]


# ---------------------------------------------------------------------------
# protocol-level: signal gating vs the pending-queue oracle
# ---------------------------------------------------------------------------


def test_blocks_invisible_until_admission():
    """After migrate() the decode PE's rows are untouched (ops deferred);
    try_admit is the completion point that both lands the data and opens the
    gate."""
    cfg, params, ctx, heap, eng, pool = _setup()
    mig = KVMigrator(ctx, pool)
    tok, _, cache1 = eng.prefill_request(
        {"tokens": _prompts(cfg, 1)[0]}, jax.random.key(9))
    heap, ids = mig.stage(heap, 0, cache1, prompt_len=10, src_pe=0)
    heap, rep = mig.migrate(heap, 0, src_pe=0, dst_pe=2, slot=0,
                            prompt_len=10, first_token=tok)
    # oracle: every byte still parked on the CompletionQueue
    assert len(ctx.pending) > 0
    for bid in ids:
        ptr = pool.block_ptr(bid)
        np.testing.assert_array_equal(np.asarray(heap.read(ptr, 2)), 0.0)
        assert ctx.pending.pending_for(ptr, 2) is not None
    assert float(heap.read(pool.sig_ptr(0), 2)) == 0
    # source row IS populated (staging was local+blocking)
    assert float(jnp.abs(heap.read(pool.block_ptr(ids[0]), 0)).max()) > 0
    heap, hdr = mig.try_admit(heap, 0, 2, rep.expected_signal)
    assert hdr == {"req_id": 0, "prompt_len": 10, "first_token": tok,
                   "n_blocks": len(ids)}
    for bid in ids:
        np.testing.assert_array_equal(
            np.asarray(heap.read(pool.block_ptr(bid), 2)),
            np.asarray(heap.read(pool.block_ptr(bid), 0)))
    assert len(ctx.pending) == 0


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 6), st.integers(0, 5))
def test_partial_signal_never_admits(n_extra_blocks, probe):
    """Property: as long as the waited value is above the signal's current
    count, admission fails AND every not-yet-signalled block still reads
    zero — checked against the pending-queue oracle after flushing a random
    prefix via a weaker wait (always a legal completion schedule)."""
    cfg, params, ctx, heap, eng, pool = _setup(num_blocks=16, max_slots=1,
                                               block_tokens=4)
    mig = KVMigrator(ctx, pool)
    S = min(4 * n_extra_blocks + 2, MAXLEN - 1)
    tok, _, cache1 = eng.prefill_request(
        {"tokens": _prompts(cfg, 1, S=S)[0]}, jax.random.key(3))
    heap, ids = mig.stage(heap, 0, cache1, prompt_len=S, src_pe=0)
    heap, rep = mig.migrate(heap, 0, src_pe=0, dst_pe=1, slot=0,
                            prompt_len=S, first_token=tok)
    expected = rep.expected_signal
    assert expected == expected_signal(len(ids))
    # a weaker wait (threshold <= partial progress) may complete a prefix;
    # the full-threshold wait must still gate
    partial = min(probe, expected - 1)
    heap, hdr = mig.try_admit(heap, 0, 1, expected) if partial == 0 else (
        heap, None)
    if partial > 0:
        from repro.core import signal as signal_mod
        heap, cur, ok = signal_mod.signal_wait_until(
            ctx, heap, pool.sig_ptr(0), 1, "ge", partial)
        # oracle: blocks whose op is still queued read zero decode-side
        for bid in ids:
            ptr = pool.block_ptr(bid)
            if ctx.pending.pending_for(ptr, 1) is not None:
                np.testing.assert_array_equal(
                    np.asarray(heap.read(ptr, 1)), 0.0)
        heap, hdr = mig.try_admit(heap, 0, 1, expected)
    assert hdr is not None            # full wait admits (and forces the rest)
    assert int(heap.read(pool.sig_ptr(0), 1)) == expected
    assert len(ctx.pending) == 0


def test_admission_blocked_without_flush_when_signal_short():
    """A wait on a value the queued signal updates cannot reach leaves the
    gate shut (satisfiability check fails even after forcing)."""
    cfg, params, ctx, heap, eng, pool = _setup(max_slots=1)
    mig = KVMigrator(ctx, pool)
    tok, _, cache1 = eng.prefill_request(
        {"tokens": _prompts(cfg, 1)[0]}, jax.random.key(5))
    heap, ids = mig.stage(heap, 7, cache1, prompt_len=10, src_pe=0)
    heap, rep = mig.migrate(heap, 7, src_pe=0, dst_pe=1, slot=0,
                            prompt_len=10, first_token=tok)
    heap, hdr = mig.try_admit(heap, 0, 1, rep.expected_signal + 1)
    assert hdr is None


# ---------------------------------------------------------------------------
# end-to-end: disagg == single-PE baseline
# ---------------------------------------------------------------------------


def _req(cfg, p):
    """Request batch with whatever frontend embeds the family needs."""
    b = {"tokens": p}
    if cfg.family == "audio":
        b["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(7), (1, cfg.encoder_seq, cfg.d_model))
    return b


def _run_disagg(arch="qwen3_4b", node_size=None, proxy=False, n_req=5,
                num_slots=3, NEW=6, admit_delay=0, S=10, stream=0,
                paged=True):
    cfg, params, ctx, heap, eng, pool = _setup(arch, node_size=node_size)
    pxy = HostProxy(ctx) if proxy else None
    mig = KVMigrator(ctx, pool, proxy=pxy)
    pre, dec = teams.disagg_partition(teams.world(4), 2)
    sched = DisaggScheduler(ctx, heap, eng, pool, mig,
                            prefill_pes=pre.pes(), decode_pes=dec.pes(),
                            num_slots=num_slots,
                            scfg=ServeConfig(max_new_tokens=NEW),
                            admit_delay_steps=admit_delay,
                            stream_chunks=stream, paged=paged)
    prompts = _prompts(cfg, n_req, S=S)
    for p in prompts:
        sched.submit(_req(cfg, p))
    outs = sched.run()
    return cfg, ctx, eng, sched, prompts, outs, NEW


def test_e2e_disagg_matches_baseline_bitwise():
    """Prefill PEs migrate paged KV to decode PEs; every request's decode
    stream equals the lockstep single-PE Engine.generate output exactly —
    with more requests than slots, so rotation/eviction is exercised."""
    cfg, ctx, eng, sched, prompts, outs, NEW = _run_disagg()
    assert sched.stats.evictions == len(prompts)
    for i, p in enumerate(prompts):
        base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])
    # telemetry: per-block cutover records and coalesced nbi transfers
    buckets = ctx.telemetry.buckets
    assert any(k[0] == "kvxfer_block" for k in buckets)
    assert any(k[0] == "put_nbi" for k in buckets)
    assert ctx.pending.stats.coalescing_ratio() > 1.0


def test_e2e_disagg_batched_baseline():
    """Same-length requests decoded together under continuous batching match
    the batched lockstep baseline (prefill is batch-invariant)."""
    cfg, ctx, eng, sched, prompts, outs, NEW = _run_disagg(
        n_req=3, num_slots=3)
    batch = {"tokens": jnp.concatenate(prompts, axis=0)}
    base = eng.generate(batch, ServeConfig(max_new_tokens=NEW))
    for i in range(len(prompts)):
        np.testing.assert_array_equal(np.asarray(base[i]), outs[i])


def test_e2e_cross_pod_via_host_proxy():
    """node_size=2 puts decode PEs in another pod: migrations are dcn-tier,
    travel the HostProxy ring, and still decode bitwise-identically."""
    cfg, ctx, eng, sched, prompts, outs, NEW = _run_disagg(
        node_size=2, proxy=True, n_req=4, admit_delay=1)
    assert any(r.op == "proxy_put" for r in ctx.ledger)
    for i, p in enumerate(prompts):
        base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])


def test_e2e_hybrid_arch_with_tail_state():
    """zamba2: SSM/recurrent tail state migrates losslessly end-to-end."""
    cfg, ctx, eng, sched, prompts, outs, NEW = _run_disagg(
        arch="zamba2_2_7b", n_req=3, NEW=5)
    for i, p in enumerate(prompts):
        base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])


def test_rotation_reuses_slots_and_blocks():
    """More requests than slots AND a pool sized so late requests must wait
    for early evictions: stalls are recorded, every request still finishes
    correctly, and the pool drains back to empty."""
    cfg, params, ctx, heap, eng, pool = _setup(num_blocks=6, max_slots=2,
                                               block_tokens=8)
    mig = KVMigrator(ctx, pool)
    sched = DisaggScheduler(ctx, heap, eng, pool, mig,
                            prefill_pes=[0, 1], decode_pes=[2, 3],
                            num_slots=2, scfg=ServeConfig(max_new_tokens=4))
    prompts = _prompts(cfg, 6, S=10)           # 2 blocks/request, pool of 6
    for p in prompts:
        sched.submit({"tokens": p})
    outs = sched.run()
    assert sched.stats.stalled_on_pool > 0 or sched.stats.stalled_on_slots > 0
    assert pool.stats()["blocks_in_use"] == 0
    for i, p in enumerate(prompts):
        base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=4))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])


def test_eos_early_stop_matches_baseline_padding():
    """eos mid-generation: the scheduler zero-pads to max_new exactly like
    Engine.generate (eos emitted, remainder zeros) — and the slot frees
    early."""
    cfg, params, ctx, heap, eng, pool = _setup()
    mig = KVMigrator(ctx, pool)
    NEW = 6
    prompt = _prompts(cfg, 1)[0]
    base = eng.generate({"tokens": prompt}, ServeConfig(max_new_tokens=NEW))
    eos = int(base[0, 1])                       # force the 2nd token as eos
    base_eos = eng.generate({"tokens": prompt},
                            ServeConfig(max_new_tokens=NEW, eos_id=eos))
    sched = DisaggScheduler(ctx, heap, eng, pool, mig,
                            prefill_pes=[0, 1], decode_pes=[2, 3],
                            num_slots=2,
                            scfg=ServeConfig(max_new_tokens=NEW, eos_id=eos))
    sched.submit({"tokens": prompt})
    outs = sched.run()
    assert outs[0].shape == (NEW,)
    np.testing.assert_array_equal(np.asarray(base_eos[0]), outs[0])


def test_ttfd_and_migration_accounting():
    cfg, ctx, eng, sched, prompts, outs, NEW = _run_disagg(admit_delay=2)
    st_ = sched.stats
    assert st_.migrations == len(prompts) == st_.admissions
    assert st_.bytes_migrated > 0
    assert all(t >= 2 for t in st_.ttfd_steps)      # wire latency respected
    assert all(t >= 0 for t in st_.ttfd_model_s)


# ---------------------------------------------------------------------------
# paged decode (default) and chunked prefill streaming
# ---------------------------------------------------------------------------


def test_paged_decode_never_rehydrates_dense_cache():
    """The tentpole invariant: with paged decode (the default) the slot
    banks' paged K/V leaves stay zero for the whole run — decode consumed
    blocks straight from the pool row — while output stays bitwise-equal to
    the lockstep baseline (checked by every other test in this file)."""
    cfg, ctx, eng, sched, prompts, outs, NEW = _run_disagg()
    lay = sched.pool.layout
    assert lay.paged                   # qwen3 has paged K/V leaves
    for bank in sched.banks.values():
        for pl in lay.paged:
            leaf = bank.cache["blocks"][pl.unit_idx][pl.key]
            np.testing.assert_array_equal(np.asarray(leaf, np.float32), 0.0)
    for i, p in enumerate(prompts):
        base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])


def test_dense_rehydrate_fallback_matches_paged():
    """paged=False keeps the PR-3 gather+insert admission; both paths must
    produce identical streams (they share the decode computation)."""
    *_, outs_paged, _ = _run_disagg(paged=True)
    *_, outs_dense, _ = _run_disagg(paged=False)
    for rid in outs_paged:
        np.testing.assert_array_equal(outs_paged[rid], outs_dense[rid])


@pytest.mark.parametrize("arch,chunk", [("qwen3_4b", 1), ("qwen3_4b", 2),
                                        ("zamba2_2_7b", 1)])
def test_streaming_matches_baseline_bitwise(arch, chunk):
    """Chunked prefill streaming (blocks on the wire mid-prefill, admission
    on the monotonic signal threshold) decodes bitwise-identically to the
    whole-prefill lockstep baseline — dense and hybrid/SSM-tail schedules,
    with rotation (more requests than slots)."""
    cfg, ctx, eng, sched, prompts, outs, NEW = _run_disagg(
        arch=arch, stream=chunk, admit_delay=1, n_req=4, NEW=5)
    # genuinely chunked: at least one installment per request, and multiple
    # per request when the chunk is smaller than the prompt's block count
    assert sched.stats.stream_chunks >= len(prompts) * max(1, 2 // chunk)
    for i, p in enumerate(prompts):
        base = eng.generate(_req(cfg, p), ServeConfig(max_new_tokens=NEW))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])


def test_streaming_encdec_and_cross_pod():
    """whisper (encdec: cross-KV rides the tail) streamed through the
    dcn-tier host proxy still decodes bitwise-identically."""
    cfg, ctx, eng, sched, prompts, outs, NEW = _run_disagg(
        arch="whisper_medium", node_size=2, proxy=True, stream=1,
        admit_delay=1, n_req=3, NEW=4)
    assert any(r.op == "proxy_put" for r in ctx.ledger)
    for i, p in enumerate(prompts):
        base = eng.generate(_req(cfg, p), ServeConfig(max_new_tokens=NEW))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])


def test_streaming_shrinks_ttfd_window():
    """The streaming win: chunks drain under later chunks' prefill compute,
    so the modeled comm window between prefill-finish and admission
    (stats.ttfd_model_s) strictly shrinks vs whole-prefill migration."""
    s_whole = _run_disagg(admit_delay=1, n_req=4)[3]
    s_stream = _run_disagg(admit_delay=1, n_req=4, stream=1)[3]
    whole = sum(s_whole.stats.ttfd_model_s) / len(s_whole.stats.ttfd_model_s)
    stream = sum(s_stream.stats.ttfd_model_s) / \
        len(s_stream.stats.ttfd_model_s)
    assert stream < whole
