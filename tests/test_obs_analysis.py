"""PR-8 observability acceptance: critical paths, auditors, recorder, alerts.

- critical-path reconstruction over stressed fleets (streaming AND fused):
  every request's lifeline is gap-free and its segment attribution sums to
  its end-to-end span EXACTLY; fused requests split migrating into wire vs
  signal-wait using the observed first_block_step; device consume instants
  thread into the path records,
- the ``python -m repro.obs.analyze`` CLI over an exported trace file,
- chain_gaps: no phantom gaps for still-open (shed/windowed) spans,
- online invariant auditors: clean runs audit clean; seeded corruptions
  (refcount, residency, signal ledger) are each caught within one audit
  period, with a flight-recorder postmortem dump that validates clean,
- SLO burn-rate alerting: fires under overload naming a truly over-deadline
  request, stays silent at nominal load,
- flight recorder: ring bounding, crash dumps, window repair,
- the extended ISHMEM_OBS_* env surface and Obs wiring.
"""
import functools
import json

import jax.numpy as jnp
import pytest

from repro.obs import (Obs, RingTracer, load_obs_env, request_chains,
                       validate)
from repro.obs import analyze as analyze_mod
from repro.obs import critical, export
from repro.obs.alerts import BurnRateMonitor, BurnWindow, parse_windows
from repro.obs.audit import AuditError, FleetAuditor
from repro.obs.recorder import FlightRecorder
from repro.obs.tracer import STEP_QUANTUM, SpanTracer
from repro.serve.frontend import TenantSpec, TrafficEngine
from repro.serve.scheduler import DECODING

from test_obs import NEW, _engine, _fleet


def _traffic(cfg, *, rate, seed, shared=0.0, steps=16):
    tenants = [TenantSpec("chat", weight=2.0, prompt_lens=(8,),
                          max_new=(NEW,), slo="interactive"),
               TenantSpec("scan", weight=1.0, prompt_lens=(12,),
                          max_new=(12,), slo="batch",
                          shared_prefix_prob=shared, prefix_groups=1)]
    eng = TrafficEngine(tenants, rate=rate, vocab=cfg.vocab_size, seed=seed)
    return eng.schedule(steps)


@functools.lru_cache(maxsize=1)
def _stressed_streaming():
    """Overloaded streaming fleet (sheds + preempts + chunked wire +
    shared prefixes), traced, audited every step, alerting armed."""
    cfg, _ = _engine()
    obs = Obs(trace=True, metrics=True, audit_period=1, alerts=True)
    fleet = _fleet(obs=obs, admission="slo", router="least_loaded",
                   num_slots=1, queue_bound=3, kv_blocks=128,
                   stream_chunks=2)
    report = fleet.run(_traffic(cfg, rate=3.0, seed=23, shared=0.5),
                       max_steps=2500)
    return fleet, obs, report


@functools.lru_cache(maxsize=1)
def _stressed_fused():
    """Overloaded FUSED-admission fleet: per-block signals, first-block
    admission, device-side consume waits — the PR-7 protocol under the
    PR-8 lens."""
    cfg, _ = _engine()
    obs = Obs(trace=True, metrics=True, audit_period=1)
    fleet = _fleet(obs=obs, admission="slo", router="least_loaded",
                   num_slots=1, queue_bound=3, kv_blocks=128,
                   stream_chunks=0, fused_attn=True)
    report = fleet.run(_traffic(cfg, rate=3.0, seed=23), max_steps=2500)
    return fleet, obs, report


@functools.lru_cache(maxsize=1)
def _fused_onepod():
    """Single-pod fused fleet: no host-proxy ring, so fused admission keeps
    its MINIMAL-prefix device wait and decode consumes trailing blocks
    per-signal (cross-pod admission drains the ring whole instead)."""
    cfg, _ = _engine()
    obs = Obs(trace=True, audit_period=1)
    fleet = _fleet(obs=obs, n_pods=1, admission="slo", router="least_loaded",
                   num_slots=1, queue_bound=3, kv_blocks=128,
                   stream_chunks=0, fused_attn=True)
    report = fleet.run(_traffic(cfg, rate=3.0, seed=23), max_steps=2500)
    return fleet, obs, report


def _assert_paths_exact(fleet, obs):
    """The acceptance invariant: every submitted request has a complete,
    gap-free critical path whose segment sum equals its e2e span."""
    chains = request_chains(obs.tracer)
    rids = {rid for _, rid in fleet.placements.values()}
    assert rids and rids == set(chains)
    paths = critical.fleet_paths(chains, obs.tracer.events)
    for rid, p in paths.items():
        assert p["complete"], f"rid {rid}: open span in a drained run"
        assert p["gaps"] == [], f"rid {rid}: untraced hole"
        assert sum(p["segments"].values()) == pytest.approx(
            p["e2e_ticks"], abs=1e-9), f"rid {rid}: attribution leak"
        if p["outcome"] == "finished":
            assert p["ttfd_ticks"] is not None
            assert sum(p["ttfd_segments"].values()) == pytest.approx(
                p["ttfd_ticks"], abs=1e-9)
    return paths


# ---------------------------------------------------------------------------
# critical paths under stress
# ---------------------------------------------------------------------------


def test_streaming_paths_gap_free_and_exact():
    fleet, obs, report = _stressed_streaming()
    assert report["shed"] > 0 and report["preempts"] >= 1
    paths = _assert_paths_exact(fleet, obs)
    # streaming requests put their installments in the wire segment, and
    # preempted requests carry a preemption segment
    assert any(p["segments"]["wire"] > 0 for p in paths.values())
    assert any(p["segments"]["preemption"] > 0 for p in paths.values())
    assert any(p["outcome"] == "shed" and p["segments"]["queue"] >= 0
               for p in paths.values())
    # clean run: the per-step auditors never fired
    assert obs.auditor.checks == fleet.elapsed_steps
    assert obs.auditor.violation_count == 0


def test_streaming_fleet_report_and_what_if():
    fleet, obs, _ = _stressed_streaming()
    rep = critical.analyze_tracer(obs.tracer)
    assert rep["requests"] == len(fleet.placements)
    assert rep["chain_gaps"] == 0 and rep["incomplete_paths"] == 0
    assert rep["admitted"] + rep["shed"] <= rep["requests"]
    assert rep["ttfd"]["p99_steps"] >= rep["ttfd"]["p50_steps"] > 0
    shares = rep["ttfd_segment_share"]
    assert sum(shares.values()) == pytest.approx(1.0)
    # the p99 request is a real request with its own exact breakdown
    worst = rep["p99_request"]
    assert worst["rid"] in dict(fleet.placements.values()).keys() or \
        worst["rid"] in {rid for _, rid in fleet.placements.values()}
    assert sum(worst["segments_steps"].values()) == pytest.approx(
        worst["ttfd_steps"], abs=1e-6)
    # what-if bounds can only improve (or match) the measured tail
    for key, val in rep["what_if"].items():
        assert val <= rep["ttfd"]["p99_steps"] + 1e-9, key


def test_fused_paths_use_observed_first_block_step():
    fleet, obs, report = _stressed_fused()
    assert report["shed"] > 0 and report["preempts"] >= 1
    paths = _assert_paths_exact(fleet, obs)
    chains = request_chains(obs.tracer)
    saw_mig = False
    for rid, chain in chains.items():
        migs = [(i, e) for i, e in enumerate(chain)
                if e["phase"] == "migrating"]
        if not migs:
            continue
        saw_mig = True
        # the split is anchored on the OBSERVED first-block step (threaded
        # from the admission poll onto the migrating end), so the wire
        # segment ends exactly where the first block landed — replay the
        # boundary-attributed split and demand an exact match
        want_wire = 0.0
        for i, mig in migs:
            assert mig["args"]["protocol"] == "fused"
            assert mig["args"]["wire_steps"] >= 0
            fbs = mig["args"].get("first_block_step", -1)
            assert fbs >= 0
            t_end = chain[i + 1]["t0"] if i + 1 < len(chain) else mig["t1"]
            dur = max(0.0, float(t_end) - float(mig["t0"]))
            migrate_step = int(mig["t0"] // STEP_QUANTUM)
            want_wire += min(max(0.0, (fbs - migrate_step) * STEP_QUANTUM),
                             dur)
        p = paths[rid]
        # every fused admission leaves an admit_fused instant, threaded
        # into the path's device record
        assert p.get("device", {}).get("fused_admit"), rid
        # no streaming under fused: wire is exactly the observed window
        assert p["segments"]["wire"] == pytest.approx(want_wire, abs=1e-9)
    assert saw_mig
    rep = critical.analyze_tracer(obs.tracer)
    assert rep["device"]["events"] > 0   # PR-7 device_* spans visible


def test_fused_consume_instants_thread_into_paths():
    # intra-pod fused admission gates on the FIRST block only, so later
    # blocks stay on the wire and decode consumes them per-signal; those
    # consume batches must land in each request's device record
    fleet, obs, report = _fused_onepod()
    assert report["shed"] > 0
    paths = _assert_paths_exact(fleet, obs)
    consumed = [p for p in paths.values()
                if p.get("device", {}).get("consumed_blocks", 0) > 0]
    assert consumed, "no device-side consume instants reached the trace"
    for p in consumed:
        assert p["device"]["consume_events"] > 0
        assert p["device"]["fused_admit"]
    assert obs.auditor.violation_count == 0


# ---------------------------------------------------------------------------
# offline analyzer CLI
# ---------------------------------------------------------------------------


def test_analyze_cli_roundtrip(tmp_path, capsys):
    _, obs, _ = _stressed_streaming()
    trace = tmp_path / "trace.json"
    export.write_chrome_trace(obs.tracer, str(trace))
    out = tmp_path / "report.json"
    rc = analyze_mod.main([str(trace), "--json", str(out)])
    assert rc == 0
    text = capsys.readouterr().out
    assert "TTFD steps:" in text and "what-if bounds:" in text
    assert "!!" not in text              # clean trace: nothing flagged
    rep = json.loads(out.read_text())
    assert rep["validation_errors"] == [] and rep["chain_gaps"] == 0
    assert rep["paths"] and all("segments" in p
                                for p in rep["paths"].values())
    # offline == online: the doc round-trip reproduces the live report
    live = critical.analyze_tracer(obs.tracer)
    assert rep["ttfd"] == live["ttfd"]
    assert rep["ttfd_segments_steps"] == live["ttfd_segments_steps"]


def test_analyze_cli_flags_truncated_trace(tmp_path, capsys):
    tr = SpanTracer(max_events=4)
    tr.begin("step", "fleet", "fleet", "steps")
    for _ in range(20):
        tr.instant("xfer", "cq", "core", "cq")
    tr.end("step", "fleet", "fleet", "steps")
    trace = tmp_path / "trunc.json"
    export.write_chrome_trace(tr, str(trace))
    rc = analyze_mod.main([str(trace)])
    assert rc == 0                       # warning, not a schema error
    assert "!!" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# chain_gaps: open spans are not phantom gaps
# ---------------------------------------------------------------------------


def test_chain_gaps_open_span_covers_tail():
    tr = SpanTracer()
    tr.async_begin("queued", "req", 9, "pod0", "requests")
    tr.async_end("queued", "req", 9, "pod0", "requests")
    # a PREEMPTED/SHED-like still-open span (opened on the next sub-tick,
    # contiguous), then a later closed span — the windowed-trace shape that
    # used to flag a phantom gap
    tr.async_begin("preempted", "req", 9, "pod0", "requests")
    tr.clock.set_step(4)
    tr.async_begin("decoding", "req", 9, "pod0", "requests")
    tr.async_end("decoding", "req", 9, "pod0", "requests")
    chain = request_chains(tr)[9]
    assert chain[1]["t1"] is None        # genuinely open
    assert export.chain_gaps(chain) == []
    # ...but a REAL hole (closed span, then silence) is still a gap
    tr2 = SpanTracer()
    tr2.async_begin("queued", "req", 1, "pod0", "requests")
    tr2.async_end("queued", "req", 1, "pod0", "requests")
    tr2.clock.set_step(3)
    tr2.async_begin("decoding", "req", 1, "pod0", "requests")
    tr2.async_end("decoding", "req", 1, "pod0", "requests")
    assert len(export.chain_gaps(request_chains(tr2)[1])) == 1


# ---------------------------------------------------------------------------
# invariant auditors: seeded corruption
# ---------------------------------------------------------------------------


def _fresh_audited_fleet(**over):
    cfg, _ = _engine()
    obs = Obs(audit_period=1, recorder_window=64)
    kw = dict(admission="slo", router="least_loaded", num_slots=1,
              queue_bound=6, kv_blocks=128, stream_chunks=2)
    kw.update(over)
    fleet = _fleet(obs=obs, **kw)
    return fleet, obs, _traffic(cfg, rate=2.0, seed=23, shared=1.0,
                                steps=10)


def _run_with_injection(fleet, specs, *, when, corrupt):
    """Drive the fleet manually; inject ``corrupt(fleet)`` once ``when``
    holds.  Returns (injected_step, caught_step, audit_error)."""
    specs = sorted(specs, key=lambda s: (s.step, s.idx))
    i, injected = 0, None
    while i < len(specs) or not fleet.done():
        assert fleet.elapsed_steps < 2500, "wedged"
        batch = []
        while i < len(specs) and specs[i].step <= fleet.elapsed_steps:
            batch.append(specs[i])
            i += 1
        if injected is None and when(fleet):
            corrupt(fleet)
            injected = fleet.elapsed_steps
        try:
            fleet.step(batch)
        except AuditError as err:
            assert injected is not None, "auditors fired without corruption"
            return injected, fleet.elapsed_steps, err
    raise AssertionError("corruption never caught")


def _assert_caught(fleet, obs, injected, caught, err, rule_prefixes):
    assert caught - injected <= obs.audit_period   # within one audit period
    rules = {v.rule for v in err.violations}
    assert any(r.startswith(p) for r in rules for p in rule_prefixes), rules
    # the recorder dumped a postmortem naming the audit, and it validates
    # clean (window repair: no dangling closers, synthesized ends)
    assert len(obs.recorder.dumps) == 1
    doc = json.loads(open(obs.recorder.dumps[0]).read())
    warnings = []
    assert validate(doc, warnings=warnings) == []
    pm = doc["otherData"]["postmortem"]
    assert pm["reason"].startswith("audit:")
    assert pm["step"] == caught


def test_seeded_refcount_corruption_is_caught(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    fleet, obs, specs = _fresh_audited_fleet()
    target = []

    def when(f):
        for ids in f.pool.block_tables.values():
            if ids:
                target.append(ids[0])
                return True
        return False

    injected, caught, err = _run_with_injection(
        fleet, specs, when=when,
        corrupt=lambda f: f.pool._refcnt.__setitem__(
            target[0], f.pool._refcnt[target[0]] + 1))
    _assert_caught(fleet, obs, injected, caught, err,
                   ("refcount-", "free-list-"))
    assert any(v.subject.get("block") == target[0]
               for v in err.violations)


def test_seeded_residency_corruption_is_caught(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    fleet, obs, specs = _fresh_audited_fleet()

    def when(f):
        # an entry with live mappers mid-flight (it outlives this step)
        return any(e.refs >= 2 for e in f.prefix_index.values())

    def corrupt(f):
        entry = max(f.prefix_index.values(), key=lambda e: e.refs)
        foreign = next(b for b in range(f.pool.num_blocks)
                       if b not in entry.block_ids)
        pe = f.pods[0].sched.decode_pes[0]
        entry.resident.setdefault(pe, set()).add(foreign)

    injected, caught, err = _run_with_injection(fleet, specs, when=when,
                                                corrupt=corrupt)
    _assert_caught(fleet, obs, injected, caught, err, ("residency-",))


def test_seeded_signal_corruption_is_caught(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    fleet, obs, specs = _fresh_audited_fleet()
    hit = []

    def when(f):
        # a freshly-admitted decoder with budget left: its slot signal word
        # must stay untouched (stream mode) until it finishes
        for pod in f.pods:
            for req in pod.sched.requests.values():
                if (req.state == DECODING and req.slot >= 0
                        and len(req.out) + 2 < req.max_new):
                    hit.append((req.decode_pe, req.slot))
                    return True
        return False

    def corrupt(f):
        pe, slot = hit[0]
        ptr = f.pool.sig_ptr(slot)
        f.heap = f.heap.write(ptr, pe, jnp.ones((1,), jnp.int32))

    injected, caught, err = _run_with_injection(fleet, specs, when=when,
                                                corrupt=corrupt)
    _assert_caught(fleet, obs, injected, caught, err, ("signal-",))


def test_clean_runs_audit_clean_across_protocols():
    for _, obs, _ in (_stressed_streaming(), _stressed_fused()):
        assert obs.auditor.checks > 0
        assert obs.auditor.violation_count == 0
    # and a standalone auditor pass over the drained fleets agrees
    for fleet, _, _ in (_stressed_streaming(), _stressed_fused()):
        assert FleetAuditor().audit(fleet) == []


# ---------------------------------------------------------------------------
# SLO burn-rate alerting
# ---------------------------------------------------------------------------


def test_burn_rate_alert_fires_under_overload_with_real_offender():
    fleet, obs, report = _stressed_streaming()
    assert obs.monitor.fired, "overloaded run never alerted"
    alert = obs.monitor.fired[0]
    assert alert.cls in report["by_class"]
    for w, burn in alert.burn.items():
        assert burn > 0
    assert alert.offenders, "alert carried no drill-down"
    worst = alert.offenders[0]
    # the named offender is TRULY over deadline in the scheduler's ledger
    sched = {pod.name: pod.sched for pod in fleet.pods}[worst["pod"]]
    req = sched.requests[worst["rid"]]
    from repro.serve.frontend import slo as slo_mod
    cls = slo_mod.resolve(req.slo, fleet.classes)
    assert cls.name == alert.cls
    if worst["outcome"] == "shed":
        assert req.state == "shed"
    else:
        assert req.state == "finished"
        assert (req.admit_step - req.arrival_step
                > cls.ttfd_deadline)
        assert worst["overshoot_steps"] == (
            req.admit_step - req.arrival_step - cls.ttfd_deadline)
    # tracer was on: the drill-down carries critical-path segments
    assert "segments_steps" in worst and worst["segments_steps"]
    assert alert.to_json()["offenders"][0]["rid"] == worst["rid"]


def test_burn_rate_silent_at_nominal_load():
    cfg, _ = _engine()
    obs = Obs(metrics=True, alerts=True)
    fleet = _fleet(obs=obs, admission="slo", router="least_loaded",
                   queue_bound=64)
    fleet.run(_traffic(cfg, rate=0.5, seed=11, steps=12), max_steps=2500)
    assert obs.monitor.observations == fleet.elapsed_steps
    assert obs.monitor.fired == [] and obs.monitor.active == set()


def test_burn_rate_mechanics_and_hysteresis():
    class _F:                             # minimal fleet stand-in
        elapsed_steps = 0
        pods = ()
        classes = None
    mon = BurnRateMonitor(target=0.9, windows=(BurnWindow(2, 2.0),),
                          min_terminal=2)
    reg_rows = []

    class _Reg:
        series = reg_rows

    def push(bad, term):
        reg_rows.append({"step": len(reg_rows) + 1,
                         "class.chat.bad": bad,
                         "class.chat.terminal": term})

    push(0, 2)
    assert mon.observe(_F(), _Reg()) == []        # burn 0
    push(3, 6)                                     # Δbad 3 / Δterm 4 = .75
    fired = mon.observe(_F(), _Reg())              # burn 7.5 > 2.0
    assert len(fired) == 1 and fired[0].cls == "chat"
    push(4, 8)
    assert mon.observe(_F(), _Reg()) == []        # still active: no re-fire
    push(4, 20)                                    # burn collapses
    assert mon.observe(_F(), _Reg()) == [] and mon.active == set()
    push(9, 25)                                    # burns again -> re-fires
    assert len(mon.observe(_F(), _Reg())) == 1
    assert len(mon.fired) == 2
    with pytest.raises(ValueError):
        BurnRateMonitor(target=1.5)
    assert parse_windows("8:6,32:3") == (BurnWindow(8, 6.0),
                                         BurnWindow(32, 3.0))
    with pytest.raises(ValueError):
        parse_windows("")


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


def test_ring_tracer_bounds_and_evicts_by_step():
    tr = RingTracer(window_steps=4)
    for step in range(20):
        tr.clock.set_step(step)
        tr.begin("step", "fleet", "fleet", "steps")
        tr.instant("xfer", "cq", "core", "cq")
        tr.end("step", "fleet", "fleet", "steps")
    assert tr.evicted > 0
    assert min(ev.ts for ev in tr.events) >= (19 - 4) * STEP_QUANTUM
    # hard cap too
    small = RingTracer(window_steps=100, max_events=8)
    for _ in range(50):
        small.instant("x", "t", "p", "t")
    assert len(small.events) == 8 and small.evicted == 42


def test_recorder_repairs_window_edges(tmp_path):
    tr = RingTracer(window_steps=2)
    rec = FlightRecorder(tr, window_steps=2, path=str(tmp_path / "pm.json"))
    tr.clock.set_step(0)
    tr.begin("old", "t", "p", "t")                # begin falls off window
    tr.flow_start(1, "migration", "pod0", "pe0")  # start falls off window
    tr.clock.set_step(5)
    tr.async_begin("decoding", "req", 1, "pod0", "requests")  # in-window
    tr.end("old", "t", "p", "t")                  # dangling closer
    tr.flow_end(1, "migration", "pod1", "pe2")    # half-flow
    tr.begin("live", "t", "p", "t")               # still open at dump
    rec.note_metrics({"step": 5, "g": 1.0})
    path = rec.dump(reason="crash:test")
    doc = json.loads(open(path).read())
    warnings = []
    assert validate(doc, warnings=warnings) == []
    pm = doc["otherData"]["postmortem"]
    assert pm["reason"] == "crash:test" and pm["metrics_rows"]
    names = [(e["ph"], e["name"]) for e in doc["traceEvents"]
             if e["ph"] != "M"]
    assert ("E", "old") not in names              # dangling closer dropped
    assert ("f", "migration") not in names        # half-flow dropped
    # the still-open slice AND the still-open async got synthesized closes
    closes = [e for e in doc["traceEvents"]
              if (e.get("args") or {}).get("truncated")]
    assert {(e["ph"], e["name"]) for e in closes} == \
        {("E", "live"), ("e", "decoding")}


def test_crash_dumps_a_postmortem(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    cfg, _ = _engine()
    obs = Obs(recorder_window=16)
    fleet = _fleet(obs=obs, queue_bound=64)
    with pytest.raises(RuntimeError, match="wedged"):
        fleet.run(_traffic(cfg, rate=1.0, seed=11, steps=12), max_steps=3)
    assert obs.recorder.dumps
    doc = json.loads(open(obs.recorder.dumps[0]).read())
    warnings = []
    assert validate(doc, warnings=warnings) == []
    assert doc["otherData"]["postmortem"]["reason"] == "crash:RuntimeError"


# ---------------------------------------------------------------------------
# env surface + Obs wiring
# ---------------------------------------------------------------------------


def test_obs_env_pr8_surface():
    cfg = load_obs_env({})
    assert cfg.audit_period == 0 and cfg.recorder_window == 0
    assert not cfg.alerts and not cfg.enabled
    cfg = load_obs_env({"ISHMEM_OBS_AUDIT": "4",
                        "ISHMEM_OBS_RECORDER": "32",
                        "ISHMEM_OBS_RECORDER_PATH": "pm.json",
                        "ISHMEM_OBS_ALERTS": "1",
                        "ISHMEM_OBS_ALERT_TARGET": "0.95",
                        "ISHMEM_OBS_ALERT_WINDOWS": "4:2,16:1.5"})
    assert cfg.enabled
    assert (cfg.audit_period, cfg.recorder_window) == (4, 32)
    assert cfg.recorder_path == "pm.json" and cfg.alerts
    assert cfg.alert_target == 0.95
    assert parse_windows(cfg.alert_windows) == (BurnWindow(4, 2.0),
                                                BurnWindow(16, 1.5))
    for bad in ({"ISHMEM_OBS_AUDIT": "-1"},
                {"ISHMEM_OBS_RECORDER": "soon"},
                {"ISHMEM_OBS_ALERT_TARGET": "often"},
                {"ISHMEM_OBS_ALERT_WINDOWS": "8"}):
        with pytest.raises(ValueError):
            load_obs_env(bad)
    obs = Obs.from_config(cfg)
    assert obs.auditor is not None and obs.recorder is not None
    assert obs.monitor is not None and obs.metrics is not None
    assert isinstance(obs.tracer, RingTracer)      # ring when trace off
    assert obs.monitor.windows == (BurnWindow(4, 2.0), BurnWindow(16, 1.5))


def test_obs_wiring_tracer_selection():
    assert not Obs().tracer.enabled
    assert isinstance(Obs(recorder_window=8).tracer, RingTracer)
    on = Obs(trace=True, recorder_window=8)
    assert isinstance(on.tracer, SpanTracer)
    assert not isinstance(on.tracer, RingTracer)   # full trace wins
    assert on.recorder.tracer is on.tracer         # windowed slices of it
    assert Obs(alerts=True).metrics is not None    # alerts imply sampling
