"""Paged decode + chunked streaming + shared-prefix policy (DESIGN.md §9).

Three guarantee families:

1. **streamed admission never reads ahead of its signal** — property-tested
   against the pending-queue oracle, chunk by chunk: blocks whose
   installment has not flushed read zero decode-side, the slot signal ramps
   monotonically with exactly the flushed installments, and the admission
   threshold gates until the stream closes.
2. **shared-prefix block mapping is refcount-correct** — two requests
   declaring the same prefix map the same physical blocks; copy-on-write
   fires before the first divergent write so shared payload rows stay
   pristine everywhere; eviction under pool starvation and mid-flight
   rotation never double-frees and never frees a block another live request
   still maps.
3. **the decode path really is paged** — assembled leaves come from the
   pool row (the slot banks never re-grow a dense K/V copy), and outputs
   stay bitwise-identical to the lockstep baseline throughout.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _minihyp import given, settings, strategies as st

from repro.configs import base as cfgbase
from repro.core import context
from repro.models import model
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kvpool import KVPool
from repro.serve.kvxfer import EXTRA_SIGNALS, KVMigrator
from repro.serve.scheduler import DisaggScheduler

MAXLEN = 24


def _setup(arch="qwen3_4b", npes=4, num_blocks=32, max_slots=3,
           block_tokens=4):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    params = model.init_params(jax.random.key(0), cfg)
    ctx, heap = context.init(npes=npes, node_size=npes)
    eng = Engine(cfg, params, max_len=MAXLEN)
    pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=num_blocks,
                         max_slots=max_slots, block_tokens=block_tokens)
    return cfg, params, ctx, heap, eng, pool


def _sched(ctx, heap, eng, pool, *, decode_pes=(2, 3), num_slots=2, NEW=5,
           temperature=0.0, **kw):
    mig = KVMigrator(ctx, pool)
    return DisaggScheduler(
        ctx, heap, eng, pool, mig, prefill_pes=[0, 1],
        decode_pes=list(decode_pes), num_slots=num_slots,
        scfg=ServeConfig(max_new_tokens=NEW, temperature=temperature), **kw)


def _prompt(cfg, S=10, key=1):
    return jax.random.randint(jax.random.key(key), (1, S), 0, cfg.vocab_size)


# ---------------------------------------------------------------------------
# 1. streamed admission vs the pending-queue oracle
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(8, 14))
def test_stream_chunks_gate_on_signal(chunk, S):
    """Property: at every point of a chunked migration, (a) blocks whose
    installment has not flushed read zero at the decode PE, (b) the slot
    signal equals exactly the number of flushed wire blocks, and (c) the
    full admission threshold stays shut until the stream closes."""
    cfg, params, ctx, heap, eng, pool = _setup(max_slots=1)
    mig = KVMigrator(ctx, pool)
    tok, _, cache1 = eng.prefill_request({"tokens": _prompt(cfg, S)},
                                         jax.random.key(3))
    heap, ids = mig.stage(heap, 0, cache1, prompt_len=S, src_pe=0)
    stream = mig.open_stream(0, src_pe=0, dst_pe=1, slot=0, prompt_len=S,
                             first_token=tok)
    assert stream.pending == ids                 # everything staged travels
    sig = pool.sig_ptr(0)
    flushed = 0
    while stream.pending:
        heap = mig.stream_chunk(heap, stream, chunk)
        # issued but unflushed: nothing visible yet (pending-queue oracle)
        for bid in ids[flushed:]:
            np.testing.assert_array_equal(
                np.asarray(heap.read(pool.block_ptr(bid), 1)), 0.0)
        assert int(heap.read(sig, 1)) == flushed
        heap = mig.stream_flush(heap, stream)
        flushed = stream.sent
        # flushed installments landed, signal ramped to match...
        assert int(heap.read(sig, 1)) == flushed
        for bid in ids[:flushed]:
            np.testing.assert_array_equal(
                np.asarray(heap.read(pool.block_ptr(bid), 1)),
                np.asarray(heap.read(pool.block_ptr(bid), 0)))
        # ...and the admission threshold still gates (tail+header missing)
        heap, hdr = mig.try_admit(heap, 0, 1, stream.expected)
        assert hdr is None
    heap, rep = mig.stream_close(heap, stream)
    assert rep.expected_signal == len(ids) + EXTRA_SIGNALS
    heap, hdr = mig.try_admit(heap, 0, 1, rep.expected_signal)
    assert hdr == {"req_id": 0, "prompt_len": S, "first_token": tok,
                   "n_blocks": len(ids)}
    assert len(ctx.pending) == 0


def test_stream_flush_completes_only_this_slots_prefix():
    """flush_dependency semantics: draining one stream's chunk leaves ops
    submitted after its signal (another slot's traffic) on the queue."""
    cfg, params, ctx, heap, eng, pool = _setup(max_slots=2)
    mig = KVMigrator(ctx, pool)
    streams = []
    for rid in range(2):
        tok, _, c1 = eng.prefill_request({"tokens": _prompt(cfg, 8, key=rid)},
                                         jax.random.key(rid))
        heap, ids = mig.stage(heap, rid, c1, prompt_len=8, src_pe=0)
        streams.append(mig.open_stream(rid, src_pe=0, dst_pe=1, slot=rid,
                                       prompt_len=8, first_token=tok))
    heap = mig.stream_chunk(heap, streams[0], 1)
    heap = mig.stream_chunk(heap, streams[1], 1)   # queued after slot 0's
    heap = mig.stream_flush(heap, streams[0])
    assert int(heap.read(pool.sig_ptr(0), 1)) == 1
    # slot 1's chunk was submitted after slot 0's signal: still pending
    assert ctx.pending.pending_for(pool.sig_ptr(1), 1) is not None
    heap = mig.stream_flush(heap, streams[1])
    assert int(heap.read(pool.sig_ptr(1), 1)) == 1
    assert len(ctx.pending) == 0


# ---------------------------------------------------------------------------
# 2. shared prefix: mapping, copy-on-write, refcount-correct eviction
# ---------------------------------------------------------------------------


def test_shared_prefix_maps_same_blocks_bitwise():
    """Identical prompts declared as a whole-prompt prefix map the same
    physical blocks (one staging, one wire copy per decode PE) and still
    decode bitwise-identically to the lockstep baseline."""
    cfg, params, ctx, heap, eng, pool = _setup()
    NEW = 5
    # one decode PE so the second request lands where the prefix is resident
    sched = _sched(ctx, heap, eng, pool, decode_pes=[2], num_slots=3,
                   NEW=NEW, shared_prefix=True)
    p = _prompt(cfg, S=10)                       # 10 % 4 != 0: boundary COW
    for _ in range(3):
        sched.submit({"tokens": p}, prefix_len=10)
    outs = sched.run()
    st_ = sched.stats
    assert st_.prefix_hits == 2
    assert st_.blocks_prefix_shared == 2 * 3     # ceil(10/4) blocks each
    assert st_.bytes_wire_saved > 0              # resident blocks not re-sent
    assert st_.cow_copies == 3                   # every mapper COWs boundary
    base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])
    assert pool.stats()["blocks_in_use"] == 0    # refcounts fully unwound


def test_shared_prefix_with_divergent_suffixes():
    """Different prompts sharing only a declared prefix: full blocks inside
    the prefix are shared, the boundary is private, and each request still
    matches its own lockstep baseline."""
    cfg, params, ctx, heap, eng, pool = _setup()
    NEW = 4
    sched = _sched(ctx, heap, eng, pool, NEW=NEW, shared_prefix=True)
    P, S = 8, 12                                 # prefix = 2 full blocks (T=4)
    head = _prompt(cfg, S=P, key=5)
    prompts = []
    for i in range(3):
        tail = jax.random.randint(jax.random.key(20 + i), (1, S - P), 0,
                                  cfg.vocab_size)
        prompts.append(jnp.concatenate([head, tail], axis=1))
        sched.submit({"tokens": prompts[-1]}, prefix_len=P)
    outs = sched.run()
    assert sched.stats.prefix_hits == 2
    assert sched.stats.blocks_prefix_shared == 2 * (P // 4)
    assert sched.stats.cow_copies == 0           # boundary never shared here
    for i, p in enumerate(prompts):
        base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])
    assert pool.stats()["blocks_in_use"] == 0


def test_whole_prefix_after_partial_mapper_resends_boundary():
    """Regression: residency must be per (decode PE, block).  A non-whole
    mapper carries only the entry's full blocks to its decode PE; when a
    whole-prompt mapper lands on that PE afterwards, the boundary block is
    NOT resident there and must still travel — an all-or-nothing PE flag
    would skip it and decode against stale (zero) pool-row bytes."""
    cfg, params, ctx, heap, eng, pool = _setup()
    NEW = 4
    sched = _sched(ctx, heap, eng, pool, decode_pes=[2, 3], num_slots=2,
                   NEW=NEW, shared_prefix=True)
    P = 10                                       # 10 % 4 != 0: boundary block
    p = _prompt(cfg, S=P)
    tail = jax.random.randint(jax.random.key(33), (1, 4), 0, cfg.vocab_size)
    longer = jnp.concatenate([p, tail], axis=1)
    # round-robin slot pick: A->(2,0) registers the whole-prompt entry,
    # B->(3,0) maps only the 2 full blocks, C->(2,1) skips all 3, D->(3,1)
    # is whole-prompt on the PE where only B's partial set is resident
    sched.submit({"tokens": p}, prefix_len=P)        # A
    sched.submit({"tokens": longer}, prefix_len=P)   # B
    sched.submit({"tokens": p}, prefix_len=P)        # C
    sched.submit({"tokens": p}, prefix_len=P)        # D
    outs = sched.run()
    # C skipped 3 resident blocks; D skipped only B's 2 and re-sent the
    # boundary — 5 skips total (a PE-level flag would claim 6)
    assert (sched.stats.bytes_wire_saved
            == 5 * pool.layout.block_bytes)
    base_p = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
    base_l = eng.generate({"tokens": longer}, ServeConfig(max_new_tokens=NEW))
    for rid, base in [(0, base_p), (1, base_l), (2, base_p), (3, base_p)]:
        np.testing.assert_array_equal(np.asarray(base[0]), outs[rid])
    assert pool.stats()["blocks_in_use"] == 0


def test_cow_keeps_shared_payload_pristine_under_divergence():
    """Sampled decoding makes the mapped requests genuinely diverge; the
    shared prefix blocks' payload at the decode PE must read identical to
    the staged payload at the prefill PE after every step — only COW makes
    that hold once decode starts writing the boundary block."""
    cfg, params, ctx, heap, eng, pool = _setup()
    sched = _sched(ctx, heap, eng, pool, decode_pes=[2], num_slots=2, NEW=6,
                   temperature=0.7, shared_prefix=True)
    p = _prompt(cfg, S=10)
    for _ in range(2):
        sched.submit({"tokens": p}, prefix_len=10)
    entry_blocks = None
    guard = 0
    while not sched.done():
        sched.step()
        guard += 1
        assert guard < 200
        if entry_blocks is None and sched.prefix_index:
            entry = next(iter(sched.prefix_index.values()))
            entry_blocks = (list(entry.block_ids), entry.home_pe)
        if entry_blocks is not None:
            ids, home = entry_blocks
            for bid in ids:
                if pool.refcount(bid) == 0:
                    continue                    # entry fully unwound
                np.testing.assert_array_equal(
                    np.asarray(sched.heap.read(pool.block_ptr(bid), 2)),
                    np.asarray(sched.heap.read(pool.block_ptr(bid), home)))
    assert sched.stats.cow_copies >= 1
    assert pool.stats()["blocks_in_use"] == 0


def _refcount_invariant(sched, pool):
    """Every mapped block has refcount == (#tables mapping it) + (#live COW
    reservations holding it) + (#prefix entries owning it); free-listed
    blocks are mapped by nobody."""
    expect = [0] * pool.num_blocks
    for ids in pool.block_tables.values():
        for i in ids:
            expect[i] += 1
    for view in sched.views.values():
        for sm in view.slots.values():
            for bid in sm.cow.values():
                expect[bid] += 1
    for req in sched.requests.values():
        for bid in req.cow_plan.values():
            expect[bid] += 1                    # reserved, not yet admitted
    for entry in sched.prefix_index.values():
        for bid in entry.block_ids:
            expect[bid] += 1
    for i in range(pool.num_blocks):
        assert pool.refcount(i) == expect[i], \
            f"block {i}: refcount {pool.refcount(i)} != mappers {expect[i]}"
        if pool.refcount(i) == 0:
            assert i in pool._free


def test_refcount_eviction_under_starvation_and_rotation():
    """The satellite guarantee: a pool sized so shared-prefix requests must
    wait for earlier evictions, driven through mid-flight rotation — no
    double-free (pool.release raises), no freeing a block another request
    still maps (invariant checked after every step), and the pool drains
    to empty with every stream matching the baseline."""
    cfg, params, ctx, heap, eng, pool = _setup(num_blocks=10, max_slots=2,
                                               block_tokens=4)
    NEW = 4
    sched = _sched(ctx, heap, eng, pool, decode_pes=[2, 3], num_slots=2,
                   NEW=NEW, shared_prefix=True, stream_chunks=1)
    p = _prompt(cfg, S=10)                       # 3 prompt blocks + COW
    other = _prompt(cfg, S=9, key=9)
    for i in range(6):
        if i % 2 == 0:
            sched.submit({"tokens": p}, prefix_len=10)
        else:
            sched.submit({"tokens": other})
    guard = 0
    while not sched.done():
        sched.step()
        _refcount_invariant(sched, pool)
        guard += 1
        assert guard < 300
    outs = {r: np.asarray(sched.requests[r].out, np.int32)
            for r in sched.requests}
    assert sched.stats.stalled_on_pool > 0 or sched.stats.stalled_on_slots > 0
    assert pool.stats()["blocks_in_use"] == 0
    base_p = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
    base_o = eng.generate({"tokens": other}, ServeConfig(max_new_tokens=NEW))
    for i in range(6):
        base = base_p if i % 2 == 0 else base_o
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])


def test_pool_sharing_api_refcounts():
    """Unit semantics of the new pool surface: alloc_with_prefix increfs,
    reserve holds blocks outside tables, remap transfers the reservation in
    and drops the shared ref, release frees only at refcount zero."""
    cfg, params, ctx, heap, eng, pool = _setup(num_blocks=8)
    a = pool.alloc(1, 3)
    assert pool.free_blocks() == 5
    b = pool.alloc_with_prefix(2, a[:2], 4)
    assert b[:2] == a[:2] and len(b) == 4
    assert pool.refcount(a[0]) == 2 and pool.refcount(a[2]) == 1
    res = pool.reserve(1)
    assert pool.free_blocks() == 8 - 3 - 2 - 1
    # COW: request 2 swaps its view of a[1] for the reserve
    old = pool.remap(2, 1, res[0])
    assert old == a[1] and pool.refcount(a[1]) == 1
    assert pool.blocks_of(2)[1] == res[0] and pool.refcount(res[0]) == 1
    assert pool.release(1) == 2                  # a[1], a[2] free; a[0] shared
    assert pool.refcount(a[0]) == 1              # still mapped by request 2
    assert pool.release(2) == 4
    assert pool.free_blocks() == 8
    with pytest.raises(ValueError):
        pool.incref([a[0]])                      # incref on a free block
    assert pool.release_ids([]) == 0


def test_prefix_plan_refuses_multimodal_batches():
    """Sharability rule 4: a batch carrying non-token inputs never maps or
    registers a prefix — the token-keyed index cannot see the embeds that
    condition K/V via cross-attention."""
    from repro.serve.scheduler import Request
    cfg, params, ctx, heap, eng, pool = _setup()
    sched = _sched(ctx, heap, eng, pool, shared_prefix=True)
    tok = _prompt(cfg, S=8)
    mm = Request(rid=0, batch={"tokens": tok,
                               "audio_embeds": jnp.zeros((1, 4, 8))},
                 max_new=4, prefix_len=8)
    assert sched._prefix_plan(mm) == ([], None, 0)
    plain = Request(rid=1, batch={"tokens": tok}, max_new=4, prefix_len=8)
    ids, key, n = sched._prefix_plan(plain)
    assert key is not None and n == 2            # 8 tokens = 2 full blocks


def test_submit_rejects_unschedulable_cow_request():
    """A whole-prompt unaligned prefix needs table + 1 blocks (the COW
    reserve); a pool of exactly table-many blocks must reject the request
    upfront instead of wedging the scheduler re-queueing it forever."""
    NEW = 4
    cfg, params, ctx, heap, eng, pool = _setup(num_blocks=4, block_tokens=4)
    assert pool.layout.blocks_for_decode(10, NEW) == 4
    sched = _sched(ctx, heap, eng, pool, NEW=NEW, shared_prefix=True)
    p = _prompt(cfg, S=10)                       # 10 % 4 != 0: boundary COW
    with pytest.raises(ValueError):
        sched.submit({"tokens": p}, prefix_len=10)
    # a multimodal batch never shares (rule 4), so no reserve is demanded
    # and the same-sized request must stay schedulable
    sched2 = _sched(ctx, heap, eng, pool, NEW=NEW, shared_prefix=True)
    sched2.submit({"tokens": p, "audio_embeds": jnp.zeros((1, 2, 4))},
                  prefix_len=10)
    sched.submit({"tokens": p})                  # no prefix: fits exactly
    sched.run()


def test_blocks_for_decode_growth():
    cfg, params, ctx, heap, eng, pool = _setup(block_tokens=4)
    lay = pool.layout
    assert not lay.ring
    assert lay.blocks_for_decode(10, 0) == lay.blocks_for_prompt(10) == 3
    assert lay.blocks_for_decode(10, 6) == 4     # writes reach pos 14
    # the final sampled token is never written back: 9 + 4 tokens end the
    # last write at pos 11, squarely inside block 2 — no dead fourth block
    assert lay.blocks_for_decode(9, 4) == 3
    assert lay.blocks_for_decode(10, 100) == lay.blocks_per_request  # capped


# ---------------------------------------------------------------------------
# 3. the decode path really is paged
# ---------------------------------------------------------------------------


def test_assembled_leaves_equal_dense_rehydrate():
    """The bitwise-identity mechanism itself: after admission, the paged
    view's assembled cache equals what insert_blocks would have rehydrated
    — byte for byte."""
    from repro.serve import kvpool as kvpool_mod
    cfg, params, ctx, heap, eng, pool = _setup()
    mig = KVMigrator(ctx, pool)
    sched = _sched(ctx, heap, eng, pool, decode_pes=[2], num_slots=2, NEW=5)
    p = _prompt(cfg, S=10)
    sched.submit({"tokens": p})
    guard = 0
    while not sched.stats.admissions and guard < 50:
        sched.step()
        guard += 1
    view = sched.views[2]
    bank = sched.banks[2]
    assembled = view.assemble(sched.heap, bank.cache)
    rid = next(iter(pool.block_tables))
    payloads, tail = mig.gather(sched.heap, rid, 0, 2)
    dense = kvpool_mod.insert_blocks(pool.layout, bank.cache, 0, payloads)
    for pl in pool.layout.paged:
        np.testing.assert_array_equal(
            np.asarray(assembled["blocks"][pl.unit_idx][pl.key][:, 0]),
            np.asarray(dense["blocks"][pl.unit_idx][pl.key][:, 0]))
    sched.run()


def test_growth_blocks_receive_decode_writes():
    """A prompt whose generation crosses a block boundary writes generated
    K/V into growth blocks that were never migrated — decode output still
    matches the baseline, and the growth blocks end up non-zero."""
    cfg, params, ctx, heap, eng, pool = _setup(block_tokens=4)
    NEW = 7                                      # writes pos 10..15: block 3
                                                 # is pure growth
    sched = _sched(ctx, heap, eng, pool, decode_pes=[2], num_slots=1,
                   NEW=NEW)
    p = _prompt(cfg, S=10)
    sched.submit({"tokens": p})
    touched = {}
    guard = 0
    while not sched.done():
        sched.step()
        guard += 1
        assert guard < 100
        for rid, ids in pool.block_tables.items():
            grown = [i for i in ids if pool.home_of(i) is None]
            for bid in grown:
                val = np.abs(np.asarray(
                    sched.heap.read(pool.block_ptr(bid), 2),
                    np.float32)).max()
                touched[bid] = max(touched.get(bid, 0.0), float(val))
    assert touched and max(touched.values()) > 0
    base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
    np.testing.assert_array_equal(np.asarray(base[0]),
                                  np.asarray(sched.requests[0].out))
