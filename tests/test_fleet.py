"""Cluster frontend: traffic engine, router, SLO admission, preemption.

The load-bearing guarantee rides along from the disagg stack: *whatever*
schedule the frontend produces — randomized routing, priority reordering,
mid-decode preemption and resume, parked slot-less streams — every
completed request's decode output stays bitwise-identical to the single-PE
``Engine.generate`` baseline (greedy decoding).  The frontend only decides
WHAT runs next; the migration protocol decides WHAT the bytes are.
"""
import functools

import jax
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.core import context
from repro.models import model
from repro.serve.engine import Engine, ServeConfig
from repro.serve.frontend import (Fleet, FleetConfig, SLOPolicy, TenantSpec,
                                  TrafficEngine, load_fleet_env, percentile)
from repro.serve.frontend import slo as slo_mod
from repro.serve.kvpool import KVPool
from repro.serve.kvxfer import KVMigrator
from repro.serve.scheduler import (DECODING, FINISHED, SHED, DisaggScheduler,
                                   Request)

MAXLEN = 24
NEW = 4


@functools.lru_cache(maxsize=1)
def _engine():
    """One engine (and one set of jitted closures) for the whole module."""
    cfg = cfgbase.reduced(cfgbase.get_config("qwen3_4b"))
    params = model.init_params(jax.random.key(0), cfg)
    return cfg, Engine(cfg, params, max_len=MAXLEN)


def _fleet(**over):
    cfg, engine = _engine()
    kw = dict(n_pods=2, prefill_per_pod=1, decode_per_pod=2, num_slots=2,
              kv_blocks=96, block_tokens=4, max_len=MAXLEN, max_new=NEW,
              stream_chunks=1, admission="slo", router="affinity", seed=11)
    kw.update(over)
    return Fleet(FleetConfig(**kw), engine=engine)


MIX = (TenantSpec("chat", weight=2.0, prompt_lens=(8,), max_new=(NEW,),
                  slo="interactive"),
       TenantSpec("scan", weight=1.0, prompt_lens=(12,), max_new=(NEW,),
                  slo="batch", shared_prefix_prob=0.5, prefix_groups=1))


# ---------------------------------------------------------------------------
# traffic engine
# ---------------------------------------------------------------------------


def test_traffic_schedule_is_deterministic():
    """Identical (seed, tenants, rate) tuples produce bitwise-identical
    schedules — including the shared prefix-group prompts — and different
    seeds genuinely differ."""
    cfg, _ = _engine()
    a = TrafficEngine(list(MIX), rate=1.0, vocab=cfg.vocab_size, seed=5)
    b = TrafficEngine(list(MIX), rate=1.0, vocab=cfg.vocab_size, seed=5)
    sa, sb = a.schedule(16), b.schedule(16)
    assert len(sa) == len(sb) > 0
    for x, y in zip(sa, sb):
        assert (x.step, x.tenant, x.slo, x.max_new, x.prefix_len) == \
            (y.step, y.tenant, y.slo, y.max_new, y.prefix_len)
        np.testing.assert_array_equal(x.tokens, y.tokens)
    c = TrafficEngine(list(MIX), rate=1.0, vocab=cfg.vocab_size, seed=6)
    sc = c.schedule(16)
    assert [s.step for s in sc] != [s.step for s in sa] or \
        any(not np.array_equal(x.tokens, y.tokens)
            for x, y in zip(sa, sc))


def test_traffic_bursty_and_mix_accounting():
    """Bursty arrivals cluster (higher variance than poisson at the same
    mean-ish rate); offered_load tallies tenants/classes; shared-prefix
    requests re-use the group prompt with a whole-prompt prefix."""
    cfg, _ = _engine()
    eng = TrafficEngine(list(MIX), rate=1.0, vocab=cfg.vocab_size, seed=9,
                        process="bursty", burst_len=4, burst_factor=4.0)
    specs = eng.schedule(64)
    counts = np.bincount([s.step for s in specs], minlength=64)
    assert counts.var() > counts.mean()          # overdispersed vs poisson
    load = eng.offered_load(specs)
    assert load["requests"] == len(specs)
    assert set(load["by_slo"]) <= {"interactive", "batch"}
    shared = [s for s in specs if s.prefix_len > 0]
    assert shared and all(s.prefix_len == s.prompt_len for s in shared)
    # every shared spec of the one group is the identical prompt
    keys = {s.prefix_key() for s in shared}
    assert len(keys) == 1


def test_fleet_env_knobs():
    env = {"ISHMEM_FLEET_PODS": "3", "ISHMEM_FLEET_ROUTER": "least_loaded",
           "ISHMEM_FLEET_ADMISSION": "fcfs",
           "ISHMEM_FLEET_QUEUE_BOUND": "7", "ISHMEM_FLEET_SEED": "2"}
    cfg = load_fleet_env(env)
    assert (cfg.pods, cfg.router, cfg.admission, cfg.queue_bound,
            cfg.seed) == (3, "least_loaded", "fcfs", 7, 2)
    assert load_fleet_env({}).router == "affinity"
    with pytest.raises(ValueError):
        load_fleet_env({"ISHMEM_FLEET_ROUTER": "psychic"})
    with pytest.raises(ValueError):
        load_fleet_env({"ISHMEM_FLEET_QUEUE_BOUND": "0"})


# ---------------------------------------------------------------------------
# SLO policy units (no model, no heap)
# ---------------------------------------------------------------------------


def _req(rid, slo, arrival=0, out_len=0, state=DECODING, preempts=0):
    r = Request(rid=rid, batch={"tokens": np.zeros((1, 4), np.int32)},
                max_new=NEW, slo=slo)
    r.arrival_step = arrival
    r.out = [1] * out_len
    r.state = state
    r.preemptions = preempts
    return r


def test_slo_policy_orders_sheds_and_preempts():
    pol = SLOPolicy(queue_bound=2)
    # priority beats FIFO; deadline breaks ties inside a class
    q = [_req(0, "batch", arrival=0), _req(1, "interactive", arrival=5),
         _req(2, "interactive", arrival=3)]
    assert pol.select(q) == 2
    # shed: best-effort past queue_bound, everything past the hard bound
    assert not pol.admit(_req(3, "batch"), queue_len=2)
    assert pol.admit(_req(3, "interactive"), queue_len=2)
    assert not pol.admit(_req(3, "interactive"), queue_len=4)
    # preemption: only over-budget best-effort victims, most progress first
    decoding = [_req(4, "batch", out_len=3), _req(5, "batch", out_len=1),
                _req(6, "interactive", out_len=9)]
    victim = pol.preempt_victim(_req(7, "interactive"), decoding)
    assert victim.rid == 4
    # best effort never preempts; exhausted victims are immune
    assert pol.preempt_victim(_req(8, "batch"), decoding) is None
    immune = [_req(9, "batch", out_len=3, preempts=pol.max_preemptions)]
    assert pol.preempt_victim(_req(10, "interactive"), immune) is None
    # unknown class names resolve to the default, not an error
    assert slo_mod.resolve("no-such-class").name == slo_mod.DEFAULT_CLASS


# ---------------------------------------------------------------------------
# preemption: bitwise resume on one scheduler
# ---------------------------------------------------------------------------


def _sched(ctx, heap, eng, pool, **kw):
    mig = KVMigrator(ctx, pool)
    kw.setdefault("prefill_pes", [0, 1])
    kw.setdefault("decode_pes", [2, 3])
    kw.setdefault("num_slots", 1)
    kw.setdefault("scfg", ServeConfig(max_new_tokens=NEW))
    return DisaggScheduler(ctx, heap, eng, pool, mig, **kw)


def test_preemption_resume_is_bitwise():
    """A batch request is preempted mid-decode by a later interactive
    request (1 slot/PE forces the contention) and resumes on the same PE;
    BOTH streams match their uninterrupted Engine.generate baselines."""
    cfg, eng = _engine()
    ctx, heap = context.init(npes=4, node_size=4)
    pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=48, max_slots=2,
                         block_tokens=4)
    sched = _sched(ctx, heap, eng, pool,
                   scfg=ServeConfig(max_new_tokens=12),
                   policy=SLOPolicy(queue_bound=64))
    prompts = [jax.random.randint(jax.random.key(k), (1, 10), 0,
                                  cfg.vocab_size) for k in range(3)]
    sched.submit({"tokens": prompts[0]}, max_new=12, slo="batch")
    sched.submit({"tokens": prompts[1]}, max_new=12, slo="batch")
    # let the batch requests occupy both decode slots and generate a bit
    for _ in range(4):
        sched.step()
    assert all(r.state == DECODING for r in sched.requests.values())
    sched.submit({"tokens": prompts[2]}, max_new=4, slo="interactive")
    outs = sched.run()
    assert sched.stats.preempts >= 1
    assert sched.stats.resumes == sched.stats.preempts
    preempted = [r for r in sched.requests.values() if r.preemptions]
    assert preempted and all(r.state == FINISHED for r in preempted)
    for rid, (p, n) in enumerate([(prompts[0], 12), (prompts[1], 12),
                                  (prompts[2], 4)]):
        base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=n))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[rid])
    assert pool.stats()["blocks_in_use"] == 0


def test_preemption_with_shared_prefix_and_cow():
    """Preempting a mapper of a shared prefix keeps its un-triggered COW
    reservation alive across the park (refcounts stay exact), and resumed
    decode still matches the baseline — the COW fires post-resume."""
    cfg, eng = _engine()
    ctx, heap = context.init(npes=4, node_size=4)
    pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=48, max_slots=2,
                         block_tokens=4)
    sched = _sched(ctx, heap, eng, pool, decode_pes=[2],
                   scfg=ServeConfig(max_new_tokens=10), shared_prefix=True,
                   policy=SLOPolicy(queue_bound=64))
    p = jax.random.randint(jax.random.key(1), (1, 10), 0, cfg.vocab_size)
    sched.submit({"tokens": p}, max_new=10, prefix_len=10, slo="batch")
    for _ in range(3):
        sched.step()
    batch_req = sched.requests[0]
    assert batch_req.state == DECODING
    sched.submit({"tokens": p}, max_new=4, prefix_len=10, slo="interactive")
    outs = sched.run()
    assert sched.stats.preempts >= 1
    base10 = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=10))
    base4 = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=4))
    np.testing.assert_array_equal(np.asarray(base10[0]), outs[0])
    np.testing.assert_array_equal(np.asarray(base4[0]), outs[1])
    assert pool.stats()["blocks_in_use"] == 0


# ---------------------------------------------------------------------------
# parked slot-less streams
# ---------------------------------------------------------------------------


def test_parked_stream_beats_whole_prefill_at_one_slot():
    """The ROADMAP open item: with ONE slot per decode PE, streamed blocks
    park in the pool (no slot held while draining) and the slot binds only
    at close — so the admission wire window still shrinks vs whole-prefill
    hand-off, where the old slot-bound streams used to tie."""
    cfg, eng = _engine()

    def run(stream):
        ctx, heap = context.init(npes=4, node_size=4)
        pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=64, max_slots=2,
                             block_tokens=4)
        sched = _sched(ctx, heap, eng, pool, num_slots=1,
                       stream_chunks=stream, admit_delay_steps=1)
        for k in range(4):
            sched.submit({"tokens": jax.random.randint(
                jax.random.key(k), (1, 12), 0, cfg.vocab_size)})
        outs = sched.run()
        return sched, outs

    s_whole, outs_w = run(0)
    s_stream, outs_s = run(1)
    for rid in outs_w:
        np.testing.assert_array_equal(outs_w[rid], outs_s[rid])
    whole = np.mean(s_whole.stats.ttfd_model_s)
    stream = np.mean(s_stream.stats.ttfd_model_s)
    assert stream < whole
    # stream signal words were all recycled and zeroed
    assert len(s_stream.pool._stream_free) == s_stream.pool.max_streams
    for i in range(s_stream.pool.max_streams):
        for pe in (2, 3):
            assert int(s_stream.heap.read(
                s_stream.pool.stream_sig_ptr(i), pe)) == 0


def test_stream_signal_exhaustion_backpressures():
    """A pool with ONE stream-signal word serializes streams: staging
    stalls (stalled_on_streams) instead of wedging, and every request
    still completes bitwise-correct."""
    cfg, eng = _engine()
    ctx, heap = context.init(npes=4, node_size=4)
    pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=64, max_slots=2,
                         block_tokens=4, max_streams=1)
    sched = _sched(ctx, heap, eng, pool, num_slots=1, stream_chunks=1)
    prompts = [jax.random.randint(jax.random.key(k), (1, 12), 0,
                                  cfg.vocab_size) for k in range(4)]
    for p in prompts:
        sched.submit({"tokens": p})
    outs = sched.run()
    assert sched.stats.stalled_on_streams > 0
    for i, p in enumerate(prompts):
        base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])


# ---------------------------------------------------------------------------
# queue-delay accounting (the t_arrival satellite)
# ---------------------------------------------------------------------------


def test_arrival_time_threads_into_latency_stats():
    """A request submitted with an arrival_step in the past reports TTFD
    from ARRIVAL (queue time included), while the migration-window stats
    keep their old meaning; queue delay is recorded at prefill."""
    cfg, eng = _engine()
    ctx, heap = context.init(npes=4, node_size=4)
    pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=48, max_slots=2,
                         block_tokens=4)
    sched = _sched(ctx, heap, eng, pool)
    p = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab_size)
    sched.submit({"tokens": p}, arrival_step=-5, t_arrival=-1.0)
    sched.run()
    st = sched.stats
    assert st.ttfd_arrival_steps[0] == st.ttfd_steps[0] + 5
    assert st.queue_delay_steps[0] == 5
    # the modeled arrival clock was handed in, so the arrival window is
    # strictly wider than the migration window
    assert st.ttfd_arrival_model_s[0] > st.ttfd_model_s[0]
    req = sched.requests[0]
    assert req.finish_step >= req.admit_step >= req.prefill_step
    assert st.e2e_steps[0] == req.finish_step + 5


# ---------------------------------------------------------------------------
# fleet end-to-end
# ---------------------------------------------------------------------------


def _baseline(eng, spec):
    base = eng.generate({"tokens": spec.tokens},
                        ServeConfig(max_new_tokens=spec.max_new))
    return np.asarray(base[0])


@pytest.mark.parametrize("router,admission,seed",
                         [("random", "slo", 11), ("round_robin", "fcfs", 13),
                          ("affinity", "slo", 17)])
def test_fleet_outputs_bitwise_under_any_routing(router, admission, seed):
    """The acceptance property: random/rr/affinity routing x FCFS/SLO
    admission (with preemption and shared prefixes in play) — every
    completed request equals its single-PE baseline bitwise."""
    cfg, eng = _engine()
    fleet = _fleet(router=router, admission=admission, seed=seed,
                   num_slots=1, queue_bound=64)
    traffic = TrafficEngine(list(MIX), rate=1.0, vocab=cfg.vocab_size,
                            seed=seed)
    specs = traffic.schedule(10)
    rep = fleet.run(specs, max_steps=1500)
    assert rep["completed"] == rep["offered"] == len(specs) > 0
    outs = fleet.outputs()
    for spec in specs:
        np.testing.assert_array_equal(_baseline(eng, spec),
                                      np.asarray(outs[spec.idx], np.int32))
    # the shared pool fully unwinds across all pods
    assert fleet.pool.stats()["blocks_in_use"] == 0


def test_fleet_slo_beats_fcfs_and_sheds_past_bound():
    """Same overloaded schedule twice: SLO strictly improves interactive
    p99 TTFD-from-arrival, and with a tight queue bound sheds fire and
    terminate as SHED (not wedged)."""
    cfg, eng = _engine()
    heavy = (TenantSpec("chat", prompt_lens=(8,), max_new=(NEW,),
                        slo="interactive"),
             TenantSpec("scan", prompt_lens=(12,), max_new=(12,),
                        slo="batch"))
    reports = {}
    for admission in ("fcfs", "slo"):
        fleet = _fleet(admission=admission, router="least_loaded",
                       num_slots=1, queue_bound=3, kv_blocks=128,
                       stream_chunks=2, max_new=NEW)
        traffic = TrafficEngine(list(heavy), rate=3.0,
                                vocab=cfg.vocab_size, seed=23)
        reports[admission] = fleet.run(traffic.schedule(16), max_steps=2500)
        if admission == "slo":
            sheds = [r for pod in fleet.pods
                     for r in pod.sched.requests.values()
                     if r.state == SHED]
            assert len(sheds) == reports["slo"]["shed"]
    slo_p99 = reports["slo"]["by_class"]["interactive"]["ttfd_p99_steps"]
    fcfs_p99 = reports["fcfs"]["by_class"]["interactive"]["ttfd_p99_steps"]
    assert slo_p99 < fcfs_p99
    assert reports["slo"]["shed"] > 0
    assert reports["slo"]["preempts"] >= 1


def test_fleet_affinity_reduces_cross_pod_bytes():
    """Prefix-affinity routing vs seeded-random routing on a shared-prefix
    workload: the affinity arm pulls fewer bytes across the pod boundary
    (the proxy ring carries the difference)."""
    cfg, eng = _engine()
    tenants = (TenantSpec("samples", prompt_lens=(12,), max_new=(NEW,),
                          slo="standard", shared_prefix_prob=0.8,
                          prefix_groups=1),)
    bytes_x = {}
    for router in ("random", "affinity"):
        fleet = _fleet(router=router, seed=5)
        traffic = TrafficEngine(list(tenants), rate=0.6,
                                vocab=cfg.vocab_size, seed=5)
        rep = fleet.run(traffic.schedule(20), max_steps=1500)
        bytes_x[router] = rep["wire"]["bytes_cross_pod"]
        assert rep["completed"] == rep["offered"]
    assert bytes_x["random"] > 0
    assert bytes_x["affinity"] < bytes_x["random"]


# ---------------------------------------------------------------------------
# proxy-ring saturation (cross-pod migration storms)
# ---------------------------------------------------------------------------


def test_migration_storm_backpressures_bounded_ring():
    """A cross-pod migration storm through a tiny (2-slot) host-proxy ring
    must backpressure — the flush drains the ring mid-run instead of
    wedging or dropping — and every stream still decodes bitwise-correct.
    Write-combining is disabled (`ISHMEM_NBI_COALESCE=0` A/B mode) so every
    block is its own ring message: a run of 3 blocks posts 3 consecutive
    puts, which is guaranteed to fill 2 slots mid-flush.  (With coalescing
    on, a run is ONE message and the data-before-flag rule drains the ring
    before each signal — the ring can never saturate, by design.)"""
    import dataclasses as _dc
    from repro.core.proxy import HostProxy
    from repro.core import teams
    cfg, eng = _engine()
    ctx, heap = context.init(npes=4, node_size=2)   # decode PEs in pod 2
    ctx.tuning = _dc.replace(ctx.tuning, nbi_coalesce=False)
    pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=64, max_slots=3,
                         block_tokens=4)
    proxy = HostProxy(ctx, slots=2)
    mig = KVMigrator(ctx, pool, proxy=proxy)
    pre, dec = teams.disagg_partition(teams.world(4), 2)
    sched = DisaggScheduler(ctx, heap, eng, pool, mig,
                            prefill_pes=pre.pes(), decode_pes=dec.pes(),
                            num_slots=3, scfg=ServeConfig(max_new_tokens=NEW),
                            admit_delay_steps=1)
    prompts = [jax.random.randint(jax.random.key(k), (1, 12), 0,
                                  cfg.vocab_size) for k in range(6)]
    for p in prompts:                   # 6 x (3 blocks + tail + header) puts
        sched.submit({"tokens": p})
    outs = sched.run()
    assert proxy.backpressure > 0       # the ring filled and drained mid-run
    assert proxy.ring.overwrite_errors == 0
    assert len(proxy.ring.delivered) == len(set(
        i for i, _ in proxy.ring.delivered))        # exactly-once
    for i, p in enumerate(prompts):
        base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
        np.testing.assert_array_equal(np.asarray(base[0]), outs[i])


def test_percentile_helper():
    assert percentile([], 99) == 0.0
    assert percentile([3.0], 50) == 3.0
    xs = list(range(1, 101))
    assert percentile(xs, 50) == pytest.approx(50.5)
    assert percentile(xs, 99) == pytest.approx(99.01)
    assert percentile(xs, 0) == 1.0 and percentile(xs, 100) == 100.0
