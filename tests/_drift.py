"""Version-keyed expectation markers for known toolchain drift.

The image pins jax 0.4.37 while parts of the suite target a newer surface.
The failures are environmental, not logic bugs — each marker below is keyed
to the installed jax version so the suite heals itself when the toolchain
catches up (the marker evaporates and the tests must pass).  The inventory
lives in ROADMAP.md under "Open items: jax version drift".
"""
import jax
import pytest

JAX_04X = jax.__version__.startswith("0.4.")

# pallas interpret-mode remote-DMA semantics under jit (ring kernels, the
# shmem comms backend, and the mesh-lowered steps built on them) and
# Compiled.cost_analysis returning a list — both fixed in jax >= 0.5
jax_drift_xfail = pytest.mark.xfail(
    condition=JAX_04X,
    reason="jax 0.4.x drift: pallas interpret-mode remote DMA under jit / "
           "cost_analysis surface — see ROADMAP.md 'Open items'",
    strict=False)

# for drift tests whose failure is expensive to reach (full mesh lowering +
# compile): skip outright on the old toolchain instead of running to the
# known failure — self-heals identically when the jax pin moves
jax_drift_skip = pytest.mark.skipif(
    JAX_04X,
    reason="jax 0.4.x drift (expensive lowering path) — see ROADMAP.md "
           "'Open items'")
