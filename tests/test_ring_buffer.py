"""Property tests for the lock-free reverse-offload ring (paper §III-D)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean interpreter: deterministic fallback
    from _minihyp import given, settings, strategies as st

from repro.core.ring import Message, RingBuffer


def drive_schedule(ring, producers, schedule):
    """Interleave producer/consumer micro-steps per schedule; then drain."""
    for actor in schedule:
        if actor == -1:
            ring.consumer_step()
        else:
            ring.producer_step(producers[actor % len(producers)])
    # drain: finish all producers then consume everything
    for _ in range(10_000):
        progressed = False
        for pid in list(producers):
            if ring.producer_step(pid) is not None:
                progressed = True
        if ring.consumer_step() is not None:
            progressed = True
        if ring.read_index == ring.write_reserve and not any(
                ring._prod[p][0] < 3 for p in ring._prod):
            break
        if not progressed and ring.read_index == ring.write_reserve:
            break
    ring.publish()


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6), st.integers(1, 40),
       st.lists(st.integers(-1, 5), max_size=200), st.sampled_from([4, 8, 16]))
def test_exactly_once_in_order(n_producers, n_msgs, schedule, slots):
    ring = RingBuffer(slots=slots, publish_every=4)
    producers = []
    sent = 0
    for m in range(n_msgs):
        pid = f"p{m % n_producers}_{m}"
        # one outstanding message per producer id
        ring.start(pid, Message("put", payload=m.to_bytes(4, "little")))
        producers.append(pid)
        sent += 1
    drive_schedule(ring, producers, schedule)
    # exactly-once, reservation order, no overwrites
    assert ring.overwrite_errors == 0
    idxs = [i for i, _ in ring.delivered]
    assert idxs == sorted(idxs) == list(range(len(idxs)))
    assert len(ring.delivered) == sent
    payloads = sorted(int.from_bytes(m.payload, "little")
                      for _, m in ring.delivered)
    assert payloads == list(range(n_msgs))


def test_flow_control_blocks_when_full():
    ring = RingBuffer(slots=4, publish_every=1)
    pids = [f"p{i}" for i in range(6)]
    for pid in pids:
        ring.start(pid, Message("put"))
    # reserve all: only 4 slots available against published count 0
    for pid in pids:
        ring.producer_step(pid)
    reserved = sum(1 for p in pids if ring._prod[p][0] >= 1)
    assert reserved == 4 and ring.spin_count >= 2
    # consumer drains -> publish -> the rest can proceed
    for pid in pids:
        ring.producer_step(pid)
        ring.producer_step(pid)
    for _ in range(4):
        ring.consumer_step()
    ring.publish()
    for pid in pids:
        for _ in range(3):
            ring.producer_step(pid)
    while ring.consumer_step() is not None:
        pass
    assert len(ring.delivered) == 6
    assert ring.overwrite_errors == 0


def test_out_of_order_completions():
    ring = RingBuffer(slots=8)
    ring.start("a", Message("put"))
    ring.start("b", Message("put"))
    for pid in ("a", "b"):
        while ring.producer_step(pid) is None:
            pass
    ring.consumer_step()
    ring.consumer_step()
    # completions independently allocated: either producer can reap first
    assert ring.producer_done("b")
    assert ring.producer_done("a")


def test_message_size_limit():
    with pytest.raises(ValueError):
        Message("put", payload=b"x" * 57)


def test_flow_control_off_critical_path():
    """Paper: <1% overhead — publishes are amortized over many messages."""
    ring = RingBuffer(slots=64, publish_every=16)
    for m in range(512):
        pid = f"p{m}"
        ring.start(pid, Message("put"))
        while ring.producer_step(pid) is None:
            if ring.spin_count > 0:
                ring.consumer_step()
        ring.consumer_step()
    while ring.consumer_step() is not None:
        pass
    assert ring.flow_control_overhead() < 0.05
    assert len(ring.delivered) == 512
