"""The paper-named API facade (ishmem_* / ishmemx_*) + the hierarchical
pod-aware allreduce."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _drift import jax_drift_xfail
from repro.core.api import Ishmem


@pytest.fixture()
def sh():
    return Ishmem(npes=8, node_size=4)


def test_paper_listing_flow(sh):
    """The §III-G1 ishmem_long_p listing, end to end."""
    buf = sh.ishmem_malloc((256,), "float32")
    sh.ishmem_p(buf.index(7), 42.0, pe=3)
    assert float(sh.ishmem_g(buf.index(7), pe=3)) == 42.0
    data = jnp.arange(256, dtype=jnp.float32)
    sh.ishmemx_put_work_group(buf, data, pe=1, work_group_size=1024)
    np.testing.assert_array_equal(
        np.asarray(sh.ishmemx_get_work_group(buf, pe=1)), np.asarray(data))


def test_amo_and_signal(sh):
    ctr = sh.ishmem_malloc((), "int32")
    assert int(sh.ishmem_atomic_fetch_add(ctr, 5, pe=2)) == 0
    sh.ishmem_atomic_inc(ctr, pe=2)
    assert int(sh.ishmem_atomic_fetch(ctr, pe=2)) == 6
    old = sh.ishmem_atomic_compare_swap(ctr, 6, 9, pe=2)
    assert int(old) == 6

    from repro.core.signal import SIGNAL_ADD
    buf = sh.ishmem_malloc((8,), "float32")
    sig = sh.ishmem_malloc((), "uint32")
    sh.ishmem_put_signal(buf, jnp.ones(8), sig, 1, SIGNAL_ADD, pe=5)
    cur, ok = sh.ishmem_signal_wait_until(sig, 5, "ge", 1)
    assert bool(ok)


def test_collectives_and_teams(sh):
    buf = sh.ishmem_malloc((16,), "float32")
    sh.heap = sh.heap.write_all(buf, jnp.ones((8, 16)))
    team = sh.ctx.team_shared(0)
    sh.ishmemx_sum_reduce_work_group(buf, buf, team, work_group_size=256)
    assert float(sh.heap.read(buf, 0)[0]) == 4.0
    assert float(sh.heap.read(buf, 7)[0]) == 1.0     # other node untouched
    sat = sh.ishmem_barrier_all()
    assert bool(sat.all())
    assert sh.ishmem_n_pes() == 8


def test_nbi_quiet_fence(sh):
    buf = sh.ishmem_malloc((128,), "float32")
    sh.ishmem_put_nbi(buf, jnp.full(128, 2.0), pe=6)
    sh.ishmem_fence()
    sh.ishmem_quiet()
    assert float(sh.ishmem_get(buf, pe=6)[0]) == 2.0


def test_free_reuse(sh):
    a = sh.ishmem_malloc((128,), "float32")
    sh.ishmem_free(a)
    b = sh.ishmem_calloc((64,), "float32")
    assert b.offset == a.offset


@jax_drift_xfail          # shmem backend rings hit the pallas interpret drift
def test_hierarchical_psum_matches_flat(mesh2x4):
    """Two-level (DCN x ICI) allreduce == flat psum; the DCN tier carries
    only 1/npes of the payload (the paper's tiered-transport architecture)."""
    from jax.sharding import PartitionSpec as P
    from repro.comms import api
    shmem = api.get_ops("shmem", npes=4)        # ici axis size
    x = jax.random.normal(jax.random.key(0), (8, 6, 256))

    def hier(v):
        return shmem.psum_hierarchical(v[0], "model", "data")[None]

    def flat(v):
        return jax.lax.psum(v[0], ("data", "model"))[None]

    fh = jax.jit(jax.shard_map(hier, mesh=mesh2x4,
                               in_specs=P(("data", "model"), None, None),
                               out_specs=P(("data", "model"), None, None),
                               check_vma=False))
    ff = jax.jit(jax.shard_map(flat, mesh=mesh2x4,
                               in_specs=P(("data", "model"), None, None),
                               out_specs=P(("data", "model"), None, None),
                               check_vma=False))
    np.testing.assert_allclose(np.asarray(fh(x)), np.asarray(ff(x)),
                               rtol=1e-5, atol=1e-5)
