import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean interpreter: deterministic fallback
    from _minihyp import given, settings, strategies as st

from repro.core import heap as heap_mod


def test_malloc_alignment_and_symmetry():
    h = heap_mod.create(npes=4)
    a = h.malloc((100,), "float32")
    b = h.malloc((3, 5), "float32")
    assert a.offset % heap_mod.ALIGN == 0
    assert b.offset % heap_mod.ALIGN == 0
    assert b.offset >= a.offset + 128          # no overlap
    assert a.shape == (100,) and b.shape == (3, 5)
    # symmetric: same ptr valid at every PE
    h = h.write(a, 0, jnp.ones(100))
    h = h.write(a, 3, jnp.full(100, 2.0))
    assert float(h.read(a, 0)[0]) == 1.0
    assert float(h.read(a, 3)[0]) == 2.0
    assert float(h.read(a, 1)[0]) == 0.0       # other PEs untouched


def test_free_reuse():
    h = heap_mod.create(npes=2)
    a = h.malloc((256,), "float32")
    h.free(a)
    b = h.malloc((128,), "float32")
    assert b.offset == a.offset                # first-fit reuse


def test_calloc_zeroes_reused_region():
    """free -> write -> calloc regression: a recycled free-list block must
    not leak the freed buffer's bytes through calloc."""
    h = heap_mod.create(npes=2)
    a = h.malloc((256,), "float32")
    h = h.write(a, 1, jnp.full(256, 7.0))      # dirty the region at PE 1
    h.free(a)
    b = h.calloc((256,), "float32")
    assert b.offset == a.offset                # reuse really happened
    np.testing.assert_array_equal(np.asarray(h.read(b, 1)), 0.0)
    np.testing.assert_array_equal(np.asarray(h.read(b, 0)), 0.0)


def test_malloc_reuse_is_dirty_but_calloc_is_not():
    # documents the malloc contract the calloc fix is defined against
    h = heap_mod.create(npes=1)
    a = h.malloc((128,), "float32")
    h = h.write(a, 0, jnp.ones(128))
    h.free(a)
    c = h.malloc((128,), "float32")
    assert float(h.read(c, 0)[0]) == 1.0       # malloc: undefined (dirty)


def test_free_coalesces_adjacent_extents():
    h = heap_mod.create(npes=1)
    ptrs = [h.malloc((128,), "float32") for _ in range(4)]
    keep = h.malloc((128,), "float32")         # guard after the freed run
    for p in (ptrs[0], ptrs[2], ptrs[1], ptrs[3]):   # out-of-order frees
        h.free(p)
    assert h._free["float32"] == [(ptrs[0].offset, 4 * 128)]
    # the coalesced extent satisfies an allocation bigger than any one piece
    big = h.malloc((512,), "float32")
    assert big.offset == ptrs[0].offset
    assert keep.offset >= 4 * 128


def test_heap_stats_accounting():
    h = heap_mod.create(npes=2)
    a = h.malloc((256,), "float32")
    b = h.malloc((128,), "int32")
    s = h.stats()
    assert s["bytes_in_use"] == 256 * 4 + 128 * 4
    assert s["bytes_free"] == 0
    assert s["pools"]["float32"]["fragmentation"] == 0.0
    h.free(a)
    s = h.stats()
    assert s["pools"]["float32"]["bytes_free"] == 256 * 4
    assert s["pools"]["float32"]["bytes_in_use"] == 0
    assert s["pools"]["int32"]["bytes_in_use"] == 128 * 4
    assert s["pools"]["float32"]["free_extents"] == 1
    # two non-adjacent free extents -> nonzero fragmentation
    h2 = heap_mod.create(npes=1)
    x = h2.malloc((128,), "float32")
    y = h2.malloc((128,), "float32")
    z = h2.malloc((128,), "float32")
    h2.free(x)
    h2.free(z)                                  # x and z are not adjacent
    st = h2.stats()["pools"]["float32"]
    assert st["free_extents"] == 2
    assert st["fragmentation"] == 0.5


def test_pool_growth():
    h = heap_mod.create(npes=2, words_per_pool=256)
    ptrs = [h.malloc((128,), "float32") for _ in range(8)]
    h = h.write(ptrs[-1], 1, jnp.arange(128))
    assert float(h.read(ptrs[-1], 1)[5]) == 5.0


def test_dtype_canonicalization():
    h = heap_mod.create(npes=2)
    p = h.malloc((), "int64")                  # narrows without x64
    assert p.dtype == "int32"


def test_read_all_write_all():
    h = heap_mod.create(npes=3)
    p = h.malloc((4,), "int32")
    h = h.write_all(p, jnp.arange(12).reshape(3, 4))
    np.testing.assert_array_equal(np.asarray(h.read_all(p)),
                                  np.arange(12).reshape(3, 4))


def test_ptr_index_bounds():
    h = heap_mod.create(npes=2)
    p = h.malloc((8,), "float32")
    assert p.index(7).offset == p.offset + 7
    with pytest.raises(IndexError):
        p.index(8)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 500),
                          st.sampled_from(["float32", "int32"])),
                min_size=1, max_size=20))
def test_allocations_never_overlap(allocs):
    h = heap_mod.create(npes=1)
    spans = {"float32": [], "int32": []}
    for n, dt in allocs:
        p = h.malloc((n,), dt)
        lo, hi = p.offset, p.offset + max(128, -(-n // 128) * 128)
        for (l2, h2) in spans[dt]:
            assert hi <= l2 or lo >= h2, "overlapping allocation"
        spans[dt].append((lo, hi))
