import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from _drift import jax_drift_xfail
from repro.roofline import hlo_parser as hp


@jax_drift_xfail          # Compiled.cost_analysis returns a list on 0.4.x
def test_scan_flops_scaled_by_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    s = hp.analyze(c.as_text())
    assert s["flops"] == pytest.approx(2 * 128 * 256 * 256 * 8)
    # raw cost_analysis undercounts by the trip count
    assert c.cost_analysis()["flops"] == pytest.approx(2 * 128 * 256 * 256)


def test_nested_scan():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def ob(x, _):
            return jax.lax.scan(inner, x, ws)[0], None
        return jax.lax.scan(ob, x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    c = jax.jit(outer).lower(x, ws).compile()
    s = hp.analyze(c.as_text())
    assert s["flops"] == pytest.approx(2 * 64 * 64 * 64 * 4 * 3)


def test_collectives_parsed_with_group_size():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = jax.ShapeDtypeStruct((16, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)

    def f(x, w):
        return jnp.sum(x @ w)

    with mesh:
        c = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P("model", None)))).lower(x, w).compile()
    s = hp.analyze(c.as_text(), num_partitions=8)
    assert s["collective_bytes"] > 0
    assert "all-reduce" in s["collective_by_kind"]


def test_tuple_typed_while_parses():
    # carries with multiple tensors produce tuple-typed while ops
    def body(c, _):
        a, b = c
        return (jnp.tanh(a @ b), b), None

    def f(a, b):
        return jax.lax.scan(body, (a, b), None, length=5)[0][0]

    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    s = hp.analyze(c.as_text())
    assert s["flops"] == pytest.approx(2 * 32 * 32 * 32 * 5)


def test_shape_bytes():
    assert hp.shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert hp.shape_bytes("bf16[8]{0}") == 16
    assert hp.shape_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
    assert hp.shape_bytes("pred[]") == 1


def test_wire_bytes_formulas():
    assert hp._wire_bytes("all-reduce", 100, 4) == pytest.approx(150.0)
    assert hp._wire_bytes("all-gather", 100, 4) == pytest.approx(75.0)
    assert hp._wire_bytes("collective-permute", 100, 4) == 100.0
    assert hp._wire_bytes("all-reduce", 100, 1) == 0.0
