"""System-invariant property tests (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean interpreter: deterministic fallback
    from _minihyp import given, settings, strategies as st

from repro.configs import base as cfgbase
from repro.models import model


def _logits_all(cfg, params, tokens):
    """Full per-position logits via the train path (no loss)."""
    from repro.models.layers import rms_norm
    x = model._embed(params, cfg, tokens)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _, _ = model.backbone(params, cfg, x, mode="train",
                             positions=positions)
    x = rms_norm(x, params["final_norm"])
    return x @ model._lm_matrix(params, cfg)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 30))
def test_causality_dense(seed, t):
    """Changing tokens after position t never changes logits at <= t."""
    cfg = cfgbase.reduced(cfgbase.get_config("qwen3_4b"))
    params = model.init_params(jax.random.key(0), cfg)
    S = 32
    t = min(t, S - 2)
    rng = jax.random.key(seed)
    toks = jax.random.randint(rng, (1, S), 0, cfg.vocab_size)
    toks2 = toks.at[0, t + 1:].set(
        (toks[0, t + 1:] + 7) % cfg.vocab_size)
    la = _logits_all(cfg, params, toks)
    lb = _logits_all(cfg, params, toks2)
    np.testing.assert_allclose(np.asarray(la[0, :t + 1]),
                               np.asarray(lb[0, :t + 1]), atol=1e-5)


@pytest.mark.parametrize("arch", ["xlstm_125m", "zamba2_2_7b"])
def test_causality_recurrent(arch):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    params = model.init_params(jax.random.key(1), cfg)
    S, t = 32, 12
    toks = jax.random.randint(jax.random.key(2), (1, S), 0, cfg.vocab_size)
    toks2 = toks.at[0, t + 1:].set(0)
    la = _logits_all(cfg, params, toks)
    lb = _logits_all(cfg, params, toks2)
    np.testing.assert_allclose(np.asarray(la[0, :t + 1]),
                               np.asarray(lb[0, :t + 1]), atol=2e-4)


def test_batch_equivariance():
    """Permuting batch rows permutes outputs (incl. MoE routing)."""
    cfg = cfgbase.reduced(cfgbase.get_config("llama4_scout_17b_a16e"))
    import dataclasses
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # avoid drop coupling
    params = model.init_params(jax.random.key(3), cfg)
    toks = jax.random.randint(jax.random.key(4), (4, 24), 0, cfg.vocab_size)
    perm = jnp.array([2, 0, 3, 1])
    la = _logits_all(cfg, params, toks)
    lb = _logits_all(cfg, params, toks[perm])
    np.testing.assert_allclose(np.asarray(la[perm]), np.asarray(lb),
                               atol=2e-4)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_loss_finite_any_tokens(seed):
    cfg = cfgbase.reduced(cfgbase.get_config("minitron_8b"))
    params = model.init_params(jax.random.key(5), cfg)
    toks = jax.random.randint(jax.random.key(seed), (2, 32), 0,
                              cfg.vocab_size)
    loss, _ = model.train_loss(params, cfg,
                               {"tokens": toks, "labels": toks})
    assert bool(jnp.isfinite(loss))


def test_flash_attn_impl_matches_blockwise_in_model():
    """policy attn_impl=flash routes the model through the fused Pallas
    kernel and reproduces the XLA blockwise forward."""
    from repro.launch import policy as policy_mod
    cfg = cfgbase.reduced(cfgbase.get_config("minitron_8b"))
    params = model.init_params(jax.random.key(7), cfg)
    toks = jax.random.randint(jax.random.key(8), (2, 128), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    with policy_mod.use(policy_mod.PerfPolicy(attn_impl="flash")):
        l_flash, _ = model.train_loss(params, cfg, batch)
    l_ref, _ = model.train_loss(params, cfg, batch)
    assert abs(float(l_flash) - float(l_ref)) < 2e-4
