import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collectives as coll, context, signal, teams


@pytest.fixture()
def ctxheap():
    return context.init(npes=8, node_size=4)


def _fill(heap, p, fn):
    vals = jnp.stack([fn(i) for i in range(heap.npes)])
    return heap.write_all(p, vals)


def test_broadcast_team(ctxheap):
    ctx, heap = ctxheap
    p = heap.malloc((8,), "float32")
    heap = _fill(heap, p, lambda i: jnp.full(8, float(i)))
    team = teams.Team(2, 1, 4)                  # PEs 2..5
    heap = coll.broadcast(ctx, heap, p, root=1, team=team)   # root = PE 3
    for pe in range(8):
        want = 3.0 if 2 <= pe <= 5 else float(pe)
        assert float(heap.read(p, pe)[0]) == want


def test_fcollect(ctxheap):
    ctx, heap = ctxheap
    src = heap.malloc((2,), "float32")
    dst = heap.malloc((16,), "float32")
    heap = _fill(heap, src, lambda i: jnp.array([2.0 * i, 2.0 * i + 1]))
    heap = coll.fcollect(ctx, heap, dst, src, ctx.team_world)
    for pe in range(8):
        np.testing.assert_array_equal(np.asarray(heap.read(dst, pe)),
                                      np.arange(16.0))


def test_collect_ragged(ctxheap):
    ctx, heap = ctxheap
    src = heap.malloc((4,), "float32")
    dst = heap.malloc((32,), "float32")
    team = teams.Team(0, 1, 4)
    heap = _fill(heap, src, lambda i: jnp.full(4, float(i)))
    nelems = [1, 2, 0, 3]
    heap = coll.collect(ctx, heap, dst, src, nelems, team)
    got = np.asarray(heap.read(dst, 2))[:6]
    np.testing.assert_array_equal(got, [0, 1, 1, 3, 3, 3])


@pytest.mark.parametrize("op,expect", [
    ("sum", np.sum), ("max", np.max), ("min", np.min), ("prod", np.prod),
])
def test_reduce_float_ops(ctxheap, op, expect):
    ctx, heap = ctxheap
    p = heap.malloc((6,), "float32")
    rows = np.random.RandomState(0).uniform(0.5, 1.5, (8, 6)).astype(np.float32)
    heap = heap.write_all(p, jnp.asarray(rows))
    heap = coll.reduce(ctx, heap, p, p, op, ctx.team_world)
    np.testing.assert_allclose(np.asarray(heap.read(p, 3)),
                               expect(rows, axis=0), rtol=1e-5)


@pytest.mark.parametrize("op", ["and", "or", "xor"])
def test_reduce_bitwise(ctxheap, op):
    ctx, heap = ctxheap
    p = heap.malloc((4,), "int32")
    rows = np.random.RandomState(1).randint(0, 255, (8, 4)).astype(np.int32)
    heap = heap.write_all(p, jnp.asarray(rows))
    heap = coll.reduce(ctx, heap, p, p, op, ctx.team_world)
    want = rows[0]
    npop = {"and": np.bitwise_and, "or": np.bitwise_or,
            "xor": np.bitwise_xor}[op]
    for r in rows[1:]:
        want = npop(want, r)
    np.testing.assert_array_equal(np.asarray(heap.read(p, 0)), want)


def test_reduce_subteam_only(ctxheap):
    ctx, heap = ctxheap
    p = heap.malloc((2,), "float32")
    heap = _fill(heap, p, lambda i: jnp.full(2, 1.0))
    team = teams.Team(0, 2, 4)                  # PEs 0,2,4,6
    heap = coll.reduce(ctx, heap, p, p, "sum", team)
    assert float(heap.read(p, 0)[0]) == 4.0
    assert float(heap.read(p, 1)[0]) == 1.0     # non-member untouched


def test_alltoall(ctxheap):
    ctx, heap = ctxheap
    team = teams.Team(0, 1, 4)
    src = heap.malloc((8,), "float32")
    dst = heap.malloc((8,), "float32")
    vals = jnp.arange(32.0).reshape(4, 8)
    heap = heap.write_all(src, jnp.concatenate(
        [vals, jnp.zeros((4, 8))], 0))
    heap = coll.alltoall(ctx, heap, dst, src, team)
    # PE j slot i == PE i chunk j
    got = np.asarray(heap.read(dst, 1))
    np.testing.assert_array_equal(got.reshape(4, 2),
                                  np.asarray(vals.reshape(4, 4, 2)[:, 1]))


def test_sync_push_counters(ctxheap):
    ctx, heap = ctxheap
    ctr = heap.malloc((), "int32")
    team = ctx.team_shared(4)                   # PEs 4..7
    heap, sat = coll.sync(ctx, heap, ctr, team)
    assert bool(sat.all())
    assert int(heap.read(ctr, 4).reshape(())) == team.size
    assert int(heap.read(ctr, 0).reshape(())) == 0   # other node untouched


def test_barrier_records_quiet(ctxheap):
    ctx, heap = ctxheap
    ctr = heap.malloc((), "int32")
    heap, sat = coll.barrier(ctx, heap, ctr, ctx.team_world)
    ops = [r.op for r in ctx.ledger]
    assert "quiet" in ops and "sync" in ops


def test_collective_path_cutover(ctxheap):
    """Paper Fig. 6: small payloads go direct (push stores), large go engine."""
    ctx, heap = ctxheap
    small = heap.malloc((128,), "float32")
    large = heap.malloc((1 << 23,), "float32")   # 32 MB > modeled cutover
    heap = coll.broadcast(ctx, heap, small, 0, ctx.team_world, work_items=256)
    p_small = ctx.ledger[-1].path
    heap = coll.broadcast(ctx, heap, large, 0, ctx.team_world, work_items=256)
    p_large = ctx.ledger[-1].path
    assert p_small == "direct" and p_large == "engine"
