"""End-to-end system tests: train -> checkpoint -> resume -> serve, and the
paper's comms layer driving a data-parallel gradient allreduce."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from _drift import jax_drift_xfail
from repro.comms import api
from repro.configs import base as cfgbase
from repro.models import model
from repro.serve.engine import Engine, ServeConfig
from repro.train import trainer


def test_train_losses_decrease_dense_moe_ssm(tmp_path):
    for arch in ("qwen3_4b", "llama4_scout_17b_a16e", "xlstm_125m"):
        cfg = cfgbase.reduced(cfgbase.get_config(arch))
        tcfg = trainer.TrainConfig(steps=12, seq_len=64, global_batch=4,
                                   log_every=1, lr=1e-3,
                                   ckpt_dir=str(tmp_path / arch))
        _, _, hist = trainer.train(cfg, tcfg, log_fn=lambda *_: None)
        first = hist[0]["loss"]
        last = min(h["loss"] for h in hist[-3:])
        assert last < first, f"{arch}: {first} -> {last}"


def test_train_checkpoint_resume_serve(tmp_path):
    cfg = cfgbase.reduced(cfgbase.get_config("h2o_danube_3_4b"))
    tcfg = trainer.TrainConfig(steps=6, seq_len=48, global_batch=2,
                               log_every=2, ckpt_every=3,
                               ckpt_dir=str(tmp_path))
    params, _, _ = trainer.train(cfg, tcfg, log_fn=lambda *_: None)
    # resume continues
    tcfg2 = trainer.TrainConfig(steps=8, seq_len=48, global_batch=2,
                                log_every=1, ckpt_dir=str(tmp_path))
    params, _, hist = trainer.train(cfg, tcfg2, resume=True,
                                    log_fn=lambda *_: None)
    assert hist[0]["step"] >= 6
    # serve with the trained params
    eng = Engine(cfg, params, max_len=32)
    out = eng.generate({"tokens": jnp.zeros((2, 16), jnp.int32)},
                       ServeConfig(max_new_tokens=8))
    assert out.shape == (2, 8)
    assert bool(jnp.isfinite(out).all())


@jax_drift_xfail
def test_dp_gradient_allreduce_via_shmem_backend(mesh8):
    """Data-parallel training step where the gradient all-reduce is the
    paper's device-initiated ring kernel — grads match a single-device step
    on the concatenated batch."""
    d, v = 64, 128
    w = jax.random.normal(jax.random.key(0), (d, v)) * 0.1
    x = jax.random.normal(jax.random.key(1), (8, 16, d))
    y = jax.random.randint(jax.random.key(2), (8, 16), 0, v)

    def loss(w, xb, yb):
        logits = xb @ w
        lse = jax.nn.logsumexp(logits, -1)
        ll = jnp.take_along_axis(logits, yb[..., None], -1)[..., 0]
        return (lse - ll).mean()

    shmem = api.get_ops("shmem", npes=8)

    def dp_step(xb, yb):
        g = jax.grad(loss)(w, xb[0], yb[0])
        return shmem.psum(g, "x")[None] / 8.0

    f = jax.jit(jax.shard_map(dp_step, mesh=mesh8,
                              in_specs=(P("x", None, None), P("x", None)),
                              out_specs=P("x", None, None),
                              check_vma=False))
    g_dp = f(x, y)[0]
    g_ref = jax.grad(loss)(w, x.reshape(-1, d), y.reshape(-1))
    np.testing.assert_allclose(np.asarray(g_dp), np.asarray(g_ref),
                               rtol=2e-4, atol=2e-6)


def test_ishmem_heap_backed_parameter_broadcast():
    """Init-time parameter broadcast through the core library (host path):
    PE0's params reach every PE bit-exactly."""
    from repro.core import collectives, context
    ctx, heap = context.init(npes=4)
    p = heap.malloc((1024,), "float32")
    w0 = jax.random.normal(jax.random.key(5), (1024,))
    heap = heap.write(p, 0, w0)
    heap = collectives.broadcast(ctx, heap, p, root=0, team=ctx.team_world)
    for pe in range(4):
        np.testing.assert_array_equal(np.asarray(heap.read(p, pe)),
                                      np.asarray(w0))
