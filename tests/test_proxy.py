import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import context, proxy


def test_put_is_deferred_until_drain():
    ctx, heap = context.init(npes=4, node_size=2)
    p = heap.malloc((64,), "float32")
    px = proxy.HostProxy(ctx)
    px.put(p, jnp.ones(64), 3)
    assert float(heap.read(p, 3).sum()) == 0.0      # not yet executed
    heap = px.drain(heap)
    assert float(heap.read(p, 3).sum()) == 64.0
    assert len(px.ring.delivered) == 1


def test_amo_add_via_ring_with_completion():
    ctx, heap = context.init(npes=4, node_size=2)
    p = heap.malloc((), "int32")
    px = proxy.HostProxy(ctx)
    pid1, idx1 = px.amo_add(p, 5, 2)
    pid2, idx2 = px.amo_add(p, 7, 2)
    heap = px.drain(heap)
    assert int(heap.read(p, 2).reshape(())) == 12
    # completions hold fetched old values (out-of-order reply capable)
    assert int(px.ring.completions[idx1]) == 0
    assert int(px.ring.completions[idx2]) == 5


def test_many_messages_wrap_ring():
    ctx, heap = context.init(npes=4, node_size=2)
    p = heap.malloc((256,), "float32")
    px = proxy.HostProxy(ctx, slots=8)
    heap0 = heap
    for i in range(5):                     # submit, drain, repeat (wraps laps)
        for j in range(6):
            px.put(p, jnp.full(256, float(i * 6 + j)), 1)
        heap0 = px.drain(heap0)
    assert px.ring.overwrite_errors == 0
    assert len(px.ring.delivered) == 30
    assert float(heap0.read(p, 1)[0]) == 29.0


def test_quiet_message():
    ctx, heap = context.init(npes=2, node_size=1)
    px = proxy.HostProxy(ctx)
    px.quiet()
    heap = px.drain(heap)
    assert any(r.op == "proxy_quiet" for r in ctx.ledger)


# ---------------------------------------------------------------------------
# drain edge cases: ring wedge, multi-producer reaping, AMO pre-images
# ---------------------------------------------------------------------------


def test_submit_wedges_when_ring_full_and_no_consumer():
    ctx, heap = context.init(npes=4, node_size=2)
    px = proxy.HostProxy(ctx, slots=4)
    p = heap.malloc((8,), "float32")
    for i in range(4):                       # fill every slot, never drain
        px.put(p, jnp.full(8, float(i)), 1)
    spins_before = px.ring.spin_count
    with pytest.raises(RuntimeError, match="ring wedged"):
        px.put(p, jnp.zeros(8), 1)
    assert px.ring.spin_count > 10_000       # detected via the spin counter
    assert px.ring.spin_count > spins_before
    # the abandoned producer must not leak in the ring's registry
    assert len(px.ring._prod) == 4
    # no slot was reserved by the wedged producer: backlog drains cleanly...
    heap = px.drain(heap)
    assert len(px.ring.delivered) == 4
    assert px.ring.overwrite_errors == 0
    # ...and the ring accepts new traffic afterwards
    px.put(p, jnp.full(8, 9.0), 2)
    heap = px.drain(heap)
    assert float(heap.read(p, 2)[0]) == 9.0


def test_drain_reaps_multiple_outstanding_producers():
    ctx, heap = context.init(npes=4, node_size=2)
    px = proxy.HostProxy(ctx, slots=16)
    p = heap.malloc((4,), "float32")
    ids = [px.put(p, jnp.full(4, float(i)), i % 4) for i in range(10)]
    # all ten producers are outstanding (visible, uncompleted) before drain
    assert len(px.ring._prod) == 10
    assert not px.ring.completions
    heap = px.drain(heap)
    # one drain executes every message AND reaps every completed producer
    assert len(px.ring.delivered) == 10
    assert len(px.ring._prod) == 0
    assert set(px.ring.completions) == {idx for _, idx in ids}
    # last writer per PE wins (FIFO ring order)
    for pe in range(4):
        last = max(i for i in range(10) if i % 4 == pe)
        assert float(heap.read(p, pe)[0]) == float(last)


def test_amo_add_returns_pre_image_per_message():
    ctx, heap = context.init(npes=4, node_size=2)
    px = proxy.HostProxy(ctx)
    p = heap.malloc((), "int32")
    adds = [3, 11, -4, 7]
    idxs = [px.amo_add(p, v, 1)[1] for v in adds]
    heap = px.drain(heap)
    # completion i carries the value *before* add i (the AMO fetch semantics),
    # even though all adds were outstanding together
    running = 0
    for v, idx in zip(adds, idxs):
        assert int(px.ring.completions[idx]) == running
        running += v
    assert int(heap.read(p, 1).reshape(())) == running


def test_amo_add_pre_image_interleaved_with_puts():
    ctx, heap = context.init(npes=2, node_size=1)
    px = proxy.HostProxy(ctx)
    p = heap.malloc((), "int32")
    _, i1 = px.amo_add(p, 5, 1)
    px.put(p, jnp.asarray(100, "int32"), 1)  # FIFO: executes after the add
    _, i2 = px.amo_add(p, 2, 1)
    heap = px.drain(heap)
    assert int(px.ring.completions[i1]) == 0     # pre-image of first add
    assert int(px.ring.completions[i2]) == 100   # put landed in between
    assert int(heap.read(p, 1).reshape(())) == 102
