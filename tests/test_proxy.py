import jax.numpy as jnp
import numpy as np

from repro.core import context, proxy


def test_put_is_deferred_until_drain():
    ctx, heap = context.init(npes=4, node_size=2)
    p = heap.malloc((64,), "float32")
    px = proxy.HostProxy(ctx)
    px.put(p, jnp.ones(64), 3)
    assert float(heap.read(p, 3).sum()) == 0.0      # not yet executed
    heap = px.drain(heap)
    assert float(heap.read(p, 3).sum()) == 64.0
    assert len(px.ring.delivered) == 1


def test_amo_add_via_ring_with_completion():
    ctx, heap = context.init(npes=4, node_size=2)
    p = heap.malloc((), "int32")
    px = proxy.HostProxy(ctx)
    pid1, idx1 = px.amo_add(p, 5, 2)
    pid2, idx2 = px.amo_add(p, 7, 2)
    heap = px.drain(heap)
    assert int(heap.read(p, 2).reshape(())) == 12
    # completions hold fetched old values (out-of-order reply capable)
    assert int(px.ring.completions[idx1]) == 0
    assert int(px.ring.completions[idx2]) == 5


def test_many_messages_wrap_ring():
    ctx, heap = context.init(npes=4, node_size=2)
    p = heap.malloc((256,), "float32")
    px = proxy.HostProxy(ctx, slots=8)
    heap0 = heap
    for i in range(5):                     # submit, drain, repeat (wraps laps)
        for j in range(6):
            px.put(p, jnp.full(256, float(i * 6 + j)), 1)
        heap0 = px.drain(heap0)
    assert px.ring.overwrite_errors == 0
    assert len(px.ring.delivered) == 30
    assert float(heap0.read(p, 1)[0]) == 29.0


def test_quiet_message():
    ctx, heap = context.init(npes=2, node_size=1)
    px = proxy.HostProxy(ctx)
    px.quiet()
    heap = px.drain(heap)
    assert any(r.op == "proxy_quiet" for r in ctx.ledger)
