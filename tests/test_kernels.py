"""Per-kernel interpret-mode validation against pure-jnp oracles, with
shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean interpreter: deterministic fallback
    from _minihyp import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int32"])
@pytest.mark.parametrize("n,off,w", [
    (128, 0, 1), (256, 128, 2), (1024, 512, 4), (4096, 0, 16),
])
def test_wg_copy_sweep(dtype, n, off, w):
    dst = jnp.zeros(8192, dtype)
    src = jnp.arange(n).astype(dtype)
    out = ops.wg_copy_local(dst, src, off, work_items=w)
    want = ref.wg_copy(dst, src, off)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("op", ["sum", "max", "min", "prod"])
@pytest.mark.parametrize("t,n,blk", [(2, 128, 128), (8, 1024, 256),
                                     (5, 640, 512)])
def test_reduce_tile_sweep(op, t, n, blk):
    rows = jax.random.uniform(jax.random.key(t * n), (t, n),
                              minval=0.5, maxval=1.5)
    out = ops.reduce_tile(rows, op, block=blk)
    want = ref.reduce_tile(rows, op)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("dtype", ["float32", "int32"])
def test_reduce_tile_dtypes(dtype):
    rows = (jnp.arange(4 * 256).reshape(4, 256) % 7).astype(dtype)
    out = ops.reduce_tile(rows, "sum")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(rows).astype(np.float64).sum(0),
                               rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 24), st.integers(0, 15), st.integers(1, 8))
def test_wg_copy_property(nblocks, offblocks, w):
    n = nblocks * 128
    off = offblocks * 128
    dst = jnp.full(128 * 48, -1.0)
    src = jnp.arange(n, dtype=jnp.float32)
    out = ops.copy_into(dst, src, off)
    want = ref.wg_copy(dst, src, off)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_copy_into_unaligned_fallback():
    dst = jnp.zeros(1000)
    src = jnp.arange(37, dtype=jnp.float32)
    out = ops.copy_into(dst, src, 13)          # unaligned -> scalar-store path
    np.testing.assert_array_equal(np.asarray(out[13:50]), np.arange(37.0))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,S,H,hd,bq,bk", [
    (1, 128, 2, 64, 64, 64),
    (2, 256, 4, 32, 128, 64),
    (1, 512, 1, 128, 256, 256),
])
def test_flash_attention_vs_oracle(dtype, B, S, H, hd, bq, bk):
    from repro.kernels import flash_attn
    ks = jax.random.split(jax.random.key(B * S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd)).astype(dtype)
    out = flash_attn.flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention(q, k, v)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_matches_blockwise_model_attention():
    """The fused kernel and the model's blockwise XLA attention agree."""
    from repro.kernels import flash_attn
    from repro.models import attention as attn_mod
    B, S, H, hd = 2, 256, 4, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    a = flash_attn.flash_attention(q, k, v)
    b = attn_mod.blockwise_causal_attn(q, k, v, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)
