"""Device-initiated SHMEM ops + fused paged attention + ring attention
(DESIGN.md §12).

Four guarantee families:

1. **work-group op semantics** — collaborative put/get/broadcast/reduce move
   the right bytes, record ``device_*`` telemetry at the group's width
   (which the estimator keeps out of p2p fits for collectives), and the
   device ``signal_wait_until`` forces only the MINIMAL pending prefix.
2. **fused migration never reads ahead of a block's signal** —
   property-tested against the pending-queue oracle: after
   ``migrate_fused``, block k stays zero decode-side until the per-block
   wait for ``sig >= EXTRA_SIGNALS + k`` completes, and admission charges
   only tail + header + first block.
3. **fused paged attention is bitwise-identical** to gathering the same
   leaves through ``PagedDecodeView.assemble`` and running the dense fused
   flash kernel — across dense, hybrid-SSM, and encoder-decoder layouts —
   and the scheduler's ``fused_attn=True`` mode reproduces the barrier
   mode's decode streams exactly while reporting a strictly earlier
   time-to-first-resident-block.
4. **sequence-parallel ring attention** matches full-sequence causal flash
   attention (partials merge by the online-softmax combination).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _minihyp import given, settings, strategies as st

from repro.configs import base as cfgbase
from repro.core import context, device as device_mod
from repro.kernels import ops
from repro.models import model
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kvpool import KVPool
from repro.serve.kvxfer import (EXTRA_SIGNALS, KVMigrator,
                                fused_admit_signal)
from repro.serve.paged_attn import PagedDecodeView
from repro.serve.scheduler import DisaggScheduler
from repro.tune.estimator import _is_p2p

MAXLEN = 24


def _setup(arch="qwen3_4b", npes=4, num_blocks=32, max_slots=3,
           block_tokens=4):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    params = model.init_params(jax.random.key(0), cfg)
    ctx, heap = context.init(npes=npes, node_size=npes)
    eng = Engine(cfg, params, max_len=MAXLEN)
    pool = KVPool.create(heap, cfg, MAXLEN, num_blocks=num_blocks,
                         max_slots=max_slots, block_tokens=block_tokens)
    return cfg, params, ctx, heap, eng, pool


def _sched(ctx, heap, eng, pool, *, decode_pes=(2, 3), num_slots=2, NEW=5,
           **kw):
    mig = KVMigrator(ctx, pool)
    return DisaggScheduler(
        ctx, heap, eng, pool, mig, prefill_pes=[0, 1],
        decode_pes=list(decode_pes), num_slots=num_slots,
        scfg=ServeConfig(max_new_tokens=NEW), **kw)


def _prompt(cfg, S=10, key=1):
    return jax.random.randint(jax.random.key(key), (1, S), 0, cfg.vocab_size)


def _req(cfg, p):
    b = {"tokens": p}
    if cfg.family == "audio":
        b["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(7), (1, cfg.encoder_seq, cfg.d_model))
    return b


# ---------------------------------------------------------------------------
# 1. work-group op semantics
# ---------------------------------------------------------------------------


def test_put_get_roundtrip_records_width():
    ctx, heap = context.init(npes=4, node_size=4)
    wg = device_mod.work_group(ctx, size=64, pe=0)
    buf = heap.malloc((128,), jnp.float32)
    val = jnp.arange(128, dtype=jnp.float32)
    heap = device_mod.put(wg, heap, buf, val, 2)
    np.testing.assert_array_equal(np.asarray(heap.read(buf, 2)),
                                  np.asarray(val))
    np.testing.assert_array_equal(np.asarray(heap.read(buf, 0)), 0.0)
    got = device_mod.get(wg, heap, buf, 2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(val))
    recs = [r for r in ctx.ledger if r.op in ("device_put", "device_get")]
    assert len(recs) == 2
    assert {r.work_items for r in recs} == {64}     # priced at wg width
    assert {r.tier for r in recs} == {"ici"}


def test_work_group_width_follows_tuning(monkeypatch):
    ctx, _ = context.init(npes=2)
    assert device_mod.work_group(ctx).size == ctx.tuning.work_group_size
    assert device_mod.work_group(ctx, size=32).size == 32
    monkeypatch.setenv("ISHMEM_WORK_GROUP_SIZE", "256")
    ctx2, _ = context.init(npes=2)
    assert device_mod.work_group(ctx2).size == 256


def test_put_signal_nbi_defers_until_device_wait():
    ctx, heap = context.init(npes=4, node_size=4)
    wg = device_mod.work_group(ctx, size=128, pe=0)
    buf = heap.malloc((64,), jnp.float32)
    sig = heap.malloc((1,), jnp.int32)
    heap = device_mod.put_signal_nbi(wg, heap, buf,
                                     jnp.ones(64, jnp.float32), sig, 1,
                                     device_mod.SIGNAL_ADD, 1)
    # parked: neither data nor flag visible before the completion point
    np.testing.assert_array_equal(np.asarray(heap.read(buf, 1)), 0.0)
    assert int(heap.read(sig, 1)[0]) == 0
    heap, cur, ok = device_mod.signal_wait_until(wg, heap, sig, 1, "ge", 1)
    assert ok and int(cur) == 1
    np.testing.assert_array_equal(np.asarray(heap.read(buf, 1)), 1.0)
    assert len(ctx.pending) == 0


def test_signal_wait_forces_minimal_prefix():
    """The device wait completes exactly the queue prefix through the first
    op that can advance the waited word — later traffic stays pending."""
    ctx, heap = context.init(npes=4, node_size=4)
    wg = device_mod.work_group(ctx, size=128, pe=0)
    a = heap.malloc((32,), jnp.float32)
    b = heap.malloc((32,), jnp.float32)
    c = heap.malloc((32,), jnp.float32)
    sig = heap.malloc((1,), jnp.int32)
    heap = device_mod.put_signal_nbi(wg, heap, a, jnp.full(32, 1.0), sig, 1,
                                     device_mod.SIGNAL_ADD, 1)
    heap = device_mod.put_signal_nbi(wg, heap, b, jnp.full(32, 2.0), sig, 1,
                                     device_mod.SIGNAL_ADD, 1)
    heap = device_mod.put_nbi(wg, heap, c, jnp.full(32, 3.0), 1)
    heap, cur, ok = device_mod.signal_wait_until(wg, heap, sig, 1, "ge", 1)
    assert ok and int(cur) == 1
    # first put+signal landed; the second pair and the trailing put did not
    np.testing.assert_array_equal(np.asarray(heap.read(a, 1)), 1.0)
    np.testing.assert_array_equal(np.asarray(heap.read(b, 1)), 0.0)
    np.testing.assert_array_equal(np.asarray(heap.read(c, 1)), 0.0)
    assert len(ctx.pending) > 0
    heap, cur, ok = device_mod.signal_wait_until(wg, heap, sig, 1, "ge", 2)
    assert ok and int(cur) == 2
    np.testing.assert_array_equal(np.asarray(heap.read(b, 1)), 2.0)
    np.testing.assert_array_equal(np.asarray(heap.read(c, 1)), 0.0)


def test_signal_wait_unsatisfiable_reports_not_ok():
    ctx, heap = context.init(npes=4, node_size=4)
    wg = device_mod.work_group(ctx, size=128, pe=0)
    sig = heap.malloc((1,), jnp.int32)
    other = heap.malloc((32,), jnp.float32)
    # nothing pending at all
    heap, cur, ok = device_mod.signal_wait_until(wg, heap, sig, 1, "ge", 1)
    assert not ok and int(cur) == 0
    # pending traffic that can never advance the waited word
    heap = device_mod.put_nbi(wg, heap, other, jnp.ones(32), 1)
    heap, cur, ok = device_mod.signal_wait_until(wg, heap, sig, 1, "ge", 1)
    assert not ok
    assert len(ctx.pending) > 0                     # unrelated op untouched


def test_broadcast_reduce_values_and_telemetry():
    ctx, heap = context.init(npes=4, node_size=4)
    wg = device_mod.work_group(ctx, size=256, pe=0)
    buf = heap.malloc((16,), jnp.float32)
    heap = heap.write(buf, 1, jnp.arange(16, dtype=jnp.float32))
    heap = device_mod.broadcast(wg, heap, buf, 1, ctx.team_world)
    for pe in range(4):
        np.testing.assert_array_equal(np.asarray(heap.read(buf, pe)),
                                      np.arange(16, dtype=np.float32))
    dest = heap.malloc((16,), jnp.float32)
    heap = device_mod.reduce(wg, heap, dest, buf, "sum", ctx.team_world)
    np.testing.assert_array_equal(np.asarray(heap.read(dest, 2)),
                                  4.0 * np.arange(16, dtype=np.float32))
    ops_seen = {r.op for r in ctx.ledger}
    assert {"device_broadcast", "device_reduce"} <= ops_seen
    # collectives scale with team size: excluded from the p2p profile fits
    assert not _is_p2p("device_broadcast")
    assert not _is_p2p("device_reduce")
    assert _is_p2p("device_put")
    assert not _is_p2p("device_put_nbi(pending)")


def test_device_put_feeds_work_group_resolved_cutovers():
    """A device.put sweep at two widths fits measured (tier, width) cutovers
    — the autotuner sees device ops at their own collaboration width."""
    from repro.core import rma
    ctx, heap = context.init(npes=4, node_size=4, heap_words=1 << 22)
    buf = heap.malloc((1 << 21,), jnp.float32)
    for wgs in (32, 512):
        wg = device_mod.work_group(ctx, size=wgs, pe=0)
        for lb in range(7, 24, 2):
            n = 1 << lb
            view = rma.SymPtr("float32", buf.offset, (n // 4,))
            heap = device_mod.put(wg, heap, view,
                                  jnp.zeros(n // 4, jnp.float32), 1)
    tbl = ctx.fit_tuning_table(arm=True)
    assert ("ici", 32) in tbl.cutovers
    assert ("ici", 512) in tbl.cutovers
    assert ctx.tuning.table is tbl                  # armed for choose_path


# ---------------------------------------------------------------------------
# 2. fused migration vs the pending-queue oracle
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(6, 20))
def test_fused_blocks_invisible_until_their_signal(S):
    """Property: after ``migrate_fused``, block k of the wire table reads
    zero decode-side until the per-block wait for ``sig >= EXTRA + k``
    completes — and admission itself consumes only the first block."""
    cfg, params, ctx, heap, eng, pool = _setup(max_slots=1)
    mig = KVMigrator(ctx, pool)
    tok, _, c1 = eng.prefill_request({"tokens": _prompt(cfg, S=S)},
                                     jax.random.key(3))
    heap, ids = mig.stage(heap, 0, c1, prompt_len=S, src_pe=0)
    heap, rep = mig.migrate_fused(heap, 0, src_pe=0, dst_pe=1, slot=0,
                                  prompt_len=S, first_token=tok)
    assert rep.fused and rep.n_wire == len(ids)
    assert rep.expected_signal == len(ids) + EXTRA_SIGNALS  # total unchanged
    for bid in ids:                       # everything still on the queue
        np.testing.assert_array_equal(
            np.asarray(heap.read(pool.block_ptr(bid), 1)), 0.0)
    heap, hdr, resident = mig.try_admit_fused(heap, 0, 1, rep.n_wire)
    assert hdr == {"req_id": 0, "prompt_len": S, "first_token": tok,
                   "n_blocks": len(ids)}
    assert resident == min(1, rep.n_wire)           # minimal-prefix admit
    sig = pool.sig_ptr(0)
    assert int(heap.read(sig, 1)) == fused_admit_signal(rep.n_wire)
    have = resident
    while have < len(ids):
        for bid in ids[have:]:            # unconsumed blocks stay invisible
            np.testing.assert_array_equal(
                np.asarray(heap.read(pool.block_ptr(bid), 1)), 0.0)
        heap, have = mig.consume_blocks(heap, 0, 1, have, have + 1)
        assert int(heap.read(sig, 1)) == EXTRA_SIGNALS + have
        for bid in ids[:have]:            # consumed blocks match the source
            np.testing.assert_array_equal(
                np.asarray(heap.read(pool.block_ptr(bid), 1)),
                np.asarray(heap.read(pool.block_ptr(bid), 0)))
    assert len(ctx.pending) == 0


# ---------------------------------------------------------------------------
# 3. fused paged attention — bitwise vs assemble + flash
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3_4b", "zamba2_2_7b",
                                  "whisper_medium"])
def test_fused_paged_attn_bitwise_vs_assemble(arch):
    """The kernel-level identity across dense / hybrid-SSM / enc-dec
    layouts: device-gathered K/V through the slot tables feeds the same
    flash kernel and reproduces assemble()'s leaves bit for bit."""
    cfg, params, ctx, heap, eng, pool = _setup(arch)
    sched = _sched(ctx, heap, eng, pool, decode_pes=[2], num_slots=2,
                   NEW=5, fused_attn=True)
    sched.submit(_req(cfg, _prompt(cfg, S=10)))
    guard = 0
    while not sched.stats.admissions and guard < 50:
        sched.step()
        guard += 1
    sched.step()                          # one decode: all blocks consumed
    view = sched.views[2]
    lay = pool.layout
    assert lay.paged
    assembled = view.assemble(sched.heap, sched.banks[2].cache)
    wg = device_mod.work_group(ctx, size=128, pe=2)
    for unit in sorted({p.unit_idx for p in lay.paged}):
        k_leaf = next(p for p in lay.paged
                      if p.unit_idx == unit and p.key == "k")
        q = jax.random.normal(
            jax.random.key(11),
            (view.num_slots, k_leaf.width, k_leaf.nkv, k_leaf.hd),
            jnp.float32)
        heap2, out = ops.fused_paged_attn(
            wg, sched.heap, view, q, unit_idx=unit,
            waits=[(pool.sig_ptr(0), EXTRA_SIGNALS)])
        k_ref = assembled["blocks"][unit]["k"][0]
        v_ref = assembled["blocks"][unit]["v"][0]
        ref = ops.flash_attention(q, k_ref, v_ref)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    sched.run()


def test_fused_paged_attn_refuses_unsatisfiable_wait():
    """The no-read-before-signal contract at the kernel boundary: a wait no
    pending traffic can satisfy raises before any block byte is read."""
    cfg, params, ctx, heap, eng, pool = _setup()
    view = PagedDecodeView(pool, pe=1, num_slots=1)
    wg = device_mod.work_group(ctx, size=128, pe=1)
    q = jnp.zeros((1, 4, 1, 8), jnp.float32)
    with pytest.raises(RuntimeError, match="never satisfy"):
        ops.fused_paged_attn(wg, heap, view, q,
                             waits=[(pool.sig_ptr(0), 5)])


def test_fused_scheduler_bitwise_and_first_block_stat():
    """fused_attn=True reproduces barrier mode's decode streams exactly
    (and the lockstep baseline), while time-to-first-resident-block lands
    strictly earlier than the barrier protocol's."""
    def serve(fused):
        cfg, params, ctx, heap, eng, pool = _setup()
        sched = _sched(ctx, heap, eng, pool, decode_pes=(2, 3), num_slots=2,
                       NEW=5, admit_delay_steps=2, fused_attn=fused)
        prompts = [_prompt(cfg, S=10, key=i) for i in range(4)]
        for p in prompts:
            sched.submit({"tokens": p})
        return cfg, eng, sched, prompts, sched.run()

    cfg, eng, s_b, prompts, outs_b = serve(False)
    _, _, s_f, _, outs_f = serve(True)
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(outs_b[i], outs_f[i])
        base = eng.generate({"tokens": p}, ServeConfig(max_new_tokens=5))
        np.testing.assert_array_equal(np.asarray(base[0]), outs_f[i])
    assert len(s_f.stats.ttfd_first_block_steps) == 4
    assert len(s_b.stats.ttfd_first_block_steps) == 4
    mean_f = np.mean(s_f.stats.ttfd_first_block_steps)
    mean_b = np.mean(s_b.stats.ttfd_first_block_steps)
    assert mean_f < mean_b                # per-block gate beats the barrier
    for req in s_f.requests.values():     # first block never after admission
        assert 0 <= req.first_block_step <= req.admit_step


def test_fused_attn_requires_paged_and_no_streaming():
    cfg, params, ctx, heap, eng, pool = _setup()
    with pytest.raises(ValueError, match="paged"):
        _sched(ctx, heap, eng, pool, fused_attn=True, paged=False)
    with pytest.raises(ValueError, match="stream"):
        _sched(ctx, heap, eng, pool, fused_attn=True, stream_chunks=1)


# ---------------------------------------------------------------------------
# 4. sequence-parallel ring attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("npes", [2, 4])
def test_ring_attention_matches_flash(npes):
    B, S, H, hd = 1, 128, 2, 16
    kq, kk, kv = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, hd), jnp.float32)
    ring = ops.ring_attention(q, k, v, npes=npes)
    ref = ops.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(ring), np.asarray(ref),
                               atol=5e-5, rtol=1e-5)


def test_flash_partial_merge_equals_full():
    """Splitting the KV sequence into shards, computing partials at their
    absolute offsets, and merging by the online-softmax combination equals
    attention over the whole sequence."""
    B, S, H, hd = 1, 64, 2, 16
    kq, kk, kv = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, hd), jnp.float32)
    half = S // 2
    parts = [
        ops.flash_partial(q, k[:, :half], v[:, :half], q_off=0, k_off=0),
        ops.flash_partial(q, k[:, half:], v[:, half:], q_off=0, k_off=half),
    ]
    merged = ops.merge_partials(parts)
    ref = ops.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref),
                               atol=5e-5, rtol=1e-5)
