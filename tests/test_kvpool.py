"""Paged KV pool: layout, block accounting, and lossless pack/unpack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.core import heap as heap_mod
from repro.models import kvcache, model
from repro.serve import kvpool


def _cfg(arch="qwen3_4b"):
    return cfgbase.reduced(cfgbase.get_config(arch))


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------


def test_layout_classifies_leaves():
    lay = kvpool.build_layout(_cfg(), 24, block_tokens=8)
    assert lay.blocks_per_request == 3
    assert {p.key for p in lay.paged} == {"k", "v"}
    assert lay.block_words == sum(p.words_per_token for p in lay.paged) * 8
    # hybrid: mamba/shared-attn states land in the tail
    lay_h = kvpool.build_layout(_cfg("zamba2_2_7b"), 24, block_tokens=8)
    assert lay_h.tail_words > 1
    assert any(t.key == "state" for t in lay_h.tail)
    # pure-SSM arch: no paged leaves, everything is tail
    lay_s = kvpool.build_layout(_cfg("xlstm_125m"), 24)
    assert not lay_s.paged and lay_s.tail_words > 1


def test_blocks_for_prompt_dense_prefix():
    lay = kvpool.build_layout(_cfg(), 32, block_tokens=8)
    assert lay.blocks_for_prompt(1) == 1
    assert lay.blocks_for_prompt(8) == 1
    assert lay.blocks_for_prompt(9) == 2
    assert lay.blocks_for_prompt(32) == 4
    assert lay.blocks_for_prompt(100) == 4     # clamped to cache width


# ---------------------------------------------------------------------------
# block accounting
# ---------------------------------------------------------------------------


def _pool(num_blocks=8, max_slots=2):
    h = heap_mod.create(npes=2)
    return h, kvpool.KVPool.create(h, _cfg(), 16, num_blocks=num_blocks,
                                   max_slots=max_slots, block_tokens=8)


def test_alloc_release_refcount():
    h, pool = _pool()
    a = pool.alloc(1, 3)
    assert a is not None and len(a) == 3
    assert pool.stats()["blocks_in_use"] == 3
    b = pool.alloc(2, 5)
    assert b is not None and not set(a) & set(b)
    assert pool.alloc(3, 1) is None            # exhausted -> caller queues
    pool.incref(a)                             # shared-prefix second reader
    pool.block_tables[3] = list(a)
    assert pool.release(1) == 0                # still referenced
    assert pool.stats()["blocks_in_use"] == 8
    assert pool.release(3) == 3                # last ref frees
    assert pool.release(2) == 5
    assert pool.stats()["blocks_free"] == 8


def test_double_alloc_and_bad_incref_raise():
    h, pool = _pool()
    pool.alloc(1, 2)
    with pytest.raises(ValueError):
        pool.alloc(1, 1)
    pool.release(1)
    with pytest.raises(ValueError):
        pool.incref([0])                       # block 0 is free again


def test_alloc_prefers_contiguous_ids():
    """Fresh pool hands out sorted contiguous ids — adjacent heap ranges, so
    the migration's nbi puts write-combine into one transfer."""
    h, pool = _pool()
    ids = pool.alloc(1, 4)
    assert ids == sorted(ids)
    assert all(b - a == 1 for a, b in zip(ids, ids[1:]))
    p0, p1 = pool.block_ptr(ids[0]), pool.block_ptr(ids[1])
    assert p1.offset == p0.offset + pool.layout.block_words


def test_block_ptr_bounds_and_symmetry():
    h, pool = _pool()
    with pytest.raises(IndexError):
        pool.block_ptr(pool.num_blocks)
    ptr = pool.block_ptr(0)
    h2 = h.write(ptr, 1, jnp.ones(pool.layout.block_words))
    assert float(h2.read(ptr, 1)[0]) == 1.0
    assert float(h2.read(ptr, 0)[0]) == 0.0    # other PE's row untouched


def test_pool_stats_report_heap():
    h, pool = _pool()
    s = pool.stats(h)
    assert s["heap"]["bytes_in_use"] > 0
    assert "fragmentation" in s["heap"]["pools"][pool.layout.kv_dtype]


# ---------------------------------------------------------------------------
# pack / unpack round trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3_4b", "zamba2_2_7b",
                                  "whisper_medium", "xlstm_125m"])
def test_pack_insert_roundtrip_bitwise(arch):
    """pack_blocks/pack_tail -> insert_blocks/insert_tail reproduces the
    prefilled request slice bit-for-bit in another slot of a bigger cache
    (the lossless-migration property every disagg guarantee rests on)."""
    cfg = _cfg(arch)
    W = 24
    lay = kvpool.build_layout(cfg, W, block_tokens=8)
    params = model.init_params(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (1, 10), 0,
                                          cfg.vocab_size)}
    if cfg.family == "audio":
        batch["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(2), (1, cfg.encoder_seq, cfg.d_model))
    c1 = kvcache.init_cache(cfg, 1, W)
    _, c1 = model.prefill(params, cfg, batch, c1)
    payloads = kvpool.pack_blocks(lay, c1)
    tail = kvpool.pack_tail(lay, c1)
    cB = kvcache.init_cache(cfg, 4, W)
    cB = kvpool.insert_blocks(lay, cB, 2, payloads)
    cB = kvpool.insert_tail(lay, cB, 2, tail)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(cB)):
        np.testing.assert_array_equal(np.asarray(a[:, 0]),
                                      np.asarray(b[:, 2]))


def test_partial_block_migration_prefix():
    """Dense cache: only blocks_for_prompt(S) blocks carry data; inserting
    just the prefix reproduces positions [0, S) exactly."""
    cfg = _cfg()
    W, S = 32, 9
    lay = kvpool.build_layout(cfg, W, block_tokens=8)
    need = lay.blocks_for_prompt(S)
    assert need == 2
    params = model.init_params(jax.random.key(0), cfg)
    c1 = kvcache.init_cache(cfg, 1, W)
    _, c1 = model.prefill(params, cfg, {"tokens": jax.random.randint(
        jax.random.key(1), (1, S), 0, cfg.vocab_size)}, c1)
    payloads = kvpool.pack_blocks(lay, c1, n_blocks=need)
    cB = kvcache.init_cache(cfg, 2, W)
    cB = kvpool.insert_blocks(lay, cB, 1, payloads)
    for pl in lay.paged:
        src = np.asarray(c1["blocks"][pl.unit_idx][pl.key][:, 0, :S])
        dst = np.asarray(cB["blocks"][pl.unit_idx][pl.key][:, 1, :S])
        np.testing.assert_array_equal(src, dst)


def test_tail_pack_lossless_int32_bitcast():
    """int32 values (ring kpos) survive the f32 tail round trip bit-exactly,
    including values a float cast would corrupt."""
    vals = jnp.asarray([[-1, 0, 1, (1 << 24) + 1, 2**31 - 1, -(2**31)]],
                       jnp.int32)
    packed = kvpool._pack_leaf_f32(vals)
    back = kvpool._unpack_leaf_f32(packed, vals.shape, "int32")
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(back))


def test_calloc_backed_pool_is_clean_after_heap_churn():
    """Pool regions come from calloc: even on a heap whose free list holds a
    dirty recycled extent, a new pool reads zero everywhere."""
    h = heap_mod.create(npes=2)
    junk = h.malloc((4096,), "float32")
    h = h.write(junk, 1, jnp.full(4096, 3.0))
    h.free(junk)
    pool = kvpool.KVPool.create(h, _cfg(), 16, num_blocks=4, max_slots=1,
                                block_tokens=8)
    # the small tail region is the one that first-fits into the dirty extent
    assert pool.tails.offset == junk.offset
    np.testing.assert_array_equal(
        np.asarray(h.read(pool.tail_ptr(0), 1)), 0.0)
    np.testing.assert_array_equal(
        np.asarray(h.read(pool.block_ptr(0), 1)), 0.0)
