"""Device-initiated ring collectives under shard_map (TPU interpret on CPU):
allclose vs the pure-jnp oracles across PE counts and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _drift import jax_drift_xfail
from repro.kernels import ops, ref

pytestmark = jax_drift_xfail


def _sm(mesh, f, ins, outs):
    from jax.sharding import PartitionSpec as P
    return jax.jit(jax.shard_map(f, mesh=mesh, in_specs=ins, out_specs=outs,
                                 check_vma=False))


@pytest.mark.parametrize("npes", [2, 4, 8])
def test_ring_allgather(npes):
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((npes,), ("x",), devices=jax.devices()[:npes])
    x = jax.random.normal(jax.random.key(0), (npes, 256))
    f = _sm(mesh, lambda v: ops.ring_allgather(
        v[0], axis_name="x", npes=npes)[None], P("x", None),
        P("x", None, None))
    np.testing.assert_allclose(np.asarray(f(x)),
                               np.asarray(ref.ring_allgather(x)), rtol=1e-6)


@pytest.mark.parametrize("npes", [2, 4, 8])
def test_ring_reduce_scatter(npes):
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((npes,), ("x",), devices=jax.devices()[:npes])
    xa = jax.random.normal(jax.random.key(1), (npes, npes, 128))
    f = _sm(mesh, lambda v: ops.ring_reduce_scatter(
        v[0], axis_name="x", npes=npes)[None], P("x", None, None),
        P("x", None))
    np.testing.assert_allclose(np.asarray(f(xa)),
                               np.asarray(ref.ring_reduce_scatter(xa)),
                               rtol=1e-5, atol=1e-5)


def test_ring_allreduce_8():
    from jax.sharding import PartitionSpec as P
    npes = 8
    mesh = jax.make_mesh((npes,), ("x",))
    xa = jax.random.normal(jax.random.key(2), (npes, npes, 128))
    f = _sm(mesh, lambda v: ops.ring_allreduce(
        v[0], axis_name="x", npes=npes)[None], P("x", None, None),
        P("x", None, None))
    out = f(xa)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(xa.sum(0))[None].repeat(npes, 0),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("root", [0, 3, 7])
def test_push_broadcast_roots(root):
    from jax.sharding import PartitionSpec as P
    npes = 8
    mesh = jax.make_mesh((npes,), ("x",))
    x = jax.random.normal(jax.random.key(3), (npes, 384))
    f = _sm(mesh, lambda v: ops.push_broadcast(
        v[0], axis_name="x", npes=npes, root=root)[None], P("x", None),
        P("x", None))
    np.testing.assert_allclose(np.asarray(f(x)),
                               np.asarray(ref.push_broadcast(x, root)),
                               rtol=1e-6)


def test_barrier_push():
    from jax.sharding import PartitionSpec as P
    npes = 8
    mesh = jax.make_mesh((npes,), ("x",))
    f = _sm(mesh, lambda: ops.barrier_push(axis_name="x", npes=npes),
            (), P("x"))
    assert f().tolist() == [1] * npes


@pytest.mark.parametrize("offset,w", [(1, 1), (3, 4)])
def test_remote_put_offsets(offset, w):
    from jax.sharding import PartitionSpec as P
    npes = 8
    mesh = jax.make_mesh((npes,), ("x",))
    x = jax.random.normal(jax.random.key(4), (npes, 256))
    f = _sm(mesh, lambda v: ops.remote_put(
        v[0], axis_name="x", npes=npes, target_offset=offset,
        work_items=w)[None], P("x", None), P("x", None))
    np.testing.assert_allclose(np.asarray(f(x)),
                               np.asarray(jnp.roll(x, offset, axis=0)),
                               rtol=1e-6)


def test_bf16_allgather():
    from jax.sharding import PartitionSpec as P
    npes = 4
    mesh = jax.make_mesh((npes,), ("x",), devices=jax.devices()[:npes])
    x = jax.random.normal(jax.random.key(5), (npes, 256)).astype(jnp.bfloat16)
    f = _sm(mesh, lambda v: ops.ring_allgather(
        v[0], axis_name="x", npes=npes)[None], P("x", None),
        P("x", None, None))
    np.testing.assert_array_equal(
        np.asarray(f(x), np.float32),
        np.asarray(ref.ring_allgather(x), np.float32))
