"""The dry-run machinery itself, exercised at test scale: reduced configs on
a 2x4 mesh of the 8 host devices (the production 512-device sweep is
repro.launch.dryrun, whose results live in experiments/dryrun)."""
import jax
import jax.numpy as jnp
import pytest

from _drift import jax_drift_skip
from repro.configs import base as cfgbase
from repro.launch import dryrun, mesh as mesh_mod, sharding, shardctx


def _small_shape(kind):
    if kind == "train":
        return cfgbase.ShapeSpec("t", "train", 64, 8)
    if kind == "prefill":
        return cfgbase.ShapeSpec("p", "prefill", 64, 8)
    return cfgbase.ShapeSpec("d", "decode", 64, 8)


@jax_drift_skip           # lowered steps hit the pallas interpret drift
@pytest.mark.parametrize("arch", ["qwen3_4b", "llama4_scout_17b_a16e",
                                  "zamba2_2_7b", "whisper_medium"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_build_and_compile_reduced(arch, kind, mesh2x4):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    shape = _small_shape(kind)
    with mesh2x4, shardctx.rules(sharding.activation_rules(cfg, mesh2x4)):
        fn, args = dryrun.build_step(cfg, shape, mesh2x4)
        compiled = fn.lower(*args).compile()
    assert compiled.memory_analysis() is not None
    cost = compiled.cost_analysis()
    assert cost["flops"] > 0


def test_model_flops_formula():
    cfg = cfgbase.get_config("arctic_480b")
    tr = cfgbase.SHAPES["train_4k"]
    de = cfgbase.SHAPES["decode_32k"]
    # MoE: active < total params; train uses 6ND on active
    assert cfg.param_count(active_only=True) < cfg.param_count()
    assert dryrun.model_flops(cfg, tr) == pytest.approx(
        6.0 * cfg.param_count(active_only=True) * tr.global_batch * tr.seq_len)
    assert dryrun.model_flops(cfg, de) == pytest.approx(
        2.0 * cfg.param_count(active_only=True) * de.global_batch)


def test_long500k_skip_rule():
    long = cfgbase.SHAPES["long_500k"]
    runs = [a for a in cfgbase.ARCH_NAMES
            if cfgbase.shape_applicable(cfgbase.get_config(a), long)]
    assert sorted(runs) == sorted(
        ["h2o_danube_3_4b", "xlstm_125m", "zamba2_2_7b"])


def test_production_mesh_shapes():
    # shape math only (512 devices are only forced inside dryrun's process)
    import numpy as np
    assert mesh_mod.batch_axes.__call__  # smoke: function exists
    # the dryrun artifacts must cover every non-skipped pair
    import glob, json, os
    arts = glob.glob("experiments/dryrun/*.pod1.json")
    if arts:   # present once the sweep has run
        ok = [json.load(open(a)) for a in arts]
        assert all(r["status"] == "ok" or r["status"].startswith("skipped")
                   for r in ok)
