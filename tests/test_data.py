import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, TokenStream


def test_determinism():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    b1 = s1.batch(17)
    b2 = s2.batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = s1.batch(18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4)
    b = TokenStream(cfg).batch(0)
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_host_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    s = TokenStream(cfg)
    parts = [s.batch(5, host_index=h, num_hosts=4) for h in range(4)]
    for p in parts:
        assert p["tokens"].shape == (2, 16)
    # hosts produce distinct slices
    assert not np.array_equal(np.asarray(parts[0]["tokens"]),
                              np.asarray(parts[1]["tokens"]))


def test_zipf_marginal_is_skewed():
    cfg = DataConfig(vocab_size=5000, seq_len=256, global_batch=16)
    b = TokenStream(cfg).batch(0)
    toks = np.asarray(b["tokens"]).ravel()
    assert toks.min() >= 0 and toks.max() < 5000
    # low-rank tokens dominate
    assert (toks < 50).mean() > 0.3


def test_frontend_stub_shapes():
    from repro.configs import base as cfgbase
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
    s = TokenStream(cfg)
    wcfg = cfgbase.reduced(cfgbase.get_config("whisper_medium"))
    fe = s.frontend(0, wcfg, 4)
    assert fe["audio_embeds"].shape == (4, wcfg.encoder_seq, wcfg.d_model)
    vcfg = cfgbase.reduced(cfgbase.get_config("llama_3_2_vision_90b"))
    fe = s.frontend(0, vcfg, 4)
    assert fe["image_embeds"].shape == (4, vcfg.image_tokens, vcfg.d_model)
