import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import context, cutover, rma


@pytest.fixture()
def ctxheap():
    return context.init(npes=8, node_size=4)


def test_put_get_roundtrip(ctxheap):
    ctx, heap = ctxheap
    p = heap.malloc((64,), "float32")
    v = jnp.arange(64, dtype=jnp.float32)
    heap = rma.put(ctx, heap, p, v, 5)
    np.testing.assert_array_equal(np.asarray(rma.get(ctx, heap, p, 5)), v)
    # other PEs untouched (one-sided semantics)
    assert float(rma.get(ctx, heap, p, 4).sum()) == 0.0


def test_scalar_p_g(ctxheap):
    ctx, heap = ctxheap
    p = heap.malloc((4,), "int32")
    heap = rma.p(ctx, heap, p.index(2), 42, 1)
    assert int(rma.g(ctx, heap, p.index(2), 1)) == 42
    assert ctx.ledger[-1].path == "direct"      # scalar put = remote store


def test_strided_iput_iget(ctxheap):
    ctx, heap = ctxheap
    p = heap.malloc((16,), "float32")
    heap = rma.iput(ctx, heap, p, jnp.arange(8.0), 2, dst_stride=2,
                    src_stride=1, nelems=8)
    out = rma.get(ctx, heap, p, 2)
    np.testing.assert_array_equal(np.asarray(out[::2]), np.arange(8.0))
    got = rma.iget(ctx, heap, p, 2, src_stride=2, nelems=8)
    np.testing.assert_array_equal(np.asarray(got), np.arange(8.0))


def test_path_selection_small_vs_large(ctxheap):
    ctx, heap = ctxheap
    small = heap.malloc((32,), "float32")       # 128 B -> direct
    large = heap.malloc((1 << 20,), "float32")  # 4 MB -> engine
    heap = rma.put(ctx, heap, small, jnp.zeros(32), 1, work_items=1)
    assert ctx.ledger[-1].path == "direct"
    heap = rma.put(ctx, heap, large, jnp.zeros(1 << 20), 1, work_items=1)
    assert ctx.ledger[-1].path == "engine"


def test_work_group_extends_cutover(ctxheap):
    """Paper Fig. 4a: more work-items keep the direct path competitive for
    larger messages."""
    ctx, heap = ctxheap
    buf = heap.malloc((1 << 15,), "float32")    # 128 KB
    heap = rma.put(ctx, heap, buf, jnp.zeros(1 << 15), 1, work_items=1)
    path_1wi = ctx.ledger[-1].path
    heap = rma.put(ctx, heap, buf, jnp.zeros(1 << 15), 1, work_items=1024)
    path_1024wi = ctx.ledger[-1].path
    assert path_1wi == "engine" and path_1024wi == "direct"


def test_cross_node_uses_proxy(ctxheap):
    ctx, heap = ctxheap                          # node_size=4
    p = heap.malloc((32,), "float32")
    heap = rma.put(ctx, heap, p, jnp.ones(32), 7, src_pe=0)
    assert ctx.ledger[-1].tier == "dcn"
    assert ctx.ledger[-1].path == "proxy"


def test_nbi_quiet(ctxheap):
    ctx, heap = ctxheap
    p = heap.malloc((32,), "float32")
    heap = rma.put_nbi(ctx, heap, p, jnp.ones(32), 3)
    assert ctx.ledger[-1].op == "put_nbi(pending)"
    heap = rma.quiet(ctx, heap)
    assert any(r.op == "put_nbi" for r in ctx.ledger)


def test_force_path_tuning():
    ctx, heap = context.init(npes=4, tuning=cutover.Tuning(force_path="engine"))
    p = heap.malloc((32,), "float32")
    heap = rma.put(ctx, heap, p, jnp.ones(32), 1)
    assert ctx.ledger[-1].path == "engine"


def test_kernel_backed_put():
    ctx, heap = context.init(npes=4, use_kernels=True)
    p = heap.malloc((256,), "float32")
    v = jnp.arange(256, dtype=jnp.float32)
    heap = rma.put(ctx, heap, p, v, 2)
    np.testing.assert_array_equal(np.asarray(heap.read(p, 2)), v)
