import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models import model
from repro.serve.engine import Engine, ServeConfig


def _engine(arch, max_len=40):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    params = model.init_params(jax.random.key(0), cfg)
    return cfg, params, Engine(cfg, params, max_len=max_len)


def test_greedy_is_deterministic():
    cfg, params, eng = _engine("qwen3_4b")
    batch = {"tokens": jax.random.randint(jax.random.key(1), (3, 16), 0,
                                          cfg.vocab_size)}
    o1 = eng.generate(batch, ServeConfig(max_new_tokens=8))
    o2 = eng.generate(batch, ServeConfig(max_new_tokens=8))
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert o1.shape == (3, 8)


def test_greedy_matches_teacher_forcing():
    """Feeding the greedy continuation back through prefill reproduces the
    same next-token choices (cache path == full path)."""
    cfg, params, eng = _engine("h2o_danube_3_4b")
    B, S, NEW = 2, 12, 6
    prompt = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    out = eng.generate({"tokens": prompt}, ServeConfig(max_new_tokens=NEW))
    # teacher-force: prefill(prompt + out[:k]) must predict out[k]
    from repro.models import kvcache
    for k in range(1, NEW):
        full = jnp.concatenate([prompt, out[:, :k]], axis=1)
        cache = kvcache.init_cache(cfg, B, full.shape[1])
        logits, _ = model.prefill(params, cfg, {"tokens": full}, cache)
        np.testing.assert_array_equal(
            np.asarray(jnp.argmax(logits, -1)), np.asarray(out[:, k]))


def test_sampled_generation_with_temperature():
    cfg, params, eng = _engine("xlstm_125m")
    batch = {"tokens": jax.random.randint(jax.random.key(3), (2, 10), 0,
                                          cfg.vocab_size)}
    out = eng.generate(batch, ServeConfig(max_new_tokens=6, temperature=1.0,
                                          seed=1))
    assert out.shape == (2, 6)
    assert int(out.max()) < cfg.vocab_size


def test_eos_stops_output():
    cfg, params, eng = _engine("qwen3_4b")
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    out0 = eng.generate(batch, ServeConfig(max_new_tokens=5))
    eos = int(out0[0, 0])                       # force first token as EOS
    out = eng.generate(batch, ServeConfig(max_new_tokens=5, eos_id=eos))
    assert out.shape == (1, 5)
    np.testing.assert_array_equal(np.asarray(out[0, 1:]), 0)


def test_cache_too_small_raises():
    cfg, params, eng = _engine("qwen3_4b", max_len=10)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    with pytest.raises(AssertionError):
        eng.generate(batch, ServeConfig(max_new_tokens=5))


def test_hybrid_and_encdec_serve():
    for arch in ("zamba2_2_7b", "whisper_medium"):
        cfg, params, eng = _engine(arch)
        batch = {"tokens": jax.random.randint(jax.random.key(4), (2, 8), 0,
                                              cfg.vocab_size)}
        if cfg.family == "audio":
            batch["audio_embeds"] = 0.1 * jax.random.normal(
                jax.random.key(5), (2, cfg.encoder_seq, cfg.d_model))
        out = eng.generate(batch, ServeConfig(max_new_tokens=4))
        assert out.shape == (2, 4)
