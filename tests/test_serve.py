import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models import model
from repro.serve.engine import Engine, ServeConfig


def _engine(arch, max_len=40):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    params = model.init_params(jax.random.key(0), cfg)
    return cfg, params, Engine(cfg, params, max_len=max_len)


def test_greedy_is_deterministic():
    cfg, params, eng = _engine("qwen3_4b")
    batch = {"tokens": jax.random.randint(jax.random.key(1), (3, 16), 0,
                                          cfg.vocab_size)}
    o1 = eng.generate(batch, ServeConfig(max_new_tokens=8))
    o2 = eng.generate(batch, ServeConfig(max_new_tokens=8))
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert o1.shape == (3, 8)


def test_greedy_matches_teacher_forcing():
    """Feeding the greedy continuation back through prefill reproduces the
    same next-token choices (cache path == full path)."""
    cfg, params, eng = _engine("h2o_danube_3_4b")
    B, S, NEW = 2, 12, 6
    prompt = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    out = eng.generate({"tokens": prompt}, ServeConfig(max_new_tokens=NEW))
    # teacher-force: prefill(prompt + out[:k]) must predict out[k]
    from repro.models import kvcache
    for k in range(1, NEW):
        full = jnp.concatenate([prompt, out[:, :k]], axis=1)
        cache = kvcache.init_cache(cfg, B, full.shape[1])
        logits, _ = model.prefill(params, cfg, {"tokens": full}, cache)
        np.testing.assert_array_equal(
            np.asarray(jnp.argmax(logits, -1)), np.asarray(out[:, k]))


def test_sampled_generation_with_temperature():
    cfg, params, eng = _engine("xlstm_125m")
    batch = {"tokens": jax.random.randint(jax.random.key(3), (2, 10), 0,
                                          cfg.vocab_size)}
    out = eng.generate(batch, ServeConfig(max_new_tokens=6, temperature=1.0,
                                          seed=1))
    assert out.shape == (2, 6)
    assert int(out.max()) < cfg.vocab_size


def test_eos_stops_output():
    cfg, params, eng = _engine("qwen3_4b")
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    out0 = eng.generate(batch, ServeConfig(max_new_tokens=5))
    eos = int(out0[0, 0])                       # force first token as EOS
    out = eng.generate(batch, ServeConfig(max_new_tokens=5, eos_id=eos))
    assert out.shape == (1, 5)
    np.testing.assert_array_equal(np.asarray(out[0, 1:]), 0)


def test_cache_too_small_raises():
    cfg, params, eng = _engine("qwen3_4b", max_len=10)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    with pytest.raises(AssertionError):
        eng.generate(batch, ServeConfig(max_new_tokens=5))


def test_slot_rotation_mid_flight():
    """Slot API: a request admitted into a slot AFTER other requests have
    been decoding (and one evicted) produces the same tokens as its own
    lockstep generate — rotation does not perturb resident numerics."""
    import numpy as np
    from repro.models import kvcache
    cfg, params, eng = _engine("qwen3_4b", max_len=30)
    S, NEW = 10, 5
    prompts = [jax.random.randint(jax.random.fold_in(jax.random.key(6), i),
                                  (1, S), 0, cfg.vocab_size)
               for i in range(3)]
    base = [eng.generate({"tokens": p}, ServeConfig(max_new_tokens=NEW))
            for p in prompts]

    slots = eng.init_slots(2)
    outs = {0: [], 1: [], 2: []}

    def admit(slots, slot, rid):
        tok, _, c1 = eng.prefill_request({"tokens": prompts[rid]},
                                         jax.random.key(0))
        # direct cache hand-off (local prefill->decode, no migration)
        from repro.serve import kvpool
        lay = kvpool.build_layout(cfg, eng.max_len)
        cache = kvpool.insert_blocks(lay, slots.cache, slot,
                                     kvpool.pack_blocks(lay, c1))
        cache = kvpool.insert_tail(lay, cache, slot,
                                   kvpool.pack_tail(lay, c1))
        import dataclasses as dc
        slots = dc.replace(slots, cache=cache)
        outs[rid].append(tok)
        return eng.activate_slot(slots, slot, pos=S, token=tok)

    slots = admit(slots, 0, 0)
    slots = admit(slots, 1, 1)
    resident = {0: 0, 1: 1}
    for step in range(20):
        if not slots.active.any():
            break
        slots, toks = eng.decode_slots(slots, jax.random.key(step))
        for s, rid in list(resident.items()):
            outs[rid].append(int(toks[s]))
            if len(outs[rid]) >= NEW:
                slots = eng.evict_slot(slots, s)
                del resident[s]
                if rid == 0:                   # rotate request 2 in mid-flight
                    slots = admit(slots, s, 2)
                    resident[s] = 2
    for rid in range(3):
        np.testing.assert_array_equal(np.asarray(base[rid][0]),
                                      np.asarray(outs[rid][:NEW]))


def test_hybrid_and_encdec_serve():
    for arch in ("zamba2_2_7b", "whisper_medium"):
        cfg, params, eng = _engine(arch)
        batch = {"tokens": jax.random.randint(jax.random.key(4), (2, 8), 0,
                                              cfg.vocab_size)}
        if cfg.family == "audio":
            batch["audio_embeds"] = 0.1 * jax.random.normal(
                jax.random.key(5), (2, cfg.encoder_seq, cfg.d_model))
        out = eng.generate(batch, ServeConfig(max_new_tokens=4))
        assert out.shape == (2, 4)
