import pytest

from repro.core import teams


def test_world_and_translate():
    t = teams.world(8)
    assert t.pes() == list(range(8))
    assert t.translate(3) == 3
    assert t.rank_of(5) == 5


def test_strided_team():
    t = teams.Team(1, 2, 4)                    # PEs 1,3,5,7
    assert t.pes() == [1, 3, 5, 7]
    assert t.translate(2) == 5
    assert t.rank_of(7) == 3
    assert t.rank_of(2) == -1
    assert t.rank_of(9) == -1


def test_split_strided():
    t = teams.world(16)
    child = t.split_strided(0, 2, 8)
    assert child.pes() == [0, 2, 4, 6, 8, 10, 12, 14]
    grand = child.split_strided(1, 2, 4)
    assert grand.pes() == [2, 6, 10, 14]
    with pytest.raises(ValueError):
        child.split_strided(0, 4, 4)


def test_shared_team():
    t = teams.shared(12, node_size=4, node_id=2)
    assert t.pes() == [8, 9, 10, 11]
    with pytest.raises(ValueError):
        teams.shared(12, node_size=4, node_id=3)


def test_translate_bounds():
    t = teams.Team(0, 1, 4)
    with pytest.raises(ValueError):
        t.translate(4)
