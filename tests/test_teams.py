import pytest

from repro.core import teams


def test_world_and_translate():
    t = teams.world(8)
    assert t.pes() == list(range(8))
    assert t.translate(3) == 3
    assert t.rank_of(5) == 5


def test_strided_team():
    t = teams.Team(1, 2, 4)                    # PEs 1,3,5,7
    assert t.pes() == [1, 3, 5, 7]
    assert t.translate(2) == 5
    assert t.rank_of(7) == 3
    assert t.rank_of(2) == -1
    assert t.rank_of(9) == -1


def test_split_strided():
    t = teams.world(16)
    child = t.split_strided(0, 2, 8)
    assert child.pes() == [0, 2, 4, 6, 8, 10, 12, 14]
    grand = child.split_strided(1, 2, 4)
    assert grand.pes() == [2, 6, 10, 14]
    with pytest.raises(ValueError):
        child.split_strided(0, 4, 4)


def test_shared_team():
    t = teams.shared(12, node_size=4, node_id=2)
    assert t.pes() == [8, 9, 10, 11]
    with pytest.raises(ValueError):
        teams.shared(12, node_size=4, node_id=3)


def test_translate_bounds():
    t = teams.Team(0, 1, 4)
    with pytest.raises(ValueError):
        t.translate(4)


def test_split_strided_bounds():
    t = teams.world(8)
    with pytest.raises(ValueError):
        t.split_strided(-1, 1, 4)              # negative start
    with pytest.raises(ValueError):
        t.split_strided(0, 0, 4)               # zero stride
    with pytest.raises(ValueError):
        t.split_strided(0, 1, 0)               # empty child
    with pytest.raises(ValueError):
        t.split_strided(7, 1, 2)               # last rank off the end
    # exactly-fitting child is legal
    assert t.split_strided(4, 1, 4).pes() == [4, 5, 6, 7]
    assert t.split_strided(7, 1, 1).pes() == [7]


def test_rank_of_non_members():
    t = teams.Team(2, 3, 3)                    # PEs 2, 5, 8
    assert [t.rank_of(p) for p in t.pes()] == [0, 1, 2]
    assert t.rank_of(1) == -1                  # below start
    assert t.rank_of(-4) == -1                 # negative, stride-aligned
    assert t.rank_of(3) == -1                  # off-stride
    assert t.rank_of(11) == -1                 # stride-aligned but past end
    assert t.rank_of(100) == -1


def test_disagg_partition_world():
    pre, dec = teams.disagg_partition(teams.world(8), 3)
    assert pre.pes() == [0, 1, 2]
    assert dec.pes() == [3, 4, 5, 6, 7]
    # partitions tile the parent with no overlap
    assert sorted(pre.pes() + dec.pes()) == list(range(8))
    assert all(dec.rank_of(p) == -1 for p in pre.pes())
    for bad in (0, 8, -1):
        with pytest.raises(ValueError):
            teams.disagg_partition(teams.world(8), bad)


def test_disagg_partition_on_shared_pod():
    """The serve launcher's intra-pod split: TEAM_SHARED of pod 1, first half
    prefill, second half decode — world PE numbering must be preserved."""
    pod = teams.shared(16, node_size=8, node_id=1)     # PEs 8..15
    pre, dec = teams.disagg_partition(pod, 4)
    assert pre.pes() == [8, 9, 10, 11]
    assert dec.pes() == [12, 13, 14, 15]
    assert pre.translate(0) == 8 and dec.translate(0) == 12
    assert pre.rank_of(12) == -1 and dec.rank_of(11) == -1


def test_pods_partition_three_pods():
    """The fleet topology: >2 contiguous pods tile the world, each further
    disagg-partitionable into its prefill/decode fleets."""
    pods = teams.pods_partition(teams.world(9), [3, 3, 3])
    assert [p.pes() for p in pods] == [[0, 1, 2], [3, 4, 5], [6, 7, 8]]
    fleets = [teams.disagg_partition(p, 1) for p in pods]
    assert [pre.pes() for pre, _ in fleets] == [[0], [3], [6]]
    assert [dec.pes() for _, dec in fleets] == [[1, 2], [4, 5], [7, 8]]
    # no pod sees another pod's PEs
    for i, p in enumerate(pods):
        for j, q in enumerate(pods):
            if i != j:
                assert all(p.rank_of(pe) == -1 for pe in q.pes())


def test_pods_partition_uneven_and_partial():
    """Uneven pod sizes (a fat prefill pod + thin decode pods) are legal,
    as is leaving trailing PEs unassigned; uneven prefill/decode splits
    inside each pod compose on top."""
    pods = teams.pods_partition(teams.world(10), [5, 2, 2])   # PE 9 spare
    assert [p.size for p in pods] == [5, 2, 2]
    assert pods[2].pes() == [7, 8]
    pre, dec = teams.disagg_partition(pods[0], 4)             # 4P + 1D
    assert pre.pes() == [0, 1, 2, 3] and dec.pes() == [4]
    pre, dec = teams.disagg_partition(pods[1], 1)             # 1P + 1D
    assert pre.pes() == [5] and dec.pes() == [6]


def test_pods_partition_rejects_bad_shapes():
    with pytest.raises(ValueError):
        teams.pods_partition(teams.world(8), [])               # no pods
    with pytest.raises(ValueError):
        teams.pods_partition(teams.world(8), [4, 0])           # empty pod
    with pytest.raises(ValueError):
        teams.pods_partition(teams.world(8), [5, 4])           # overflow
    with pytest.raises(ValueError):
        teams.pods_partition(teams.world(8), [-2, 4])          # negative
    # a pod team of size 1 cannot be disagg-partitioned (needs both fleets)
    solo = teams.pods_partition(teams.world(4), [1, 3])[0]
    with pytest.raises(ValueError):
        teams.disagg_partition(solo, 1)
