import pytest

from repro.core import teams


def test_world_and_translate():
    t = teams.world(8)
    assert t.pes() == list(range(8))
    assert t.translate(3) == 3
    assert t.rank_of(5) == 5


def test_strided_team():
    t = teams.Team(1, 2, 4)                    # PEs 1,3,5,7
    assert t.pes() == [1, 3, 5, 7]
    assert t.translate(2) == 5
    assert t.rank_of(7) == 3
    assert t.rank_of(2) == -1
    assert t.rank_of(9) == -1


def test_split_strided():
    t = teams.world(16)
    child = t.split_strided(0, 2, 8)
    assert child.pes() == [0, 2, 4, 6, 8, 10, 12, 14]
    grand = child.split_strided(1, 2, 4)
    assert grand.pes() == [2, 6, 10, 14]
    with pytest.raises(ValueError):
        child.split_strided(0, 4, 4)


def test_shared_team():
    t = teams.shared(12, node_size=4, node_id=2)
    assert t.pes() == [8, 9, 10, 11]
    with pytest.raises(ValueError):
        teams.shared(12, node_size=4, node_id=3)


def test_translate_bounds():
    t = teams.Team(0, 1, 4)
    with pytest.raises(ValueError):
        t.translate(4)


def test_split_strided_bounds():
    t = teams.world(8)
    with pytest.raises(ValueError):
        t.split_strided(-1, 1, 4)              # negative start
    with pytest.raises(ValueError):
        t.split_strided(0, 0, 4)               # zero stride
    with pytest.raises(ValueError):
        t.split_strided(0, 1, 0)               # empty child
    with pytest.raises(ValueError):
        t.split_strided(7, 1, 2)               # last rank off the end
    # exactly-fitting child is legal
    assert t.split_strided(4, 1, 4).pes() == [4, 5, 6, 7]
    assert t.split_strided(7, 1, 1).pes() == [7]


def test_rank_of_non_members():
    t = teams.Team(2, 3, 3)                    # PEs 2, 5, 8
    assert [t.rank_of(p) for p in t.pes()] == [0, 1, 2]
    assert t.rank_of(1) == -1                  # below start
    assert t.rank_of(-4) == -1                 # negative, stride-aligned
    assert t.rank_of(3) == -1                  # off-stride
    assert t.rank_of(11) == -1                 # stride-aligned but past end
    assert t.rank_of(100) == -1


def test_disagg_partition_world():
    pre, dec = teams.disagg_partition(teams.world(8), 3)
    assert pre.pes() == [0, 1, 2]
    assert dec.pes() == [3, 4, 5, 6, 7]
    # partitions tile the parent with no overlap
    assert sorted(pre.pes() + dec.pes()) == list(range(8))
    assert all(dec.rank_of(p) == -1 for p in pre.pes())
    for bad in (0, 8, -1):
        with pytest.raises(ValueError):
            teams.disagg_partition(teams.world(8), bad)


def test_disagg_partition_on_shared_pod():
    """The serve launcher's intra-pod split: TEAM_SHARED of pod 1, first half
    prefill, second half decode — world PE numbering must be preserved."""
    pod = teams.shared(16, node_size=8, node_id=1)     # PEs 8..15
    pre, dec = teams.disagg_partition(pod, 4)
    assert pre.pes() == [8, 9, 10, 11]
    assert dec.pes() == [12, 13, 14, 15]
    assert pre.translate(0) == 8 and dec.translate(0) == 12
    assert pre.rank_of(12) == -1 and dec.rank_of(11) == -1
