"""Numerical equivalence: shmem (device-initiated Pallas) backend == xla
(lax collectives) backend for every collective the models consume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _drift import jax_drift_xfail
from repro.comms import api
from repro.core import cutover

pytestmark = jax_drift_xfail

NPES = 8


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((NPES,), ("x",))


def _pair(mesh, fn_shmem, fn_xla, ins, outs, *args):
    f1 = jax.jit(jax.shard_map(fn_shmem, mesh=mesh, in_specs=ins,
                               out_specs=outs, check_vma=False))
    f2 = jax.jit(jax.shard_map(fn_xla, mesh=mesh, in_specs=ins,
                               out_specs=outs, check_vma=False))
    return f1(*args), f2(*args)


def test_psum_large(mesh):
    shmem = api.get_ops("shmem", npes=NPES)
    xla = api.get_ops("xla")
    x = jax.random.normal(jax.random.key(0), (NPES, 4, 512))
    a, b = _pair(mesh, lambda v: shmem.psum(v[0], "x")[None],
                 lambda v: xla.psum(v[0], "x")[None],
                 P("x", None, None), P("x", None, None), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_psum_small_uses_dup_compute(mesh):
    """Small messages take the paper's fcollect+local-reduce strategy."""
    shmem = api.get_ops("shmem", npes=NPES)
    xla = api.get_ops("xla")
    x = jax.random.normal(jax.random.key(1), (NPES, 64))
    a, b = _pair(mesh, lambda v: shmem.psum(v[0], "x")[None],
                 lambda v: xla.psum(v[0], "x")[None],
                 P("x", None), P("x", None), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_psum_overlap_matches_xla(mesh):
    """The nbi ring step (pass-around allreduce with compute off the
    transfer chain) is numerically identical to lax.psum — both the small
    (pass-around) and large (chunked RS+AG) branches."""
    shmem = api.get_ops("shmem", npes=NPES)
    xla = api.get_ops("xla")
    for shape in ((NPES, 64), (NPES, 64, 1024)):       # both branches
        x = jax.random.normal(jax.random.key(7), shape)
        a, b = _pair(mesh, lambda v: shmem.psum_overlap(v[0], "x")[None],
                     lambda v: xla.psum(v[0], "x")[None],
                     P(*("x",) + (None,) * (len(shape) - 1)),
                     P(*("x",) + (None,) * (len(shape) - 1)), x)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_all_gather(mesh):
    shmem = api.get_ops("shmem", npes=NPES)
    xla = api.get_ops("xla")
    x = jax.random.normal(jax.random.key(2), (NPES, 256))
    a, b = _pair(mesh, lambda v: shmem.all_gather(v[0], "x")[None],
                 lambda v: xla.all_gather(v[0], "x")[None],
                 P("x", None), P("x", None, None), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_reduce_scatter(mesh):
    shmem = api.get_ops("shmem", npes=NPES)
    xla = api.get_ops("xla")
    x = jax.random.normal(jax.random.key(3), (NPES, NPES, 128))
    a, b = _pair(mesh, lambda v: shmem.reduce_scatter(v[0], "x")[None],
                 lambda v: xla.reduce_scatter(v[0], "x")[None],
                 P("x", None, None), P("x", None), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_broadcast(mesh):
    shmem = api.get_ops("shmem", npes=NPES)
    xla = api.get_ops("xla")
    x = jax.random.normal(jax.random.key(4), (NPES, 256))
    a, b = _pair(mesh, lambda v: shmem.broadcast(v[0], "x", root=5)[None],
                 lambda v: xla.broadcast(v[0], "x", root=5)[None],
                 P("x", None), P("x", None), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_tp_layer_end_to_end(mesh):
    """A Megatron-style TP FFN using each backend: y = psum((x @ w1) @ w2)."""
    d, ff = 128, 512
    w1 = jax.random.normal(jax.random.key(5), (NPES, d, ff // NPES)) * 0.05
    w2 = jax.random.normal(jax.random.key(6), (NPES, ff // NPES, d)) * 0.05
    x = jax.random.normal(jax.random.key(7), (4, d))

    def layer(ops_impl):
        def f(w1s, w2s):
            h = jax.nn.relu(x @ w1s[0])
            return ops_impl.psum(h @ w2s[0], "x")[None]
        return f

    shmem = api.get_ops("shmem", npes=NPES)
    xla = api.get_ops("xla")
    a, b = _pair(mesh, layer(shmem), layer(xla),
                 (P("x", None, None), P("x", None, None)),
                 P("x", None, None), w1, w2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4)
