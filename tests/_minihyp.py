"""Deterministic fallback for ``hypothesis`` on clean interpreters.

The property tests prefer real hypothesis when it is installed (see
``requirements.txt``); when it is missing this shim supplies the tiny subset
of the API they use — ``given``, ``settings`` and the ``integers`` /
``sampled_from`` / ``lists`` / ``tuples`` strategies — driven by a fixed-seed
PRNG plus boundary-value examples, so the suite still exercises the
properties instead of skipping six whole modules.  No shrinking, no database:
failures report the generated arguments in the assertion traceback.
"""
from __future__ import annotations

import functools
import inspect
import random

_SEED = 0x15836  # stable across runs: failures are reproducible


class _Strategy:
    def __init__(self, sample, corners=()):
        self._sample = sample
        self.corners = list(corners)

    def example(self, rnd):
        return self._sample(rnd)


class strategies:                                     # mirrors `hypothesis.strategies as st`
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value),
                         corners=[min_value, max_value])

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda r: r.choice(seq), corners=[seq[0], seq[-1]])

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        def sample(r):
            k = r.randint(min_size, max_size)
            return [elem.example(r) for _ in range(k)]
        corners = [[]] if min_size == 0 else []
        return _Strategy(sample, corners=corners)

    @staticmethod
    def tuples(*elems):
        return _Strategy(lambda r: tuple(e.example(r) for e in elems))


st = strategies


class settings:
    def __init__(self, max_examples=25, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        # decorator order is @settings above @given: fn is given()'s wrapper
        fn._minihyp_max_examples = self.max_examples
        return fn


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*fixture_args, **fixture_kwargs):
            rnd = random.Random(_SEED)
            n = getattr(wrapper, "_minihyp_max_examples", 25)
            for i in range(n):
                vals = []
                for s in strats:
                    if i < len(s.corners):             # boundary values first
                        vals.append(s.corners[i])
                    else:
                        vals.append(s.example(rnd))
                fn(*fixture_args, *vals, **fixture_kwargs)
        # hide the strategy-filled trailing params from pytest's fixture
        # resolution (hypothesis fills positional args right-to-left)
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        visible = params[: len(params) - len(strats)] if strats else params
        wrapper.__signature__ = sig.replace(parameters=visible)
        del wrapper.__wrapped__                        # signature wins
        return wrapper
    return deco
