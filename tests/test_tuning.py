"""Acceptance tests for the online autotuning subsystem (repro.tune):

- telemetry aggregation + bounded memory,
- estimator recovery of alpha/bw from synthetic op_time samples (<=10% error),
- learned-table vs analytic choose_path agreement (>=95% of the grid),
- TuningTable JSON round-trip + merge,
- ISHMEM_* env-var surface feeding cutover.Tuning / context.init,
- benchmarks profile mode emitting a valid BENCH_cutover.json.
"""
import json
import math

import pytest

from repro.core import context, cutover
from repro.tune import env as env_mod, estimator, table as table_mod, telemetry

HW = cutover.HwParams()
WORK_ITEMS = (1, 16, 128, 1024)


def _fitted_table(noise=0.0):
    sink = estimator.synthetic_sweep(HW, work_items=WORK_ITEMS, noise=noise)
    return estimator.build_table(sink)


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


def test_sink_aggregates_by_key():
    sink = telemetry.TelemetrySink()
    for i in range(10):
        sink.record(telemetry.OpRecord("put", 1024, "direct", "ici", 1e-6, 16))
    sink.record(telemetry.OpRecord("put", 2048, "engine", "ici", 2e-6, 16))
    b = sink.buckets[("put", "direct", "ici", 16)]
    assert b.count == 10 and b.bytes_total == 10240
    assert sink.total_count() == 11
    assert sink.total_time() == pytest.approx(10 * 1e-6 + 2e-6)
    assert sink.samples(path="engine", tier="ici") == [(2048, 2e-6)]


def test_sink_bounded_memory():
    sink = telemetry.TelemetrySink(max_trace=128, max_samples_per_bucket=32)
    for i in range(10_000):
        sink.record(telemetry.OpRecord("put", i + 1, "direct", "ici",
                                       1e-6 * (i + 1), 1))
    assert len(sink.trace) <= 128
    b = sink.buckets[("put", "direct", "ici", 1)]
    assert len(b.samples) <= 32
    assert b.count == 10_000                      # aggregates never dropped
    # decimation keeps spread: both early and late samples survive
    xs = [x for x, _ in b.samples]
    assert min(xs) < 2_000 and max(xs) > 8_000


def test_context_records_through_sink():
    ctx, heap = context.init(npes=4, node_size=2)
    ctx.record("put", 4096, "direct", "ici", 16)
    assert ctx.ledger[-1].op == "put"             # back-compat trace view
    assert ("put", "direct", "ici", 16) in ctx.telemetry.buckets
    assert ctx.total_time() > 0
    ctx.reset_ledger()
    assert not ctx.ledger and not ctx.telemetry.buckets


# ---------------------------------------------------------------------------
# estimator: recovery + agreement (acceptance criteria)
# ---------------------------------------------------------------------------


def test_estimator_recovers_alpha_and_bw():
    tbl = _fitted_table()
    for wi in WORK_ITEMS:
        d = tbl.profiles[("direct", "ici", wi)]
        e = tbl.profiles[("engine", "ici", table_mod.ANY_WI)]
        true_gap = HW.alpha_engine - HW.alpha_direct
        assert (e.alpha - d.alpha) == pytest.approx(true_gap, rel=0.10)
        assert d.bw == pytest.approx(cutover.direct_bw(HW, wi), rel=0.10)
        assert e.bw == pytest.approx(HW.ici_bw, rel=0.10)


def test_estimator_robust_to_noise():
    tbl = _fitted_table(noise=0.05)
    d = tbl.profiles[("direct", "ici", 16)]
    e = tbl.profiles[("engine", "ici", table_mod.ANY_WI)]
    assert d.bw == pytest.approx(cutover.direct_bw(HW, 16), rel=0.10)
    assert e.bw == pytest.approx(HW.ici_bw, rel=0.10)


def test_learned_table_agrees_with_analytic_model():
    tbl = _fitted_table()
    frac = estimator.agreement(tbl, HW, work_items=WORK_ITEMS)
    assert frac >= 0.95


def test_choose_path_consults_learned_table():
    # absurd learned cutover flips the decision away from the analytic model
    tbl = table_mod.TuningTable(cutovers={("ici", 1): 1 << 30})
    armed = cutover.Tuning(table=tbl)
    n = 1 << 20                                   # analytic: engine at wi=1
    assert cutover.choose_path(n, work_items=1, tier="ici", hw=HW) == "engine"
    assert cutover.choose_path(n, work_items=1, tier="ici", hw=HW,
                               tuning=armed) == "direct"
    # uncovered tier falls back to the analytic model
    assert cutover.choose_path(n, work_items=1, tier="local", hw=HW,
                               tuning=armed) == \
        cutover.choose_path(n, work_items=1, tier="local", hw=HW)


def test_lookup_nearest_work_items():
    tbl = table_mod.TuningTable(cutovers={("ici", 1): 100, ("ici", 1024): 900})
    assert tbl.lookup("ici", 1) == 100
    assert tbl.lookup("ici", 2) == 100            # nearest in log space
    assert tbl.lookup("ici", 512) == 900
    assert tbl.lookup("dcn", 1) is None


def test_fit_linear_degenerate_inputs():
    assert estimator.fit_linear([(64, 1e-6)]) is None           # too few
    assert estimator.fit_linear([(64, 1e-6)] * 5) is None       # no spread
    flat = estimator.fit_linear([(1 << b, 2e-6) for b in range(6, 12)])
    assert flat is not None and math.isinf(flat.bw)             # pure latency
    assert flat.alpha == pytest.approx(2e-6)


# ---------------------------------------------------------------------------
# table persistence
# ---------------------------------------------------------------------------


def test_table_json_roundtrip(tmp_path):
    tbl = _fitted_table()
    path = tmp_path / "tuning.json"
    tbl.save(str(path))
    back = table_mod.TuningTable.load(str(path))
    assert back.cutovers == tbl.cutovers
    assert set(back.profiles) == set(tbl.profiles)
    for k, p in tbl.profiles.items():
        assert back.profiles[k].alpha == pytest.approx(p.alpha)
        assert back.profiles[k].bw == pytest.approx(p.bw) or \
            (math.isinf(back.profiles[k].bw) and math.isinf(p.bw))
    # infinite cutovers survive as null
    doc = json.loads(path.read_text())
    assert any(v is None for v in doc["cutovers"].values())


def test_table_merge_weighted():
    a = table_mod.TuningTable(
        profiles={("direct", "ici", 1): table_mod.PathProfile(1e-6, 1e9, 10)},
        cutovers={("ici", 1): 1000})
    b = table_mod.TuningTable(
        profiles={("direct", "ici", 1): table_mod.PathProfile(3e-6, 3e9, 30),
                  ("engine", "ici", 0): table_mod.PathProfile(5e-6, 50e9, 20)},
        cutovers={("ici", 16): 2000})
    m = a.merge(b)
    p = m.profiles[("direct", "ici", 1)]
    assert p.nsamples == 40
    assert p.alpha == pytest.approx(0.25 * 1e-6 + 0.75 * 3e-6)
    assert m.cutovers[("ici", 16)] == 2000        # union preserved
    # (ici,1) recomputed from merged direct+engine fits
    assert m.cutovers[("ici", 1)] == table_mod.cutover_from_profiles(
        p, m.profiles[("engine", "ici", 0)])


# ---------------------------------------------------------------------------
# env-var surface
# ---------------------------------------------------------------------------


def test_env_defaults_empty():
    cfg = env_mod.load_env({})
    assert cfg == env_mod.EnvConfig()
    t = env_mod.tuning_from_env({})
    assert t == cutover.Tuning()


def test_env_parsing():
    cfg = env_mod.load_env({
        "ISHMEM_ENABLE_CUTOVER": "1",
        "ISHMEM_CUTOVER_BYTES": "16K",
        "ISHMEM_FORCE_PATH": "engine",
        "ISHMEM_WORK_GROUP_SIZE": "256",
    })
    assert cfg.cutover_bytes == 16384
    assert cfg.force_path == "engine"
    assert cfg.work_group_size == 256
    assert env_mod.parse_bytes("2M") == 2 << 20
    assert env_mod.parse_bytes("1G") == 1 << 30
    with pytest.raises(ValueError):
        env_mod.load_env({"ISHMEM_FORCE_PATH": "warp"})
    with pytest.raises(ValueError):
        env_mod.load_env({"ISHMEM_ENABLE_CUTOVER": "maybe"})


def test_env_disable_cutover_pins_direct():
    t = env_mod.tuning_from_env({"ISHMEM_ENABLE_CUTOVER": "0"})
    assert t.force_path is None                   # dcn must keep its proxy
    assert cutover.choose_path(1 << 24, tier="ici", tuning=t) == "direct"
    assert cutover.choose_path(1 << 24, tier="dcn", tuning=t) == "proxy"
    # an explicit force path survives the disable
    t2 = env_mod.tuning_from_env({"ISHMEM_ENABLE_CUTOVER": "0",
                                  "ISHMEM_FORCE_PATH": "engine"})
    assert t2.force_path == "engine"


def test_estimator_ignores_collective_samples():
    # collective timings scale with npes; mixing them into the p2p fit used
    # to skew bandwidth by >4x (review finding) — they must be excluded
    sink = estimator.synthetic_sweep(HW, work_items=(128,))
    for lb in range(7, 25):
        n = 1 << lb
        t = cutover.t_collective("fcollect", n, 8, work_items=128,
                                 path="direct", hw=HW)
        sink.record(telemetry.OpRecord("fcollect", n, "direct", "ici", t, 128))
    tbl = estimator.build_table(sink)
    d = tbl.profiles[("direct", "ici", 128)]
    assert d.bw == pytest.approx(cutover.direct_bw(HW, 128), rel=0.10)


def test_uncovered_table_leaves_collective_model_alone():
    from repro.core import collectives
    ctx, heap = context.init(npes=2, node_size=2, tuning=cutover.Tuning())
    want = collectives._path(ctx, "alltoall", 8192, 2, 1)
    # armed table with NO ici coverage must not reroute collectives through
    # the point-to-point model (review finding)
    ctx.tuning = cutover.Tuning(table=table_mod.TuningTable(
        cutovers={("local", 1): 123}))
    assert collectives._path(ctx, "alltoall", 8192, 2, 1) == want


def test_null_sink_safe_for_nbi():
    import jax.numpy as jnp
    from repro.core import rma
    ctx, heap = context.init(npes=2, node_size=2,
                             telemetry=telemetry.NullSink())
    p = heap.malloc((8,), "float32")
    heap = rma.put_nbi(ctx, heap, p, jnp.ones(8), 1)   # used to IndexError
    heap = rma.quiet(ctx, heap)
    assert float(heap.read(p, 1).sum()) == 8.0
    assert ctx.ledger == [] and ctx.total_time() == 0.0


def test_trace_trim_preserves_pending_nbi():
    sink = telemetry.TelemetrySink(max_trace=64)
    sink.record(telemetry.OpRecord("put_nbi(pending)", 64, "engine", "ici",
                                   1e-6, 1))
    for i in range(500):
        sink.record(telemetry.OpRecord("put", 64, "direct", "ici", 1e-6, 1))
    assert any(r.op == "put_nbi(pending)" for r in sink.trace)


def test_trace_bound_wins_over_pending_flood():
    # pathological: more pending markers than the bound — the bound holds
    # (oldest pending drop) rather than degrading to unbounded growth
    sink = telemetry.TelemetrySink(max_trace=64)
    for i in range(1000):
        sink.record(telemetry.OpRecord("put_nbi(pending)", 64, "engine",
                                       "ici", 1e-6, 1))
    assert len(sink.trace) <= 64


def test_env_tuning_file_warm_start(tmp_path, monkeypatch):
    tbl = _fitted_table()
    path = tmp_path / "warm.json"
    tbl.save(str(path))
    t = env_mod.tuning_from_env({"ISHMEM_TUNING_FILE": str(path)})
    assert t.table is not None
    assert t.table.cutovers == tbl.cutovers
    # and through ishmem_init via the process environment
    monkeypatch.setenv("ISHMEM_TUNING_FILE", str(path))
    monkeypatch.setenv("ISHMEM_WORK_GROUP_SIZE", "64")
    ctx, _ = context.init(npes=2)
    assert ctx.tuning.work_group_size == 64
    assert ctx.tuning.table.cutovers == tbl.cutovers
    monkeypatch.setenv("ISHMEM_TUNING_FILE", str(tmp_path / "missing.json"))
    with pytest.raises(FileNotFoundError):
        context.init(npes=2)


# ---------------------------------------------------------------------------
# profile -> persist -> warm-start pipeline
# ---------------------------------------------------------------------------


def test_context_fit_tuning_table_online():
    ctx, _ = context.init(npes=4, node_size=4, tuning=cutover.Tuning())
    estimator.synthetic_sweep(ctx.hw, sink=ctx.telemetry)
    tbl = ctx.fit_tuning_table()
    assert ctx.tuning.table is tbl
    assert estimator.agreement(tbl, ctx.hw) >= 0.95


def test_bench_profile_emits_valid_json(tmp_path):
    from benchmarks import bench_cutover
    out = tmp_path / "BENCH_cutover.json"
    doc = bench_cutover.profile(str(out))
    loaded = json.loads(out.read_text())
    assert loaded["bench"] == "cutover_profile"
    assert loaded["agreement_vs_analytic"] >= 0.95
    assert loaded["samples"] == doc["samples"] > 0
    back = table_mod.TuningTable.from_json(loaded["table"])
    assert back.cutovers                           # usable for warm-start


def test_best_of_records_measured_wall_clock():
    """Satellite of the completion-engine PR: benchmark wall clock flows
    into a TelemetrySink (benchmarks.common.MEASURED) that the estimator can
    fit, instead of the analytic model replayed."""
    from benchmarks import common as bench_common
    sink = telemetry.TelemetrySink()
    orig = bench_common.MEASURED
    bench_common.MEASURED = sink
    try:
        for lg in (10, 12, 14):          # spread so the fit is constrained
            bench_common.best_of(lambda: None, trials=2, min_warm_s=0.0,
                                 record=("put", 1 << lg, "direct", "local",
                                         4))
    finally:
        bench_common.MEASURED = orig
    # measured provenance: records land in the "wallclock" stream, never
    # the model stream (total_count/buckets stay the deterministic clock)
    assert sink.total_count() == 0
    assert sink.nsamples("wallclock") == 3
    samples = sink.samples(path="direct", tier="local", work_items=4,
                           source="wallclock")
    assert len(samples) == 3
    assert all(t >= 0.0 for _, t in samples)
    prof = estimator.fit_linear(samples)
    assert prof is not None and prof.nsamples == 3


# ---------------------------------------------------------------------------
# sink merge: reservoir retention + exact additivity (observability PR)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # clean interpreter: deterministic
    from _minihyp import given, settings, strategies as st


def test_null_sink_state_is_per_instance():
    """NullSink.buckets/trace used to be class-level mutable defaults: a
    consumer mutating one sink's view corrupted every other NullSink."""
    a, b = telemetry.NullSink(), telemetry.NullSink()
    a.buckets[("put", "direct", "ici", 1)] = telemetry.StatBucket()
    a.trace.append(telemetry.OpRecord("put", 64, "direct", "ici", 1e-6))
    assert b.buckets == {} and b.trace == []
    assert telemetry.NullSink().buckets == {}


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 600), st.integers(1, 600))
def test_merge_retains_samples_from_both_runs(na, nb):
    """Merging two reservoirs (full or not) keeps samples from BOTH
    parents: the old concatenate-then-halve stride could delete every
    sample of one side when both arrived full."""
    a = telemetry.TelemetrySink(max_samples_per_bucket=32)
    b = telemetry.TelemetrySink(max_samples_per_bucket=32)
    for _ in range(na):                  # run a tags its samples nbytes=64
        a.record(telemetry.OpRecord("put", 64, "direct", "ici", 1e-6, 16))
    for _ in range(nb):                  # run b tags nbytes=65
        b.record(telemetry.OpRecord("put", 65, "direct", "ici", 2e-6, 16))
    a.merge(b)
    bucket = a.buckets[("put", "direct", "ici", 16)]
    xs = {x for x, _ in bucket.samples}
    assert xs == {64, 65}                # both runs stay represented
    assert len(bucket.samples) <= bucket.max_samples
    assert bucket.count == na + nb


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 1 << 20), min_size=1, max_size=50),
       st.lists(st.integers(1, 1 << 20), min_size=1, max_size=50))
def test_merge_time_total_exactly_additive(xs_a, xs_b):
    """Per-bucket time_total after a merge is ONE float add of the parents'
    totals — exact equality, not approx — so fleet-wide attribution sums
    survive any number of sink merges bit-for-bit."""
    a = telemetry.TelemetrySink()
    b = telemetry.TelemetrySink()
    for n in xs_a:
        a.record(telemetry.OpRecord("put", n, "direct", "ici", n * 1e-9, 1))
    for n in xs_b:
        b.record(telemetry.OpRecord("put", n, "direct", "ici", n * 1e-9, 1))
    key = ("put", "direct", "ici", 1)
    ta, tb = a.buckets[key].time_total, b.buckets[key].time_total
    a.merge(b)
    assert a.buckets[key].time_total == ta + tb
    assert a.total_count() == len(xs_a) + len(xs_b)
    # merging into an empty sink is the identity on totals
    fresh = telemetry.TelemetrySink()
    fresh.merge(b)
    assert fresh.buckets[key].time_total == tb
    assert [s for s in fresh.buckets[key].samples] == list(b.buckets[key].samples)
