"""Measured-time profiling layer acceptance (repro.obs.prof / calibrate):

- clock segregation: profiling on vs off => bitwise-identical outputs,
  fleet report, and Chrome-trace document; wall-clock values never reach a
  deterministic ``ts`` (export.validate's integral rule, + negatives),
- scope pairing: measured wall seconds ride next to the analytic model's
  pricing of the same region; wallclock records land in their own telemetry
  provenance stream and never move the modeled comm clock,
- estimator/refit provenance: ``sample_source="wallclock"`` fits only
  measured samples and stamps ``source="wallclock"`` through table JSON,
  merge, and the online refitter's hot-swap,
- calibration report: deterministic from a canned sample file, ranked
  divergence, honest unmodeled coverage, step-clocked measured track,
- benchmark hooks: ``best_of`` trial env knob, details dict, and the
  trimmed-median wallclock record.
"""
import functools
import json

import jax
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.core import context
from repro.models import model
from repro.obs import (Obs, OnlineRefitter, calibrate_mod, chrome_trace,
                       load_obs_env, prof_mod, validate)
from repro.obs.prof import NULL_PROF, ProfClock, Profiler, ProfSample
from repro.obs.tracer import STEP_QUANTUM
from repro.serve.engine import Engine
from repro.serve.frontend import Fleet, FleetConfig, TenantSpec, TrafficEngine
from repro.tune import estimator, table as table_mod
from repro.tune import telemetry as telemetry_mod

MAXLEN = 24
NEW = 4


@functools.lru_cache(maxsize=1)
def _engine():
    cfg = cfgbase.reduced(cfgbase.get_config("qwen3_4b"))
    params = model.init_params(jax.random.key(0), cfg)
    return cfg, Engine(cfg, params, max_len=MAXLEN)


def _serve(obs):
    cfg, engine = _engine()
    fleet = Fleet(FleetConfig(
        n_pods=2, prefill_per_pod=1, decode_per_pod=2, num_slots=2,
        kv_blocks=96, block_tokens=4, max_len=MAXLEN, max_new=NEW,
        stream_chunks=1, admission="slo", router="affinity", seed=11),
        engine=engine, obs=obs)
    traffic = TrafficEngine(
        [TenantSpec("chat", weight=2.0, prompt_lens=(8,), max_new=(NEW,),
                    slo="interactive"),
         TenantSpec("scan", weight=1.0, prompt_lens=(12,), max_new=(NEW,),
                    slo="batch")],
        rate=1.0, vocab=cfg.vocab_size, seed=17)
    rep = fleet.run(traffic.schedule(6), max_steps=1500)
    rep.pop("obs", None)
    return fleet, rep


# ---------------------------------------------------------------------------
# clock segregation: profiling on/off is bitwise identical
# ---------------------------------------------------------------------------


def test_profiling_off_is_bitwise_identical():
    """The tentpole contract: a recording wall-clock profiler must not
    change one bit of any deterministic output — tokens, fleet report, or
    the step-clocked Chrome trace (measured data is an opt-in extra track,
    never mixed into the base document)."""
    fleet_off, rep_off = _serve(Obs(trace=True))
    fleet_on, rep_on = _serve(Obs(trace=True, prof=True))

    assert rep_off == rep_on
    outs_off, outs_on = fleet_off.outputs(), fleet_on.outputs()
    assert set(outs_off) == set(outs_on)
    for idx in outs_off:
        np.testing.assert_array_equal(outs_off[idx], outs_on[idx])

    doc_off = chrome_trace(fleet_off.obs.tracer)
    doc_on = chrome_trace(fleet_on.obs.tracer)
    assert json.dumps(doc_off, sort_keys=True) == \
        json.dumps(doc_on, sort_keys=True)
    assert validate(doc_on) == []

    # the profiler DID measure the run (this test must not pass vacuously)
    prof = fleet_on.obs.prof
    assert prof is not None and len(prof.samples) > 0
    assert {"serve_prefill", "serve_decode"} <= {s.op for s in prof.samples}
    # ...and its wallclock telemetry stayed in its own provenance stream
    tel = fleet_on.ctx.telemetry
    assert tel.nsamples("wallclock") > 0
    assert tel.source_time("wallclock") > 0.0
    assert all(r.source == telemetry_mod.MODEL_SOURCE for r in tel.trace)


def test_measured_track_is_additive_and_step_clocked():
    fleet, _ = _serve(Obs(trace=True, prof=True))
    tracer, prof = fleet.obs.tracer, fleet.obs.prof
    track = calibrate_mod.measured_track_events(prof.samples)
    assert len(track) == len(prof.samples)
    doc_with = chrome_trace(tracer, measured=track)
    assert validate(doc_with) == []
    # strictly additive: re-exporting without the track gives the base doc
    base = chrome_trace(tracer)
    assert json.dumps(chrome_trace(tracer), sort_keys=True) == \
        json.dumps(base, sort_keys=True)
    assert len(doc_with["traceEvents"]) > len(base["traceEvents"])
    # step-clocked instants: integral ts on the measured pid, wall time
    # only in args
    for ev in track:
        assert ev["pid"] == "measured" and ev["ph"] == "i"
        assert isinstance(ev["ts"], int)
        assert ev["ts"] // STEP_QUANTUM == ev["args"]["step"]
        assert "wall_us" in ev["args"]


def test_validate_rejects_wallclock_shaped_timestamps():
    """The integral-ts rule is the leak detector: a perf_counter value
    sneaking into ``ts``/``dur`` shows up as a fractional timestamp."""
    def doc(**ev):
        base = {"name": "x", "cat": "c", "ph": "i", "s": "t",
                "pid": "p", "tid": "t", "ts": 0}
        base.update(ev)
        return {"traceEvents": [base]}

    assert validate(doc()) == []
    errs = validate(doc(ts=1.5))
    assert any("non-integral ts" in e for e in errs)
    errs = validate(doc(ph="X", dur=2.5))
    assert any("non-integral dur" in e for e in errs)
    assert validate(doc(ts=3.0)) == []            # integral float is fine


# ---------------------------------------------------------------------------
# profiler scopes
# ---------------------------------------------------------------------------


class _ScriptClock(ProfClock):
    """Deterministic stand-in for perf_counter."""

    def __init__(self, vals):
        self.vals = list(vals)

    def now(self):
        return self.vals.pop(0)


def test_scope_pairs_wall_with_model_delta():
    ctx, _ = context.init(npes=2, node_size=2)
    prof = Profiler(clock=_ScriptClock([10.0, 10.5])).attach(ctx)
    assert ctx.prof is prof
    prof.set_step(5)
    t_model0 = ctx.telemetry.total_time()

    with prof.scope("copy", nbytes=4096, path="direct", tier="ici",
                    work_items=4) as ps:
        # the analytic model prices one op inside the scope
        ctx.telemetry.record(telemetry_mod.OpRecord(
            "put", 4096, "direct", "ici", 0.25, 4))
        assert ps(("x", 1)) == ("x", 1)           # block_until_ready passthru

    (s,) = prof.samples
    assert (s.op, s.nbytes, s.path, s.tier, s.work_items) == \
        ("copy", 4096, "direct", "ici", 4)
    assert s.step == 5
    assert s.wall_s == pytest.approx(0.5)
    assert s.model_s == pytest.approx(0.25)

    # the wallclock record went to its own stream: the modeled comm clock
    # moved only by the model op, and the ledger trace holds no wallclock row
    tel = ctx.telemetry
    assert tel.total_time() == pytest.approx(t_model0 + 0.25)
    assert tel.source_time("wallclock") == pytest.approx(0.5)
    key = ("copy", "direct", "ici", 4)
    assert key in tel.sources["wallclock"] and key not in tel.buckets
    assert all(r.source == telemetry_mod.MODEL_SOURCE for r in tel.trace)

    prof.set_step(3)                              # monotonic max, like tracer
    assert prof.step == 5


def test_null_prof_is_inert():
    assert not NULL_PROF.enabled
    sc = NULL_PROF.scope("copy", nbytes=1)
    with sc as ps:
        obj = object()
        assert ps(obj) is obj
    assert NULL_PROF.samples == []
    ctx, _ = context.init(npes=2, node_size=2)
    with pytest.raises(RuntimeError):
        NULL_PROF.attach(ctx)                     # off == ctx.prof unset


# ---------------------------------------------------------------------------
# telemetry provenance streams
# ---------------------------------------------------------------------------


def _rec(op="put", nbytes=1024, path="direct", tier="ici", t=1e-6, wi=1,
         source=telemetry_mod.MODEL_SOURCE):
    return telemetry_mod.OpRecord(op, nbytes, path, tier, t, wi, source)


def test_sink_source_segregation_merge_and_snapshot():
    sink = telemetry_mod.TelemetrySink()
    sink.record(_rec(t=1e-6))
    sink.record(_rec(t=5e-3, source="wallclock"))
    key = ("put", "direct", "ici", 1)

    assert sink.buckets[key].count == 1           # model stream only
    assert sink.sources["wallclock"][key].count == 1
    assert sink.total_time() == pytest.approx(1e-6)
    assert sink.source_time("wallclock") == pytest.approx(5e-3)
    assert len(sink.trace) == 1                   # wallclock never ledgers
    assert sink.nsamples() == 1 and sink.nsamples("wallclock") == 1
    assert sink.tiers(source="wallclock") == ["ici"]
    assert sink.samples(path="direct", tier="ici",
                        source="wallclock") == [(1024, 5e-3)]

    snap = sink.snapshot()
    assert snap["buckets"]["put/direct/ici/1"]["count"] == 1
    assert snap["buckets"]["put/direct/ici/1@wallclock"]["count"] == 1
    assert snap["total_time"] == pytest.approx(1e-6)   # model clock only

    other = telemetry_mod.TelemetrySink()
    other.record(_rec(t=7e-3, source="wallclock"))
    sink.merge(other)                             # source-by-source merge
    assert sink.sources["wallclock"][key].count == 2
    assert sink.buckets[key].count == 1


# ---------------------------------------------------------------------------
# estimator / table / refit provenance
# ---------------------------------------------------------------------------


def _wallclock_sink():
    sink = telemetry_mod.TelemetrySink()
    for n in (1 << 10, 1 << 12, 1 << 14, 1 << 16):
        sink.record(_rec(nbytes=n, t=1e-6 + n / 1e9, source="wallclock"))
    return sink


def test_estimator_fits_only_the_requested_stream(tmp_path):
    sink = _wallclock_sink()
    tbl = estimator.build_table(sink, source="wallclock",
                                sample_source="wallclock")
    assert tbl.profiles and tbl.source == "wallclock"
    assert all(p.source == "wallclock" for p in tbl.profiles.values())
    # default fit reads the (empty) model stream — measured samples must
    # never leak into a model-provenance table
    assert not estimator.build_table(sink).profiles

    path = str(tmp_path / "tuning.json")
    tbl.save(path)
    loaded = table_mod.TuningTable.load(path)
    assert "wallclock" in loaded.source
    assert all(p.source == "wallclock" for p in loaded.profiles.values())


def test_merge_never_launders_wallclock_provenance():
    assert table_mod._merge_source("wallclock", "wallclock") == "wallclock"
    assert table_mod._merge_source("", "wallclock") == "wallclock"
    assert table_mod._merge_source("wallclock", "") == "wallclock"
    assert table_mod._merge_source("wallclock", "model") == "wallclock+model"

    key = ("direct", "ici", 0)
    a = table_mod.TuningTable(profiles={key: table_mod.PathProfile(
        1e-6, 1e9, nsamples=4, source="wallclock")}, source="wallclock")
    b = table_mod.TuningTable(profiles={key: table_mod.PathProfile(
        2e-6, 2e9, nsamples=4, source="model")}, source="model")
    merged = a.merge(b)
    assert merged.profiles[key].source == "wallclock+model"
    # one-sided keys pass provenance through untouched
    only = a.merge(table_mod.TuningTable(source="model"))
    assert only.profiles[key].source == "wallclock"


def test_refitter_hot_swaps_a_measured_table():
    ctx, _ = context.init(npes=2, node_size=2)
    for n in (1 << 10, 1 << 12, 1 << 14, 1 << 16):
        ctx.telemetry.record(_rec(nbytes=n, t=1e-6 + n / 1e9,
                                  source="wallclock"))
    rf = OnlineRefitter(ctx, period_steps=1, min_samples=1,
                        sample_source="wallclock")
    ev = rf.maybe_refit(1)
    assert ev is not None and ev.nsamples == 4
    tbl = ctx.tuning.table
    assert tbl is not None and "wallclock" in tbl.source
    assert tbl.profiles
    assert all("wallclock" in p.source for p in tbl.profiles.values())


# ---------------------------------------------------------------------------
# calibration report
# ---------------------------------------------------------------------------


def _canned():
    return (
        [ProfSample(op="serve_decode", nbytes=4096, path="engine",
                    tier="local", work_items=4, step=s, wall_s=2e-3,
                    model_s=1e-3) for s in range(4)]
        + [ProfSample(op="stream_flush", nbytes=65536, path="proxy",
                      tier="dcn", work_items=8, step=0, wall_s=5e-3,
                      model_s=5e-4),
           ProfSample(op="serve_prefill", nbytes=8192, path="engine",
                      tier="local", work_items=1, step=1, wall_s=3e-3,
                      model_s=0.0)])


def test_calibration_report_is_deterministic_and_ranked(tmp_path):
    samples = _canned()
    report = calibrate_mod.report_from_samples(samples)
    assert report["samples"] == 6
    assert report["populated_buckets"] == 2       # prefill is unmodeled
    # worst divergence first: flush at 10x beats decode at 2x
    assert [w["op"] for w in report["worst"]] == \
        ["stream_flush", "serve_decode"]
    assert report["worst"][0]["ratio_p50"] == pytest.approx(10.0)
    assert report["worst"][1]["ratio_p50"] == pytest.approx(2.0)
    # unmodeled coverage is reported honestly, not folded into a ratio
    cov = report["coverage"]
    assert cov["unmodeled_wall_s"] == pytest.approx(3e-3)
    assert cov["unmodeled_wall_frac"] == pytest.approx(3e-3 / 16e-3)
    by_op = {b["op"]: b for b in report["buckets"]}
    assert by_op["serve_prefill"]["ratio"] is None
    assert by_op["serve_prefill"]["modeled_n"] == 0

    # byte-for-byte deterministic from a saved sample file
    prof = Profiler(sink_records=False)
    prof.samples = samples
    path = str(tmp_path / "prof.json")
    prof.save(path)
    loaded = calibrate_mod.report_from_samples(prof_mod.load_samples(path))
    assert json.dumps(loaded, sort_keys=True) == \
        json.dumps(report, sort_keys=True)
    assert calibrate_mod.render(report)           # CLI rendering never dies


def test_overlay_and_sink_join():
    overlay = calibrate_mod.measured_overlay(_canned())
    assert overlay["compute"]["n"] == 5           # decode + prefill
    assert overlay["wire"]["wall_s"] == pytest.approx(5e-3)
    assert calibrate_mod.measured_overlay(
        [ProfSample(op="weird", nbytes=1, path="p", tier="t", work_items=1,
                    step=0, wall_s=1.0, model_s=0.0)])["other"]["n"] == 1

    sink = telemetry_mod.TelemetrySink()
    sink.record(_rec(t=1e-3))
    sink.record(_rec(t=4e-3, source="wallclock"))
    sink.record(_rec(op="lonely", t=9e-3, source="wallclock"))  # no model twin
    rows = calibrate_mod.sink_join(sink)
    assert [r["op"] for r in rows] == ["put"]     # only keys in BOTH streams
    assert rows[0]["ratio"] == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# benchmark hooks + env surface
# ---------------------------------------------------------------------------


def test_best_of_env_trials_details_and_record(monkeypatch):
    from benchmarks import common
    monkeypatch.setenv("ISHMEM_BENCH_TRIALS", "4")
    details = {}
    before = common.MEASURED.nsamples("wallclock")
    best = common.best_of(lambda: None, discard=2, details=details,
                          record=("test_measured_op", 512, "direct", "ici", 7))
    assert details["trials"] == 4 and details["discarded"] == 2
    assert best == details["min"] <= details["tmed"]
    key = ("test_measured_op", "direct", "ici", 7)
    bucket = common.MEASURED.sources["wallclock"][key]
    assert bucket.count >= 1
    assert common.MEASURED.nsamples("wallclock") > before
    assert key not in common.MEASURED.buckets     # never the model stream

    monkeypatch.setenv("ISHMEM_BENCH_TRIALS", "zero")
    with pytest.raises(ValueError):
        common._env_trials()
    monkeypatch.setenv("ISHMEM_BENCH_TRIALS", "0")
    with pytest.raises(ValueError):
        common._env_trials()


def test_trimmed_median():
    from benchmarks.common import trimmed_median
    assert trimmed_median([5.0]) == 5.0
    assert trimmed_median([1.0, 2.0, 3.0, 4.0]) == 2.5     # small n: plain
    assert trimmed_median([1.0, 2.0, 3.0, 4.0, 100.0]) == 3.0  # outlier cut
    assert trimmed_median([100.0, 3.0, 1.0, 2.0, 4.0]) == 3.0  # order-free


def test_obs_env_prof_and_calibration():
    cfg = load_obs_env({})
    assert not cfg.prof and not cfg.calibration and not cfg.enabled
    cfg = load_obs_env({"ISHMEM_OBS_PROF": "1"})
    assert cfg.prof and cfg.prof_path is None and cfg.enabled
    cfg = load_obs_env({"ISHMEM_OBS_PROF": "/tmp/prof.json"})
    assert cfg.prof and cfg.prof_path == "/tmp/prof.json"
    cfg = load_obs_env({"ISHMEM_OBS_CALIBRATION": "/tmp/cal.json"})
    assert cfg.calibration and cfg.calibration_path == "/tmp/cal.json"
    assert cfg.prof                               # calibration implies prof
