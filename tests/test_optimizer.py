import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer as opt_mod


def _rosenbrockish(params):
    x = params["w"]
    return jnp.sum((x - 1.5) ** 2) + jnp.sum(jnp.abs(x[:2]))


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_decreases_loss(name):
    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}
    cfg = opt_mod.OptConfig(name=name, lr=0.05, warmup_steps=1,
                            total_steps=100, weight_decay=0.0)
    state = opt_mod.init(name, params)
    loss0 = float(_rosenbrockish(params))
    for _ in range(60):
        grads = jax.grad(_rosenbrockish)(params)
        params, state, m = opt_mod.update(name, params, grads, state, cfg)
    assert float(_rosenbrockish(params)) < 0.5 * loss0


def test_adafactor_state_is_factored():
    params = {"mat": jnp.zeros((64, 32)), "vec": jnp.zeros((16,))}
    state = opt_mod.adafactor_init(params)
    assert state["v"]["mat"]["vr"].shape == (64,)
    assert state["v"]["mat"]["vc"].shape == (32,)
    assert state["v"]["vec"]["v"].shape == (16,)
    # memory win vs adam: factored state << full second moment
    n_fact = 64 + 32
    assert n_fact < 64 * 32


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = opt_mod.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert abs(float(opt_mod.global_norm(clipped)) - 1.0) < 1e-5


def test_schedule_warmup_and_decay():
    cfg = opt_mod.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(opt_mod.schedule(cfg, jnp.asarray(s)))
           for s in range(0, 100, 5)]
    assert lrs[0] < lrs[1]                      # warming up
    assert lrs[-1] < lrs[3]                     # decayed
    assert lrs[-1] >= 0.1 * 0.99                # floor


def test_weight_decay_pulls_to_zero():
    params = {"w": jnp.full((4,), 10.0)}
    cfg = opt_mod.OptConfig(name="adamw", lr=0.1, warmup_steps=1,
                            total_steps=50, weight_decay=0.5)
    state = opt_mod.adamw_init(params)
    zero_grads = {"w": jnp.zeros((4,))}
    for _ in range(20):
        params, state, _ = opt_mod.adamw_update(params, zero_grads, state,
                                                cfg)
    assert float(jnp.abs(params["w"]).max()) < 10.0
