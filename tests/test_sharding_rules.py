"""Sharding-rule validity for the PRODUCTION meshes (16x16 and 2x16x16) via
AbstractMesh — no devices needed: every assigned axis must divide its dim."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import base as cfgbase
from repro.launch import sharding
from repro.models import model
from repro.train import optimizer as opt_mod


def _abstract_mesh(multi_pod):
    if multi_pod:
        return AbstractMesh((2, 16, 16), ("pod", "data", "model"))
    return AbstractMesh((16, 16), ("data", "model"))


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _check_divisible(spec, shape, sizes, where):
    for dim, s in zip(shape, spec):
        if s is None:
            continue
        axes = s if isinstance(s, tuple) else (s,)
        k = 1
        for a in axes:
            k *= sizes[a]
        assert dim % k == 0, f"{where}: dim {dim} not divisible by {k} ({s})"


@pytest.mark.parametrize("arch", cfgbase.ARCH_NAMES)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_and_opt_specs_divide(arch, multi_pod):
    cfg = cfgbase.get_config(arch)
    mesh = _abstract_mesh(multi_pod)
    sizes = _axis_sizes(mesh)
    params_s = jax.eval_shape(lambda: model.init_params(jax.random.key(0),
                                                        cfg))
    opt_s = jax.eval_shape(lambda: opt_mod.init(cfg.optimizer, params_s))
    for struct, name in ((params_s, "param"), (opt_s, "opt")):
        def check(path, leaf):
            pstr = jax.tree_util.keystr(path)
            spec = sharding.spec_for_param(pstr, leaf.shape, mesh)
            _check_divisible(spec, leaf.shape, sizes, f"{arch} {name} {pstr}")
        jax.tree_util.tree_map_with_path(check, struct)


@pytest.mark.parametrize("arch", cfgbase.ARCH_NAMES)
def test_cache_specs_divide(arch):
    cfg = cfgbase.get_config(arch)
    mesh = _abstract_mesh(False)
    sizes = _axis_sizes(mesh)
    for shape_name in ("decode_32k", "long_500k"):
        shape = cfgbase.SHAPES[shape_name]
        if not cfgbase.shape_applicable(cfg, shape):
            continue
        cache_s = cfgbase.cache_specs(cfg, shape.global_batch, shape.seq_len)
        shardings = sharding.cache_shardings(cfg, mesh, cache_s)

        def check(leaf_s, sh):
            _check_divisible(sh.spec, leaf_s.shape, sizes,
                             f"{arch} {shape_name}")
        jax.tree.map(check, cache_s, shardings)


def test_moe_experts_on_model_axis():
    cfg = cfgbase.get_config("arctic_480b")
    mesh = _abstract_mesh(False)
    spec = sharding.spec_for_param(
        "['blocks'][0]['moe']['w_gate']", (35, 128, 7168, 4864), mesh)
    assert spec[1] == "model"                   # expert parallelism


def test_embed_vocab_fallback_when_indivisible():
    """whisper vocab 51865 is not divisible by 16 -> d_model gets the axis."""
    mesh = _abstract_mesh(False)
    spec = sharding.spec_for_param("['embed']", (51865, 1024), mesh)
    assert spec[0] is None
    spec = sharding.spec_for_param("['lm_head']", (1024, 51865), mesh)
    assert spec == P("model", None)


def test_batch_sharding_replicates_batch1():
    cfg = cfgbase.get_config("xlstm_125m")
    mesh = _abstract_mesh(False)
    struct = {"token": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    sh = sharding.batch_shardings(cfg, mesh, struct)
    assert sh["token"].spec == P(None, None)
