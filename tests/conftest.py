"""Test session configuration.

The ring-collective kernels and the comms backends are *multi-PE by nature*,
so the test session runs with 8 simulated host devices (deliberate, documented
choice — this is NOT the 512-device dry-run flag, which only
repro.launch.dryrun sets for itself).  Model smoke tests ignore the extra
devices (plain jit places on device 0).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    return jax.make_mesh((8,), ("x",))


@pytest.fixture(scope="session")
def mesh2x4():
    return jax.make_mesh((2, 4), ("data", "model"))
