"""Test session configuration.

The ring-collective kernels and the comms backends are *multi-PE by nature*,
so the test session runs with 8 simulated host devices (deliberate, documented
choice — this is NOT the 512-device dry-run flag, which only
repro.launch.dryrun sets for itself).  Model smoke tests ignore the extra
devices (plain jit places on device 0).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import pytest  # noqa: E402

# --- jax API compat ---------------------------------------------------------
# The tests target the current jax surface; older installs (e.g. 0.4.x) spell
# these differently.  Shim only what is missing so new jax runs untouched.
# The shard_map shim is shared with the benchmark harness (one copy).

from repro._jaxcompat import ensure_jax_compat  # noqa: E402

ensure_jax_compat()

try:
    _am = jax.sharding.AbstractMesh((1,), ("_probe",))
    del _am
except TypeError:                                 # old ctor: ((name, size), ...)
    _OldAbstractMesh = jax.sharding.AbstractMesh

    def _compat_abstract_mesh(axis_sizes, axis_names=None, **kwargs):
        if axis_names is None:
            return _OldAbstractMesh(axis_sizes, **kwargs)
        return _OldAbstractMesh(tuple(zip(axis_names, axis_sizes)), **kwargs)

    jax.sharding.AbstractMesh = _compat_abstract_mesh


@pytest.fixture(scope="session")
def mesh8():
    return jax.make_mesh((8,), ("x",))


@pytest.fixture(scope="session")
def mesh2x4():
    return jax.make_mesh((2, 4), ("data", "model"))
