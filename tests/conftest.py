"""Test session configuration.

The ring-collective kernels and the comms backends are *multi-PE by nature*,
so the test session runs with 8 simulated host devices (deliberate, documented
choice — this is NOT the 512-device dry-run flag, which only
repro.launch.dryrun sets for itself).  Model smoke tests ignore the extra
devices (plain jit places on device 0).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import pytest  # noqa: E402

# --- jax API compat ---------------------------------------------------------
# The tests target the current jax surface; older installs (e.g. 0.4.x) spell
# these differently.  Shim only what is missing so new jax runs untouched.

if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map  # noqa: E402

    def _compat_shard_map(f, **kwargs):
        if "check_vma" in kwargs:                 # renamed from check_rep
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(f, **kwargs)

    jax.shard_map = _compat_shard_map

try:
    _am = jax.sharding.AbstractMesh((1,), ("_probe",))
    del _am
except TypeError:                                 # old ctor: ((name, size), ...)
    _OldAbstractMesh = jax.sharding.AbstractMesh

    def _compat_abstract_mesh(axis_sizes, axis_names=None, **kwargs):
        if axis_names is None:
            return _OldAbstractMesh(axis_sizes, **kwargs)
        return _OldAbstractMesh(tuple(zip(axis_names, axis_sizes)), **kwargs)

    jax.sharding.AbstractMesh = _compat_abstract_mesh


@pytest.fixture(scope="session")
def mesh8():
    return jax.make_mesh((8,), ("x",))


@pytest.fixture(scope="session")
def mesh2x4():
    return jax.make_mesh((2, 4), ("data", "model"))
