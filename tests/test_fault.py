"""Chaos harness: deterministic fault injection against the serving fleet.

The acceptance property (DESIGN.md §14): under ANY seeded :class:`FaultPlan`
— decode PEs killed mid-stream, prefill PEs killed with staged blocks in
flight, whole-pod loss, dcn partitions, drain/join churn — every request
that survives decodes tokens bitwise-identical to the no-fault control run,
the shared KV pool unwinds to zero residency, and the PR-8 invariant
auditors stay clean through recovery.  Requests whose only copy died with
the casualty are re-routed (recompute) or shed; "wrong tokens" are never an
outcome.

Dead heap rows are poisoned at the fault site (``fault.scramble_rows``), so
any silent read of a dead PE's memory lands NaN in the decode path and the
bitwise check here catches it — the harness does not need to instrument
reads.
"""
import functools
import json

import jax
import numpy as np
import pytest

from repro.core import context
from repro.obs import Obs
from repro.obs import export as obs_export
from repro.obs.audit import FleetAuditor
from repro.serve.engine import Engine
from repro.serve.fault import (FaultEvent, FaultPlan, load_fault_env,
                               scramble_rows)
from repro.serve.frontend import Fleet, FleetConfig, TenantSpec, TrafficEngine
from repro.configs import base as cfgbase

MAXLEN = 24
NEW = 4


@functools.lru_cache(maxsize=1)
def _engine():
    from repro.models import model
    cfg = cfgbase.reduced(cfgbase.get_config("qwen3_4b"))
    params = model.init_params(jax.random.key(0), cfg)
    return cfg, Engine(cfg, params, max_len=MAXLEN)


def _fleet(fault_plan=None, obs=None, **over):
    cfg, engine = _engine()
    kw = dict(n_pods=2, prefill_per_pod=1, decode_per_pod=2, num_slots=2,
              kv_blocks=96, block_tokens=4, max_len=MAXLEN, max_new=NEW,
              stream_chunks=1, admission="fcfs", router="affinity", seed=11,
              queue_bound=64)
    kw.update(over)
    return Fleet(FleetConfig(**kw), engine=engine, obs=obs,
                 fault_plan=fault_plan)


MIX = (TenantSpec("chat", weight=2.0, prompt_lens=(8,), max_new=(NEW,),
                  slo="interactive"),
       TenantSpec("scan", weight=1.0, prompt_lens=(12,), max_new=(NEW,),
                  slo="batch", shared_prefix_prob=0.5, prefix_groups=1))


def _specs(seed, steps=6, rate=1.0):
    cfg, _ = _engine()
    return TrafficEngine(list(MIX), rate=rate, vocab=cfg.vocab_size,
                         seed=seed).schedule(steps)


def _assert_chaos_invariants(fleet, specs, control_outputs):
    """The three ISSUE properties, checked on a drained post-fault fleet."""
    outs = fleet.outputs()
    wrong = []
    for spec in specs:
        got = list(np.asarray(outs[spec.idx]).ravel())
        want = list(np.asarray(control_outputs[spec.idx]).ravel())
        if got and got != want:
            wrong.append(spec.idx)
    assert not wrong, f"wrong tokens on surviving requests {wrong}"
    # no leaked blocks: the shared pool's refcounts all unwound at drain
    ps = fleet.pool.stats()
    assert ps["blocks_in_use"] == 0, ps
    assert ps["streams_active"] == 0, ps
    assert ps["requests_resident"] == 0, ps
    # the auditors stay clean on the recovered end state (surviving pods
    # only — a dead PE's rows are poison by design)
    violations = FleetAuditor().audit(fleet)
    assert not violations, [str(v) for v in violations]


# ---------------------------------------------------------------------------
# FaultPlan grammar / seeding (no model)
# ---------------------------------------------------------------------------


def test_fault_plan_grammar_roundtrip_and_validation():
    plan = FaultPlan.parse(" kill_pod=pod1@6, kill_pe=4@2 ,partition=3@8")
    assert [e.spec() for e in plan.events] == \
        ["kill_pe=4@2", "kill_pod=pod1@6", "partition=3@8"]   # step-sorted
    assert FaultPlan.parse(plan.spec()) == plan               # round-trip
    assert FaultPlan.parse("").events == ()
    for bad in ("kill_pe=4", "explode=1@2", "kill_pe=x@2", "kill_pe=4@-1",
                "partition=-3@2", "kill_pe@2"):
        with pytest.raises(ValueError):
            FaultPlan.parse(bad)


def test_fault_plan_random_is_pure_function_of_seed():
    kw = dict(max_step=10, pes=(1, 2, 4, 5), pods=("pod0", "pod1"),
              n_events=3)
    a = FaultPlan.random(7, **kw)
    assert a == FaultPlan.random(7, **kw)
    assert a != FaultPlan.random(8, **kw)
    assert all(e.kind in ("kill_pe", "kill_pod", "partition")
               for e in a.events)
    with pytest.raises(ValueError):
        FaultPlan.random(0, max_step=10)      # no victims to target


def test_fault_env_knobs():
    cfg = load_fault_env({"ISHMEM_FAULT_PLAN": "kill_pe=2@3",
                          "ISHMEM_FAULT_SEED": "5"})
    assert (cfg.plan, cfg.seed) == ("kill_pe=2@3", 5)
    assert load_fault_env({}) == load_fault_env({"ISHMEM_FAULT_PLAN": ""})
    with pytest.raises(ValueError):
        load_fault_env({"ISHMEM_FAULT_PLAN": "explode=1@2"})
    with pytest.raises(ValueError):
        load_fault_env({"ISHMEM_FAULT_SEED": "many"})
    with pytest.raises(ValueError):
        load_fault_env({"ISHMEM_FAULT_SEED": "-1"})


def test_scramble_rows_poisons_only_dead_rows():
    ctx, heap = context.init(npes=4, node_size=4)
    p = heap.malloc((8,), "float32")
    q = heap.malloc((4,), "int32")
    for pe in range(4):
        heap = heap.write(p, pe, np.full(8, 1.0, np.float32))
        heap = heap.write(q, pe, np.full(4, 7, np.int32))
    heap = scramble_rows(heap, [2])
    assert np.isnan(np.asarray(heap.read(p, 2))).all()
    assert (np.asarray(heap.read(q, 2)) != 7).all()
    for pe in (0, 1, 3):                      # live rows untouched
        np.testing.assert_array_equal(np.asarray(heap.read(p, pe)),
                                      np.full(8, 1.0, np.float32))
        np.testing.assert_array_equal(np.asarray(heap.read(q, pe)),
                                      np.full(4, 7, np.int32))


# ---------------------------------------------------------------------------
# chaos property sweep: kill-step x victim-PE x workload grid
# ---------------------------------------------------------------------------
# Pod layout at the default shape: pod0 = PE 0 (prefill) + PEs 1,2 (decode),
# pod1 = PE 3 (prefill) + PEs 4,5 (decode).


@pytest.mark.parametrize("workload_seed", (11, 23))
@pytest.mark.parametrize("victim_pe", (2, 4))
@pytest.mark.parametrize("kill_step", (2, 4))
def test_chaos_kill_grid_zero_wrong_tokens_no_leaks(workload_seed,
                                                    victim_pe, kill_step):
    """Kill one decode PE at every (step, victim, workload) grid point:
    surviving outputs bitwise vs control, pool drained, auditors clean
    within one audit period of recovery (audit_period=1 runs them every
    step, so any transiently-broken invariant would abort the run)."""
    specs = _specs(workload_seed)
    control = _fleet()
    control.run(specs)
    co = control.outputs()
    fleet = _fleet(fault_plan=f"kill_pe={victim_pe}@{kill_step}",
                   obs=Obs(audit_period=1))
    rep = fleet.run(specs)
    _assert_chaos_invariants(fleet, specs, co)
    assert victim_pe in fleet.ctx.fault.dead_pes
    assert rep["fault"]["dead_pes"] == [victim_pe]


@pytest.mark.parametrize("chaos_seed", (0, 1, 2, 3))
def test_chaos_random_plan_sweep(chaos_seed):
    """Seeded random plans (the FaultPlan.random generator) mixing PE
    kills, whole-pod loss, and partitions — same invariants."""
    specs = _specs(11)
    control = _fleet()
    control.run(specs)
    co = control.outputs()
    plan = FaultPlan.random(chaos_seed, max_step=6, pes=(1, 2, 4, 5),
                            pods=("pod0", "pod1"), n_events=2)
    fleet = _fleet(fault_plan=plan, obs=Obs(audit_period=1))
    try:
        fleet.run(specs)
    except ValueError as e:
        # a random plan may kill BOTH pods — whole-fleet failure is the
        # one fault the fleet refuses to recover from, by contract
        assert "whole-fleet" in str(e)
        return
    _assert_chaos_invariants(fleet, specs, co)


def test_chaos_drain_join_loses_nothing():
    """Administrative drain/join is not a failure: every request completes
    bitwise-identical (in-flight work finishes in place, queued work
    re-routes, the drained pod rejoins)."""
    specs = _specs(11)
    control = _fleet()
    rep0 = control.run(specs)
    co = control.outputs()
    fleet = _fleet(fault_plan="drain=pod0@1,join=pod0@5",
                   obs=Obs(audit_period=1))
    rep = fleet.run(specs)
    _assert_chaos_invariants(fleet, specs, co)
    assert rep["completed"] == rep0["completed"] == len(specs)
    for spec in specs:                        # ALL survive a drain
        assert list(np.asarray(fleet.outputs()[spec.idx]).ravel()) == \
            list(np.asarray(co[spec.idx]).ravel())
    assert len(fleet.router.pods) == 2        # pod0 rejoined the rotation


def test_chaos_lone_prefill_kill_escalates_to_adoption():
    """Killing a pod's ONLY prefill PE escalates to whole-pod adoption —
    the pod cannot stage new work, so its requests move to survivors."""
    specs = _specs(11)
    control = _fleet()
    control.run(specs)
    fleet = _fleet(fault_plan="kill_pe=0@2", obs=Obs(audit_period=1))
    fleet.run(specs)
    _assert_chaos_invariants(fleet, specs, control.outputs())
    assert [p.name for p in fleet.dead_pods] == ["pod0"]
    assert [p.name for p in fleet.pods] == ["pod1"]


# ---------------------------------------------------------------------------
# seeded regression scenarios (each with a validated postmortem dump)
# ---------------------------------------------------------------------------


def _chaos_obs(tmp_path):
    return Obs(audit_period=1, recorder_window=32,
               recorder_path=str(tmp_path / "postmortem.json"))


def _postmortem(fleet, reason):
    rec = fleet.obs.recorder
    assert rec.dumps, "fault fired but no postmortem dump was written"
    doc = json.load(open(rec.dumps[0]))
    assert obs_export.validate(doc) == []
    assert doc["otherData"]["postmortem"]["reason"] == reason
    return doc


def test_regression_kill_decode_pe_mid_stream(tmp_path):
    """Scenario 1: a decode PE dies while streams are in flight to it.
    Its requests re-migrate from live home PEs (or recompute) and replay
    their decoded-so-far tokens; the recorder names the fault."""
    specs = _specs(11)
    control = _fleet()
    control.run(specs)
    fleet = _fleet(fault_plan="kill_pe=4@5", obs=_chaos_obs(tmp_path))
    rep = fleet.run(specs)
    _assert_chaos_invariants(fleet, specs, control.outputs())
    _postmortem(fleet, "fault:kill_pe:4")
    recov = rep["recovered"]
    assert recov["remigrated"] >= 1           # KV re-migrated from home PEs
    assert recov["replayed_tokens"] >= 1      # decoded-so-far replay fired
    assert recov["recovered_requests"] >= 1


def test_regression_kill_prefill_pe_with_staged_blocks(tmp_path):
    """Scenario 2: a prefill PE dies holding staged blocks (2 prefill PEs
    per pod so the kill does NOT escalate).  Prefix entries homed on it
    drop from the index, victims recompute from prompt, and the ledger
    reconciliation keeps the auditors clean."""
    specs = _specs(11)
    shape = dict(prefill_per_pod=2, decode_per_pod=2)
    control = _fleet(**shape)
    control.run(specs)
    fleet = _fleet(fault_plan="kill_pe=0@4", obs=_chaos_obs(tmp_path),
                   **shape)                   # pod0 = prefill 0,1 + decode 2,3
    rep = fleet.run(specs)
    _assert_chaos_invariants(fleet, specs, control.outputs())
    _postmortem(fleet, "fault:kill_pe:0")
    assert rep["recovered"]["recomputed"] >= 1
    assert fleet.pods[0].name == "pod0"       # no escalation: pod0 survives
    assert 0 not in fleet.pods[0].sched.prefill_pes


def test_regression_partition_parks_cross_pod_traffic(tmp_path):
    """Scenario 3: the dcn fabric partitions for K steps.  Cross-pod ops
    stay queued (neither lost nor delivered), heal drains them, and NOTHING
    is a casualty — every request finishes bitwise-identical."""
    specs = _specs(11)
    # random routing forces cross-pod prefix pulls over the proxy ring
    control = _fleet(router="random")
    rep0 = control.run(specs)
    co = control.outputs()
    fleet = _fleet(router="random", fault_plan="partition=3@2",
                   obs=_chaos_obs(tmp_path))
    rep = fleet.run(specs)
    _assert_chaos_invariants(fleet, specs, co)
    _postmortem(fleet, "fault:partition")
    assert not fleet.ctx.fault.dcn_down       # healed
    assert rep["completed"] == rep0["completed"] == len(specs)
    for spec in specs:                        # zero casualties
        assert list(np.asarray(fleet.outputs()[spec.idx]).ravel()) == \
            list(np.asarray(co[spec.idx]).ravel())


def test_regression_whole_pod_adoption(tmp_path):
    """Whole-pod loss: survivors adopt the dead pod's requests under new
    rids with full token replay; report() carries the fault record."""
    specs = _specs(11)
    control = _fleet()
    control.run(specs)
    fleet = _fleet(fault_plan="kill_pod=pod1@3", obs=_chaos_obs(tmp_path))
    rep = fleet.run(specs)
    _assert_chaos_invariants(fleet, specs, control.outputs())
    _postmortem(fleet, "fault:kill_pod:pod1")
    assert rep["fault"]["dead_pods"] == ["pod1"]
    assert sorted(rep["fault"]["dead_pes"]) == [3, 4, 5]
    assert [e["kind"] for e in rep["fault"]["events"]] == ["kill_pod"]
