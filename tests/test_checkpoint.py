import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": {"w": jax.random.normal(k, (8, 4)),
              "b": jnp.arange(5, dtype=jnp.int32)},
        "scale": jnp.float32(3.5),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t)
    restored, meta = ck.restore(str(tmp_path), 7, jax.tree.map(
        jnp.zeros_like, t))
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, _tree(s), keep=3)
    assert ck.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000003", "step_00000004", "step_00000005"]


def test_shape_mismatch_raises(tmp_path):
    ck.save(str(tmp_path), 1, _tree())
    bad = _tree()
    bad["a"]["w"] = jnp.zeros((9, 4))
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 1, bad)


def test_missing_key_raises(tmp_path):
    ck.save(str(tmp_path), 1, {"x": jnp.zeros(3)})
    with pytest.raises(KeyError):
        ck.restore(str(tmp_path), 1, {"y": jnp.zeros(3)})


def test_trainer_resume(tmp_path):
    from repro.configs import base as cfgbase
    from repro.train import trainer
    cfg = cfgbase.reduced(cfgbase.get_config("xlstm_125m"))
    tcfg = trainer.TrainConfig(steps=4, seq_len=32, global_batch=2,
                               log_every=1, ckpt_every=2,
                               ckpt_dir=str(tmp_path))
    trainer.train(cfg, tcfg)
    assert ck.latest_step(str(tmp_path)) == 4
    tcfg2 = trainer.TrainConfig(steps=6, seq_len=32, global_batch=2,
                                log_every=1, ckpt_dir=str(tmp_path))
    _, _, hist = trainer.train(cfg, tcfg2, resume=True)
    assert hist[0]["step"] == 4                 # continued, not restarted
