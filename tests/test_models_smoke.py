"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward/train step on CPU with correct output
shapes and no NaNs, plus prefill+decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import base as cfgbase
from repro.models import kvcache, model
from repro.train import optimizer as opt_mod, train_step as ts_mod

ARCHS = cfgbase.ARCH_NAMES


def _batch(cfg, rng, B, S):
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    batch["labels"] = batch["tokens"]
    if cfg.family == "audio":
        batch["audio_embeds"] = 0.1 * jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            rng, (B, cfg.image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    assert cfg.d_model <= 512
    assert cfg.num_layers <= max(2, len(cfgbase.repeat_unit(
        cfgbase.get_config(arch))[0]))
    assert (cfg.num_experts or 0) <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    rng = jax.random.key(0)
    params, opt_state = ts_mod.init_state(rng, cfg)
    step = jax.jit(ts_mod.make_train_step(
        cfg, opt_mod.OptConfig(name=cfg.optimizer, warmup_steps=2,
                               total_steps=10)))
    B, S = 2, 64
    batch = _batch(cfg, rng, B, S)
    params, opt_state, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert jnp.isfinite(metrics["grad_norm"]), arch
    leaves = jax.tree.leaves(params)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    rng = jax.random.key(1)
    params = model.init_params(rng, cfg)
    B, S = 2, 32
    batch = _batch(cfg, rng, B, S)
    cache = kvcache.init_cache(cfg, B, S + 4)
    logits, cache = model.prefill(params, cfg, batch, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), arch
    tok = batch["tokens"][:, :1]
    lg, cache = model.decode_step(params, cfg, tok,
                                  jnp.full((B,), S, jnp.int32), cache)
    assert lg.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any()), arch


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if "arctic" not in a and "llama4" not in a])
def test_decode_matches_full_forward(arch):
    """decode(prefill(S), token S) == prefill(S+1) last logits.
    (MoE archs excluded: capacity dropping differs between batch sizes —
    covered by test_moe_consistency_high_capacity.)"""
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    rng = jax.random.key(2)
    params = model.init_params(rng, cfg)
    B, S = 2, 24
    batch = _batch(cfg, rng, B, S + 1)
    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :S]
    cache = kvcache.init_cache(cfg, B, S + 1)
    _, cache = model.prefill(params, cfg, short, cache)
    lg_dec, _ = model.decode_step(params, cfg, batch["tokens"][:, S:S + 1],
                                  jnp.full((B,), S, jnp.int32), cache)
    cache2 = kvcache.init_cache(cfg, B, S + 1)
    lg_full, _ = model.prefill(params, cfg, batch, cache2)
    assert float(jnp.abs(lg_dec - lg_full).max()) < 2e-4, arch


@pytest.mark.parametrize("arch", ["llama4_scout_17b_a16e", "arctic_480b"])
def test_moe_consistency_high_capacity(arch):
    cfg = dataclasses.replace(cfgbase.reduced(cfgbase.get_config(arch)),
                              capacity_factor=8.0)
    rng = jax.random.key(3)
    params = model.init_params(rng, cfg)
    B, S = 2, 24
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    cache = kvcache.init_cache(cfg, B, S + 1)
    _, cache = model.prefill(params, cfg, {"tokens": toks[:, :S]}, cache)
    lg_dec, _ = model.decode_step(params, cfg, toks[:, S:S + 1],
                                  jnp.full((B,), S, jnp.int32), cache)
    cache2 = kvcache.init_cache(cfg, B, S + 1)
    lg_full, _ = model.prefill(params, cfg, {"tokens": toks}, cache2)
    assert float(jnp.abs(lg_dec - lg_full).max()) < 2e-4


def test_swa_matches_full_when_window_covers():
    """SWA with window >= seq == full attention."""
    cfg = cfgbase.reduced(cfgbase.get_config("h2o_danube_3_4b"))
    cfg_full = dataclasses.replace(cfg, attention="full")
    cfg_wide = dataclasses.replace(cfg, window=4096)
    rng = jax.random.key(4)
    pa = model.init_params(rng, cfg_wide)
    B, S = 2, 48
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    batch["labels"] = batch["tokens"]
    l1, _ = model.train_loss(pa, cfg_wide, batch)
    l2, _ = model.train_loss(pa, cfg_full, batch)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_moe_aux_loss_present():
    cfg = cfgbase.reduced(cfgbase.get_config("arctic_480b"))
    rng = jax.random.key(5)
    params = model.init_params(rng, cfg)
    batch = _batch(cfg, rng, 2, 32)
    _, metrics = model.train_loss(params, cfg, batch)
    assert float(metrics["aux"]) > 0.0


def test_zamba_shared_attention_is_shared():
    cfg = cfgbase.reduced(cfgbase.get_config("zamba2_2_7b"))
    params = model.init_params(jax.random.key(6), cfg)
    assert "shared_attn" in params
    # the scanned stack holds an empty placeholder at the shared position
    unit, _ = cfgbase.repeat_unit(cfg)
    assert "shared_attn" in unit
    idx = unit.index("shared_attn")
    assert params["blocks"][idx] == {}
