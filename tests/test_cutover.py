"""Properties of the cutover engine that mirror the paper's measured
behaviour (Figs. 3-6)."""
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean interpreter: deterministic fallback
    from _minihyp import given, settings, strategies as st

from repro.core import cutover


def test_cutover_reference_points():
    """Paper Fig. 3: single-threaded cutover is a few KB; Fig. 4a/5: at ~1k
    work-items the direct path stays ahead to ~MB scale."""
    c1 = cutover.cutover_bytes(work_items=1)
    c1k = cutover.cutover_bytes(work_items=1024)
    assert 1 << 10 <= c1 <= 1 << 14          # few KB (paper: ~4 KB)
    assert c1k >= 1 << 20                    # >= 1 MB


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 1023))
def test_cutover_monotone_in_work_items(w):
    assert cutover.cutover_bytes(work_items=w) <= \
        cutover.cutover_bytes(work_items=w + 1) + 1


@settings(max_examples=40, deadline=None)
@given(st.integers(6, 26), st.sampled_from([1, 16, 128, 1024]))
def test_choose_path_consistent_with_times(log2n, w):
    n = 1 << log2n
    path = cutover.choose_path(n, work_items=w, tier="ici")
    hw = cutover.HwParams()
    td = cutover.t_direct(hw, n, w, "ici")
    te = cutover.t_engine(hw, n, "ici")
    assert path == ("direct" if td <= te else "engine")


def test_dcn_always_proxy():
    assert cutover.choose_path(64, tier="dcn") == "proxy"
    assert cutover.t_direct(cutover.HwParams(), 64, 1024, "dcn") == math.inf


def test_forced_and_fixed_cutover():
    t = cutover.Tuning(force_path="engine")
    assert cutover.choose_path(8, tuning=t) == "engine"
    t = cutover.Tuning(cutover_bytes=1000)
    assert cutover.choose_path(999, tuning=t) == "direct"
    assert cutover.choose_path(1001, tuning=t) == "engine"


def test_collective_cutover_grows_with_pes():
    """Paper Fig. 6: with more PEs the direct (push) path stays ahead to a
    larger element count (4 PEs cutover ~4K elems; 12 PEs still direct)."""
    c4 = cutover.collective_cutover_elems("fcollect", 4, 4, work_items=256)
    c12 = cutover.collective_cutover_elems("fcollect", 12, 4, work_items=256)
    assert c12 >= c4


def test_engine_flat_in_work_items():
    """Paper Fig. 4b: copy-engine bandwidth does not depend on work-items."""
    hw = cutover.HwParams()
    assert cutover.t_engine(hw, 1 << 20, "ici") == \
        cutover.t_engine(hw, 1 << 20, "ici")
    t1 = cutover.op_time(1 << 20, "engine", work_items=1)
    t2 = cutover.op_time(1 << 20, "engine", work_items=1024)
    assert t1 == t2


def test_op_time_monotone_in_bytes():
    hw = cutover.HwParams()
    for path in ("direct", "engine", "proxy"):
        prev = 0.0
        for lb in range(6, 24, 2):
            t = cutover.op_time(1 << lb, path, work_items=64)
            assert t >= prev
            prev = t


def test_sync_cost_scales_with_pes():
    t4 = cutover.t_collective("sync", 8, 4)
    t12 = cutover.t_collective("sync", 8, 12)
    assert t12 > t4


# ---------------------------------------------------------------------------
# comm-compute overlap model (completion engine)
# ---------------------------------------------------------------------------


def test_ring_overlap_never_slower_when_compute_bound():
    """With app tile compute to hide, the nbi schedule beats blocking."""
    hw = cutover.HwParams()
    for lb in (18, 20, 22, 24):
        n = 1 << lb
        eff = cutover.overlap_efficiency(n, 8, hw=hw,
                                         step_compute_bytes=4 * n / 8)
        assert eff > 1.0, (lb, eff)


def test_ring_overlap_bounded_by_two():
    """Perfect overlap can at most halve a transfer+compute step."""
    hw = cutover.HwParams()
    for lb in (12, 16, 20, 24):
        for c in (0.0, 1.0, 8.0):
            eff = cutover.overlap_efficiency(1 << lb, 8, hw=hw,
                                             step_compute_bytes=c * (1 << lb))
            assert eff < 2.0


def test_ring_blocking_matches_sum_of_steps():
    hw = cutover.HwParams()
    n, npes = 1 << 20, 8
    chunk = n / npes
    tx = cutover.t_ring_step(chunk, hw=hw)
    ta = chunk / hw.reduce_bw
    expect = (npes - 1) * (tx + ta) + (npes - 1) * tx
    got = cutover.t_ring_allreduce(n, npes, hw=hw, overlap=False)
    assert got == pytest.approx(expect)


def test_choose_collective_path_precedence():
    """The single chooser honors FORCE_PATH > CUTOVER_BYTES > table >
    analytic for collectives too (the dedup of collectives._path)."""
    assert cutover.choose_collective_path(
        "broadcast", 1 << 20, 8,
        tuning=cutover.Tuning(force_path="proxy")) == "proxy"
    assert cutover.choose_collective_path(
        "broadcast", 1 << 20, 8,
        tuning=cutover.Tuning(cutover_bytes=1 << 10)) == "engine"
    assert cutover.choose_collective_path(
        "broadcast", 64, 8,
        tuning=cutover.Tuning(cutover_bytes=1 << 10)) == "direct"
    # analytic fallback: identical to the old collectives._path comparison
    td = cutover.t_collective("reduce", 4096, 8, work_items=16, path="direct")
    te = cutover.t_collective("reduce", 4096, 8, path="engine")
    want = "direct" if td <= te else "engine"
    assert cutover.choose_collective_path("reduce", 4096, 8,
                                          work_items=16) == want
