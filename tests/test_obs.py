"""Observability acceptance (repro.obs):

- StepClock determinism + SpanTracer span bookkeeping and truncation,
- Chrome-trace export schema validation (positive + adversarial negatives),
- trace causality invariants under a stressed fleet (preemption, shed,
  chunked streaming): every span closes, every per-request lifeline is
  gap-free and reconstructs with queue/wire/compute attribution,
- tracer off => bitwise-identical outputs and report,
- online re-fit: a stale warm-start table is corrected from live telemetry
  and at least one cutover decision flips,
- ISHMEM_OBS_* env surface + metrics registry units.
"""
import functools
import json

import jax
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.core import context, cutover
from repro.models import model
from repro.obs import (NULL_TRACER, Obs, OnlineRefitter, SpanTracer,
                       chrome_trace, load_obs_env, request_chains, validate)
from repro.obs.export import chain_gaps, write_chrome_trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import STEP_QUANTUM, StepClock
from repro.serve.engine import Engine
from repro.serve.frontend import Fleet, FleetConfig, TenantSpec, TrafficEngine
from repro.tune import estimator, table as table_mod

MAXLEN = 24
NEW = 4


@functools.lru_cache(maxsize=1)
def _engine():
    cfg = cfgbase.reduced(cfgbase.get_config("qwen3_4b"))
    params = model.init_params(jax.random.key(0), cfg)
    return cfg, Engine(cfg, params, max_len=MAXLEN)


def _fleet(obs=None, **over):
    cfg, engine = _engine()
    kw = dict(n_pods=2, prefill_per_pod=1, decode_per_pod=2, num_slots=2,
              kv_blocks=96, block_tokens=4, max_len=MAXLEN, max_new=NEW,
              stream_chunks=1, admission="slo", router="affinity", seed=11)
    kw.update(over)
    return Fleet(FleetConfig(**kw), engine=engine, obs=obs)


# ---------------------------------------------------------------------------
# step clock
# ---------------------------------------------------------------------------


def test_step_clock_deterministic_and_monotonic():
    clk = StepClock()
    a, b, c = clk.now(), clk.now(), clk.now()
    assert a < b < c                               # sub-ticks strictly grow
    clk.set_step(3)
    t = clk.now()
    assert t == 3 * STEP_QUANTUM                   # fresh quantum, seq reset
    clk.set_step(1)                                # going back is a no-op
    assert clk.step == 3
    assert clk.now() > t
    # sub-ticks never bleed into the next step's quantum
    for _ in range(2 * STEP_QUANTUM):
        last = clk.now()
    assert last < 4 * STEP_QUANTUM


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------


def test_span_tracer_bookkeeping_and_export():
    tr = SpanTracer()
    tr.begin("flush", "cq", "core", "cq", ops=3)
    tr.instant("xfer", "cq", "core", "cq", path="direct")
    tr.end("flush", "cq", "core", "cq", bytes=128)
    tr.async_begin("queued", "req", 7, "pod0", "requests")
    tr.async_end("queued", "req", 7, "pod0", "requests")
    tr.flow_start(7, "migration", "pod0", "pe0")
    tr.flow_end(7, "migration", "pod1", "pe2")
    tr.counter("cq_pending", "core", "cq", pending=0)
    assert tr.open_spans() == {"slices": {}, "async": {}}
    doc = chrome_trace(tr)
    assert validate(doc) == []
    # metadata rows name every process/thread track exactly once
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {(m["name"], m["pid"]) for m in meta} >= \
        {("process_name", "core"), ("process_name", "pod0")}


def test_span_tracer_open_spans_reports_leaks():
    tr = SpanTracer()
    tr.begin("flush", "cq", "core", "cq")
    tr.async_begin("decoding", "req", 3, "pod0", "requests")
    leaks = tr.open_spans()
    assert leaks["slices"] == {("core", "cq"): ["flush"]}
    assert leaks["async"] == {("req", 3, "decoding"): 1}
    assert validate(chrome_trace(tr))              # and validate agrees


def test_span_tracer_truncation_still_closes_spans():
    tr = SpanTracer(max_events=4)
    tr.begin("step", "fleet", "fleet", "steps")
    tr.async_begin("decoding", "req", 1, "pod0", "requests")
    for _ in range(50):
        tr.instant("xfer", "cq", "core", "cq")
    assert tr.dropped > 0 and len(tr.events) <= 4 + 2
    # ends of known-open spans are force-admitted past the bound, so the
    # truncated trace still validates clean
    tr.async_end("decoding", "req", 1, "pod0", "requests")
    tr.end("step", "fleet", "fleet", "steps")
    assert tr.open_spans() == {"slices": {}, "async": {}}
    doc = chrome_trace(tr)
    # structurally valid, but the truncation is SURFACED: by default the
    # dropped-event warning rides the error list (a truncated trace must
    # not silently pass the CI gate); warnings=[] splits it back out
    warnings = []
    assert validate(doc, warnings=warnings) == []
    assert len(warnings) == 1 and "dropped" in warnings[0]
    errs = validate(doc)
    assert len(errs) == 1 and errs[0].startswith("warning:")
    assert doc["otherData"]["dropped_events"] == tr.dropped > 0


# ---------------------------------------------------------------------------
# export validation: adversarial documents
# ---------------------------------------------------------------------------


def _doc(events):
    return {"traceEvents": events}


def test_validate_rejects_malformed_documents():
    ok = {"name": "x", "cat": "t", "ph": "i", "ts": 1, "pid": "p", "tid": "t"}
    assert validate(_doc([ok])) == []
    assert validate({"nope": 1})                   # traceEvents missing
    assert validate(_doc([{"ph": "i", "ts": 1}]))  # missing name/pid
    assert validate(_doc([dict(ok, ts=None)]))     # non-numeric ts
    bad_tid = dict(ok)
    del bad_tid["tid"]
    assert validate(_doc([bad_tid]))
    # ts regression on one (pid, tid) track
    assert validate(_doc([dict(ok, ts=5), dict(ok, ts=3)]))
    # unmatched E / E under wrong name
    assert validate(_doc([dict(ok, ph="E", name="f")]))
    assert validate(_doc([dict(ok, ph="B", name="a", ts=1),
                          dict(ok, ph="E", name="b", ts=2)]))
    # unclosed B at end of trace
    assert validate(_doc([dict(ok, ph="B", name="a")]))
    # async end before begin / async without id / unclosed async
    assert validate(_doc([dict(ok, ph="e", id="1")]))
    assert validate(_doc([dict(ok, ph="b")]))
    assert validate(_doc([dict(ok, ph="b", id="1")]))
    # flows: start without finish, finish without start, count mismatch
    assert validate(_doc([dict(ok, ph="s", id="9")]))
    assert validate(_doc([dict(ok, ph="f", id="9")]))
    assert validate(_doc([dict(ok, ph="s", id="9", ts=1),
                          dict(ok, ph="s", id="9", ts=2),
                          dict(ok, ph="f", id="9", ts=3)]))


def test_request_chains_and_gap_detection():
    tr = SpanTracer()
    tr.async_begin("queued", "req", 5, "pod0", "requests", prompt_len=8)
    tr.async_end("queued", "req", 5, "pod0", "requests", queue_steps=0)
    tr.async_begin("prefill", "req", 5, "pod0", "requests")
    tr.async_end("prefill", "req", 5, "pod0", "requests", pe=0)
    # untraced hole: next phase opens 500 ticks later
    tr.clock.set_step(2)
    tr.async_begin("decoding", "req", 5, "pod0", "requests")
    tr.async_end("decoding", "req", 5, "pod0", "requests",
                 outcome="finished")
    chains = request_chains(tr)
    assert list(chains) == [5]
    phases = [e["phase"] for e in chains[5]]
    assert phases == ["queued", "prefill", "decoding"]
    # end-side args override/merge onto the begin-side ones
    assert chains[5][0]["args"] == {"prompt_len": 8, "queue_steps": 0}
    gaps = chain_gaps(chains[5])
    assert len(gaps) == 1 and gaps[0][1] == 2 * STEP_QUANTUM
    # adjacent sub-tick handoffs (the normal case) are NOT gaps
    assert chain_gaps(chains[5][:2]) == []


# ---------------------------------------------------------------------------
# causality invariants under a stressed fleet
# ---------------------------------------------------------------------------

TERMINAL = {"finished", "shed"}


@functools.lru_cache(maxsize=1)
def _stressed_run():
    """One overloaded fleet run (sheds + preempts + chunked streaming),
    traced and metered — shared by the invariant tests below."""
    cfg, _ = _engine()
    heavy = (TenantSpec("chat", prompt_lens=(8,), max_new=(NEW,),
                        slo="interactive"),
             TenantSpec("scan", prompt_lens=(12,), max_new=(12,),
                        slo="batch"))
    obs = Obs(trace=True, metrics=True)
    fleet = _fleet(obs=obs, admission="slo", router="least_loaded",
                   num_slots=1, queue_bound=3, kv_blocks=128,
                   stream_chunks=2)
    traffic = TrafficEngine(list(heavy), rate=3.0, vocab=cfg.vocab_size,
                            seed=23)
    report = fleet.run(traffic.schedule(16), max_steps=2500)
    return fleet, obs, report


def test_stressed_trace_all_spans_close_and_validate(tmp_path):
    fleet, obs, report = _stressed_run()
    assert report["preempts"] >= 1 and report["shed"] > 0   # stress happened
    assert obs.tracer.open_spans() == {"slices": {}, "async": {}}
    doc = write_chrome_trace(obs.tracer, str(tmp_path / "trace.json"))
    assert validate(doc) == []
    # the file round-trips and still validates (what CI gate (b) runs)
    loaded = json.loads((tmp_path / "trace.json").read_text())
    assert validate(loaded) == []
    assert loaded["otherData"]["schema_version"] >= 1


def test_stressed_trace_chains_cover_every_request():
    fleet, obs, report = _stressed_run()
    chains = request_chains(obs.tracer)
    # every submitted request (routed through placements) has a lifeline
    rids = {rid for _, rid in fleet.placements.values()}
    assert rids and rids == set(chains)
    saw_preempt = saw_shed = saw_stream = False
    for rid, chain in chains.items():
        # parent-before-child: phases begin in order, no overlaps missing
        t0s = [e["t0"] for e in chain]
        assert t0s == sorted(t0s)
        assert all(e["t1"] is not None and e["t1"] >= e["t0"]
                   for e in chain), f"rid {rid}: unclosed phase"
        assert chain_gaps(chain) == [], f"rid {rid}: lifeline has holes"
        last = chain[-1]["args"].get("outcome")
        assert last in TERMINAL, f"rid {rid}: ended in {last!r}"
        phases = [e["phase"] for e in chain]
        if last == "shed":
            assert phases == ["shed"]
            saw_shed = True
        else:
            assert phases[0] == "queued"
            assert phases[-1] == "decoding"
            saw_preempt |= "preempted" in phases
            saw_stream |= "streaming" in phases
            # attribution rides on the phase that measured it
            by = {e["phase"]: e["args"] for e in chain}
            assert by["queued"]["queue_steps"] >= 0
            assert by["migrating"]["bytes"] > 0
            assert by["migrating"]["wire_model_s"] >= 0.0
            assert by["decoding"]["decode_steps"] >= 0
    assert saw_shed and saw_preempt and saw_stream


def test_stressed_metrics_series_track_fleet_steps():
    fleet, obs, report = _stressed_run()
    rows = obs.metrics.series
    assert len(rows) == fleet.elapsed_steps
    assert [r["step"] for r in rows] == list(range(1, len(rows) + 1))
    last = rows[-1]
    # drained: pool empty, queues empty, per-class goodput tallied
    assert last["pool.blocks_in_use"] == 0
    assert last["pod0.queue_depth"] == 0 and last["pod1.queue_depth"] == 0
    assert last["class.interactive.offered"] > 0
    assert 0.0 <= last["class.interactive.goodput"] <= 1.0
    assert last["class.batch.shed"] + last["class.interactive.shed"] == \
        report["shed"]
    # mid-run rows saw real occupancy
    assert max(r.get("pool.blocks_in_use", 0) for r in rows) > 0
    assert report["obs"]["trace_events"] == len(obs.tracer.events)


# ---------------------------------------------------------------------------
# tracer off => bitwise identical
# ---------------------------------------------------------------------------


def test_tracer_off_is_bitwise_identical():
    """The overhead contract: attaching a recording tracer must not change
    one bit of scheduling, outputs, or the report — and NO tracer (the
    default Null path) must equal the pre-obs stack exactly."""
    cfg, _ = _engine()

    def run(obs):
        fleet = _fleet(obs=obs, num_slots=1, queue_bound=64, seed=17)
        traffic = TrafficEngine(
            [TenantSpec("chat", weight=2.0, prompt_lens=(8,),
                        max_new=(NEW,), slo="interactive"),
             TenantSpec("scan", weight=1.0, prompt_lens=(12,),
                        max_new=(NEW,), slo="batch", shared_prefix_prob=0.5,
                        prefix_groups=1)],
            rate=1.0, vocab=cfg.vocab_size, seed=17)
        rep = fleet.run(traffic.schedule(8), max_steps=1500)
        rep.pop("obs", None)
        return fleet.outputs(), rep

    outs_off, rep_off = run(None)
    outs_null, rep_null = run(Obs())               # bundle present, all off
    outs_on, rep_on = run(Obs(trace=True, metrics=True))
    assert rep_off == rep_null == rep_on
    assert set(outs_off) == set(outs_null) == set(outs_on)
    for idx in outs_off:
        np.testing.assert_array_equal(outs_off[idx], outs_null[idx])
        np.testing.assert_array_equal(outs_off[idx], outs_on[idx])


# ---------------------------------------------------------------------------
# online re-fit
# ---------------------------------------------------------------------------


def _stale_table():
    """A warm-start table whose cutovers are absurdly high: every probe
    point decides 'direct', contradicting both the analytic model and what
    live telemetry supports at large sizes / small work-groups."""
    big = 1 << 30
    return table_mod.TuningTable(cutovers={
        ("local", 1): big, ("local", 512): big,
        ("ici", 1): big, ("ici", 512): big})


def test_online_refit_corrects_stale_warm_start():
    ctx, _ = context.init(npes=4, node_size=2,
                          tuning=cutover.Tuning(table=_stale_table()))
    estimator.synthetic_sweep(ctx.hw, sink=ctx.telemetry)
    rf = OnlineRefitter(ctx, period_steps=10, min_samples=8)
    assert rf.maybe_refit(5) is None               # period not yet elapsed
    ev = rf.maybe_refit(20)
    assert ev is not None and len(ev.changed) >= 1
    assert rf.decisions_changed() >= 1
    # the stale table was hot-swapped out, and the corrected decisions
    # agree with the analytic model the live samples were priced by
    assert ctx.tuning.table is not None
    assert ctx.tuning.table.cutovers != _stale_table().cutovers
    assert all(old != new for (_, _, _, old, new) in ev.changed)
    # far from any boundary the corrected decision must match the analytic
    # model the live samples were priced by: 4 MiB at 1 work-item is engine
    big = max(rf.probe_sizes)
    assert ("ici", 1, big, "direct", "engine") in ev.changed
    # serialization carries the flip list (what the bench emits)
    j = ev.to_json()
    assert j["nsamples"] >= 8 and len(j["changed"]) == len(ev.changed)
    assert rf.maybe_refit(21) is None              # period re-arms


def test_online_refit_gates_on_samples_and_period():
    ctx, _ = context.init(npes=2, node_size=2)
    rf = OnlineRefitter(ctx, period_steps=1, min_samples=8)
    assert rf.maybe_refit(100) is None             # empty sink: no re-fit
    assert rf.history == []
    with pytest.raises(ValueError):
        OnlineRefitter(ctx, period_steps=0)


def test_refit_from_clean_start_is_a_stable_noop():
    """Honesty check on the demo design: with NO stale table, live samples
    are priced by the same analytic model choose_path falls back to, so a
    re-fit converges to the decisions already being made.  (Probed at the
    work-item sizes the sweep covered: in between, the table's nearest-key
    lookup intentionally quantizes and may differ from the analytic model.)
    """
    ctx, _ = context.init(npes=4, node_size=2, tuning=cutover.Tuning())
    estimator.synthetic_sweep(ctx.hw, work_items=(1, 128),
                              sink=ctx.telemetry)
    rf = OnlineRefitter(ctx, period_steps=1, min_samples=8,
                        probe_wis=(1, 128))
    ev = rf.refit(0)
    assert ev.changed == []


# ---------------------------------------------------------------------------
# Obs bundle + env surface
# ---------------------------------------------------------------------------


def test_obs_bundle_wiring():
    obs = Obs()
    assert obs.tracer is NULL_TRACER and obs.metrics is None
    with pytest.raises(RuntimeError):
        obs.write_trace("/dev/null")
    with pytest.raises(RuntimeError):
        obs.write_metrics("/dev/null")
    ctx, _ = context.init(npes=2, node_size=2)
    assert ctx.tracer is NULL_TRACER               # the default default
    on = Obs(trace=True, refit_period=25, trace_limit=4096)
    on.attach(ctx)
    assert ctx.tracer is on.tracer and on.tracer.enabled
    assert on.tracer.max_events == 4096
    assert on.refitter is not None
    assert on.refitter.period_steps == 25


def test_obs_env_surface():
    cfg = load_obs_env({})
    assert not cfg.enabled and not cfg.trace and cfg.refit_period == 0
    cfg = load_obs_env({"ISHMEM_OBS_TRACE": "1",
                        "ISHMEM_OBS_METRICS": "m.json",
                        "ISHMEM_OBS_REFIT": "50",
                        "ISHMEM_OBS_REFIT_MIN_SAMPLES": "16",
                        "ISHMEM_OBS_TRACE_LIMIT": "64K"})
    assert cfg.enabled and cfg.trace and cfg.trace_path is None
    assert cfg.metrics and cfg.metrics_path == "m.json"
    assert (cfg.refit_period, cfg.refit_min_samples) == (50, 16)
    assert cfg.trace_limit == 64 << 10
    assert load_obs_env({"ISHMEM_OBS_TRACE": "off"}).trace is False
    assert load_obs_env({"ISHMEM_OBS_TRACE": "t.json"}).trace_path == "t.json"
    with pytest.raises(ValueError):
        load_obs_env({"ISHMEM_OBS_REFIT": "often"})
    with pytest.raises(ValueError):
        load_obs_env({"ISHMEM_OBS_REFIT": "-1"})
    with pytest.raises(ValueError):
        load_obs_env({"ISHMEM_OBS_TRACE_LIMIT": "lots"})
    obs = Obs.from_config(load_obs_env({"ISHMEM_OBS_TRACE": "1"}))
    assert obs.tracer.enabled


def test_metrics_registry_units(tmp_path):
    reg = MetricsRegistry()
    reg.count("flushes")
    reg.count("flushes", 2)
    reg.gauge("queue_depth", 7)
    for v in (1, 2, 1000):
        reg.observe("xfer_bytes", v)
    row = reg.sample(step=3)
    assert row == {"step": 3, "queue_depth": 7.0, "flushes": 3.0}
    doc = reg.write(str(tmp_path / "metrics.json"))
    loaded = json.loads((tmp_path / "metrics.json").read_text())
    assert loaded == doc
    assert loaded["counters"]["flushes"] == 3.0
    assert loaded["histograms"]["xfer_bytes"] == {"0": 1, "1": 1, "9": 1}
    assert loaded["series"] == [row]
