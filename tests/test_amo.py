import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean interpreter: deterministic fallback
    from _minihyp import given, settings, strategies as st

from repro.core import amo, context


@pytest.fixture()
def ctxheap():
    return context.init(npes=4)


def test_fetch_add_inc(ctxheap):
    ctx, heap = ctxheap
    p = heap.malloc((), "int32")
    heap, old = amo.fetch_add(ctx, heap, p, 5, 2)
    assert int(old) == 0
    heap, old = amo.fetch_inc(ctx, heap, p, 2)
    assert int(old) == 5
    assert int(amo.fetch(ctx, heap, p, 2)) == 6
    assert int(amo.fetch(ctx, heap, p, 1)) == 0   # other PE untouched


def test_swap_cswap(ctxheap):
    ctx, heap = ctxheap
    p = heap.malloc((), "int32")
    heap = amo.set_(ctx, heap, p, 7, 0)
    heap, old = amo.swap(ctx, heap, p, 9, 0)
    assert int(old) == 7
    heap, old = amo.compare_swap(ctx, heap, p, 9, 11, 0)
    assert int(old) == 9 and int(amo.fetch(ctx, heap, p, 0)) == 11
    heap, old = amo.compare_swap(ctx, heap, p, 999, 0, 0)   # cond fails
    assert int(amo.fetch(ctx, heap, p, 0)) == 11


def test_bitwise(ctxheap):
    ctx, heap = ctxheap
    p = heap.malloc((), "uint32")
    heap = amo.set_(ctx, heap, p, 0b1100, 1)
    heap, _ = amo.fetch_and(ctx, heap, p, 0b1010, 1)
    assert int(amo.fetch(ctx, heap, p, 1)) == 0b1000
    heap, _ = amo.fetch_or(ctx, heap, p, 0b0001, 1)
    assert int(amo.fetch(ctx, heap, p, 1)) == 0b1001
    heap, _ = amo.fetch_xor(ctx, heap, p, 0b1111, 1)
    assert int(amo.fetch(ctx, heap, p, 1)) == 0b0110


def test_float_amo(ctxheap):
    ctx, heap = ctxheap
    p = heap.malloc((), "float32")
    heap, _ = amo.fetch_add(ctx, heap, p, 0.5, 3)
    heap, _ = amo.fetch_add(ctx, heap, p, 0.25, 3)
    assert float(amo.fetch(ctx, heap, p, 3)) == 0.75


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["add", "cswap", "swap"]),
                          st.integers(-5, 5)), max_size=15))
def test_linearizable_against_python_model(ops):
    """Any sequential schedule of AMOs matches a plain python RMW model."""
    ctx, heap = context.init(npes=2)
    p = heap.malloc((), "int32")
    model = 0
    for kind, v in ops:
        if kind == "add":
            heap, old = amo.fetch_add(ctx, heap, p, v, 0)
            assert int(old) == model
            model += v
        elif kind == "swap":
            heap, old = amo.swap(ctx, heap, p, v, 0)
            assert int(old) == model
            model = v
        else:
            heap, old = amo.compare_swap(ctx, heap, p, model, v, 0)
            assert int(old) == model
            model = v
    assert int(amo.fetch(ctx, heap, p, 0)) == model
