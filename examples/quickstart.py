"""Quickstart: the Intel-SHMEM-style PGAS API in 60 lines.

Creates 8 PEs (2 "pods" of 4), allocates symmetric buffers, and exercises the
paper's core ops: put/get, work-group put, atomics, signaling, push-style
sync, broadcast/fcollect/reduce, and a reverse-offloaded cross-pod put via
the lock-free 64-byte ring (paper §III-D).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import amo, collectives, context, proxy, rma, signal

# ishmem_init: 8 PEs, 4 per shared-fabric node (pod)
ctx, heap = context.init(npes=8, node_size=4)

# --- symmetric allocation (host-only API, identical layout at every PE) ----
buf = heap.malloc((1024,), "float32")
sig = heap.malloc((), "uint32")
ctr = heap.malloc((), "int32")

# --- RMA: blocking put/get (paper Fig. 3) -----------------------------------
data = jnp.arange(1024, dtype=jnp.float32)
heap = rma.put(ctx, heap, buf, data, dst_pe=3, src_pe=0)         # intra-pod
print("get(3)[:4]          =", rma.get(ctx, heap, buf, 3)[:4])

# work-group collaborative put: 1024 work-items (paper Fig. 4a)
heap = rma.put(ctx, heap, buf, data * 2, dst_pe=1, src_pe=0, work_items=1024)
print("wg put path          =", ctx.ledger[-1].path,
      f"({ctx.ledger[-1].t_sec * 1e6:.2f} us)")

# --- AMOs + signaling -------------------------------------------------------
heap, old = amo.fetch_add(ctx, heap, ctr, 5, pe=2)
heap = signal.put_signal(ctx, heap, buf, data, sig, 1,
                         signal.SIGNAL_ADD, dst_pe=2, src_pe=0)
heap, cur, ok = signal.signal_wait_until(ctx, heap, sig, 2, "ge", 1)
print("signal at PE2        =", int(cur), "satisfied:", bool(ok))

# --- non-blocking ops: deferred until quiet (completion engine) -------------
heap = rma.put_nbi(ctx, heap, buf, data * 3, dst_pe=2, src_pe=0)
print("before quiet [1]     =", float(heap.read(buf, 2)[1]), "(old value)")
heap = rma.quiet(ctx, heap)                 # completes + coalesces the queue
print("after  quiet [1]     =", float(heap.read(buf, 2)[1]),
      f"(coalescing ratio {ctx.pending.stats.coalescing_ratio():.1f})")

# --- collectives on the shared-fabric team (paper Figs. 6-7) ---------------
team = ctx.team_shared(0)                                   # PEs 0..3
heap = collectives.broadcast(ctx, heap, buf, root=0, team=team,
                             work_items=128)
heap = collectives.reduce(ctx, heap, buf, buf, "sum", team)
print("reduce[0][:4]        =", heap.read(buf, 0)[:4])

sync_ctr = heap.malloc((), "int32")
heap, sat = collectives.sync(ctx, heap, sync_ctr, team)
print("push-sync satisfied  =", sat.tolist())

# --- cross-pod put: reverse offload through the 64-byte ring ---------------
px = proxy.HostProxy(ctx)
px.put(buf, jnp.full((1024,), 9.0), pe=7)                   # PE 7 = other pod
heap = px.drain(heap)                                       # host proxy thread
print("cross-pod put        =", heap.read(buf, 7)[:4],
      f"(ring: {len(px.ring.delivered)} msgs, "
      f"flow-control overhead {px.ring.flow_control_overhead():.1%})")

print("\nledger:", len(ctx.ledger), "ops,",
      f"modeled total {ctx.total_time() * 1e6:.1f} us")
