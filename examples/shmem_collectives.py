"""Device-initiated collectives: the paper's technique as Pallas kernels.

Runs the ring fcollect / reduce-scatter / push broadcast / push barrier
kernels across 8 simulated PEs (TPU interpret mode — the same pallas_calls
compile to real ICI RDMA on TPU), and compares the shmem comms backend
against jax.lax for a tensor-parallel psum.

Run:  PYTHONPATH=src python examples/shmem_collectives.py
(This example sets XLA_FLAGS itself; run it as a standalone script.)
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
from jax.sharding import PartitionSpec as P                    # noqa: E402

from repro.comms import api                                    # noqa: E402
from repro.kernels import ops, ref                             # noqa: E402

NPES = 8
mesh = jax.make_mesh((NPES,), ("x",))
sm = lambda f, ins, outs: jax.jit(jax.shard_map(
    f, mesh=mesh, in_specs=ins, out_specs=outs, check_vma=False))

x = jax.random.normal(jax.random.key(0), (NPES, 512))

# fcollect (ring all-gather), device-initiated
ag = sm(lambda v: ops.ring_allgather(v[0], axis_name="x", npes=NPES)[None],
        P("x", None), P("x", None, None))(x)
print("fcollect ok     :", bool(jnp.allclose(ag, ref.ring_allgather(x))))

# push broadcast from root 2
bc = sm(lambda v: ops.push_broadcast(v[0], axis_name="x", npes=NPES,
                                     root=2)[None],
        P("x", None), P("x", None))(x)
print("broadcast ok    :", bool(jnp.allclose(bc, ref.push_broadcast(x, 2))))

# push-style barrier (the paper's atomic-increment sync)
bar = sm(lambda: ops.barrier_push(axis_name="x", npes=NPES), (), P("x"))()
print("barrier         :", bar.tolist())

# tensor-parallel psum: shmem backend vs lax
xa = jax.random.normal(jax.random.key(1), (NPES, 4, 256))
shmem = api.get_ops("shmem", npes=NPES)
xla = api.get_ops("xla")
ps_shmem = sm(lambda v: shmem.psum(v[0], "x")[None],
              P("x", None, None), P("x", None, None))(xa)
ps_xla = sm(lambda v: xla.psum(v[0], "x")[None],
            P("x", None, None), P("x", None, None))(xa)
err = float(jnp.abs(ps_shmem - ps_xla).max())
print(f"psum shmem==xla : max|diff| = {err:.2e}")
