"""End-to-end serving driver, in three acts:

1. lockstep batched generation across architecture families (the original
   demo — prefill + decode with KV/recurrent caches),
2. **continuous batching** on the slot engine: more requests than decode
   slots, requests admitted mid-flight as earlier ones finish and are
   evicted — decode reads K/V straight from the symmetric-heap block pool
   (paged attention), and
3. **streaming admission**: chunked prefill puts each filled block run on
   the wire mid-prefill with a monotonically ramping signal, so admission
   waits only for the final installment — plus shared-prefix block reuse
   across many samples of one prompt (copy-on-write on divergence).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax

from repro.configs import base as cfgbase
from repro.core import context, teams
from repro.models import model
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kvpool import KVPool
from repro.serve.kvxfer import KVMigrator
from repro.serve.scheduler import DisaggScheduler

# --- act 1: lockstep batches across families -------------------------------
for arch in ("qwen3-4b", "zamba2-2.7b", "whisper-medium"):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    params = model.init_params(jax.random.key(0), cfg)
    B, S, NEW = 4, 24, 12
    eng = Engine(cfg, params, max_len=S + NEW)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.family == "audio":
        batch["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq, cfg.d_model))
    t0 = time.time()
    out = eng.generate(batch, ServeConfig(max_new_tokens=NEW,
                                          temperature=0.8))
    dt = time.time() - t0
    print(f"[serve] {arch:16s} batch={B} prompt={S} new={NEW} "
          f"({dt:.2f}s, {B * NEW / dt:.1f} tok/s)  sample: {out[0][:8]}")

# --- act 2: continuous batching with slot rotation -------------------------
# 7 requests through 2 decode slots: the scheduler prefills, migrates the
# paged KV over the symmetric heap, admits on the block signal, and rotates
# finished requests out mid-flight.
cfg = cfgbase.reduced(cfgbase.get_config("qwen3-4b"))
params = model.init_params(jax.random.key(0), cfg)
S, NEW, NPES = 16, 8, 4
ctx, heap = context.init(npes=NPES, node_size=NPES)
pre, dec = teams.disagg_partition(teams.world(NPES), 2)
eng = Engine(cfg, params, max_len=S + NEW)
pool = KVPool.create(heap, cfg, S + NEW, num_blocks=24, max_slots=2,
                     block_tokens=8)
sched = DisaggScheduler(
    ctx, heap, eng, pool, KVMigrator(ctx, pool),
    prefill_pes=pre.pes(), decode_pes=dec.pes(), num_slots=2,
    scfg=ServeConfig(max_new_tokens=NEW), admit_delay_steps=1)
for i in range(7):
    sched.submit({"tokens": jax.random.randint(
        jax.random.fold_in(jax.random.key(3), i), (1, S), 0,
        cfg.vocab_size)})
t0 = time.time()
outs = sched.run()
dt = time.time() - t0
st = sched.stats
print(f"[serve] continuous batching: {len(outs)} reqs through "
      f"{len(dec.pes())}x2 slots in {st.decode_steps} decode steps "
      f"({dt:.2f}s); {st.migrations} migrations "
      f"{st.bytes_migrated // 1024} KiB, coalescing "
      f"{ctx.pending.stats.coalescing_ratio():.2f}, "
      f"ttfd {sum(st.ttfd_steps) / len(st.ttfd_steps):.1f} steps")
for rid in sorted(outs)[:3]:
    print(f"[serve]   req {rid}: {outs[rid].tolist()}")

# --- act 3: streaming admission + shared prefixes ---------------------------
# 6 samples of ONE prompt: prefix blocks are mapped, not restaged (one wire
# copy per decode PE), prefill streams 1 block per step mid-prefill, and the
# first divergent decode write copy-on-writes the shared boundary block.
ctx, heap = context.init(npes=NPES, node_size=NPES)
pool = KVPool.create(heap, cfg, S + NEW, num_blocks=24, max_slots=2,
                     block_tokens=4)
sched = DisaggScheduler(
    ctx, heap, eng, pool, KVMigrator(ctx, pool),
    prefill_pes=pre.pes(), decode_pes=dec.pes(), num_slots=2,
    scfg=ServeConfig(max_new_tokens=NEW, temperature=0.8, seed=4),
    admit_delay_steps=1, stream_chunks=1, shared_prefix=True)
prompt = jax.random.randint(jax.random.key(5), (1, S - 2), 0, cfg.vocab_size)
for _ in range(6):
    sched.submit({"tokens": prompt}, prefix_len=S - 2)
outs = sched.run()
st = sched.stats
print(f"[serve] streaming admission: {st.stream_chunks} wire installments, "
      f"window {sum(st.ttfd_model_s) / len(st.ttfd_model_s) * 1e6:.1f} us; "
      f"shared prefix: {st.prefix_hits} hits / "
      f"{st.blocks_prefix_shared} blocks mapped / "
      f"{st.bytes_wire_saved // 1024} KiB wire saved / "
      f"{st.cow_copies} copy-on-writes")
for rid in sorted(outs)[:3]:
    print(f"[serve]   sample {rid}: {outs[rid].tolist()}")
