"""End-to-end serving driver: batched requests through prefill + decode with
KV/recurrent caches — including a sub-quadratic arch (zamba2 hybrid) whose
long-context decode path is the paper technique's latency-bound showcase.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.models import model
from repro.serve.engine import Engine, ServeConfig

for arch in ("qwen3-4b", "zamba2-2.7b", "whisper-medium"):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    params = model.init_params(jax.random.key(0), cfg)
    B, S, NEW = 4, 24, 12
    eng = Engine(cfg, params, max_len=S + NEW)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.family == "audio":
        batch["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq, cfg.d_model))
    t0 = time.time()
    out = eng.generate(batch, ServeConfig(max_new_tokens=NEW,
                                          temperature=0.8))
    dt = time.time() - t0
    print(f"[serve] {arch:16s} batch={B} prompt={S} new={NEW} "
          f"({dt:.2f}s, {B * NEW / dt:.1f} tok/s)  sample: {out[0][:8]}")
