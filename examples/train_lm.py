"""End-to-end driver: train a ~100M-parameter dense LM with the full stack
(data pipeline -> model -> AdamW -> checkpointing), resumable.

The default invocation is CPU-sized; pass --d-model 640 --layers 10
--vocab 50304 --steps 300 for the full ~100M x few-hundred-steps run
(recorded in EXPERIMENTS.md).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 40
"""
import argparse
import dataclasses

from repro.configs import base as cfgbase
from repro.train import trainer


def make_cfg(d_model, layers, vocab):
    base = cfgbase.get_config("qwen3-4b")     # dense GQA family
    heads = max(4, d_model // 128)
    return dataclasses.replace(
        base, num_layers=layers, d_model=d_model, num_heads=heads,
        num_kv_heads=max(1, heads // 4), head_dim=d_model // heads,
        d_ff=4 * d_model, vocab_size=vocab, qk_norm=True,
        dtype="float32", param_dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = make_cfg(args.d_model, args.layers, args.vocab)
    n = cfg.param_count()
    print(f"[train_lm] {cfg.name}-derived dense LM: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ seq {args.seq_len} batch {args.batch}")
    tcfg = trainer.TrainConfig(
        steps=args.steps, seq_len=args.seq_len, global_batch=args.batch,
        log_every=max(1, args.steps // 20), ckpt_every=args.ckpt_every,
        ckpt_dir="checkpoints/train_lm")
    _, _, history = trainer.train(cfg, tcfg, resume=args.resume)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"[train_lm] loss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'did not decrease'})")


if __name__ == "__main__":
    main()
