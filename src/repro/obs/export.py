"""Chrome-trace / Perfetto export, trace validation, and chain reconstruction.

``chrome_trace`` serializes a :class:`~repro.obs.tracer.SpanTracer` into the
Trace Event Format dict that ``chrome://tracing`` and https://ui.perfetto.dev
load directly: one process track per pod (plus ``core`` and ``fleet``), one
thread track per PE / subsystem, per-request causal lifelines as async spans
(``b``/``e`` correlated by ``cat="req"`` + request id), migrations as flow
arrows (``s``/``f``) from the source PE's issue slice to the destination
PE's admit.

``validate`` is the CI gate's schema check: structural invariants every
export must satisfy (ids/timestamps present, slice stacks balanced, async
spans and flows paired).  ``request_chains`` rebuilds one request's
arrival→…→finish phase sequence from the raw events — what a human does by
eye in Perfetto, done mechanically so tests and benchmarks can assert on it.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.tracer import SpanTracer, TraceEvent

#: schema version stamped into exported metadata
TRACE_SCHEMA_VERSION = 1


def _sort_key(pid) -> tuple:
    # stable track order: pods first (pod0, pod1, ...), then named tracks
    s = str(pid)
    if s.startswith("pod") and s[3:].isdigit():
        return (0, int(s[3:]), s)
    return (1, 0, s)


def _event_json(ev: TraceEvent) -> dict:
    obj = {
        "name": ev.name,
        "cat": ev.cat,
        "ph": ev.ph,
        "ts": ev.ts,
        "pid": str(ev.pid),
        "tid": str(ev.tid),
    }
    if ev.id is not None:
        obj["id"] = str(ev.id)
    if ev.args:
        obj["args"] = ev.args
    return obj


def chrome_trace_events(span_events, *, dropped: int = 0,
                        other: Optional[dict] = None,
                        measured: Optional[List[dict]] = None) -> dict:
    """Trace-Event-Format document from an explicit event sequence — the
    serializer behind :func:`chrome_trace`, reused by the flight recorder
    for windowed postmortem dumps.  ``other`` merges extra keys into
    ``otherData`` (e.g. the dump reason).

    ``measured`` appends a pre-serialized ``measured`` track
    (:func:`repro.obs.calibrate.measured_track_events`): wall-clock profiler
    instants on step-clocked timestamps.  The track is additive — omitting
    it yields a byte-identical document, which is what keeps profiling-off
    exports bitwise."""
    events: List[dict] = []
    span_events = list(span_events)
    measured = list(measured or [])
    # metadata naming: one process_name per pid, sorted for stable diffs
    pids = sorted({ev.pid for ev in span_events}, key=_sort_key)
    if measured:
        pids.append("measured")
    for pid in pids:
        events.append({"name": "process_name", "ph": "M", "pid": str(pid),
                       "args": {"name": str(pid)}})
    seen_tids = set()
    for ev in span_events:
        key = (ev.pid, ev.tid)
        if key not in seen_tids:
            seen_tids.add(key)
            events.append({"name": "thread_name", "ph": "M",
                           "pid": str(ev.pid), "tid": str(ev.tid),
                           "args": {"name": str(ev.tid)}})
        events.append(_event_json(ev))
    for ev in measured:
        key = (ev["pid"], ev["tid"])
        if key not in seen_tids:
            seen_tids.add(key)
            events.append({"name": "thread_name", "ph": "M",
                           "pid": str(ev["pid"]), "tid": str(ev["tid"]),
                           "args": {"name": str(ev["tid"])}})
        events.append(ev)
    other_data = {
        "schema_version": TRACE_SCHEMA_VERSION,
        "clock": "step",                # ts = step * 1000 + sub-tick
        "dropped_events": dropped,
    }
    if measured:
        other_data["measured_samples"] = len(measured)
    if other:
        other_data.update(other)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": other_data,
    }


def chrome_trace(tracer: SpanTracer, *,
                 measured: Optional[List[dict]] = None) -> dict:
    """Full Trace-Event-Format document (``traceEvents`` + metadata)."""
    return chrome_trace_events(tracer.events, dropped=tracer.dropped,
                               measured=measured)


def write_chrome_trace(tracer: SpanTracer, path: str, *,
                       measured: Optional[List[dict]] = None) -> dict:
    doc = chrome_trace(tracer, measured=measured)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


# --------------------------------------------------------------------------
# validation (CI gate b)
# --------------------------------------------------------------------------

def validate(doc: dict, *, warnings: Optional[list] = None) -> List[str]:
    """Structural schema check; returns a list of violations (empty = valid).

    Invariants:

    - every event has ``ph``/``name``/``pid``/``tid``; non-metadata events
      have a numeric ``ts`` that is non-decreasing per (pid, tid) track
    - every ``ts`` (and ``dur``, when present) is an INTEGER value: the
      deterministic step clock only produces ``step*1000 + sub-tick``, so a
      fractional timestamp means a wall-clock (``ProfClock``) value leaked
      into a deterministic field — measured seconds belong in ``args``
      (the ``measured`` track keeps wall time there for exactly this rule)
    - ``B``/``E`` slice stacks balance per (pid, tid) and never go negative
    - ``b``/``e`` async spans balance per (cat, id, name), end-after-begin
    - every flow start (``s``) has a matching finish (``f``) with the same
      id, and vice versa
    - async/flow events carry an ``id``

    Tracer-bound truncation (``otherData.dropped_events > 0``) is surfaced
    as a ``"warning: ..."`` entry: a truncated trace is structurally valid
    (ends of open spans are force-admitted) but spans may be *missing*, so
    chain reconstruction over it cannot be trusted.  Pass ``warnings=[]``
    to collect warnings separately and keep the return value errors-only.
    """
    errors: List[str] = []
    warn_sink = errors if warnings is None else warnings
    dropped = (doc.get("otherData") or {}).get("dropped_events", 0)
    if dropped:
        warn_sink.append(
            f"warning: tracer dropped {dropped} event(s) at its buffer "
            f"bound — spans may be missing; request-chain reconstruction "
            f"over this trace is untrustworthy")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return errors + ["traceEvents missing or not a list"]

    slice_stacks: Dict[tuple, List[str]] = {}
    async_open: Dict[tuple, int] = {}
    flow_starts: Dict[str, int] = {}
    flow_ends: Dict[str, int] = {}
    last_ts: Dict[tuple, float] = {}

    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None or "name" not in ev or "pid" not in ev:
            errors.append(f"event {i}: missing ph/name/pid")
            continue
        if ph == "M":
            continue
        if "tid" not in ev:
            errors.append(f"event {i} ({ev['name']}): missing tid")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"event {i} ({ev['name']}): missing/non-numeric ts")
            continue
        if float(ts) != int(ts):
            errors.append(
                f"event {i} ({ev['name']}): non-integral ts {ts!r} — "
                f"wall-clock value leaked into a step-clocked field "
                f"(measured seconds belong in args, not ts)")
        dur = ev.get("dur")
        if dur is not None and (not isinstance(dur, (int, float))
                                or float(dur) != int(dur)):
            errors.append(
                f"event {i} ({ev['name']}): non-integral dur {dur!r} — "
                f"wall-clock value leaked into a step-clocked field")
        track = (ev["pid"], ev["tid"])
        if ts < last_ts.get(track, float("-inf")):
            errors.append(f"event {i} ({ev['name']}): ts regressed on "
                          f"track {track}")
        last_ts[track] = ts

        if ph == "B":
            slice_stacks.setdefault(track, []).append(ev["name"])
        elif ph == "E":
            stack = slice_stacks.get(track)
            if not stack:
                errors.append(f"event {i}: E '{ev['name']}' with empty "
                              f"stack on {track}")
            elif stack[-1] != ev["name"]:
                errors.append(f"event {i}: E '{ev['name']}' does not match "
                              f"open '{stack[-1]}' on {track}")
                stack.pop()
            else:
                stack.pop()
        elif ph in ("b", "e"):
            if "id" not in ev:
                errors.append(f"event {i} ({ev['name']}): async without id")
                continue
            key = (ev.get("cat"), ev["id"], ev["name"])
            if ph == "b":
                async_open[key] = async_open.get(key, 0) + 1
            else:
                n = async_open.get(key, 0)
                if n <= 0:
                    errors.append(f"event {i}: async end {key} before begin")
                else:
                    async_open[key] = n - 1
        elif ph == "s":
            if "id" not in ev:
                errors.append(f"event {i} ({ev['name']}): flow without id")
            else:
                flow_starts[ev["id"]] = flow_starts.get(ev["id"], 0) + 1
        elif ph == "f":
            if "id" not in ev:
                errors.append(f"event {i} ({ev['name']}): flow without id")
            else:
                flow_ends[ev["id"]] = flow_ends.get(ev["id"], 0) + 1

    for track, stack in slice_stacks.items():
        if stack:
            errors.append(f"unclosed slices on {track}: {stack}")
    for key, n in async_open.items():
        if n:
            errors.append(f"unclosed async span {key} (x{n})")
    for fid, n in flow_starts.items():
        if flow_ends.get(fid, 0) != n:
            errors.append(f"flow id {fid}: {n} starts, "
                          f"{flow_ends.get(fid, 0)} finishes")
    for fid, n in flow_ends.items():
        if fid not in flow_starts:
            errors.append(f"flow id {fid}: {n} finishes, 0 starts")
    return errors


# --------------------------------------------------------------------------
# per-request chain reconstruction
# --------------------------------------------------------------------------

def _chains_from_events(events) -> Dict[int, List[dict]]:
    chains: Dict[int, List[dict]] = {}
    open_phase: Dict[tuple, dict] = {}
    for ev in events:
        if ev.cat != "req" or ev.id is None:
            continue
        key = (ev.id, ev.name)
        if ev.ph == "b":
            entry = {"phase": ev.name, "t0": ev.ts, "t1": None,
                     "args": dict(ev.args or {})}
            chains.setdefault(ev.id, []).append(entry)
            open_phase[key] = entry
        elif ev.ph == "e":
            entry = open_phase.pop(key, None)
            if entry is not None:
                entry["t1"] = ev.ts
                entry["args"].update(ev.args or {})
    for chain in chains.values():
        chain.sort(key=lambda e: e["t0"])
    return chains


def request_chains(tracer: SpanTracer) -> Dict[int, List[dict]]:
    """Reconstruct each request's causal lifeline from ``cat="req"`` async
    spans: ``{rid: [{"phase", "t0", "t1", "args"}, ...]}`` ordered by begin
    timestamp.  ``args`` merges begin- and end-side attribution (end wins on
    key collision, so closing attribution like wire/queue/compute seconds
    lands on the phase that measured it)."""
    return _chains_from_events(tracer.events)


def events_from_doc(doc: dict) -> List[TraceEvent]:
    """Rehydrate :class:`TraceEvent` records from an exported (or loaded)
    Chrome-trace document — the offline entry into :func:`request_chains`
    and the critical-path analyzer (``python -m repro.obs.analyze``).
    Metadata (``ph="M"``) records are skipped; async/flow ids round-trip
    back to ints (request ids are serialized as strings)."""
    out: List[TraceEvent] = []
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "M":
            continue
        eid = ev.get("id")
        if isinstance(eid, str) and eid.lstrip("-").isdigit():
            eid = int(eid)
        out.append(TraceEvent(ph=ev.get("ph"), name=ev.get("name"),
                              cat=ev.get("cat"), ts=ev.get("ts"),
                              pid=ev.get("pid"), tid=ev.get("tid"),
                              id=eid, args=ev.get("args")))
    return out


def request_chains_doc(doc: dict) -> Dict[int, List[dict]]:
    """:func:`request_chains` over a loaded Chrome-trace JSON document."""
    return _chains_from_events(events_from_doc(doc))


def chain_gaps(chain: List[dict], *, slack: float = 1.0) -> List[tuple]:
    """Uncovered (t1_prev, t0_next) intervals in a request's phase chain —
    a gap-free lifeline (the causality tests' invariant) returns [].

    Phase transitions close the old span and open the new one on
    *consecutive* sub-ticks (the step clock advances once per event), so a
    begin within ``slack`` ticks of the covered frontier is contiguous;
    anything further means the request spent untraced time between phases.

    A still-open span (``t1 is None`` — a SHED/PREEMPTED/mid-flight request
    in a windowed or truncated trace) covers everything from its begin
    onward: the request is *in* that phase, so nothing after it is
    untraced.  Skipping such entries (the old behavior) left the covered
    frontier at the previous close and flagged phantom gaps against spans
    that sorted after the open one.
    """
    gaps = []
    covered_until = None
    for entry in chain:
        if covered_until is not None and entry["t0"] > covered_until + slack:
            gaps.append((covered_until, entry["t0"]))
        t1 = float("inf") if entry["t1"] is None else entry["t1"]
        covered_until = t1 if covered_until is None else max(covered_until,
                                                             t1)
    return gaps
