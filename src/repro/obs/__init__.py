"""Fleet-wide observability: causal spans, Chrome-trace export, metrics
time series, and online tuner re-fit (DESIGN.md §11).

The one-stop entry point is :class:`Obs` — a bundle of (tracer, metrics
registry, refitter config) that the fleet driver and launchers thread
through the stack:

    obs = Obs(trace=True, refit_period=50)
    fleet = Fleet(fcfg, obs=obs)          # installs tracer on fleet.ctx
    fleet.run(specs)
    obs.write_trace("out.json")           # load in ui.perfetto.dev

Everything is opt-in: with no ``Obs`` (or ``Obs()`` with all features off)
the context keeps the :data:`~repro.obs.tracer.NULL_TRACER` and runs are
bitwise-identical to the uninstrumented stack.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.env import ObsConfig, load_obs_env
from repro.obs.export import (chrome_trace, request_chains, validate,
                              write_chrome_trace)
from repro.obs.metrics import MetricsRegistry, sample_fleet
from repro.obs.refit import OnlineRefitter, RefitEvent
from repro.obs.tracer import NULL_TRACER, SpanTracer, TraceEvent, Tracer

__all__ = [
    "Obs", "ObsConfig", "load_obs_env",
    "Tracer", "SpanTracer", "TraceEvent", "NULL_TRACER",
    "MetricsRegistry", "sample_fleet",
    "OnlineRefitter", "RefitEvent",
    "chrome_trace", "write_chrome_trace", "validate", "request_chains",
]


class Obs:
    """Observability bundle a driver attaches to a run.

    Parameters mirror :class:`ObsConfig`; :meth:`from_env` builds one from
    the ``ISHMEM_OBS_*`` variables.  ``attach(ctx)`` installs the tracer on
    a context and (when a re-fit period is set) creates the
    :class:`OnlineRefitter` against it.
    """

    def __init__(self, *, trace: bool = False, metrics: bool = False,
                 refit_period: int = 0, refit_min_samples: int = 64,
                 trace_limit: int = 1 << 20):
        self.tracer = SpanTracer(max_events=trace_limit) if trace \
            else NULL_TRACER
        self.metrics = MetricsRegistry() if metrics else None
        self.refit_period = refit_period
        self.refit_min_samples = refit_min_samples
        self.refitter: Optional[OnlineRefitter] = None

    @classmethod
    def from_env(cls, cfg: Optional[ObsConfig] = None) -> "Obs":
        cfg = load_obs_env() if cfg is None else cfg
        return cls(trace=cfg.trace, metrics=cfg.metrics,
                   refit_period=cfg.refit_period,
                   refit_min_samples=cfg.refit_min_samples,
                   trace_limit=cfg.trace_limit)

    @classmethod
    def from_config(cls, cfg: ObsConfig) -> "Obs":
        return cls.from_env(cfg)

    # ------------------------------------------------------------- wiring
    def attach(self, ctx) -> None:
        """Install the tracer on a context and arm the refit loop."""
        ctx.tracer = self.tracer
        if self.refit_period > 0:
            self.refitter = OnlineRefitter(
                ctx, period_steps=self.refit_period,
                min_samples=self.refit_min_samples, tracer=self.tracer)

    # ------------------------------------------------- fleet step hooks
    def begin_step(self, step: int) -> None:
        if self.tracer.enabled:
            self.tracer.clock.set_step(step)
            self.tracer.begin("step", "fleet", "fleet", "steps", step=step)

    def end_step(self, fleet) -> None:
        if self.refitter is not None:
            self.refitter.maybe_refit(fleet.elapsed_steps)
        if self.metrics is not None:
            sample_fleet(self.metrics, fleet, tracer=self.tracer)
        if self.tracer.enabled:
            self.tracer.end("step", "fleet", "fleet", "steps")

    # ------------------------------------------------------------- output
    def write_trace(self, path: str) -> dict:
        if not self.tracer.enabled:
            raise RuntimeError("tracing was not enabled on this Obs")
        return write_chrome_trace(self.tracer, path)

    def write_metrics(self, path: str) -> dict:
        if self.metrics is None:
            raise RuntimeError("metrics were not enabled on this Obs")
        return self.metrics.write(path)

    def summary(self) -> dict:
        """Small JSON-able roll-up for benchmark emission."""
        out = {}
        if self.tracer.enabled:
            out["trace_events"] = len(self.tracer.events)
            out["trace_dropped"] = self.tracer.dropped
        if self.metrics is not None:
            out["metrics_series_rows"] = len(self.metrics.series)
        if self.refitter is not None:
            out["refits"] = len(self.refitter.history)
            out["refit_decisions_changed"] = self.refitter.decisions_changed()
            out["refit_events"] = [ev.to_json()
                                   for ev in self.refitter.history]
        return out
