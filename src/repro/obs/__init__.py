"""Fleet-wide observability: causal spans, Chrome-trace export, metrics
time series, online tuner re-fit, critical-path analysis, invariant
auditors, a flight recorder, and SLO burn-rate alerting (DESIGN.md §11,
§13).

The one-stop entry point is :class:`Obs` — a bundle of (tracer, metrics
registry, refitter, auditor, flight recorder, burn-rate monitor) that the
fleet driver and launchers thread through the stack:

    obs = Obs(trace=True, refit_period=50, audit_period=8,
              recorder_window=64, alerts=True)
    fleet = Fleet(fcfg, obs=obs)          # installs tracer on fleet.ctx
    fleet.run(specs)
    obs.write_trace("out.json")           # load in ui.perfetto.dev

Everything is opt-in: with no ``Obs`` (or ``Obs()`` with all features off)
the context keeps the :data:`~repro.obs.tracer.NULL_TRACER` and runs are
bitwise-identical to the uninstrumented stack.  The flight recorder is the
middle setting — spans recorded into a bounded last-K-steps ring
(:class:`~repro.obs.recorder.RingTracer`), exported only as a postmortem
dump when a crash, audit violation, or SLO alert demands one.
"""
from __future__ import annotations

from typing import Optional, Union

from repro.obs import calibrate as calibrate_mod
from repro.obs import prof as prof_mod
from repro.obs.alerts import (DEFAULT_WINDOWS, Alert, BurnRateMonitor,
                              BurnWindow, parse_windows)
from repro.obs.audit import AuditError, AuditViolation, FleetAuditor
from repro.obs.env import ObsConfig, load_obs_env
from repro.obs.export import (chrome_trace, request_chains, validate,
                              write_chrome_trace)
from repro.obs.metrics import MetricsRegistry, sample_fleet
from repro.obs.prof import NULL_PROF, ProfClock, Profiler, ProfSample
from repro.obs.recorder import FlightRecorder, RingTracer
from repro.obs.refit import OnlineRefitter, RefitEvent
from repro.obs.tracer import NULL_TRACER, SpanTracer, TraceEvent, Tracer

__all__ = [
    "Obs", "ObsConfig", "load_obs_env",
    "Tracer", "SpanTracer", "TraceEvent", "NULL_TRACER", "RingTracer",
    "Profiler", "ProfClock", "ProfSample", "NULL_PROF",
    "MetricsRegistry", "sample_fleet",
    "OnlineRefitter", "RefitEvent",
    "FleetAuditor", "AuditError", "AuditViolation",
    "FlightRecorder",
    "BurnRateMonitor", "BurnWindow", "Alert", "DEFAULT_WINDOWS",
    "chrome_trace", "write_chrome_trace", "validate", "request_chains",
]


class Obs:
    """Observability bundle a driver attaches to a run.

    Parameters mirror :class:`ObsConfig`; :meth:`from_env` builds one from
    the ``ISHMEM_OBS_*`` variables.  ``attach(ctx)`` installs the tracer on
    a context and (when a re-fit period is set) creates the
    :class:`OnlineRefitter` against it.

    Per-step driving (the fleet loop calls :meth:`begin_step` /
    :meth:`end_step`): metrics sampling feeds the flight recorder and the
    burn-rate monitor; every ``audit_period`` steps the invariant auditors
    sweep the live fleet and **raise** :class:`AuditError` on a violation —
    after the recorder (when armed) has written a postmortem dump.  A newly
    fired SLO alert also triggers a dump, but does not raise.
    """

    def __init__(self, *, trace: bool = False, metrics: bool = False,
                 refit_period: int = 0, refit_min_samples: int = 64,
                 trace_limit: int = 1 << 20,
                 audit_period: int = 0,
                 recorder_window: int = 0,
                 recorder_path: str = "postmortem_trace.json",
                 alerts: bool = False, alert_target: float = 0.9,
                 alert_windows: Union[str, tuple] = DEFAULT_WINDOWS,
                 prof: bool = False, calibration: bool = False):
        if trace:
            self.tracer = SpanTracer(max_events=trace_limit)
        elif recorder_window > 0:
            # recorder without full tracing: bounded last-K-steps ring
            self.tracer = RingTracer(window_steps=recorder_window,
                                     max_events=trace_limit)
        else:
            self.tracer = NULL_TRACER
        # the burn-rate monitor reads the per-class ledger off the metrics
        # series, so alerting implies sampling
        self.metrics = MetricsRegistry() if (metrics or alerts) else None
        self.refit_period = refit_period
        self.refit_min_samples = refit_min_samples
        self.refitter: Optional[OnlineRefitter] = None
        self.audit_period = audit_period
        self.auditor = (FleetAuditor() if audit_period > 0 else None)
        self.recorder = (FlightRecorder(self.tracer,
                                        window_steps=recorder_window,
                                        path=recorder_path)
                         if recorder_window > 0 else None)
        if isinstance(alert_windows, str):
            alert_windows = parse_windows(alert_windows)
        self.monitor = (BurnRateMonitor(target=alert_target,
                                        windows=alert_windows)
                        if alerts else None)
        # wall-clock profiler (strictly segregated clock): a calibration
        # report needs measured samples, so calibration implies prof
        self.calibration = calibration
        self.prof: Optional[Profiler] = (Profiler()
                                         if (prof or calibration) else None)

    @classmethod
    def from_env(cls, cfg: Optional[ObsConfig] = None) -> "Obs":
        cfg = load_obs_env() if cfg is None else cfg
        return cls(trace=cfg.trace, metrics=cfg.metrics,
                   refit_period=cfg.refit_period,
                   refit_min_samples=cfg.refit_min_samples,
                   trace_limit=cfg.trace_limit,
                   audit_period=cfg.audit_period,
                   recorder_window=cfg.recorder_window,
                   recorder_path=cfg.recorder_path,
                   alerts=cfg.alerts, alert_target=cfg.alert_target,
                   alert_windows=cfg.alert_windows,
                   prof=cfg.prof, calibration=cfg.calibration)

    @classmethod
    def from_config(cls, cfg: ObsConfig) -> "Obs":
        return cls.from_env(cfg)

    # ------------------------------------------------------------- wiring
    def attach(self, ctx) -> None:
        """Install the tracer (and profiler, when armed) on a context and
        arm the refit loop.  With the profiler attached the refitter fits
        the *measured* wallclock stream — the adapt-from-measurement loop —
        instead of the analytic model echo."""
        ctx.tracer = self.tracer
        if self.prof is not None:
            self.prof.attach(ctx)
        if self.refit_period > 0:
            self.refitter = OnlineRefitter(
                ctx, period_steps=self.refit_period,
                min_samples=self.refit_min_samples, tracer=self.tracer,
                sample_source=("wallclock" if self.prof is not None
                               else None))

    # ------------------------------------------------- fleet step hooks
    def begin_step(self, step: int) -> None:
        if self.tracer.enabled:
            self.tracer.clock.set_step(step)
            self.tracer.begin("step", "fleet", "fleet", "steps", step=step)
        if self.prof is not None:
            self.prof.set_step(step)

    def end_step(self, fleet) -> None:
        if self.refitter is not None:
            self.refitter.maybe_refit(fleet.elapsed_steps)
        row = None
        if self.metrics is not None:
            row = sample_fleet(self.metrics, fleet, tracer=self.tracer)
        if self.recorder is not None and row is not None:
            self.recorder.note_metrics(row)
        if self.tracer.enabled:
            self.tracer.end("step", "fleet", "fleet", "steps")
        # auditors sweep after the step slice closes, so a violation dump
        # is a clean window (no spans left open by the abort itself)
        step = fleet.elapsed_steps
        if (self.auditor is not None and self.audit_period > 0
                and step > 0 and step % self.audit_period == 0):
            violations = self.auditor.audit(fleet)
            if violations:
                if self.recorder is not None:
                    self.recorder.dump(
                        reason="audit:" + ";".join(sorted(
                            {f"{v.auditor}/{v.rule}" for v in violations})),
                        step=step)
                raise AuditError(violations)
        if self.monitor is not None and self.metrics is not None:
            fired = self.monitor.observe(fleet, self.metrics,
                                         tracer=self.tracer)
            if fired and self.recorder is not None:
                self.recorder.dump(
                    reason="slo-burn:" + ",".join(a.cls for a in fired),
                    step=step)

    def crash_dump(self, reason: str) -> Optional[str]:
        """Postmortem dump on an unhandled fleet-loop exception; returns
        the path written, or None when no recorder is armed."""
        if self.recorder is None:
            return None
        return self.recorder.dump(reason=f"crash:{reason}")

    # ------------------------------------------------------------- output
    def write_trace(self, path: str, *, measured: bool = False) -> dict:
        """Export the Chrome trace; ``measured=True`` additionally appends
        the profiler's step-clocked ``measured`` track.  The track is
        strictly additive and opt-in — the default export is byte-identical
        whether or not a profiler ran."""
        if not self.tracer.enabled:
            raise RuntimeError("tracing was not enabled on this Obs")
        track = None
        if measured:
            if self.prof is None:
                raise RuntimeError("measured track requested but profiling "
                                   "was not enabled on this Obs")
            track = calibrate_mod.measured_track_events(self.prof.samples)
        return write_chrome_trace(self.tracer, path, measured=track)

    def write_metrics(self, path: str) -> dict:
        if self.metrics is None:
            raise RuntimeError("metrics were not enabled on this Obs")
        return self.metrics.write(path)

    def write_prof(self, path: str) -> dict:
        """Persist the measured sample file (``repro.obs.analyze
        --calibration`` input)."""
        if self.prof is None:
            raise RuntimeError("profiling was not enabled on this Obs")
        return self.prof.save(path)

    def calibration_report(self) -> dict:
        """Measured-vs-modeled divergence report over the profiler samples
        collected so far (``repro.obs.calibrate``)."""
        if self.prof is None:
            raise RuntimeError("profiling was not enabled on this Obs")
        return calibrate_mod.report_from_samples(self.prof.samples)

    def summary(self) -> dict:
        """Small JSON-able roll-up for benchmark emission."""
        out = {}
        if self.tracer.enabled:
            out["trace_events"] = len(self.tracer.events)
            out["trace_dropped"] = self.tracer.dropped
        if self.metrics is not None:
            out["metrics_series_rows"] = len(self.metrics.series)
        if self.refitter is not None:
            out["refits"] = len(self.refitter.history)
            out["refit_decisions_changed"] = self.refitter.decisions_changed()
            out["refit_events"] = [ev.to_json()
                                   for ev in self.refitter.history]
        if self.auditor is not None:
            out["audit"] = self.auditor.summary()
        if self.recorder is not None:
            out["recorder"] = self.recorder.summary()
        if self.monitor is not None:
            out["alerts"] = self.monitor.summary()
        if self.prof is not None:
            out["prof"] = self.prof.summary()
            if self.calibration:
                out["calibration"] = self.calibration_report()
        return out
