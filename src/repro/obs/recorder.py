"""Always-on flight recorder: a bounded ring of recent spans + metrics rows
that turns into a *valid* postmortem trace the moment something goes wrong.

Production runs keep full tracing off (the bitwise-identical-off contract);
this module is the middle setting: a :class:`RingTracer` records into a
last-K-steps ring (old events fall off the back, memory stays bounded, no
export unless asked), and a :class:`FlightRecorder` snapshots that window
into a Chrome-trace document on demand — on an unhandled exception in the
fleet loop, on an :class:`~repro.obs.audit.AuditError`, or on an SLO
burn-rate alert (``repro.obs.alerts``).

A raw window slice is *not* a valid trace: spans that began before the
window opened have dangling ``E``/``e`` closers, flows can lose one end,
and spans still open at the crash have no close at all.  ``snapshot``
repairs all three — unmatched closers and half-flows are dropped, still-
open spans get synthesized closes (``args: {"truncated": true}``) at the
window tail — so every dump passes ``export.validate`` clean and loads in
Perfetto.  Postmortem context (reason, step, eviction count, the recent
metrics rows) rides in ``otherData.postmortem``, deliberately *not* in
``otherData.dropped_events``: window eviction is the recorder working as
designed, not tracer truncation.

When full tracing is already on, point the recorder at the main
:class:`SpanTracer` instead — dumps become windowed slices of the complete
trace, with the same repair.
"""
from __future__ import annotations

import collections
import json
from typing import Deque, List, Optional

from repro.obs.export import chrome_trace_events
from repro.obs.tracer import STEP_QUANTUM, SpanTracer, TraceEvent

__all__ = ["RingTracer", "FlightRecorder"]


class RingTracer(SpanTracer):
    """A :class:`SpanTracer` whose buffer is a last-``window_steps`` ring.

    Events older than the window (by step-clocked timestamp) are evicted
    from the front as new ones arrive; ``evicted`` counts them.  A hard
    ``max_events`` cap additionally bounds pathological single-step floods.
    Nothing is ever "dropped" in the truncation sense — the ring is the
    design, and :class:`FlightRecorder` repairs the window edge at dump
    time."""

    def __init__(self, window_steps: int = 64, max_events: int = 1 << 20):
        super().__init__(max_events=max_events)
        self.window_steps = window_steps
        self.events: Deque[TraceEvent] = collections.deque()
        self.evicted = 0

    def _emit(self, ev: TraceEvent, *, force: bool = False) -> None:
        self.events.append(ev)
        floor = (self.clock.step - self.window_steps) * STEP_QUANTUM
        while self.events and self.events[0].ts < floor:
            self.events.popleft()
            self.evicted += 1
        while len(self.events) > self.max_events:
            self.events.popleft()
            self.evicted += 1


class FlightRecorder:
    """Windowed postmortem dumps over a live tracer (ring or full).

    ``note_metrics(row)`` keeps the last-window metrics rows alongside the
    spans; ``dump(reason=...)`` writes the repaired window as a Chrome-trace
    JSON document and remembers the path in ``dumps``.
    """

    def __init__(self, tracer: SpanTracer, *, window_steps: int = 64,
                 path: str = "postmortem_trace.json"):
        self.tracer = tracer
        self.window_steps = window_steps
        self.path = path
        self.dumps: List[str] = []
        self._metrics: Deque[dict] = collections.deque()

    # ------------------------------------------------------------- intake
    def note_metrics(self, row: dict) -> None:
        """Remember a metrics sample row (must carry ``"step"``)."""
        self._metrics.append(row)
        floor = self.tracer.clock.step - self.window_steps
        while self._metrics and self._metrics[0].get("step", 0) < floor:
            self._metrics.popleft()

    # -------------------------------------------------------- window + fix
    def _window(self, step: int) -> List[TraceEvent]:
        floor = (step - self.window_steps) * STEP_QUANTUM
        return [ev for ev in self.tracer.events if ev.ts >= floor]

    @staticmethod
    def _repair(events: List[TraceEvent]) -> List[TraceEvent]:
        """Make a window slice structurally valid (see module docstring):
        drop closers whose opens fell off the window edge, drop flow events
        whose pair is missing (keeping matched pairs), then synthesize
        closes for spans still open at the tail."""
        n_s = collections.Counter(ev.id for ev in events if ev.ph == "s")
        n_f = collections.Counter(ev.id for ev in events if ev.ph == "f")
        flow_keep = {fid: min(n, n_f.get(fid, 0)) for fid, n in n_s.items()}
        seen_s: collections.Counter = collections.Counter()
        seen_f: collections.Counter = collections.Counter()

        kept: List[TraceEvent] = []
        stacks: dict = {}          # (pid, tid) -> [(name, cat)]
        async_open: dict = {}      # (cat, id, name) -> [count, pid, tid]
        for ev in events:
            if ev.ph == "B":
                stacks.setdefault((ev.pid, ev.tid), []).append((ev.name,
                                                                ev.cat))
                kept.append(ev)
            elif ev.ph == "E":
                stack = stacks.get((ev.pid, ev.tid))
                if stack and stack[-1][0] == ev.name:
                    stack.pop()
                    kept.append(ev)
                # else: open fell off the window — drop the dangling closer
            elif ev.ph == "b":
                rec = async_open.setdefault((ev.cat, ev.id, ev.name),
                                            [0, ev.pid, ev.tid])
                rec[0] += 1
                kept.append(ev)
            elif ev.ph == "e":
                rec = async_open.get((ev.cat, ev.id, ev.name))
                if rec is not None and rec[0] > 0:
                    rec[0] -= 1
                    kept.append(ev)
            elif ev.ph == "s":
                seen_s[ev.id] += 1
                if seen_s[ev.id] <= flow_keep.get(ev.id, 0):
                    kept.append(ev)
            elif ev.ph == "f":
                seen_f[ev.id] += 1
                if seen_f[ev.id] <= flow_keep.get(ev.id, 0):
                    kept.append(ev)
            else:                   # i / C / anything future
                kept.append(ev)

        # synthesized closes at the tail, strictly increasing timestamps so
        # every track stays monotonic
        ts = (max(ev.ts for ev in kept) if kept else 0.0) + 1.0
        for (pid, tid), stack in sorted(stacks.items(),
                                        key=lambda kv: str(kv[0])):
            for name, cat in reversed(stack):
                kept.append(TraceEvent("E", name, cat, ts, pid, tid,
                                       args={"truncated": True}))
                ts += 1.0
        for (cat, aid, name), (n, pid, tid) in sorted(
                async_open.items(), key=lambda kv: str(kv[0])):
            for _ in range(n):
                kept.append(TraceEvent("e", name, cat, ts, pid, tid, id=aid,
                                       args={"truncated": True}))
                ts += 1.0
        return kept

    # -------------------------------------------------------------- output
    def snapshot(self, *, reason: str, step: Optional[int] = None) -> dict:
        """The repaired window as a Chrome-trace document (no file I/O)."""
        step = self.tracer.clock.step if step is None else step
        events = self._repair(self._window(step))
        evicted = getattr(self.tracer, "evicted", 0)
        return chrome_trace_events(
            events, dropped=getattr(self.tracer, "dropped", 0),
            other={"postmortem": {
                "reason": reason,
                "step": step,
                "window_steps": self.window_steps,
                "evicted": evicted,
                "metrics_rows": list(self._metrics),
            }})

    def dump(self, path: Optional[str] = None, *, reason: str,
             step: Optional[int] = None) -> str:
        """Write a postmortem dump; returns the path written."""
        path = self.path if path is None else path
        doc = self.snapshot(reason=reason, step=step)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        self.dumps.append(path)
        return path

    def summary(self) -> dict:
        return {"window_steps": self.window_steps,
                "buffered_events": len(self.tracer.events),
                "evicted": getattr(self.tracer, "evicted", 0),
                "dumps": list(self.dumps)}
