"""Online invariant auditors: the protocol rules of DESIGN.md §8–§12,
checked against a *live* fleet every N steps (DESIGN.md §13).

Each auditor is a pure read of host-side control-plane state (plus signal
words and — optionally — resident block bytes off the symmetric heap); it
never mutates anything, so auditing on cannot perturb outputs.  Violations
come back as structured :class:`AuditViolation` records; the fleet driver's
``Obs(audit_period=N)`` hook raises them bundled in an :class:`AuditError`
(after triggering a flight-recorder postmortem dump when one is armed).

Auditor families (the §13 invariant table maps each rule to its DESIGN
section):

- **heap** — free-extent sanity on every dtype pool: sorted, positive,
  non-overlapping, coalesced, inside the allocation cursor.
- **refcount** — block-reference conservation over the KV pool: every
  block's refcount equals (tables mapping it) + (1 if a prefix entry owns
  it) + (COW reserves targeting it, in views or parked ``cow_plan``s); the
  free list is exactly the refcount-zero set; entry ``refs`` equals its
  live mappers.
- **signal** — signal-ledger vs CompletionQueue consistency: folding the
  pending SIGNAL ops over a word's current value must land exactly on what
  the migration protocol issued (slot words: ``expected_signal``; stream
  words: blocks sent so far), and the *current* value never exceeds it —
  i.e. no block is readable before its signal.
- **residency** — prefix-index residency agreement: every (PE, block) the
  index claims resident is an entry block, still referenced, and (deep
  mode) its bytes at that PE equal the home PE's staged payload.
- **slots** — slot-bank vs scheduler-state agreement: slot ownership,
  bank ``active`` masks, and paged-view attachments all tell one story.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

__all__ = ["AuditViolation", "AuditError", "FleetAuditor", "AUDITORS"]

#: auditor family names, in run order
AUDITORS = ("heap", "refcount", "signal", "residency", "slots")


@dataclasses.dataclass(frozen=True)
class AuditViolation:
    """One broken invariant: which auditor, which rule, where, and what."""
    auditor: str                  # family (see AUDITORS)
    rule: str                     # short invariant id, e.g. "refcount-conservation"
    detail: str                   # human-readable account
    subject: dict                 # structured locus ({"block": 5}, {"pe", "slot"}, ...)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class AuditError(RuntimeError):
    """Raised by the enforcing hook when an audit pass found violations."""

    def __init__(self, violations: List[AuditViolation]):
        self.violations = list(violations)
        heads = "; ".join(f"[{v.auditor}/{v.rule}] {v.detail}"
                          for v in self.violations[:3])
        more = ("" if len(self.violations) <= 3
                else f" (+{len(self.violations) - 3} more)")
        super().__init__(
            f"{len(self.violations)} invariant violation(s): {heads}{more}")


def _v(auditor: str, rule: str, detail: str, **subject) -> AuditViolation:
    return AuditViolation(auditor=auditor, rule=rule, detail=detail,
                          subject=subject)


class _HeapSnapshot:
    """One host copy per touched dtype pool for a single audit pass.

    Every ``heap.read(ptr, pe)`` is a device slice plus a host transfer —
    a full sync each.  At ``audit_period=1`` the signal auditor reads a
    word per (pe, slot) per step, so per-word reads dominate the audit
    budget; copying the whole pool once and indexing it with numpy keeps
    each pass to one transfer per dtype."""

    def __init__(self, heap):
        self._heap = heap
        self._pools: Dict[str, np.ndarray] = {}

    def read(self, ptr, pe: int) -> np.ndarray:
        pool = self._pools.get(ptr.dtype)
        if pool is None:
            pool = self._pools[ptr.dtype] = np.asarray(
                self._heap.pools[ptr.dtype])
        flat = pool[pe, ptr.offset:ptr.offset + max(ptr.size, 1)]
        return flat[: ptr.size].reshape(ptr.shape)


class FleetAuditor:
    """Run every auditor family against a live Fleet.

    ``deep_residency`` additionally compares resident block *bytes* against
    the home PE's staged payload (exact, but touches heap rows — leave on
    for CI-sized pools, off for large production sweeps).
    """

    def __init__(self, *, deep_residency: bool = True):
        self.deep_residency = deep_residency
        self.checks = 0               # audit passes run
        self.violation_count = 0      # total violations across passes
        self.audit_seconds = 0.0      # host time spent auditing (bench gate)

    # ------------------------------------------------------------- driving
    def audit(self, fleet) -> List[AuditViolation]:
        """One full pass; returns (and counts) violations, never raises."""
        t0 = time.perf_counter()
        out: List[AuditViolation] = []
        out += self.audit_heap(fleet.heap)
        out += self.audit_refcounts(fleet)
        out += self.audit_signals(fleet)
        out += self.audit_residency(fleet)
        out += self.audit_slots(fleet)
        self.checks += 1
        self.violation_count += len(out)
        self.audit_seconds += time.perf_counter() - t0
        return out

    def enforce(self, fleet) -> None:
        """Audit and raise :class:`AuditError` on any violation."""
        violations = self.audit(fleet)
        if violations:
            raise AuditError(violations)

    def summary(self) -> dict:
        return {"checks": self.checks,
                "violations": self.violation_count,
                "audit_seconds": self.audit_seconds,
                "deep_residency": self.deep_residency}

    # ------------------------------------------------- heap extent sanity
    def audit_heap(self, heap) -> List[AuditViolation]:
        """Free-list extents per dtype pool: sorted, positive, disjoint,
        coalesced (``free`` always merges adjacent spans), and inside the
        allocation cursor — the §III-E allocator's conservation law."""
        out: List[AuditViolation] = []
        for dt, extents in getattr(heap, "_free", {}).items():
            cursor = heap._cursor.get(dt, 0)
            prev_end = None
            for off, sz in extents:
                if sz <= 0:
                    out.append(_v("heap", "heap-extent-empty",
                                  f"pool {dt}: zero/negative free extent "
                                  f"({off}, {sz})", dtype=dt, offset=off))
                if prev_end is not None and off < prev_end:
                    out.append(_v("heap", "heap-extent-overlap",
                                  f"pool {dt}: free extents overlap/unsorted "
                                  f"at offset {off} (prev end {prev_end})",
                                  dtype=dt, offset=off))
                elif prev_end is not None and off == prev_end:
                    out.append(_v("heap", "heap-extent-uncoalesced",
                                  f"pool {dt}: adjacent free extents never "
                                  f"merged at offset {off}",
                                  dtype=dt, offset=off))
                if off + sz > cursor:
                    out.append(_v("heap", "heap-extent-bounds",
                                  f"pool {dt}: free extent ({off}, {sz}) "
                                  f"past allocation cursor {cursor}",
                                  dtype=dt, offset=off))
                prev_end = off + sz if prev_end is None else max(prev_end,
                                                                 off + sz)
        return out

    # ------------------------------------------- block refcount conservation
    def audit_refcounts(self, fleet) -> List[AuditViolation]:
        """§9's ownership law: ``refcnt[b] == tables(b) + entry_own(b) +
        cow_holds(b)``, and the free list is exactly ``{b: refcnt == 0}``."""
        from repro.serve.scheduler import TERMINAL

        out: List[AuditViolation] = []
        pool = fleet.pool
        expected = [0] * pool.num_blocks
        for ids in pool.block_tables.values():
            for b in ids:
                expected[b] += 1
        for entry in fleet.prefix_index.values():
            for b in entry.block_ids:
                expected[b] += 1                 # the entry's own reference
        for pod in fleet.pods:
            sched = pod.sched
            for req in sched.requests.values():
                if req.state in TERMINAL:
                    continue
                for tgt in req.cow_plan.values():
                    expected[tgt] += 1           # parked COW reservation
            for view in getattr(sched, "views", {}).values():
                for sm in view.slots.values():
                    for tgt in sm.cow.values():
                        expected[tgt] += 1       # armed COW reservation
        for b in range(pool.num_blocks):
            if pool._refcnt[b] != expected[b]:
                out.append(_v("refcount", "refcount-conservation",
                              f"block {b}: refcount {pool._refcnt[b]} but "
                              f"{expected[b]} accounted reference(s)",
                              block=b, refcount=pool._refcnt[b],
                              expected=expected[b]))
        free = set(pool._free)
        if len(free) != len(pool._free):
            out.append(_v("refcount", "free-list-duplicate",
                          "free list holds duplicate block ids",
                          free_len=len(pool._free)))
        zero = {b for b in range(pool.num_blocks) if pool._refcnt[b] == 0}
        for b in sorted(free - zero):
            out.append(_v("refcount", "free-list-referenced",
                          f"block {b} on the free list with refcount "
                          f"{pool._refcnt[b]}", block=b))
        for b in sorted(zero - free):
            out.append(_v("refcount", "free-list-leak",
                          f"block {b} has refcount 0 but never returned to "
                          f"the free list", block=b))
        # prefix entry refs == live (non-terminal) mappers
        mappers: Dict[tuple, int] = {}
        for pod in fleet.pods:
            for req in pod.sched.requests.values():
                if req.prefix_key is not None and req.state not in TERMINAL:
                    mappers[req.prefix_key] = mappers.get(req.prefix_key,
                                                          0) + 1
        for key, entry in fleet.prefix_index.items():
            live = mappers.get(key, 0)
            if entry.refs != live:
                out.append(_v("refcount", "prefix-refs",
                              f"prefix entry {key!r:.40}: refs "
                              f"{entry.refs} but {live} live mapper(s)",
                              refs=entry.refs, mappers=live))
        return out

    # --------------------------------------------- signal ledger vs queue
    @staticmethod
    def _eventual(ctx, heap, ptr, pe: int, *, snap=None) -> tuple:
        """(current, eventual) value of a signal word: the heap's row value
        now, and the value after every pending SIGNAL op targeting it is
        applied in queue order — the ledger the protocol issued.  Passing a
        :class:`_HeapSnapshot` reads the word from the pass's host copy
        instead of syncing the device per word."""
        from repro.core import pending as pending_mod

        raw = (snap or heap).read(ptr, pe)
        cur = int(np.asarray(raw).reshape(-1)[0])
        val = raw
        for op in ctx.pending.ops:
            if (op.kind == pending_mod.SIGNAL and op.pe == pe
                    and op.ptr.dtype == ptr.dtype
                    and op.ptr.offset == ptr.offset):
                val = op.apply(val)
        return cur, int(np.asarray(val).reshape(-1)[0])

    def audit_signals(self, fleet) -> List[AuditViolation]:
        """§10/§12's data-before-flag law, host-checkable form: for every
        live signal word, ``current + pending == issued`` (no lost or
        duplicated signal) and ``current <= issued`` (the word never
        advances past what the migrator sent — a block readable before its
        signal would show up as exactly that overrun)."""
        from repro.serve.scheduler import (DECODING, MIGRATING, PARKED,
                                           STREAMING, TERMINAL)

        out: List[AuditViolation] = []
        ctx, heap, pool = fleet.ctx, fleet.heap, fleet.pool
        snap = _HeapSnapshot(heap)
        for pod in fleet.pods:
            sched = pod.sched
            streaming_mode = sched.stream_chunks > 0
            for pe in sched.decode_pes:
                for slot, rid in enumerate(sched.slot_req[pe]):
                    ptr = pool.sig_ptr(slot)
                    cur, ev = self._eventual(ctx, heap, ptr, pe, snap=snap)
                    req = (sched.requests.get(rid)
                           if rid is not None else None)
                    if req is None or streaming_mode:
                        # free slot — or stream mode, where the wire rides
                        # the stream word and the slot word stays zero
                        issued = 0
                    elif req.preemptions > 0:
                        # a resumed request re-binds a slot WITHOUT
                        # re-migration (its blocks never left the pool):
                        # the preempt path consumed every in-flight block
                        # and re-armed the word, so nothing was issued
                        # against this binding
                        issued = 0
                    else:
                        issued = req.expected_sig
                    if ev != issued:
                        out.append(_v(
                            "signal", "signal-ledger",
                            f"{pod.name} pe{pe} slot {slot}: signal word "
                            f"reads {cur} (+pending -> {ev}) but the "
                            f"protocol issued {issued}",
                            pod=pod.name, pe=pe, slot=slot, current=cur,
                            eventual=ev, issued=issued, rid=rid))
                    elif cur > issued:
                        out.append(_v(
                            "signal", "signal-overrun",
                            f"{pod.name} pe{pe} slot {slot}: signal word "
                            f"at {cur} exceeds the {issued} issued — block "
                            f"readable before its signal",
                            pod=pod.name, pe=pe, slot=slot, current=cur,
                            issued=issued, rid=rid))
            # stream words of slot-less in-flight requests
            for req in sched.requests.values():
                if req.state in TERMINAL or req.park_sig < 0:
                    continue
                ptr = pool.stream_sig_ptr(req.park_sig)
                pe = req.decode_pe
                cur, ev = self._eventual(ctx, heap, ptr, pe, snap=snap)
                if req.state in (STREAMING, PARKED):
                    issued = req.stream.sent if req.stream is not None else 0
                elif req.state == MIGRATING:
                    issued = req.expected_sig
                elif req.state == DECODING:
                    continue                      # word recycled at admit
                else:
                    continue
                if ev != issued:
                    out.append(_v(
                        "signal", "signal-ledger",
                        f"{pod.name} rid {req.rid} stream word "
                        f"{req.park_sig}@pe{pe}: reads {cur} (+pending -> "
                        f"{ev}) but the stream issued {issued}",
                        pod=pod.name, pe=pe, stream=req.park_sig,
                        current=cur, eventual=ev, issued=issued,
                        rid=req.rid))
                elif cur > issued:
                    out.append(_v(
                        "signal", "signal-overrun",
                        f"{pod.name} rid {req.rid} stream word "
                        f"{req.park_sig}@pe{pe}: at {cur}, past the "
                        f"{issued} issued", pod=pod.name, pe=pe,
                        stream=req.park_sig, current=cur, issued=issued,
                        rid=req.rid))
        return out

    # ------------------------------------------ prefix residency agreement
    def audit_residency(self, fleet) -> List[AuditViolation]:
        """§9.4's per-(PE, block) residency law: everything the prefix
        index claims resident is an entry block, still live, and actually
        carries the home PE's staged bytes (deep mode)."""
        out: List[AuditViolation] = []
        pool, heap = fleet.pool, fleet.heap
        snap = _HeapSnapshot(heap)
        for key, entry in fleet.prefix_index.items():
            ids = set(entry.block_ids)
            for pe, blocks in entry.resident.items():
                for b in sorted(blocks):
                    if b not in ids:
                        out.append(_v(
                            "residency", "residency-foreign-block",
                            f"prefix entry {key!r:.40}: block {b} recorded "
                            f"resident at pe{pe} but is not an entry block",
                            pe=pe, block=b))
                        continue
                    if pool.refcount(b) <= 0:
                        out.append(_v(
                            "residency", "residency-freed-block",
                            f"prefix entry {key!r:.40}: resident block {b} "
                            f"at pe{pe} has refcount "
                            f"{pool.refcount(b)}", pe=pe, block=b))
                        continue
                    if self.deep_residency and pe != entry.home_pe:
                        ptr = pool.block_ptr(b)
                        home = snap.read(ptr, entry.home_pe)
                        there = snap.read(ptr, pe)
                        if not np.array_equal(home, there):
                            out.append(_v(
                                "residency", "residency-bytes",
                                f"prefix entry {key!r:.40}: block {b} "
                                f"recorded resident at pe{pe} but its bytes "
                                f"differ from home pe{entry.home_pe}",
                                pe=pe, block=b, home_pe=entry.home_pe))
        return out

    # --------------------------------------- slot bank / scheduler agreement
    def audit_slots(self, fleet) -> List[AuditViolation]:
        """§8's occupancy law: ``slot_req``, the engine slot bank's
        ``active`` mask, the paged view's attachments, and each request's
        (state, decode_pe, slot) all agree."""
        from repro.serve.scheduler import DECODING, MIGRATING, PREEMPTED

        out: List[AuditViolation] = []
        for pod in fleet.pods:
            sched = pod.sched
            views = getattr(sched, "views", {})
            for pe in sched.decode_pes:
                bank = sched.banks[pe]
                view = views.get(pe)
                for slot, rid in enumerate(sched.slot_req[pe]):
                    active = bool(bank.active[slot])
                    if rid is None:
                        if active:
                            out.append(_v(
                                "slots", "slot-ghost-active",
                                f"{pod.name} pe{pe} slot {slot}: bank "
                                f"active with no owning request",
                                pod=pod.name, pe=pe, slot=slot))
                        if view is not None and slot in view.slots:
                            out.append(_v(
                                "slots", "slot-stale-view",
                                f"{pod.name} pe{pe} slot {slot}: paged view "
                                f"still attached (rid "
                                f"{view.slots[slot].req_id}) on a free slot",
                                pod=pod.name, pe=pe, slot=slot))
                        continue
                    req = sched.requests.get(rid)
                    if req is None:
                        out.append(_v("slots", "slot-unknown-owner",
                                      f"{pod.name} pe{pe} slot {slot}: "
                                      f"owner rid {rid} unknown",
                                      pod=pod.name, pe=pe, slot=slot,
                                      rid=rid))
                        continue
                    if req.slot != slot or req.decode_pe != pe:
                        out.append(_v(
                            "slots", "slot-owner-mismatch",
                            f"{pod.name} pe{pe} slot {slot}: owner rid "
                            f"{rid} believes it is at pe{req.decode_pe} "
                            f"slot {req.slot}", pod=pod.name, pe=pe,
                            slot=slot, rid=rid))
                    if req.state == DECODING and not active:
                        out.append(_v(
                            "slots", "slot-inactive-decoding",
                            f"{pod.name} pe{pe} slot {slot}: rid {rid} is "
                            f"DECODING but the bank slot is inactive",
                            pod=pod.name, pe=pe, slot=slot, rid=rid))
                    elif req.state == MIGRATING and active:
                        out.append(_v(
                            "slots", "slot-active-premature",
                            f"{pod.name} pe{pe} slot {slot}: rid {rid} "
                            f"still MIGRATING but the bank slot is active",
                            pod=pod.name, pe=pe, slot=slot, rid=rid))
                    elif req.state not in (DECODING, MIGRATING):
                        out.append(_v(
                            "slots", "slot-nonresident-owner",
                            f"{pod.name} pe{pe} slot {slot}: owner rid "
                            f"{rid} in state {req.state!r} cannot hold a "
                            f"slot", pod=pod.name, pe=pe, slot=slot,
                            rid=rid, state=req.state))
                    if (view is not None and req.state == DECODING
                            and sched.paged):
                        sm = view.slots.get(slot)
                        if sm is None or sm.req_id != rid:
                            out.append(_v(
                                "slots", "slot-view-mismatch",
                                f"{pod.name} pe{pe} slot {slot}: paged view "
                                f"maps {getattr(sm, 'req_id', None)!r}, "
                                f"scheduler says rid {rid}",
                                pod=pod.name, pe=pe, slot=slot, rid=rid))
            # the reverse direction: every slot-holding request is registered
            for req in sched.requests.values():
                if req.state == DECODING:
                    if (req.slot < 0
                            or sched.slot_req[req.decode_pe][req.slot]
                            != req.rid):
                        out.append(_v(
                            "slots", "slot-unregistered",
                            f"{pod.name} rid {req.rid} DECODING but not "
                            f"registered at pe{req.decode_pe} slot "
                            f"{req.slot}", pod=pod.name, rid=req.rid))
                elif req.state == PREEMPTED and req.slot != -1:
                    out.append(_v(
                        "slots", "slot-preempted-holding",
                        f"{pod.name} rid {req.rid} PREEMPTED but still "
                        f"holds slot {req.slot}", pod=pod.name,
                        rid=req.rid, slot=req.slot))
        return out
