"""Online tuner re-fit: close the telemetry -> estimator -> cutover loop
*during* a run.

The offline path already exists end-to-end: ops recorded into the context's
:class:`~repro.tune.telemetry.TelemetrySink` are fitted by
``tune.estimator.build_table`` into a :class:`~repro.tune.table.TuningTable`
that ``core.cutover.choose_path`` consults.  What was missing (ROADMAP:
"re-fit the tuner online from live telemetry during a run") is a driver
that does this *periodically while the fleet is serving*, so a warm-started
table that no longer matches reality — stale profile file, different
message-size mix, changed work-group sizes — gets corrected mid-run
instead of steering every subsequent transfer wrong.

:class:`OnlineRefitter` is that driver.  Every ``period_steps`` fleet steps
(and only once enough new samples accumulated) it re-runs the estimator
over the live sink and hot-swaps the armed table via
``ctx.fit_tuning_table(arm=True)``.  To make the effect observable it
probes ``choose_path`` over a small (tier, work_items, nbytes) grid before
and after the swap and reports exactly which decisions flipped — the CI
gate asserts at least one flip in the heterogeneous-tier smoke run, and
each re-fit lands in the trace as a ``fleet/refit`` instant carrying the
flip list.

Two sample streams can feed the fit.  The default (``sample_source=None``)
fits the analytic model stream: live op timings are priced by the same
model ``choose_path`` falls back to, so a re-fit from a *clean* start
converges to the decisions already being made (a no-op — correct behavior,
not a failure); the interesting case is a stale/skewed warm-start table,
which the re-fit visibly overwrites.  With a wall-clock profiler attached
(``repro.obs.prof``), ``sample_source="wallclock"`` fits only the profiler's
*measured* samples instead — the table that gets hot-swapped then carries
``source="wallclock"`` provenance down to its profiles, closing the paper's
adapt-from-measurement loop with genuinely measured time rather than model
echo.  ``benchmarks/bench_obs.py`` exercises both shapes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core import cutover

#: default probe grid: sizes bracketing typical cutovers (64 B .. 4 MiB)
PROBE_SIZES = tuple(1 << s for s in range(6, 23, 2))
PROBE_TIERS = ("local", "ici")          # dcn is pinned to proxy — no decision
PROBE_WIS = (1, 32, 128, 512)


@dataclasses.dataclass
class RefitEvent:
    """One completed re-fit: when, over how much data, what flipped."""
    step: int
    nsamples: int                       # retained sink samples fitted over
    ncutovers: int                      # cutover entries in the new table
    changed: List[Tuple[str, int, int, str, str]]  # (tier, wi, nbytes, old, new)

    def to_json(self) -> dict:
        return {
            "step": self.step,
            "nsamples": self.nsamples,
            "ncutovers": self.ncutovers,
            "changed": [
                {"tier": t, "work_items": wi, "nbytes": n,
                 "old": old, "new": new}
                for (t, wi, n, old, new) in self.changed
            ],
        }


class OnlineRefitter:
    """Periodically re-fit the tuning table from the context's live sink.

    ``maybe_refit(step)`` is cheap when it declines (two int compares), so
    the fleet calls it unconditionally every step."""

    def __init__(self, ctx, *, period_steps: int = 50,
                 min_samples: int = 64,
                 probe_sizes: Sequence[int] = PROBE_SIZES,
                 probe_tiers: Sequence[str] = PROBE_TIERS,
                 probe_wis: Sequence[int] = PROBE_WIS,
                 tracer=None,
                 sample_source: Optional[str] = None):
        if period_steps <= 0:
            raise ValueError("period_steps must be positive (0 = use no "
                             "refitter at all)")
        self.ctx = ctx
        self.period_steps = period_steps
        self.min_samples = min_samples
        self.probe_sizes = tuple(probe_sizes)
        self.probe_tiers = tuple(probe_tiers)
        self.probe_wis = tuple(probe_wis)
        self.tracer = tracer
        # telemetry provenance stream to fit (None = the model stream;
        # "wallclock" = measured profiler samples only)
        self.sample_source = sample_source
        self.last_refit_step = -1
        self.history: List[RefitEvent] = []

    # ------------------------------------------------------------ plumbing
    def _probe(self) -> dict:
        """choose_path over the probe grid under the currently armed
        tuning — the observable surface a re-fit can change."""
        out = {}
        for tier in self.probe_tiers:
            for wi in self.probe_wis:
                for n in self.probe_sizes:
                    out[(tier, wi, n)] = cutover.choose_path(
                        n, work_items=wi, tier=tier, hw=self.ctx.hw,
                        tuning=self.ctx.tuning)
        return out

    def _nsamples(self) -> int:
        tel = self.ctx.telemetry
        count = getattr(tel, "nsamples", None)
        if count is not None:
            return count(self.sample_source)
        buckets = getattr(tel, "buckets", None) or {}
        return sum(len(b.samples) for b in buckets.values())

    # -------------------------------------------------------------- public
    def maybe_refit(self, step: int) -> Optional[RefitEvent]:
        """Re-fit if a full period elapsed and the sink has enough samples;
        returns the :class:`RefitEvent` when a re-fit ran, else None."""
        if step - self.last_refit_step < self.period_steps:
            return None
        nsamples = self._nsamples()
        if nsamples < self.min_samples:
            return None
        return self.refit(step, nsamples=nsamples)

    def refit(self, step: int, *, nsamples: Optional[int] = None) -> RefitEvent:
        """Unconditional re-fit + hot-swap; records and returns the event."""
        before = self._probe()
        tbl = self.ctx.fit_tuning_table(arm=True,
                                        sample_source=self.sample_source)
        after = self._probe()
        changed = [(t, wi, n, before[(t, wi, n)], after[(t, wi, n)])
                   for (t, wi, n) in before
                   if after[(t, wi, n)] != before[(t, wi, n)]]
        ev = RefitEvent(step=step,
                        nsamples=(self._nsamples() if nsamples is None
                                  else nsamples),
                        ncutovers=len(tbl.cutovers),
                        changed=changed)
        self.last_refit_step = step
        self.history.append(ev)
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.instant(
                "refit", "fleet", "fleet", "tuner",
                step=step, nsamples=ev.nsamples, ncutovers=ev.ncutovers,
                decisions_changed=len(changed),
                source=self.sample_source or "model")
        return ev

    def decisions_changed(self) -> int:
        return sum(len(ev.changed) for ev in self.history)
