"""Deterministic multi-window SLO burn-rate alerting over the metrics series.

Classic SRE burn-rate alerting (fast window catches cliffs, slow window
confirms they are sustained), recast onto the fleet's step clock so alerts
are bit-reproducible for a fixed seed: no wall time, no sampling jitter —
the monitor reads the cumulative per-class SLO ledger that
``metrics.sample_fleet`` writes every step (``class.{name}.bad`` /
``class.{name}.terminal``) and nothing else.

For an SLO target ``t`` (e.g. 0.9 ⇒ a 10% error budget), the burn rate over
a trailing window is::

    burn = (Δbad / Δterminal) / (1 - t)

— burn 1.0 spends the budget exactly; burn 6 over the fast window plus
burn 3 over the slow window (the defaults) is the "page now" posture.  An
alert fires only when **every** window exceeds its threshold (the fast
window alone is noise; the slow window alone is too late), re-arms only
after the class drops back under (hysteresis via the active set), and skips
windows with fewer than ``min_terminal`` verdicts (1-of-1 is not a signal).

Every alert carries a drill-down: the top offending requests of that class
by deadline overshoot — shed outright or admitted past their TTFD deadline
inside the slow window — each with its critical-path segment breakdown
(``obs.critical``) when a tracer is recording, so the alert names not just
*that* the budget is burning but *where the steps went*.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.obs import critical as critical_mod
from repro.obs import export as export_mod
from repro.obs.tracer import STEP_QUANTUM

__all__ = ["BurnWindow", "DEFAULT_WINDOWS", "Alert", "BurnRateMonitor"]


@dataclasses.dataclass(frozen=True)
class BurnWindow:
    """One trailing window: ``steps`` long, fires past ``threshold``."""
    steps: int
    threshold: float


#: fast window catches cliffs, slow window proves they are sustained
DEFAULT_WINDOWS: Tuple[BurnWindow, ...] = (BurnWindow(8, 6.0),
                                           BurnWindow(32, 3.0))


def parse_windows(spec: str) -> Tuple[BurnWindow, ...]:
    """``"8:6,32:3"`` → windows; the ``ISHMEM_OBS_ALERT_WINDOWS`` format."""
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        steps, thr = part.split(":")
        out.append(BurnWindow(int(steps), float(thr)))
    if not out:
        raise ValueError(f"no windows in spec {spec!r}")
    return tuple(out)


@dataclasses.dataclass
class Alert:
    """One fired burn-rate alert, with its evidence."""
    cls: str                      # SLO class name
    step: int                     # fleet step it fired at
    target: float                 # SLO target the budget derives from
    burn: Dict[int, float]        # window steps -> measured burn rate
    offenders: List[dict]         # drill-down, worst overshoot first

    def to_json(self) -> dict:
        return {"cls": self.cls, "step": self.step, "target": self.target,
                "burn": {str(k): v for k, v in sorted(self.burn.items())},
                "offenders": self.offenders}


class BurnRateMonitor:
    """Stateful per-class burn-rate watcher; drive with :meth:`observe`
    once per fleet step (after ``sample_fleet``).

    ``fired`` accumulates every alert ever raised; :meth:`observe` returns
    only the *newly* fired ones (the hysteresis edge), so a driver can dump
    a flight-recorder postmortem exactly once per incident.
    """

    def __init__(self, *, target: float = 0.9,
                 windows: Tuple[BurnWindow, ...] = DEFAULT_WINDOWS,
                 top_n: int = 3, min_terminal: int = 4):
        if not 0.0 < target < 1.0:
            raise ValueError(f"SLO target must be in (0, 1), got {target}")
        self.target = target
        self.windows = tuple(sorted(windows, key=lambda w: w.steps))
        self.top_n = top_n
        self.min_terminal = min_terminal
        self.active: set = set()          # class names currently firing
        self.fired: List[Alert] = []
        self.observations = 0

    # ------------------------------------------------------------ mechanics
    def _burn(self, rows: List[dict], cls: str,
              w: BurnWindow) -> Optional[float]:
        """Burn rate for one class over one trailing window, or None when
        the window saw fewer than ``min_terminal`` verdicts."""
        cur = rows[-1]
        base = rows[-1 - w.steps] if len(rows) > w.steps else {}
        d_bad = (cur.get(f"class.{cls}.bad", 0)
                 - base.get(f"class.{cls}.bad", 0))
        d_term = (cur.get(f"class.{cls}.terminal", 0)
                  - base.get(f"class.{cls}.terminal", 0))
        if d_term < self.min_terminal:
            return None
        return (d_bad / d_term) / (1.0 - self.target)

    def _drilldown(self, fleet, cls: str, window_steps: int,
                   tracer=None) -> List[dict]:
        """The requests actually burning the budget: this class's terminal
        SLO violations inside the window, worst deadline overshoot first."""
        from repro.serve.frontend import slo as slo_mod
        from repro.serve.scheduler import FINISHED, SHED

        step = fleet.elapsed_steps
        paths = None
        if tracer is not None and getattr(tracer, "enabled", False):
            paths = critical_mod.fleet_paths(
                export_mod.request_chains(tracer))
        offenders = []
        for pod in fleet.pods:
            for req in pod.sched.requests.values():
                sc = slo_mod.resolve(req.slo, fleet.classes)
                if sc.name != cls or req.finish_step < step - window_steps:
                    continue
                if req.state == SHED:
                    rec = {"rid": req.rid, "pod": pod.name,
                           "outcome": "shed",
                           "waited_steps": req.finish_step
                           - req.arrival_step,
                           "deadline_steps": sc.ttfd_deadline,
                           # a shed never produced a token: the whole
                           # deadline (plus the wait) is forfeit
                           "overshoot_steps": (req.finish_step
                                               - req.arrival_step)
                           + sc.ttfd_deadline}
                elif req.state == FINISHED:
                    ttfd = req.admit_step - req.arrival_step
                    if ttfd <= sc.ttfd_deadline:
                        continue
                    rec = {"rid": req.rid, "pod": pod.name,
                           "outcome": "late",
                           "ttfd_steps": ttfd,
                           "deadline_steps": sc.ttfd_deadline,
                           "overshoot_steps": ttfd - sc.ttfd_deadline}
                else:
                    continue
                if paths is not None and req.rid in paths:
                    p = paths[req.rid]
                    rec["segments_steps"] = {
                        s: p["segments"][s] / STEP_QUANTUM
                        for s in critical_mod.SEGMENTS
                        if p["segments"][s] > 0}
                    rec["preemptions"] = p["preemptions"]
                offenders.append(rec)
        offenders.sort(key=lambda r: (-r["overshoot_steps"], r["rid"]))
        return offenders[:self.top_n]

    # -------------------------------------------------------------- driving
    def observe(self, fleet, reg, *, tracer=None) -> List[Alert]:
        """Check every class against every window; returns alerts newly
        fired this step (empty while an incident stays active)."""
        self.observations += 1
        rows = reg.series
        if not rows:
            return []
        cur = rows[-1]
        classes = sorted({k.split(".")[1] for k in cur
                          if k.startswith("class.")
                          and k.endswith(".terminal")})
        new: List[Alert] = []
        for cls in classes:
            burns = {w.steps: self._burn(rows, cls, w)
                     for w in self.windows}
            firing = all(
                burns[w.steps] is not None
                and burns[w.steps] > w.threshold
                for w in self.windows)
            if firing and cls not in self.active:
                self.active.add(cls)
                alert = Alert(
                    cls=cls, step=fleet.elapsed_steps, target=self.target,
                    burn={k: v for k, v in burns.items() if v is not None},
                    offenders=self._drilldown(
                        fleet, cls, self.windows[-1].steps, tracer=tracer))
                self.fired.append(alert)
                new.append(alert)
            elif not firing and cls in self.active:
                self.active.discard(cls)          # re-arm (hysteresis edge)
        return new

    def summary(self) -> dict:
        return {"target": self.target,
                "windows": [[w.steps, w.threshold] for w in self.windows],
                "observations": self.observations,
                "alerts": [a.to_json() for a in self.fired],
                "active": sorted(self.active)}
