"""Scoped wall-clock profiler: measured time for the jax/pallas hot paths.

Every other clock in this codebase is *deterministic*: the span tracer's
:class:`~repro.obs.tracer.StepClock` ticks ``step*1000 + seq`` and the
telemetry comm clock is priced by the analytic cost model.  That determinism
is load-bearing (bitwise traces, replayable audits) — but it also means no
headline number is ever *measured*.  This module adds the missing instrument
without touching the deterministic side:

- :class:`ProfClock` is the ONE ``time.perf_counter`` wrapper in the stack.
  Its values never reach a trace ``ts`` field, a scheduler decision, or the
  modeled comm clock; they live only in :class:`ProfSample` records and in
  ``source="wallclock"`` telemetry buckets (``repro.tune.telemetry`` keeps
  per-provenance bucket maps precisely so the two streams cannot mix).
- :class:`Profiler` hands out scopes that time the *actual execution* of a
  region — serve decode steps, paged-attention kernels, prefill chunks,
  migration flush slices.  The scope object is callable: ``ps(x)`` runs
  ``jax.block_until_ready`` on ``x`` so a jitted region is timed to
  completion, not to dispatch.  Even in interpret mode, CPU wall clock is a
  truth signal for *relative* wins.
- Each closed scope pairs the measured wall seconds with the analytic
  model's opinion of the same interval: the delta of the sink's model-stream
  time across the scope (exactly the ops the model priced inside it).  The
  pairs feed ``repro.obs.calibrate`` — the measured-vs-modeled divergence
  report — and the wallclock telemetry records feed
  ``tune.estimator.build_table(sample_source="wallclock")`` so the online
  refitter can hot-swap a genuinely measured table mid-run.

Profiling off is the shared :data:`NULL_PROF` (or an unset ``ctx.prof``):
scopes are no-ops, ``ps(x)`` is identity, nothing is recorded, and every
deterministic output stays bitwise-identical.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import List, Optional

from repro.tune import telemetry as telemetry_mod


class ProfClock:
    """The stack's only wall-clock source (``time.perf_counter``).

    Kept as a class (rather than bare calls) so tests can substitute a fake
    and so the segregation rule is auditable: grep for ``perf_counter`` and
    this is the single non-benchmark site."""

    def now(self) -> float:
        return time.perf_counter()


@dataclasses.dataclass
class ProfSample:
    """One measured region: what ran, how big it was, what it cost.

    ``step`` is the deterministic fleet/scheduler step the sample was taken
    at (for joining against step-clocked traces); ``wall_s`` is measured
    wall time; ``model_s`` is what the analytic model priced *inside* the
    scope (0.0 = the model does not price this region at all — honest
    coverage signal, not an error)."""
    op: str
    nbytes: int
    path: str
    tier: str
    work_items: int
    step: int
    wall_s: float
    model_s: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj: dict) -> "ProfSample":
        return cls(op=str(obj["op"]), nbytes=int(obj["nbytes"]),
                   path=str(obj["path"]), tier=str(obj["tier"]),
                   work_items=int(obj["work_items"]), step=int(obj["step"]),
                   wall_s=float(obj["wall_s"]), model_s=float(obj["model_s"]))


class _NullScope:
    """Scope used when profiling is off: enter/exit no-ops, identity call."""
    __slots__ = ()

    def __enter__(self) -> "_NullScope":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def __call__(self, x):
        return x


_NULL_SCOPE = _NullScope()


class _Scope:
    """One timed region.  ``with prof.scope(...) as ps: out = ps(fn())``."""
    __slots__ = ("prof", "op", "nbytes", "path", "tier", "work_items",
                 "_t0", "_m0")

    def __init__(self, prof: "Profiler", op: str, nbytes: int, path: str,
                 tier: str, work_items: int):
        self.prof = prof
        self.op = op
        self.nbytes = int(nbytes)
        self.path = path
        self.tier = tier
        self.work_items = int(work_items)

    def __enter__(self) -> "_Scope":
        self._m0 = self.prof._model_time()
        self._t0 = self.prof.clock.now()
        return self

    def __call__(self, x):
        """Block on a jax value (pytrees fine) so the timed region covers
        execution, not dispatch; returns ``x`` unchanged."""
        import jax
        return jax.block_until_ready(x)

    def __exit__(self, exc_type, exc, tb) -> bool:
        wall = self.prof.clock.now() - self._t0
        if exc_type is None:
            self.prof._close(self, wall,
                             self.prof._model_time() - self._m0)
        return False


class Profiler:
    """Scoped wall-clock profiler a driver attaches to a context.

    Mirrors the tracer's lifecycle: ``attach(ctx)`` installs it as
    ``ctx.prof``; instrumented hot paths fetch it with ``getattr`` and guard
    on ``enabled``, so an unattached/disabled run pays one attribute check.
    ``set_step`` mirrors ``StepClock.set_step`` (monotonic max) so samples
    carry the deterministic step they were measured at."""

    enabled = True

    def __init__(self, *, clock: Optional[ProfClock] = None,
                 max_samples: int = 65536,
                 sink_records: bool = True):
        self.clock = clock or ProfClock()
        self.max_samples = max_samples
        self.sink_records = sink_records
        self.samples: List[ProfSample] = []
        self.dropped = 0
        self.step = 0
        self.ctx = None

    # ------------------------------------------------------------ lifecycle
    def attach(self, ctx) -> "Profiler":
        self.ctx = ctx
        ctx.prof = self
        return self

    def set_step(self, step: int) -> None:
        if step > self.step:
            self.step = int(step)

    # -------------------------------------------------------------- scoping
    def scope(self, op: str, *, nbytes: int, path: str = "engine",
              tier: str = "local", work_items: int = 1) -> _Scope:
        return _Scope(self, op, nbytes, path, tier, work_items)

    # ------------------------------------------------------------- plumbing
    def _model_time(self) -> float:
        """The model stream's accumulated seconds (for pairing a scope with
        the analytic pricing of the ops recorded inside it)."""
        ctx = self.ctx
        if ctx is None:
            return 0.0
        tel = getattr(ctx, "telemetry", None)
        if tel is None:
            return 0.0
        total = getattr(tel, "total_time", None)
        return float(total()) if total is not None else 0.0

    def _close(self, sc: _Scope, wall_s: float, model_s: float) -> None:
        self.samples.append(ProfSample(
            op=sc.op, nbytes=sc.nbytes, path=sc.path, tier=sc.tier,
            work_items=sc.work_items, step=self.step,
            wall_s=wall_s, model_s=max(0.0, model_s)))
        if len(self.samples) >= self.max_samples:
            # decimate, keep spread — same policy as StatBucket reservoirs
            self.dropped += len(self.samples) - len(self.samples[::2])
            self.samples = self.samples[::2]
        if self.sink_records and self.ctx is not None:
            self.ctx.telemetry.record(telemetry_mod.OpRecord(
                sc.op, sc.nbytes, sc.path, sc.tier, wall_s,
                sc.work_items, telemetry_mod.WALLCLOCK_SOURCE))

    # -------------------------------------------------------------- queries
    def total_wall(self) -> float:
        return sum(s.wall_s for s in self.samples)

    def summary(self) -> dict:
        return {
            "samples": len(self.samples),
            "dropped": self.dropped,
            "wall_s": self.total_wall(),
            "model_s": sum(s.model_s for s in self.samples),
            "ops": sorted({s.op for s in self.samples}),
        }

    # ------------------------------------------------------------- persist
    def save(self, path: str) -> dict:
        doc = {"schema_version": 1,
               "samples": [s.to_json() for s in self.samples]}
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        return doc


class _NullProf(Profiler):
    """Profiling off: scope() hands back the shared no-op scope."""

    enabled = False

    def __init__(self):
        super().__init__(sink_records=False)

    def attach(self, ctx) -> "Profiler":      # pragma: no cover — guard only
        raise RuntimeError("NULL_PROF must not be attached; leave ctx.prof "
                           "unset for profiling-off")

    def scope(self, op: str, *, nbytes: int, path: str = "engine",
              tier: str = "local", work_items: int = 1):
        return _NULL_SCOPE

    def set_step(self, step: int) -> None:
        pass


NULL_PROF = _NullProf()


def load_samples(path: str) -> List[ProfSample]:
    """Rehydrate a saved sample file (the calibration CLI input)."""
    with open(path) as f:
        doc = json.load(f)
    rows = doc["samples"] if isinstance(doc, dict) else doc
    return [ProfSample.from_json(r) for r in rows]
