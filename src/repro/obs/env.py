"""``ISHMEM_OBS_*`` environment surface — observability's init-time knobs.

Mirrors the ``ISHMEM_*`` convention from ``repro.tune.env``: everything
defaults to *off* (Null tracer, no metrics, no re-fit), so an unconfigured
run is bitwise-identical to one built before this subsystem existed.

===============================  ============================================
``ISHMEM_OBS_TRACE``             ``1`` (collect in memory) or a path —
                                 enable the span tracer; a path also writes
                                 the Chrome-trace JSON there at shutdown
``ISHMEM_OBS_METRICS``           ``1`` or a path — per-fleet-step metrics
                                 registry (counters/gauges/histograms)
``ISHMEM_OBS_REFIT``             re-fit period in fleet steps (``0``/unset =
                                 online re-fit off)
``ISHMEM_OBS_REFIT_MIN_SAMPLES`` minimum retained telemetry samples before a
                                 due re-fit runs (default 64)
``ISHMEM_OBS_TRACE_LIMIT``       tracer event-buffer bound (default 2^20);
                                 accepts K/M suffixes
===============================  ============================================

CLI flags on ``launch/serve.py`` (``--trace``/``--metrics``/``--refit``)
override the environment.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping, Optional

from repro.tune.env import parse_bytes

PREFIX = "ISHMEM_OBS_"


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    trace: bool = False
    trace_path: Optional[str] = None
    metrics: bool = False
    metrics_path: Optional[str] = None
    refit_period: int = 0               # fleet steps; 0 = off
    refit_min_samples: int = 64
    trace_limit: int = 1 << 20

    @property
    def enabled(self) -> bool:
        return self.trace or self.metrics or self.refit_period > 0


def _flag_or_path(val: Optional[str]) -> tuple:
    """``None``/``0`` -> (False, None); ``1`` -> (True, None);
    anything else -> (True, path)."""
    if val is None:
        return False, None
    s = val.strip()
    if s in ("0", "", "off", "false", "no"):
        return False, None
    if s in ("1", "on", "true", "yes"):
        return True, None
    return True, s


def load_obs_env(environ: Optional[Mapping[str, str]] = None) -> ObsConfig:
    env = os.environ if environ is None else environ

    def get(name: str) -> Optional[str]:
        val = env.get(PREFIX + name)
        return val if val not in (None, "") else None

    trace, trace_path = _flag_or_path(get("TRACE"))
    metrics, metrics_path = _flag_or_path(get("METRICS"))
    refit = get("REFIT")
    try:
        refit_period = int(refit) if refit is not None else 0
    except ValueError:
        raise ValueError(f"ISHMEM_OBS_REFIT: expected a step count, "
                         f"got {refit!r}") from None
    if refit_period < 0:
        raise ValueError("ISHMEM_OBS_REFIT must be >= 0")
    min_samples = get("REFIT_MIN_SAMPLES")
    try:
        refit_min = int(min_samples) if min_samples is not None else 64
    except ValueError:
        raise ValueError(f"ISHMEM_OBS_REFIT_MIN_SAMPLES: expected an "
                         f"integer, got {min_samples!r}") from None
    limit = get("TRACE_LIMIT")
    try:
        trace_limit = parse_bytes(limit) if limit is not None else 1 << 20
    except ValueError:
        raise ValueError(f"ISHMEM_OBS_TRACE_LIMIT: expected a count like "
                         f"65536/1M, got {limit!r}") from None
    return ObsConfig(trace=trace, trace_path=trace_path,
                     metrics=metrics, metrics_path=metrics_path,
                     refit_period=refit_period,
                     refit_min_samples=refit_min,
                     trace_limit=trace_limit)
