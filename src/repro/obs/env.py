"""``ISHMEM_OBS_*`` environment surface — observability's init-time knobs.

Mirrors the ``ISHMEM_*`` convention from ``repro.tune.env``: everything
defaults to *off* (Null tracer, no metrics, no re-fit), so an unconfigured
run is bitwise-identical to one built before this subsystem existed.

===============================  ============================================
``ISHMEM_OBS_TRACE``             ``1`` (collect in memory) or a path —
                                 enable the span tracer; a path also writes
                                 the Chrome-trace JSON there at shutdown
``ISHMEM_OBS_METRICS``           ``1`` or a path — per-fleet-step metrics
                                 registry (counters/gauges/histograms)
``ISHMEM_OBS_REFIT``             re-fit period in fleet steps (``0``/unset =
                                 online re-fit off)
``ISHMEM_OBS_REFIT_MIN_SAMPLES`` minimum retained telemetry samples before a
                                 due re-fit runs (default 64)
``ISHMEM_OBS_TRACE_LIMIT``       tracer event-buffer bound (default 2^20);
                                 accepts K/M suffixes
``ISHMEM_OBS_AUDIT``             invariant-audit period in fleet steps
                                 (``0``/unset = auditors off); each audit
                                 runs every ``repro.obs.audit`` family and
                                 raises on any violation
``ISHMEM_OBS_RECORDER``          flight-recorder window in fleet steps
                                 (``0``/unset = off); postmortem dumps of
                                 the last-window spans on crash / audit
                                 violation / SLO alert
``ISHMEM_OBS_RECORDER_PATH``     postmortem dump path (default
                                 ``postmortem_trace.json``)
``ISHMEM_OBS_ALERTS``            ``1`` — SLO burn-rate monitor (implies
                                 metrics sampling)
``ISHMEM_OBS_ALERT_TARGET``      SLO target the error budget derives from
                                 (default 0.9)
``ISHMEM_OBS_ALERT_WINDOWS``     burn windows as ``steps:threshold`` pairs,
                                 e.g. ``8:6,32:3`` (the default)
``ISHMEM_OBS_PROF``              ``1`` (collect in memory) or a path —
                                 wall-clock profiler on serve hot paths; a
                                 path also writes the measured-sample JSON
                                 there at shutdown.  Deterministic outputs
                                 stay bitwise-identical either way
``ISHMEM_OBS_CALIBRATION``       ``1`` or a path — measured-vs-modeled
                                 divergence report at shutdown (implies
                                 ``PROF``); a path writes the report JSON
===============================  ============================================

CLI flags on ``launch/serve.py`` (``--trace``/``--metrics``/``--refit``/
``--audit``/``--recorder``/``--alerts``/``--profile``/``--calibration``)
override the environment.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping, Optional

from repro.tune.env import parse_bytes

PREFIX = "ISHMEM_OBS_"


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    trace: bool = False
    trace_path: Optional[str] = None
    metrics: bool = False
    metrics_path: Optional[str] = None
    refit_period: int = 0               # fleet steps; 0 = off
    refit_min_samples: int = 64
    trace_limit: int = 1 << 20
    audit_period: int = 0               # fleet steps; 0 = off
    recorder_window: int = 0            # fleet steps; 0 = off
    recorder_path: str = "postmortem_trace.json"
    alerts: bool = False
    alert_target: float = 0.9
    alert_windows: str = "8:6,32:3"     # parse_windows format
    prof: bool = False
    prof_path: Optional[str] = None
    calibration: bool = False
    calibration_path: Optional[str] = None

    @property
    def enabled(self) -> bool:
        return (self.trace or self.metrics or self.refit_period > 0
                or self.audit_period > 0 or self.recorder_window > 0
                or self.alerts or self.prof or self.calibration)


def _flag_or_path(val: Optional[str]) -> tuple:
    """``None``/``0`` -> (False, None); ``1`` -> (True, None);
    anything else -> (True, path)."""
    if val is None:
        return False, None
    s = val.strip()
    if s in ("0", "", "off", "false", "no"):
        return False, None
    if s in ("1", "on", "true", "yes"):
        return True, None
    return True, s


def load_obs_env(environ: Optional[Mapping[str, str]] = None) -> ObsConfig:
    env = os.environ if environ is None else environ

    def get(name: str) -> Optional[str]:
        val = env.get(PREFIX + name)
        return val if val not in (None, "") else None

    trace, trace_path = _flag_or_path(get("TRACE"))
    metrics, metrics_path = _flag_or_path(get("METRICS"))
    refit = get("REFIT")
    try:
        refit_period = int(refit) if refit is not None else 0
    except ValueError:
        raise ValueError(f"ISHMEM_OBS_REFIT: expected a step count, "
                         f"got {refit!r}") from None
    if refit_period < 0:
        raise ValueError("ISHMEM_OBS_REFIT must be >= 0")
    min_samples = get("REFIT_MIN_SAMPLES")
    try:
        refit_min = int(min_samples) if min_samples is not None else 64
    except ValueError:
        raise ValueError(f"ISHMEM_OBS_REFIT_MIN_SAMPLES: expected an "
                         f"integer, got {min_samples!r}") from None
    limit = get("TRACE_LIMIT")
    try:
        trace_limit = parse_bytes(limit) if limit is not None else 1 << 20
    except ValueError:
        raise ValueError(f"ISHMEM_OBS_TRACE_LIMIT: expected a count like "
                         f"65536/1M, got {limit!r}") from None

    def get_steps(name: str) -> int:
        raw = get(name)
        try:
            val = int(raw) if raw is not None else 0
        except ValueError:
            raise ValueError(f"{PREFIX}{name}: expected a step count, "
                             f"got {raw!r}") from None
        if val < 0:
            raise ValueError(f"{PREFIX}{name} must be >= 0")
        return val

    audit_period = get_steps("AUDIT")
    recorder_window = get_steps("RECORDER")
    recorder_path = get("RECORDER_PATH") or "postmortem_trace.json"
    alerts, _ = _flag_or_path(get("ALERTS"))
    raw_target = get("ALERT_TARGET")
    try:
        alert_target = float(raw_target) if raw_target is not None else 0.9
    except ValueError:
        raise ValueError(f"ISHMEM_OBS_ALERT_TARGET: expected a float in "
                         f"(0, 1), got {raw_target!r}") from None
    alert_windows = get("ALERT_WINDOWS") or "8:6,32:3"
    from repro.obs.alerts import parse_windows
    parse_windows(alert_windows)        # fail fast on a malformed spec
    prof, prof_path = _flag_or_path(get("PROF"))
    calibration, calibration_path = _flag_or_path(get("CALIBRATION"))
    if calibration:
        prof = True                     # a report needs measured samples
    return ObsConfig(trace=trace, trace_path=trace_path,
                     metrics=metrics, metrics_path=metrics_path,
                     refit_period=refit_period,
                     refit_min_samples=refit_min,
                     trace_limit=trace_limit,
                     audit_period=audit_period,
                     recorder_window=recorder_window,
                     recorder_path=recorder_path,
                     alerts=alerts,
                     alert_target=alert_target,
                     alert_windows=alert_windows,
                     prof=prof, prof_path=prof_path,
                     calibration=calibration,
                     calibration_path=calibration_path)
