"""Per-request critical-path reconstruction and fleet TTFD attribution.

PR 6 gave every request a causal lifeline (``cat="req"`` async spans,
``export.request_chains``); this module *consumes* it: each chain becomes a
critical path whose time is attributed exactly to five segments —

======== ====================================================================
queue    waiting for a resource: intake queue (``queued``), a decode slot or
         stream word (``staged``), a slot while the wire drains (``parked``),
         plus the shed span of rejected requests
wire     modeled bytes-in-flight: the ``streaming`` installment ramp, and the
         modeled wire window of the ``migrating`` span (``wire_steps`` —
         fused migrations refine it with the *observed* ``first_block_step``)
signal_  the ``migrating`` remainder past the wire window: the decode PE
wait     watching the slot/stream signal word ramp (flush latency, another
         request's admission completing this one's queue prefix, device
         ``signal_wait_until`` spins)
compute  ``prefill`` and ``decoding`` spans
preempt  ``preempted`` spans (parked in the pool between decode bursts)
======== ====================================================================

Durations are **boundary-attributed**: each phase runs from its begin to the
next phase's begin (the last runs to its own end), so the segment sum equals
the end-to-end span *exactly* — the invariant the stressed-fleet tests gate
on.  The migrating span is split wire/signal-wait inside those boundaries.

``analyze`` rolls paths up into the "where does p99 TTFD go" fleet report
with what-if estimates (e.g. the zero-wire TTFD bound: the p99 if every
wire segment cost nothing).  ``python -m repro.obs.analyze trace.json``
(``repro/obs/analyze.py``) is the offline CLI over an exported trace.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.obs import export as export_mod
from repro.obs.tracer import STEP_QUANTUM

#: attribution buckets, in report order
SEGMENTS = ("queue", "wire", "signal_wait", "compute", "preemption")

#: lifeline phase -> segment; ``migrating`` is split wire/signal_wait
PHASE_SEGMENT = {
    "shed": "queue",
    "queued": "queue",
    "prefill": "compute",
    "staged": "queue",
    "streaming": "wire",
    "parked": "queue",
    "decoding": "compute",
    "preempted": "preemption",
}


def _migrating_split(entry: dict, dur: float) -> tuple:
    """(wire_ticks, signal_wait_ticks) for one ``migrating`` span.

    The modeled wire window is ``wire_steps`` (the scheduler's admission
    gate: ``admit_ready_step - migrate_step``).  Fused migrations gate on
    the FIRST block's signal, and the scheduler records the step that block
    was *observed* resident (``first_block_step`` — possibly earlier than
    the gate, when another request's admission flush completed this one's
    queue prefix); when present it overrides the model, so fused requests
    show the true wire / signal-wait split.  Everything past the wire
    window until admission is the decode PE waiting on the signal word.
    """
    args = entry["args"]
    wire = None
    if args.get("protocol") == "fused":
        fbs = args.get("first_block_step", -1)
        if isinstance(fbs, (int, float)) and fbs >= 0:
            migrate_step = int(entry["t0"] // STEP_QUANTUM)
            wire = (float(fbs) - migrate_step) * STEP_QUANTUM
    if wire is None:
        wire = float(args.get("wire_steps", 0)) * STEP_QUANTUM
    wire = max(0.0, min(float(dur), wire))
    return wire, float(dur) - wire


def critical_path(chain: List[dict]) -> dict:
    """One request's critical path from its reconstructed phase chain.

    Returns::

        {"segments": {segment: ticks}, "phases": [{"phase", "ticks"}],
         "t0", "t1", "e2e_ticks", "ttfd_ticks" (None before first decode),
         "ttfd_segments": {segment: ticks up to the first decoding begin},
         "outcome", "preemptions", "complete": bool, "gaps": [...]}

    ``sum(segments.values()) == e2e_ticks`` holds exactly for a complete
    chain (boundary attribution, see module docstring); ``complete`` is
    False when any span is still open (windowed/truncated trace).
    """
    segments = {s: 0.0 for s in SEGMENTS}
    ttfd_segments = {s: 0.0 for s in SEGMENTS}
    phases: List[dict] = []
    if not chain:
        return {"segments": segments, "ttfd_segments": ttfd_segments,
                "phases": phases, "t0": None, "t1": None, "e2e_ticks": 0.0,
                "ttfd_ticks": None, "outcome": None, "preemptions": 0,
                "complete": False, "gaps": []}
    complete = all(e["t1"] is not None for e in chain)
    t_decode0 = next((e["t0"] for e in chain if e["phase"] == "decoding"),
                     None)
    for i, entry in enumerate(chain):
        t0 = entry["t0"]
        t_end = chain[i + 1]["t0"] if i + 1 < len(chain) else entry["t1"]
        if t_end is None:                      # open tail span
            t_end = t0
        dur = max(0.0, float(t_end) - float(t0))
        if entry["phase"] == "migrating":
            wire, sw = _migrating_split(entry, dur)
            parts = (("wire", wire), ("signal_wait", sw))
        else:
            seg = PHASE_SEGMENT.get(entry["phase"], "compute")
            parts = ((seg, dur),)
        for seg, ticks in parts:
            segments[seg] += ticks
            if t_decode0 is not None and t0 < t_decode0:
                # TTFD prefix: clip the phase to the first decoding begin
                clip = min(float(t_end), float(t_decode0)) - float(t0)
                if dur > 0:
                    ttfd_segments[seg] += ticks * max(0.0, clip) / dur
                else:
                    ttfd_segments[seg] += 0.0
        phases.append({"phase": entry["phase"], "ticks": dur})
    t0 = float(chain[0]["t0"])
    last = chain[-1]
    t1 = float(last["t1"] if last["t1"] is not None else last["t0"])
    args_last = last["args"]
    return {
        "segments": segments,
        "ttfd_segments": ttfd_segments,
        "phases": phases,
        "t0": t0,
        "t1": t1,
        "e2e_ticks": t1 - t0,
        "ttfd_ticks": (None if t_decode0 is None
                       else float(t_decode0) - t0),
        "outcome": args_last.get("outcome"),
        "preemptions": args_last.get("preemptions", 0),
        "complete": complete,
        "gaps": export_mod.chain_gaps(chain),
    }


def device_waits(events) -> Dict[int, dict]:
    """Per-rid device-side wait attribution from the ``kvx`` instants the
    fused protocol emits: ``admit_fused`` (the first-block admission gate)
    and ``consume`` (per-block ``device_signal_wait`` batches inside
    decode).  ``{rid: {"consumed_blocks", "consume_events", "fused_admit"}}``
    — threads the PR-7 device spans into each request's path record."""
    out: Dict[int, dict] = {}
    for ev in events:
        if ev.cat != "kvx" or ev.ph != "i":
            continue
        rid = (ev.args or {}).get("rid")
        if rid is None:
            continue
        rec = out.setdefault(int(rid), {"consumed_blocks": 0,
                                        "consume_events": 0,
                                        "fused_admit": False})
        if ev.name == "consume":
            rec["consume_events"] += 1
            rec["consumed_blocks"] += int(ev.args.get("blocks", 0))
        elif ev.name == "admit_fused":
            rec["fused_admit"] = True
    return out


def fleet_paths(chains: Dict[int, List[dict]],
                events=None) -> Dict[int, dict]:
    """Critical path per request; when the raw event stream is supplied the
    device-wait attribution (``device_waits``) is merged into each path."""
    paths = {rid: critical_path(chain) for rid, chain in chains.items()}
    if events is not None:
        dev = device_waits(events)
        for rid, rec in dev.items():
            if rid in paths:
                paths[rid]["device"] = rec
    return paths


def _percentile(xs: List[float], q: float) -> float:
    """Interpolated percentile (mirrors ``serve.frontend.metrics``) without
    importing the serving stack into the offline analyzer."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    if len(xs) == 1:
        return float(xs[0])
    pos = (len(xs) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return float(xs[lo] * (1 - frac) + xs[hi] * frac)


def analyze(chains: Dict[int, List[dict]], events=None, *,
            q: float = 99.0, measured=None) -> dict:
    """The "where does p99 TTFD go" fleet report.

    Aggregates every admitted request's TTFD-prefix segments, names the
    order-statistic request behind the p-``q`` TTFD with its own breakdown,
    and computes what-if bounds: for each segment, the p-``q`` TTFD if that
    segment cost zero (``zero_wire_p99_steps`` is the headline — the bound
    a perfect interconnect could reach without touching the scheduler).
    All times are in scheduler steps (ticks / STEP_QUANTUM).

    ``measured`` optionally carries wall-clock profiler samples
    (:class:`repro.obs.prof.ProfSample`); when given, the report grows a
    ``measured_overlay`` — per-segment *measured* wall seconds next to the
    step-clocked attribution, so "wire is 60% of TTFD" can be sanity-checked
    against what a real clock saw for the same segments."""
    paths = fleet_paths(chains, events)
    admitted = {rid: p for rid, p in paths.items()
                if p["ttfd_ticks"] is not None}
    shed = sum(1 for p in paths.values() if p["outcome"] == "shed")
    incomplete = sum(1 for p in paths.values() if not p["complete"])
    gaps = sum(len(p["gaps"]) for p in paths.values())

    ttfd = {rid: p["ttfd_ticks"] / STEP_QUANTUM
            for rid, p in admitted.items()}
    xs = list(ttfd.values())
    seg_totals = {s: 0.0 for s in SEGMENTS}
    for p in admitted.values():
        for s in SEGMENTS:
            seg_totals[s] += p["ttfd_segments"][s] / STEP_QUANTUM
    total = sum(seg_totals.values()) or 1.0

    # the request actually sitting at the p-q order statistic
    worst = None
    if xs:
        target = _percentile(xs, q)
        rid = min(ttfd, key=lambda r: (abs(ttfd[r] - target), r))
        worst = {
            "rid": rid,
            "ttfd_steps": ttfd[rid],
            "segments_steps": {s: admitted[rid]["ttfd_segments"][s]
                               / STEP_QUANTUM for s in SEGMENTS},
            "preemptions": admitted[rid]["preemptions"],
        }

    what_if = {}
    for s in ("wire", "signal_wait", "queue"):
        bound = [t - p["ttfd_segments"][s] / STEP_QUANTUM
                 for t, p in zip(xs, admitted.values())]
        what_if[f"zero_{s}_p{int(q)}_steps"] = _percentile(bound, q)

    e2e = [p["e2e_ticks"] / STEP_QUANTUM for p in paths.values()
           if p["complete"]]
    dev_events = 0
    dev_spins = 0
    if events is not None:
        for ev in events:
            if ev.ph == "i" and str(ev.name).startswith("device_"):
                dev_events += 1
                dev_spins += int((ev.args or {}).get("spins", 0))
    overlay = None
    if measured is not None:
        from repro.obs import calibrate as calibrate_mod
        overlay = calibrate_mod.measured_overlay(measured)
    return {
        "requests": len(paths),
        "admitted": len(admitted),
        "shed": shed,
        "incomplete_paths": incomplete,
        "chain_gaps": gaps,
        "ttfd": {
            "p50_steps": _percentile(xs, 50.0),
            f"p{int(q)}_steps": _percentile(xs, q),
            "mean_steps": (sum(xs) / len(xs)) if xs else 0.0,
        },
        "ttfd_segments_steps": seg_totals,
        "ttfd_segment_share": {s: seg_totals[s] / total for s in SEGMENTS},
        f"p{int(q)}_request": worst,
        "what_if": what_if,
        "e2e": {
            "p50_steps": _percentile(e2e, 50.0),
            f"p{int(q)}_steps": _percentile(e2e, q),
        },
        "device": {"events": dev_events, "spins": dev_spins},
        "measured_overlay": overlay,
    }


def analyze_tracer(tracer, *, q: float = 99.0, measured=None) -> dict:
    """:func:`analyze` straight off a live :class:`SpanTracer`."""
    return analyze(export_mod.request_chains(tracer), tracer.events, q=q,
                   measured=measured)


def analyze_doc(doc: dict, *, q: float = 99.0, measured=None) -> dict:
    """:func:`analyze` over a loaded Chrome-trace JSON document."""
    events = export_mod.events_from_doc(doc)
    return analyze(export_mod._chains_from_events(events), events, q=q,
                   measured=measured)
