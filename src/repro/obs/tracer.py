"""Span tracer: causal, step-clocked structured events across every layer.

The observability counterpart of the paper's per-op instrumentation: the
stack already *computes* everything a trace needs (scheduler steps, modeled
comm seconds, byte counts, path decisions) — this module only gives those
numbers a shared event vocabulary so one request's lifeline is
reconstructible across the completion queue, the migration engine, the
scheduler state machine, the router, and the fleet driver.

Design rules (DESIGN.md §11):

- **No wall clock.**  Timestamps come from a :class:`StepClock`: each
  scheduler/fleet step is one quantum (rendered as 1 ms in Perfetto), and
  events within a step get strictly increasing sub-ticks — so traces are
  bit-reproducible for a fixed seed and diffable across runs.  Modeled comm
  seconds ride along in event ``args`` where attribution needs them.
- **Null by default.**  Every context carries a tracer; the default is the
  shared :data:`NULL_TRACER` whose methods are no-ops and whose ``enabled``
  flag lets hot paths skip building args entirely.  Tracer off ⇒ the run is
  bitwise-identical to an uninstrumented one (the tracer only ever *reads*).
- **Chrome-trace-shaped.**  Events carry the Trace Event Format phases
  directly (``B/E`` thread slices, ``b/e`` async spans correlated by
  request id, ``i`` instants, ``C`` counters, ``s/f`` flows), so export
  (``repro.obs.export``) is a serialization, not a transformation.

Event taxonomy (cat / name):

====== ======================= =========================================
cat    names                   emitted by
====== ======================= =========================================
cq     flush, xfer, nbi        core/pending.py — coalesce + flush + path
kvx    stage, migrate,         serve/kvxfer.py — wire installments, with
       stream_chunk,           ``s/f`` flows (id = request id) linking
       stream_close, admit     issue on the src PE to admit on the dst PE
req    queued, prefill,        serve/scheduler.py — async spans (id =
       staged, streaming,      request id): the causal lifeline; ends
       parked, migrating,      carry queue/wire/compute attribution args
       decoding, preempted
sched  decode, prefill         serve/scheduler.py — per-PE thread slices
fleet  step, route, refit      serve/frontend/fleet.py + obs.refit
====== ======================= =========================================
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

#: sub-ticks per scheduler step; exported ts = step * quantum + seq.  1000
#: renders one step as 1 ms in Perfetto's us-denominated timeline.
STEP_QUANTUM = 1000


class StepClock:
    """Deterministic step-based clock: ``now()`` is monotonically increasing
    and advances by sub-ticks within a step, quanta across steps."""

    def __init__(self):
        self.step = 0
        self._seq = 0

    def set_step(self, step: int) -> None:
        """Advance to a scheduler/fleet step (monotonic: going 'back' in
        step — e.g. two pod schedulers sharing one clock — is a no-op)."""
        if step > self.step:
            self.step = step
            self._seq = 0

    def now(self) -> float:
        """Current timestamp; every call returns a strictly larger value
        within a step (sub-tick), capped below the next step's quantum."""
        ts = self.step * STEP_QUANTUM + min(self._seq, STEP_QUANTUM - 1)
        self._seq += 1
        return float(ts)


@dataclasses.dataclass
class TraceEvent:
    """One Trace-Event-Format record (see module docstring for phases)."""
    ph: str                       # B E b e i C s f
    name: str
    cat: str
    ts: float
    pid: object                   # process track (pod / "core" / "fleet")
    tid: object                   # thread track ("pe3" / "cq" / "requests")
    id: Optional[int] = None      # async-span / flow correlation id (rid)
    args: Optional[dict] = None


class Tracer:
    """No-op base tracer (the production default).

    ``enabled`` is False so instrumentation sites can guard arg
    construction: ``if tracer.enabled: tracer.instant(...)``.  All methods
    exist and do nothing, so un-guarded calls are still safe.
    """

    enabled: bool = False

    def __init__(self):
        self.clock = StepClock()

    # every emission is a no-op on the base class
    def begin(self, name, cat, pid, tid, **args) -> None:
        pass

    def end(self, name, cat, pid, tid, **args) -> None:
        pass

    def async_begin(self, name, cat, id, pid, tid, **args) -> None:
        pass

    def async_end(self, name, cat, id, pid, tid, **args) -> None:
        pass

    def instant(self, name, cat, pid, tid, **args) -> None:
        pass

    def counter(self, name, pid, tid, **values) -> None:
        pass

    def flow_start(self, id, name, pid, tid) -> None:
        pass

    def flow_end(self, id, name, pid, tid) -> None:
        pass


#: shared do-nothing tracer — safe as a default because it is stateless
#: beyond its clock, which nobody advances when tracing is off
NULL_TRACER = Tracer()


class SpanTracer(Tracer):
    """Recording tracer: bounded in-memory event list + open-span ledger.

    ``max_events`` bounds memory; past it new events are *counted*
    (``dropped``) but not stored — a truncated trace stays valid (it never
    drops an already-recorded begin's end: ends of known-open spans are
    always admitted)."""

    enabled = True

    def __init__(self, max_events: int = 1 << 20):
        super().__init__()
        self.max_events = max_events
        self.events: List[TraceEvent] = []
        self.dropped = 0
        # open-span bookkeeping (validation + always-close-on-truncate)
        self._open_slices: Dict[tuple, List[str]] = {}   # (pid,tid) -> stack
        self._open_async: Dict[tuple, int] = {}          # (cat,id,name) -> n

    # ------------------------------------------------------------ plumbing
    def _emit(self, ev: TraceEvent, *, force: bool = False) -> None:
        if len(self.events) >= self.max_events and not force:
            self.dropped += 1
            return
        self.events.append(ev)

    def now(self) -> float:
        return self.clock.now()

    # ------------------------------------------------------ thread slices
    def begin(self, name, cat, pid, tid, **args) -> None:
        self._open_slices.setdefault((pid, tid), []).append(name)
        self._emit(TraceEvent("B", name, cat, self.now(), pid, tid,
                              args=args or None))

    def end(self, name, cat, pid, tid, **args) -> None:
        stack = self._open_slices.get((pid, tid))
        if stack and stack[-1] == name:
            stack.pop()
        self._emit(TraceEvent("E", name, cat, self.now(), pid, tid,
                              args=args or None), force=True)

    # ------------------------------------------------------- async spans
    def async_begin(self, name, cat, id, pid, tid, **args) -> None:
        key = (cat, id, name)
        self._open_async[key] = self._open_async.get(key, 0) + 1
        self._emit(TraceEvent("b", name, cat, self.now(), pid, tid, id=id,
                              args=args or None))

    def async_end(self, name, cat, id, pid, tid, **args) -> None:
        key = (cat, id, name)
        open_n = self._open_async.get(key, 0)
        if open_n:
            self._open_async[key] = open_n - 1
        self._emit(TraceEvent("e", name, cat, self.now(), pid, tid, id=id,
                              args=args or None), force=open_n > 0)

    # ---------------------------------------------------------- the rest
    def instant(self, name, cat, pid, tid, **args) -> None:
        self._emit(TraceEvent("i", name, cat, self.now(), pid, tid,
                              args=args or None))

    def counter(self, name, pid, tid, **values) -> None:
        self._emit(TraceEvent("C", name, "counter", self.now(), pid, tid,
                              args=values))

    def flow_start(self, id, name, pid, tid) -> None:
        self._emit(TraceEvent("s", name, "flow", self.now(), pid, tid,
                              id=id))

    def flow_end(self, id, name, pid, tid) -> None:
        self._emit(TraceEvent("f", name, "flow", self.now(), pid, tid,
                              id=id))

    # -------------------------------------------------------------- query
    def open_spans(self) -> dict:
        """Spans begun but not ended — must be empty at end of a clean run
        (the causality invariant tests assert this)."""
        slices = {k: list(v) for k, v in self._open_slices.items() if v}
        asyncs = {k: n for k, n in self._open_async.items() if n}
        return {"slices": slices, "async": asyncs}

    def __len__(self) -> int:
        return len(self.events)
