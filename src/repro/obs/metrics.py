"""Metrics registry: per-fleet-step counters / gauges / histograms.

The span tracer (``obs.tracer``) answers "what happened to request 17?";
this module answers "what was the *system* doing at step 40?" — heap
fragmentation, proxy-ring occupancy and backpressure, KV-pool residency,
per-class goodput — snapshotted once per fleet step into a time series that
``--metrics out.json`` dumps next to the trace.

:class:`MetricsRegistry` is deliberately dumb storage (three dicts + a
sample loop); :func:`sample_fleet` is the one place that knows where each
number lives in the stack, so adding a gauge is a one-line change there.
"""
from __future__ import annotations

import json
from typing import Dict, List


def _log2_bucket(v: float) -> int:
    return max(0, int(v).bit_length() - 1) if v >= 1 else 0


class MetricsRegistry:
    """Counters (monotonic), gauges (point-in-time), log2 histograms, and a
    per-step time series of every gauge/counter sampled that step."""

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Dict[int, int]] = {}
        self.series: List[dict] = []          # one row per sampled step

    def count(self, name: str, delta: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + delta

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        h = self.hists.setdefault(name, {})
        b = _log2_bucket(value)
        h[b] = h.get(b, 0) + 1

    def sample(self, step: int) -> dict:
        """Append (and return) one time-series row: the current value of
        every gauge and counter, stamped with the fleet step."""
        row = {"step": step}
        row.update(self.gauges)
        row.update(self.counters)
        self.series.append(row)
        return row

    # ---------------------------------------------------------------- dump
    def snapshot(self) -> dict:
        return {
            "counters": dict(sorted(self.counters.items())),
            "gauges": dict(sorted(self.gauges.items())),
            "histograms": {k: {str(b): n for b, n in sorted(v.items())}
                           for k, v in sorted(self.hists.items())},
            "series": self.series,
        }

    def write(self, path: str) -> dict:
        doc = self.snapshot()
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        return doc


def sample_fleet(reg: MetricsRegistry, fleet, *, tracer=None) -> dict:
    """Read the whole stack's health gauges off a live Fleet and sample one
    time-series row (call once per fleet step, after the pods advance)."""
    from repro.serve.frontend import slo as slo_mod
    from repro.serve.scheduler import FINISHED, RECOVERED, SHED

    # --- symmetric heap: allocator pressure + fragmentation ---------------
    hs = fleet.heap.stats()
    reg.gauge("heap.bytes_in_use", hs["bytes_in_use"])
    reg.gauge("heap.bytes_free", hs["bytes_free"])
    frag = max((p["fragmentation"] for p in hs["pools"].values()),
               default=0.0)
    reg.gauge("heap.fragmentation_max", frag)
    reg.observe("heap.fragmentation", frag * 1024)   # log2 over milli-units

    # --- KV pool residency ------------------------------------------------
    ps = fleet.pool.stats()
    reg.gauge("pool.blocks_in_use", ps["blocks_in_use"])
    reg.gauge("pool.utilization", ps["utilization"])
    reg.gauge("pool.blocks_shared", ps["blocks_shared"])
    reg.gauge("pool.streams_active", ps["streams_active"])
    reg.gauge("pool.requests_resident", ps["requests_resident"])

    # --- host-proxy ring: occupancy + backpressure ------------------------
    if fleet.proxy is not None:
        ring = fleet.proxy.ring
        occ = ring.write_reserve - ring.consumed_published
        reg.gauge("proxy.ring_occupancy", occ)
        reg.gauge("proxy.ring_slots", ring.slots)
        reg.gauge("proxy.backpressure", fleet.proxy.backpressure)
        reg.observe("proxy.occupancy_hist", occ)

    # --- per-pod queue/slot pressure, fleet-wide class goodput ------------
    offered = {}
    good = {}
    shed = {}
    finished = {}
    recovered = 0
    for pod in fleet.pods + getattr(fleet, "dead_pods", []):
        sched = pod.sched
        reg.gauge(f"{pod.name}.queue_depth", len(sched.queue))
        reg.gauge(f"{pod.name}.waiting", pod.waiting())
        reg.gauge(f"{pod.name}.free_slots", pod.free_slots())
        reg.gauge(f"{pod.name}.occupancy", pod.occupancy())
        recovered += len(sched.stats.recovery_steps)
        for req in sched.requests.values():
            if req.state == RECOVERED:
                continue    # adopted elsewhere under a new rid — not offered
            cls = slo_mod.resolve(req.slo, fleet.classes)
            offered[cls.name] = offered.get(cls.name, 0) + 1
            if req.state == SHED:
                shed[cls.name] = shed.get(cls.name, 0) + 1
            elif req.state == FINISHED:
                finished[cls.name] = finished.get(cls.name, 0) + 1
                if (req.admit_step - req.arrival_step
                        <= cls.ttfd_deadline):
                    good[cls.name] = good.get(cls.name, 0) + 1
    for name, n in offered.items():
        n_shed = shed.get(name, 0)
        n_fin = finished.get(name, 0)
        n_good = good.get(name, 0)
        reg.gauge(f"class.{name}.offered", n)
        reg.gauge(f"class.{name}.good", n_good)
        reg.gauge(f"class.{name}.shed", n_shed)
        reg.gauge(f"class.{name}.goodput", n_good / n)
        # cumulative SLO ledger for the burn-rate monitor (obs.alerts):
        # terminal = requests with a final verdict, bad = the SLO-violating
        # subset (shed outright, or finished past the admission deadline)
        reg.gauge(f"class.{name}.finished", n_fin)
        reg.gauge(f"class.{name}.terminal", n_fin + n_shed)
        reg.gauge(f"class.{name}.bad", n_shed + (n_fin - n_good))

    # --- fault / recovery -------------------------------------------------
    fault = getattr(fleet.ctx, "fault", None)
    if fault is not None:
        reg.gauge("fault.dead_pes", len(fault.dead_pes))
        reg.gauge("fault.dcn_down", 1.0 if fault.dcn_down else 0.0)
        reg.gauge("fault.cancelled_ops", fleet.ctx.pending.stats.cancelled)
    # requests that came back from a fault: re-admitted to decode with
    # their pre-fault tokens replayed (the ISSUE's recovered_requests)
    reg.gauge("recovered_requests", recovered)

    # --- tracer health (self-observability) -------------------------------
    if tracer is not None and tracer.enabled:
        reg.gauge("trace.events", len(tracer.events))
        reg.gauge("trace.dropped", tracer.dropped)

    return reg.sample(fleet.elapsed_steps)
