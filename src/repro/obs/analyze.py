"""Offline critical-path analysis of an exported trace.

    python -m repro.obs.analyze trace.json [--json report.json] [--q 99]
                                [--calibration samples.json]

Loads a Chrome-trace document written by ``--trace`` (or a flight-recorder
postmortem dump), validates it, reconstructs every request's critical path
(``repro.obs.critical``), and prints the "where does p99 TTFD go" report:
per-segment attribution, the order-statistic request behind the p99, and
the what-if bounds (zero-wire / zero-signal-wait / zero-queue TTFD).

With ``--calibration`` pointing at a profiler sample file (written by
``--profile`` on the serve driver, ``repro.obs.prof.Profiler.save``), the
report additionally carries the measured-vs-modeled divergence summary
(``repro.obs.calibrate``) and a per-segment *measured* overlay next to the
step-clocked attribution.

Truncated traces (``otherData.dropped_events > 0``) are analyzed but loudly
flagged: with spans missing, chains can have phantom gaps and the segment
attribution is a lower bound, not the truth.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import calibrate, critical, export, prof as prof_mod


def _fmt_steps(x: float) -> str:
    return f"{x:8.1f}"


def render(report: dict, *, q: int, errors, warnings) -> str:
    lines = []
    lines.append(f"requests {report['requests']} "
                 f"(admitted {report['admitted']}, shed {report['shed']})")
    if warnings:
        for w in warnings:
            lines.append(f"!! {w}")
    if errors:
        lines.append(f"!! trace failed schema validation "
                     f"({len(errors)} error(s)); first: {errors[0]}")
    if report["incomplete_paths"]:
        lines.append(f"!! {report['incomplete_paths']} request(s) with "
                     f"still-open spans (windowed/aborted trace)")
    if report["chain_gaps"]:
        lines.append(f"!! {report['chain_gaps']} untraced hole(s) across "
                     f"request lifelines")
    t = report["ttfd"]
    lines.append(f"TTFD steps: p50 {t['p50_steps']:.1f}  "
                 f"p{q} {t[f'p{q}_steps']:.1f}  mean {t['mean_steps']:.1f}")
    lines.append(f"where the TTFD goes (fleet aggregate over admission "
                 f"prefixes):")
    for seg in critical.SEGMENTS:
        steps = report["ttfd_segments_steps"][seg]
        share = report["ttfd_segment_share"][seg]
        lines.append(f"  {seg:<12}{_fmt_steps(steps)} steps  "
                     f"{share * 100:5.1f}%")
    worst = report[f"p{q}_request"]
    if worst is not None:
        segs = ", ".join(f"{s}={v:.1f}" for s, v in
                         worst["segments_steps"].items() if v > 0)
        lines.append(f"p{q} request: rid {worst['rid']} "
                     f"ttfd {worst['ttfd_steps']:.1f} steps "
                     f"({segs}; {worst['preemptions']} preemption(s))")
    lines.append("what-if bounds:")
    for name, val in report["what_if"].items():
        lines.append(f"  {name:<28}{val:8.1f} steps")
    dev = report["device"]
    if dev["events"]:
        lines.append(f"device waits: {dev['events']} device_* event(s), "
                     f"{dev['spins']} flush spin(s)")
    overlay = report.get("measured_overlay")
    if overlay:
        lines.append("measured overlay (wall-clock seconds per segment):")
        for seg, row in overlay.items():
            lines.append(f"  {seg:<12}{row['wall_s'] * 1e3:10.3f} ms wall  "
                         f"{row['model_s'] * 1e3:10.3f} ms modeled  "
                         f"(n={row['n']})")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.analyze",
        description="critical-path / TTFD-attribution report over an "
                    "exported Chrome-trace document")
    ap.add_argument("trace", help="trace JSON written by --trace or a "
                                  "flight-recorder postmortem dump")
    ap.add_argument("--json", metavar="OUT.json", default=None,
                    help="also write the full report (with per-request "
                         "paths) as JSON")
    ap.add_argument("--q", type=int, default=99,
                    help="tail percentile for the report (default 99)")
    ap.add_argument("--calibration", metavar="SAMPLES.json", default=None,
                    help="profiler sample file (serve --profile output); "
                         "adds the measured-vs-modeled divergence report "
                         "and a per-segment measured overlay")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        doc = json.load(f)
    warnings: list = []
    errors = export.validate(doc, warnings=warnings)
    events = export.events_from_doc(doc)
    chains = export._chains_from_events(events)
    samples = (prof_mod.load_samples(args.calibration)
               if args.calibration else None)
    report = critical.analyze(chains, events, q=float(args.q),
                              measured=samples)
    cal_report = (calibrate.report_from_samples(samples)
                  if samples is not None else None)

    if args.json:
        paths = critical.fleet_paths(chains, events)
        full = dict(report)
        full["validation_errors"] = errors
        full["validation_warnings"] = warnings
        if cal_report is not None:
            full["calibration"] = cal_report
        full["paths"] = {str(rid): p for rid, p in sorted(paths.items())}
        with open(args.json, "w") as f:
            json.dump(full, f, indent=1, sort_keys=True)
            f.write("\n")
    print(render(report, q=args.q, errors=errors, warnings=warnings))
    if cal_report is not None:
        print(calibrate.render(cal_report))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
