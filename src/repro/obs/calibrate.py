"""Model-calibration layer: join measured wall clock against modeled cost.

The profiler (``repro.obs.prof``) produces :class:`~repro.obs.prof.ProfSample`
pairs — measured ``wall_s`` next to the analytic model's ``model_s`` for the
same region — and ``source="wallclock"`` telemetry buckets next to the
``"model"`` stream.  This module turns both into a *divergence report*:

- per (op, tier, log2-size-bucket, work_items) bucket: sample count, wall
  and model statistics, and ratio (wall/model) percentiles where the model
  prices the region at all;
- the worst-diverging buckets ranked by ``|log(ratio)|`` — an integer-factor
  divergence in either direction is the headline finding (NVSHMEM-style
  analyses show exactly that across message-size regimes);
- coverage: how much measured wall time the model does not price at all
  (``model_s == 0`` regions — e.g. pure prefill compute), reported honestly
  instead of folded into a ratio;
- a sink-level join over telemetry keys present in BOTH provenance streams
  (the benchmark ``best_of(record=...)`` path lands here);
- a per-segment measured overlay for the critical-path analyzer and a
  ``measured`` Chrome-trace track (instants on deterministic step-clock
  timestamps; wall seconds ride in ``args`` only — the export validator
  enforces that no wall-clock value reaches a ``ts`` field).

Everything here is pure arithmetic over samples: given a canned sample file
the report is deterministic byte for byte.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs import prof as prof_mod
from repro.obs.tracer import STEP_QUANTUM
from repro.tune import telemetry as telemetry_mod

#: profiler op -> critical-path segment (repro.obs.critical.SEGMENTS) for
#: the measured overlay; unlisted ops fall into "other"
OP_SEGMENT = {
    "serve_decode": "compute",
    "serve_prefill": "compute",
    "paged_attn": "compute",
    "stream_flush": "wire",
    "migrate_flush": "wire",
    "flush": "wire",
}

BucketKey = Tuple[str, str, int, int]     # (op, tier, size_bucket, work_items)


def size_bucket(nbytes: int) -> int:
    """log2 size class (0 for empty regions) — same binning as the
    telemetry size histogram."""
    return max(0, int(nbytes).bit_length() - 1) if nbytes > 0 else 0


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile over an already-sorted list (deterministic,
    no interpolation surprises across platforms)."""
    if not sorted_vals:
        return 0.0
    rank = max(0, min(len(sorted_vals) - 1,
                      int(math.ceil(q / 100.0 * len(sorted_vals))) - 1))
    return sorted_vals[rank]


def _stats(vals: List[float]) -> dict:
    s = sorted(vals)
    return {
        "n": len(s),
        "mean": (sum(s) / len(s)) if s else 0.0,
        "p50": _percentile(s, 50.0),
        "p90": _percentile(s, 90.0),
        "max": s[-1] if s else 0.0,
        "total": sum(s),
    }


def report_from_samples(samples: Iterable[prof_mod.ProfSample], *,
                        worst: int = 8) -> dict:
    """The divergence report (JSON-able, deterministic given the samples)."""
    samples = list(samples)
    groups: Dict[BucketKey, List[prof_mod.ProfSample]] = {}
    for s in samples:
        groups.setdefault(
            (s.op, s.tier, size_bucket(s.nbytes), s.work_items),
            []).append(s)

    buckets = []
    for (op, tier, sb, wi) in sorted(groups):
        rows = groups[(op, tier, sb, wi)]
        walls = [r.wall_s for r in rows]
        models = [r.model_s for r in rows]
        ratios = sorted(r.wall_s / r.model_s for r in rows
                        if r.model_s > 0.0 and r.wall_s > 0.0)
        buckets.append({
            "op": op,
            "tier": tier,
            "size_bucket": sb,
            "size_bytes": 1 << sb,
            "work_items": wi,
            "n": len(rows),
            "modeled_n": sum(1 for r in rows if r.model_s > 0.0),
            "wall": _stats(walls),
            "model": _stats(models),
            "ratio": ({
                "p50": _percentile(ratios, 50.0),
                "p90": _percentile(ratios, 90.0),
                "max": ratios[-1],
            } if ratios else None),
        })

    populated = [b for b in buckets if b["ratio"] is not None]
    worst_rows = sorted(
        populated,
        key=lambda b: (-abs(math.log(max(b["ratio"]["p50"], 1e-300))),
                       b["op"], b["tier"], b["size_bucket"],
                       b["work_items"]))[:worst]

    wall_total = sum(s.wall_s for s in samples)
    model_total = sum(s.model_s for s in samples)
    unmodeled = sum(s.wall_s for s in samples if s.model_s <= 0.0)
    return {
        "schema_version": 1,
        "samples": len(samples),
        "buckets": buckets,
        "populated_buckets": len(populated),
        "worst": [
            {"op": b["op"], "tier": b["tier"],
             "size_bucket": b["size_bucket"],
             "work_items": b["work_items"],
             "ratio_p50": b["ratio"]["p50"], "n": b["n"]}
            for b in worst_rows
        ],
        "coverage": {
            "wall_s": wall_total,
            "model_s": model_total,
            "unmodeled_wall_s": unmodeled,
            "unmodeled_wall_frac": (unmodeled / wall_total
                                    if wall_total > 0 else 0.0),
        },
    }


def sink_join(sink: telemetry_mod.TelemetrySink, *,
              base: str = telemetry_mod.MODEL_SOURCE,
              other: str = telemetry_mod.WALLCLOCK_SOURCE) -> List[dict]:
    """Join telemetry keys present in BOTH provenance streams: mean modeled
    vs mean measured seconds per (op, path, tier, work_items).  This is the
    coarse sink-level view (no per-size pairing); the profiler's paired
    samples give the fine-grained one."""
    sources = getattr(sink, "sources", None)
    if not sources:
        return []
    base_map = sources.get(base, {})
    other_map = sources.get(other, {})
    out = []
    for key in sorted(set(base_map) & set(other_map)):
        mb, ob = base_map[key], other_map[key]
        mean_b, mean_o = mb.mean_time(), ob.mean_time()
        op, path, tier, wi = key
        out.append({
            "op": op, "path": path, "tier": tier, "work_items": wi,
            base: {"n": mb.count, "mean": mean_b},
            other: {"n": ob.count, "mean": mean_o},
            "ratio": (mean_o / mean_b) if mean_b > 0 else None,
        })
    return out


def measured_overlay(samples: Iterable[prof_mod.ProfSample]) -> dict:
    """Per-critical-path-segment measured wall seconds — the overlay the
    analyzer prints next to its step-clocked segment attribution."""
    seg: Dict[str, dict] = {}
    for s in samples:
        name = OP_SEGMENT.get(s.op, "other")
        row = seg.setdefault(name, {"wall_s": 0.0, "model_s": 0.0, "n": 0})
        row["wall_s"] += s.wall_s
        row["model_s"] += s.model_s
        row["n"] += 1
    return {k: seg[k] for k in sorted(seg)}


def measured_track_events(samples: Iterable[prof_mod.ProfSample]) -> List[dict]:
    """Chrome-trace instants for the ``measured`` track.

    Timestamps are STEP-CLOCKED (``step*1000 + seq``, seq = arrival order
    within the step, saturating like the deterministic clock) so the track
    aligns with the rest of the trace; the measured wall/model microseconds
    ride only in ``args`` — never in ``ts`` — which keeps the export
    validator's integral-timestamp rule intact."""
    events = []
    seq: Dict[int, int] = {}
    for s in samples:
        k = seq.get(s.step, 0)
        seq[s.step] = k + 1
        events.append({
            "name": s.op, "cat": "measured", "ph": "i", "s": "t",
            "pid": "measured", "tid": s.op,
            "ts": s.step * STEP_QUANTUM + min(k, STEP_QUANTUM - 1),
            "args": {
                "step": s.step,
                "nbytes": s.nbytes,
                "path": s.path,
                "tier": s.tier,
                "work_items": s.work_items,
                "wall_us": s.wall_s * 1e6,
                "model_us": s.model_s * 1e6,
            },
        })
    events.sort(key=lambda e: (e["tid"], e["ts"]))
    return events


def render(report: dict, *, sink_rows: Optional[List[dict]] = None) -> str:
    """Human-readable divergence report for the CLI."""
    lines = []
    cov = report["coverage"]
    lines.append(f"calibration: {report['samples']} measured samples, "
                 f"{report['populated_buckets']} populated "
                 f"(op, tier, size, wi) buckets")
    lines.append(f"  measured wall {cov['wall_s'] * 1e3:.3f} ms   "
                 f"modeled {cov['model_s'] * 1e3:.3f} ms   "
                 f"unmodeled wall {cov['unmodeled_wall_frac'] * 100:.1f}%")
    if report["worst"]:
        lines.append("  worst divergence (ratio = wall/model, p50):")
        for b in report["worst"]:
            lines.append(
                f"    {b['op']:<16} tier={b['tier']:<5} "
                f"2^{b['size_bucket']:<2}B wi={b['work_items']:<4} "
                f"ratio {b['ratio_p50']:9.3f}  (n={b['n']})")
    else:
        lines.append("  no model-priced buckets measured (nothing to join)")
    if sink_rows:
        lines.append("  sink join (mean measured / mean modeled):")
        for r in sink_rows:
            ratio = r["ratio"]
            lines.append(
                f"    {r['op']:<16} {r['path']}/{r['tier']}/wi{r['work_items']}"
                f"  ratio {ratio:9.3f}" if ratio is not None else
                f"    {r['op']:<16} {r['path']}/{r['tier']}/wi{r['work_items']}"
                f"  (model mean 0)")
    return "\n".join(lines)
