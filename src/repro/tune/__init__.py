"""Online autotuning subsystem (paper §IV: "tuned" transport selection).

Four modules that turn the static analytic cost model in ``core.cutover``
into the paper's *measured* adaptive behaviour:

- ``telemetry``  — pluggable per-(op, path, tier, work_items) sample sink
                   that replaces the flat context ledger (bounded memory);
- ``estimator``  — least-squares fits of effective alpha/bandwidth per
                   transport path from observed (nbytes, t_sec) samples,
                   and measured cutover tables derived from the fits;
- ``table``      — JSON-persistable :class:`TuningTable` (save/load/merge)
                   so one profiling run warm-starts later sessions;
- ``env``        — the ``ISHMEM_*`` environment-variable configuration
                   surface mirroring the real Intel SHMEM library.

Typical workflow::

    sink  = telemetry.TelemetrySink()          # or ctx.telemetry after a run
    ...                                        # run ops / a profiling sweep
    tbl   = estimator.build_table(sink)        # fit measured cutovers
    tbl.save("BENCH_cutover.json")             # persist
    # later session:
    #   ISHMEM_TUNING_FILE=BENCH_cutover.json  -> context.init arms the table
"""
from repro.tune import env, estimator, table, telemetry  # noqa: F401

__all__ = ["env", "estimator", "table", "telemetry"]
