"""Least-squares transport-profile estimator.

Fits the linear cost model t(n) = alpha + n / bw per transport path from
observed (nbytes, t_sec) samples in a :class:`telemetry.TelemetrySink`, and
derives *measured* cutover tables keyed by (tier, work_items) — the empirical
replacement for the closed-form-only ``cutover.cutover_bytes``.

Fitting detail: the direct path's bandwidth depends on the issuing work-group
size (paper Fig. 4a), so direct profiles are fitted per (tier, work_items);
the engine and proxy paths are work-group-independent (Fig. 4b) and pool all
samples per tier under the ``ANY_WI`` key.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.tune import telemetry as telemetry_mod
from repro.tune.table import (ANY_WI, PathProfile, TuningTable,
                              cutover_from_profiles)

MIN_SAMPLES = 3          # below this a fit is too unconstrained to trust

# Collective timings scale with the team size (t ~ alpha + n*(npes-1)/bw and
# friends — core.cutover.t_collective), so pooling them with point-to-point
# samples would poison the per-op linear fit.  The profiles fitted here model
# ONE p2p transfer; collective records are excluded by op name.
COLLECTIVE_OPS = frozenset({
    "sync", "barrier", "broadcast", "fcollect", "collect", "alltoall",
    "reduce", "psum", "psum_nbi", "all_gather", "reduce_scatter", "ppermute",
    "psum_hierarchical", "device_broadcast", "device_reduce",
})


def _is_p2p(op: str) -> bool:
    if op.endswith("(pending)") or op.endswith("(done)"):
        return False              # zero-cost queue markers, not transfers
    return op.split("[")[0] not in COLLECTIVE_OPS


def fit_linear(samples: Sequence[Tuple[int, float]]) -> Optional[PathProfile]:
    """Ordinary least squares for t = alpha + n * inv_bw.

    Returns None when the samples cannot constrain a line (fewer than
    MIN_SAMPLES points or no spread in n).  A non-positive fitted slope
    (time flat or decreasing in size — pure-latency regime) degrades to
    bw = inf with alpha = mean(t).
    """
    if len(samples) < MIN_SAMPLES:
        return None
    n = float(len(samples))
    mean_x = sum(s[0] for s in samples) / n
    mean_t = sum(s[1] for s in samples) / n
    # centered normal equations (conditioning: nbytes spans ~8 decades)
    sxx = sum((s[0] - mean_x) ** 2 for s in samples)
    if sxx <= 0.0:
        return None
    sxt = sum((s[0] - mean_x) * (s[1] - mean_t) for s in samples)
    slope = sxt / sxx
    if slope <= 0.0:
        prof = PathProfile(alpha=mean_t, bw=float("inf"), nsamples=int(n))
    else:
        alpha = mean_t - slope * mean_x
        prof = PathProfile(alpha=max(0.0, alpha), bw=1.0 / slope,
                           nsamples=int(n))
    sq = sum((prof.time(x) - t) ** 2 for x, t in samples)
    prof.resid = math.sqrt(sq / n)
    return prof


def fit_profiles(sink: telemetry_mod.TelemetrySink, *,
                 min_samples: int = MIN_SAMPLES,
                 sample_source: Optional[str] = None
                 ) -> Dict[Tuple[str, str, int], PathProfile]:
    """Fit every (path, tier[, work_items]) combination with enough samples.

    ``sample_source`` selects one provenance stream from the sink (e.g.
    ``"wallclock"`` to fit only measured profiler samples); ``None`` keeps
    the historical behavior of fitting the analytic model stream.  Each
    fitted profile is stamped with the stream it came from."""
    profiles: Dict[Tuple[str, str, int], PathProfile] = {}
    label = sample_source or telemetry_mod.MODEL_SOURCE
    for tier in sink.tiers(source=sample_source):
        for wi in sink.work_item_keys(path="direct", tier=tier,
                                      source=sample_source):
            prof = fit_linear(sink.samples(path="direct", tier=tier,
                                           work_items=wi, op_ok=_is_p2p,
                                           source=sample_source))
            if prof is not None and prof.nsamples >= min_samples:
                prof.source = label
                profiles[("direct", tier, wi)] = prof
        for path in ("engine", "proxy"):
            prof = fit_linear(sink.samples(path=path, tier=tier,
                                           op_ok=_is_p2p,
                                           source=sample_source))
            if prof is not None and prof.nsamples >= min_samples:
                prof.source = label
                profiles[(path, tier, ANY_WI)] = prof
    return profiles


def derive_cutovers(profiles: Dict[Tuple[str, str, int], PathProfile]
                    ) -> Dict[Tuple[str, int], int]:
    """Measured direct->engine crossover per (tier, work_items)."""
    cutovers: Dict[Tuple[str, int], int] = {}
    for (path, tier, wi), direct in profiles.items():
        if path != "direct":
            continue
        engine = (profiles.get(("engine", tier, wi))
                  or profiles.get(("engine", tier, ANY_WI)))
        if engine is None:
            continue
        cutovers[(tier, wi)] = cutover_from_profiles(direct, engine)
    return cutovers


def build_table(sink: telemetry_mod.TelemetrySink, *,
                min_samples: int = MIN_SAMPLES,
                source: str = "measured",
                sample_source: Optional[str] = None) -> TuningTable:
    """Sink -> fitted profiles -> measured cutover table (the whole pipeline).

    ``source`` labels the table artifact; ``sample_source`` restricts the fit
    to one telemetry provenance stream (``"wallclock"`` fits only measured
    samples — the table the online refitter arms when profiling is on)."""
    profiles = fit_profiles(sink, min_samples=min_samples,
                            sample_source=sample_source)
    return TuningTable(cutovers=derive_cutovers(profiles), profiles=profiles,
                       source=source)


# ---------------------------------------------------------------------------
# Profiling sweeps — generate samples by *executing* the cost model (or, on
# real hardware, by timing the kernels; benchmarks/bench_cutover.py uses this
# for the --json profile mode and the acceptance tests use it as ground truth).
# ---------------------------------------------------------------------------

DEFAULT_SIZES = tuple(1 << b for b in range(7, 25))        # 128 B .. 16 MB
DEFAULT_WORK_ITEMS = (1, 16, 128, 1024)
DEFAULT_TIERS = ("local", "ici")


def synthetic_sweep(hw=None, *, tiers: Iterable[str] = DEFAULT_TIERS,
                    work_items: Iterable[int] = DEFAULT_WORK_ITEMS,
                    sizes: Iterable[int] = DEFAULT_SIZES,
                    noise: float = 0.0, seed: int = 0,
                    sink: Optional[telemetry_mod.TelemetrySink] = None
                    ) -> telemetry_mod.TelemetrySink:
    """Record one (path x tier x work_items x size) grid of op timings into a
    sink, timing each configuration with ``cutover.op_time`` under ``hw``.

    ``noise`` adds deterministic multiplicative jitter (+-noise, fixed seed)
    so tests can exercise the estimator's robustness to measurement scatter.
    """
    from repro.core import cutover

    hw = hw or cutover.HwParams()
    sink = sink or telemetry_mod.TelemetrySink()
    rng_state = seed or 1
    wi_list = list(work_items)

    def jitter() -> float:
        nonlocal rng_state
        if noise <= 0.0:
            return 1.0
        rng_state = (1103515245 * rng_state + 12345) % (1 << 31)
        return 1.0 + noise * (2.0 * rng_state / float(1 << 31) - 1.0)

    for tier in tiers:
        for nbytes in sizes:
            for wi in wi_list:
                if tier != "dcn":
                    t = cutover.op_time(nbytes, "direct", work_items=wi,
                                        tier=tier, hw=hw) * jitter()
                    sink.record(telemetry_mod.OpRecord(
                        "sweep_put", nbytes, "direct", tier, t, wi))
            t = cutover.op_time(nbytes, "engine", tier=tier, hw=hw) * jitter()
            sink.record(telemetry_mod.OpRecord(
                "sweep_put", nbytes, "engine", tier, t, wi_list[0]))
            if tier == "dcn":
                t = cutover.op_time(nbytes, "proxy", tier=tier,
                                    hw=hw) * jitter()
                sink.record(telemetry_mod.OpRecord(
                    "sweep_put", nbytes, "proxy", tier, t, wi_list[0]))
    return sink


def agreement(table: TuningTable, hw=None, *,
              tiers: Iterable[str] = DEFAULT_TIERS,
              work_items: Iterable[int] = DEFAULT_WORK_ITEMS,
              sizes: Iterable[int] = DEFAULT_SIZES) -> float:
    """Fraction of a (nbytes x work_items x tier) grid where the learned
    table and the analytic model pick the same direct/engine path."""
    from repro.core import cutover

    hw = hw or cutover.HwParams()
    armed = cutover.Tuning(table=table)
    total = hits = 0
    for tier in tiers:
        for wi in work_items:
            for nbytes in sizes:
                want = cutover.choose_path(nbytes, work_items=wi, tier=tier,
                                           hw=hw)
                got = cutover.choose_path(nbytes, work_items=wi, tier=tier,
                                          hw=hw, tuning=armed)
                hits += int(want == got)
                total += 1
    return hits / total if total else 1.0


def sweep_records(sink: telemetry_mod.TelemetrySink
                  ) -> List[telemetry_mod.OpRecord]:
    """Convenience for debugging: the sink's retained trace."""
    return list(sink.trace)
