"""JSON-persistable tuning table: measured transport profiles + cutovers.

A :class:`TuningTable` is the durable artifact of a profiling run: per-path
fitted (alpha, bw) profiles and the derived direct->engine cutover points
keyed by (tier, work_items).  ``save``/``load`` round-trip through JSON so a
sweep (``python -m benchmarks.run --only cutover --json``) warm-starts later
sessions via ``ISHMEM_TUNING_FILE``; ``merge`` folds tables from several runs
(sample-count-weighted) so profiles accumulate across hosts/sessions.

The table is consulted by ``core.cutover.choose_path`` when armed on a
``Tuning`` (duck-typed through the ``lookup`` method — no import cycle with
``core``).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Optional, Tuple

# cutover sentinel: "never switch to the engine path" (matches core.cutover)
INF_CUTOVER = 1 << 62

# work_items key meaning "any work-group size" (engine/proxy bandwidth does
# not depend on the issuing work-group — paper Fig. 4b)
ANY_WI = 0

CutKey = Tuple[str, int]                  # (tier, work_items)
ProfKey = Tuple[str, str, int]            # (path, tier, work_items|ANY_WI)


@dataclasses.dataclass
class PathProfile:
    """Fitted t(n) = alpha + n / bw for one (path, tier[, work_items])."""
    alpha: float                          # s — effective startup latency
    bw: float                             # B/s — effective bandwidth (may be inf)
    nsamples: int = 0
    resid: float = 0.0                    # RMS residual of the fit (s)
    source: str = ""                      # sample provenance ("model",
    #                                       "wallclock", ""=pre-provenance)

    def time(self, nbytes: int) -> float:
        if not math.isfinite(self.bw) or self.bw <= 0:
            return self.alpha
        return self.alpha + nbytes / self.bw


def _merge_source(a: str, b: str) -> str:
    """Provenance of a sample-weighted profile merge: identical (or absent)
    labels pass through, mixes are recorded explicitly so ``"wallclock"``
    provenance is never silently laundered into a model label."""
    if a == b or not b:
        return a
    if not a:
        return b
    return f"{a}+{b}"


@dataclasses.dataclass
class TuningTable:
    cutovers: Dict[CutKey, int] = dataclasses.field(default_factory=dict)
    profiles: Dict[ProfKey, PathProfile] = dataclasses.field(
        default_factory=dict)
    source: str = "measured"
    version: int = 1

    # -------------------------------------------------------------- lookup
    def lookup(self, tier: str, work_items: int) -> Optional[int]:
        """Measured cutover bytes for (tier, work_items); nearest observed
        work-group size (log-space) when the exact one was not profiled.
        Returns None when the tier was never profiled (caller falls back to
        the analytic model)."""
        exact = self.cutovers.get((tier, work_items))
        if exact is not None:
            return exact
        cands = [wi for (t, wi) in self.cutovers if t == tier]
        if not cands:
            return None
        target = math.log2(max(1, work_items))
        best = min(cands, key=lambda wi: abs(math.log2(max(1, wi)) - target))
        return self.cutovers[(tier, best)]

    def profile(self, path: str, tier: str,
                work_items: int = ANY_WI) -> Optional[PathProfile]:
        p = self.profiles.get((path, tier, work_items))
        if p is None and work_items != ANY_WI:
            p = self.profiles.get((path, tier, ANY_WI))
        return p

    # --------------------------------------------------------------- merge
    def merge(self, other: "TuningTable") -> "TuningTable":
        """New table folding ``other`` into ``self``.  Profile collisions are
        combined by sample-count-weighted average; cutovers are recomputed
        from the merged profiles where both paths are present, else the entry
        with more backing samples wins (ties: self)."""
        profiles: Dict[ProfKey, PathProfile] = dict(self.profiles)
        for key, theirs in other.profiles.items():
            mine = profiles.get(key)
            if mine is None or mine.nsamples == 0:
                profiles[key] = theirs
                continue
            if theirs.nsamples == 0:
                continue
            n = mine.nsamples + theirs.nsamples
            wa, wb = mine.nsamples / n, theirs.nsamples / n
            inv_bw = (wa * (0.0 if not math.isfinite(mine.bw) else 1.0 / mine.bw)
                      + wb * (0.0 if not math.isfinite(theirs.bw)
                              else 1.0 / theirs.bw))
            profiles[key] = PathProfile(
                alpha=wa * mine.alpha + wb * theirs.alpha,
                bw=(1.0 / inv_bw) if inv_bw > 0 else float("inf"),
                nsamples=n,
                resid=max(mine.resid, theirs.resid),
                source=_merge_source(mine.source, theirs.source))
        def backing(tbl: "TuningTable", tier: str, wi: int) -> int:
            d = tbl.profiles.get(("direct", tier, wi))
            e = (tbl.profiles.get(("engine", tier, wi))
                 or tbl.profiles.get(("engine", tier, ANY_WI)))
            return (d.nsamples if d else 0) + (e.nsamples if e else 0)

        cutovers: Dict[CutKey, int] = dict(self.cutovers)
        for key, val in other.cutovers.items():
            if key not in cutovers:
                cutovers[key] = val
            elif backing(other, *key) > backing(self, *key):
                cutovers[key] = val
        # recompute from merged fits where possible
        for (tier, wi) in list(cutovers):
            d = profiles.get(("direct", tier, wi))
            e = (profiles.get(("engine", tier, wi))
                 or profiles.get(("engine", tier, ANY_WI)))
            if d is not None and e is not None:
                cutovers[(tier, wi)] = cutover_from_profiles(d, e)
        return TuningTable(cutovers=cutovers, profiles=profiles,
                           source=f"merge({self.source},{other.source})",
                           version=max(self.version, other.version))

    # ---------------------------------------------------------------- json
    def to_json(self) -> dict:
        return {
            "version": self.version,
            "source": self.source,
            "cutovers": {f"{t}/{wi}": (None if c >= INF_CUTOVER else c)
                         for (t, wi), c in sorted(self.cutovers.items())},
            "profiles": {
                f"{p}/{t}/{wi}": {
                    "alpha": prof.alpha,
                    "bw": (None if not math.isfinite(prof.bw) else prof.bw),
                    "nsamples": prof.nsamples,
                    "resid": prof.resid,
                    "source": prof.source,
                }
                for (p, t, wi), prof in sorted(self.profiles.items())
            },
        }

    @classmethod
    def from_json(cls, obj: dict) -> "TuningTable":
        cutovers: Dict[CutKey, int] = {}
        for key, val in obj.get("cutovers", {}).items():
            tier, wi = key.rsplit("/", 1)
            cutovers[(tier, int(wi))] = INF_CUTOVER if val is None else int(val)
        profiles: Dict[ProfKey, PathProfile] = {}
        for key, val in obj.get("profiles", {}).items():
            path, tier, wi = key.split("/")
            bw = val.get("bw")
            profiles[(path, tier, int(wi))] = PathProfile(
                alpha=float(val["alpha"]),
                bw=float("inf") if bw is None else float(bw),
                nsamples=int(val.get("nsamples", 0)),
                resid=float(val.get("resid", 0.0)),
                source=str(val.get("source", "")))
        return cls(cutovers=cutovers, profiles=profiles,
                   source=str(obj.get("source", "loaded")),
                   version=int(obj.get("version", 1)))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "TuningTable":
        with open(path) as f:
            return cls.from_json(json.load(f))


def cutover_from_profiles(direct: PathProfile, engine: PathProfile) -> int:
    """Crossing point of two fitted lines (same closed form as the analytic
    ``cutover.cutover_bytes``, but over *measured* alpha/bw)."""
    inv_d = 0.0 if not math.isfinite(direct.bw) else 1.0 / direct.bw
    inv_e = 0.0 if not math.isfinite(engine.bw) else 1.0 / engine.bw
    if inv_d <= inv_e:                    # direct at least as fast at all sizes
        return INF_CUTOVER
    n = (engine.alpha - direct.alpha) / (inv_d - inv_e)
    return max(0, int(n))
