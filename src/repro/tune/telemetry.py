"""Telemetry sink: bounded per-(op, path, tier, work_items) sample aggregation.

Replaces the flat write-only ``ledger`` list that used to live on
``ShmemContext``.  Every recorded op updates

- a bounded *trace* of recent :class:`OpRecord`\\ s (back-compat: the context's
  ``ledger`` property is a view of it, so tests can still inspect the last
  recorded op), and
- an aggregate :class:`StatBucket` keyed by ``(op, path, tier, work_items)``
  holding count / byte / time totals, a log2 message-size histogram, and a
  bounded (nbytes, t_sec) sample reservoir that the estimator fits.

Memory is bounded in both dimensions: the trace drops its oldest half when it
exceeds ``max_trace``, and each bucket's reservoir decimates (keep every other
sample, double the stride) when it reaches ``max_samples`` — so long runs keep
a spread of samples across time instead of only the newest.

Provenance (``OpRecord.source``): records default to ``"model"`` — the
deterministic analytic pricing stream that existed before the measured-time
layer.  The wall-clock profiler (``repro.obs.prof``) and the benchmark
``best_of(record=...)`` hook emit ``source="wallclock"`` records instead.
Each source aggregates into its OWN bucket map so measured CPU wall clock can
never contaminate the modeled comm clock (``total_time`` and the public
``buckets`` attribute remain the model stream — that invariant is what keeps
profiling-on runs bitwise-identical in every deterministic output).  Only the
model stream lands in the bounded ``trace`` (the back-compat ledger); other
sources are aggregate-only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

Key = Tuple[str, str, str, int]          # (op, path, tier, work_items)

#: provenance of the default (analytically priced) record stream
MODEL_SOURCE = "model"
#: provenance of measured wall-clock samples (profiler / best_of records)
WALLCLOCK_SOURCE = "wallclock"


@dataclasses.dataclass
class OpRecord:
    """One recorded operation (canonical definition; re-exported by
    ``core.context`` for backward compatibility)."""
    op: str
    nbytes: int
    path: str
    tier: str
    t_sec: float
    work_items: int = 1
    source: str = MODEL_SOURCE


def _log2_bucket(nbytes: int) -> int:
    return max(0, int(nbytes).bit_length() - 1) if nbytes > 0 else 0


@dataclasses.dataclass
class StatBucket:
    """Aggregate stats for one (op, path, tier, work_items) key."""
    count: int = 0
    bytes_total: int = 0
    time_total: float = 0.0
    t_min: float = float("inf")
    t_max: float = 0.0
    size_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    samples: List[Tuple[int, float]] = dataclasses.field(default_factory=list)
    max_samples: int = 256
    _stride: int = 1
    _seen: int = 0

    def add(self, nbytes: int, t_sec: float) -> None:
        self.count += 1
        self.bytes_total += nbytes
        self.time_total += t_sec
        self.t_min = min(self.t_min, t_sec)
        self.t_max = max(self.t_max, t_sec)
        b = _log2_bucket(nbytes)
        self.size_hist[b] = self.size_hist.get(b, 0) + 1
        if self._seen % self._stride == 0:
            self.samples.append((nbytes, t_sec))
            if len(self.samples) >= self.max_samples:
                self.samples = self.samples[::2]     # decimate, keep spread
                self._stride *= 2
        self._seen += 1

    def mean_time(self) -> float:
        return self.time_total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "bytes_total": self.bytes_total,
            "time_total": self.time_total,
            "t_min": self.t_min if self.count else 0.0,
            "t_max": self.t_max,
            "size_hist": {str(k): v for k, v in sorted(self.size_hist.items())},
            "samples_kept": len(self.samples),
        }


class Sink:
    """Pluggable sink interface consumed by ``ShmemContext.record``."""

    def record(self, rec: OpRecord) -> None:          # pragma: no cover
        raise NotImplementedError


class NullSink(Sink):
    """Discards everything (zero-overhead mode for production serving)."""

    def __init__(self):
        # both per-instance: callers may index the trace or iterate the
        # buckets, and a class-level dict would alias every NullSink (a
        # consumer mutating one sink's view would corrupt all of them)
        self.trace: List[OpRecord] = []
        self.buckets: Dict[Key, StatBucket] = {}

    def record(self, rec: OpRecord) -> None:
        pass

    def total_time(self) -> float:
        return 0.0

    def source_time(self, source: str = MODEL_SOURCE) -> float:
        return 0.0

    def clear(self) -> None:
        pass


class TelemetrySink(Sink):
    def __init__(self, max_trace: int = 65536,
                 max_samples_per_bucket: int = 256):
        self.max_trace = max_trace
        self.max_samples_per_bucket = max_samples_per_bucket
        self.trace: List[OpRecord] = []
        self.buckets: Dict[Key, StatBucket] = {}
        # per-provenance bucket maps; "model" aliases self.buckets so every
        # pre-provenance consumer (comm clock, tests, merge of old sinks)
        # keeps reading exactly the stream it always read
        self.sources: Dict[str, Dict[Key, StatBucket]] = {
            MODEL_SOURCE: self.buckets}

    # -------------------------------------------------------------- record
    def record(self, rec: OpRecord) -> None:
        source = getattr(rec, "source", MODEL_SOURCE) or MODEL_SOURCE
        if source == MODEL_SOURCE:
            # only the deterministic model stream feeds the ledger trace:
            # wall-clock records interleaving there would perturb every
            # "last recorded op" consumer when profiling is on
            self.trace.append(rec)
            if len(self.trace) > self.max_trace:
                # amortized drop-oldest — preferring to keep pending nbi
                # markers (rma.quiet() completes them later), but the bound
                # always wins: if pending ops alone overflow it, the oldest
                # are dropped too
                half = len(self.trace) // 2
                pending = [r for r in self.trace[:half]
                           if r.op.endswith("(pending)")]
                self.trace[:half] = pending
                if len(self.trace) > self.max_trace:
                    del self.trace[: len(self.trace) - self.max_trace]
        buckets = self.sources.get(source)
        if buckets is None:
            buckets = self.sources[source] = {}
        key = (rec.op, rec.path, rec.tier, rec.work_items)
        bucket = buckets.get(key)
        if bucket is None:
            bucket = buckets[key] = StatBucket(
                max_samples=self.max_samples_per_bucket)
        bucket.add(rec.nbytes, rec.t_sec)

    # --------------------------------------------------------------- query
    def _source_buckets(self, source: Optional[str]) -> Dict[Key, StatBucket]:
        """Bucket map for one provenance; ``None`` selects the model stream
        (the pre-provenance default, so every legacy caller is unchanged)."""
        return self.sources.get(source or MODEL_SOURCE, {})

    def total_time(self) -> float:
        """Total MODELED time over all recorded ops (stable even after the
        bounded trace has dropped old records).  Deliberately excludes
        wall-clock sources: this is the deterministic comm clock."""
        return sum(b.time_total for b in self.buckets.values())

    def total_count(self) -> int:
        return sum(b.count for b in self.buckets.values())

    def source_time(self, source: str = MODEL_SOURCE) -> float:
        """Total recorded time for ONE provenance stream."""
        return sum(b.time_total
                   for b in self._source_buckets(source).values())

    def nsamples(self, source: Optional[str] = None) -> int:
        """Retained reservoir samples for one provenance stream."""
        return sum(len(b.samples)
                   for b in self._source_buckets(source).values())

    def samples(self, *, path: str, tier: str,
                work_items: Optional[int] = None,
                op: Optional[str] = None,
                op_ok=None,
                source: Optional[str] = None) -> List[Tuple[int, float]]:
        """All retained (nbytes, t_sec) samples matching the filter.
        ``op_ok`` is an optional predicate over the op name (e.g. to keep
        collective timings out of a point-to-point fit); ``source`` selects
        a provenance stream (default: the model stream)."""
        out: List[Tuple[int, float]] = []
        for (k_op, k_path, k_tier, k_wi), b in \
                self._source_buckets(source).items():
            if k_path != path or k_tier != tier:
                continue
            if work_items is not None and k_wi != work_items:
                continue
            if op is not None and k_op != op:
                continue
            if op_ok is not None and not op_ok(k_op):
                continue
            out.extend(b.samples)
        return out

    def work_item_keys(self, *, path: str, tier: str,
                       source: Optional[str] = None) -> List[int]:
        """Distinct work-group sizes observed for (path, tier)."""
        keys = {k_wi for (_, k_path, k_tier, k_wi)
                in self._source_buckets(source)
                if k_path == path and k_tier == tier}
        return sorted(keys)

    def tiers(self, source: Optional[str] = None) -> List[str]:
        return sorted({k_tier for (_, _, k_tier, _)
                       in self._source_buckets(source)})

    # ------------------------------------------------------------ maintain
    def clear(self) -> None:
        self.trace = []
        self.buckets = {}
        self.sources = {MODEL_SOURCE: self.buckets}

    def _merge_buckets(self, mine_map: Dict[Key, StatBucket],
                       other_map: Dict[Key, StatBucket]) -> None:
        for key, b in other_map.items():
            mine = mine_map.get(key)
            if mine is None:
                mine = mine_map[key] = StatBucket(
                    max_samples=self.max_samples_per_bucket)
            mine.count += b.count
            mine.bytes_total += b.bytes_total
            mine.time_total += b.time_total
            mine.t_min = min(mine.t_min, b.t_min)
            mine.t_max = max(mine.t_max, b.t_max)
            for h, c in b.size_hist.items():
                mine.size_hist[h] = mine.size_hist.get(h, 0) + c
            # combine reservoirs under the bound WITHOUT over-dropping:
            # decimate the larger side only, so both runs stay represented
            # (concatenate-then-halve could strip one side to nothing when
            # both reservoirs arrive full — stride-2 over an interleave
            # deletes every sample of one parent)
            sa, sb = list(mine.samples), list(b.samples)
            while (len(sa) + len(sb) >= mine.max_samples
                   and (len(sa) > 1 or len(sb) > 1)):
                if len(sa) >= len(sb) and len(sa) > 1:
                    sa = sa[::2]
                else:
                    sb = sb[::2]
            mine.samples = sa + sb
            mine._stride = max(mine._stride, b._stride)
            mine._seen += b._seen

    def merge(self, other: "TelemetrySink") -> None:
        """Fold another sink's aggregates into this one, source by source
        (trace not merged)."""
        other_sources = getattr(other, "sources", None)
        if other_sources is None:                # pre-provenance sink
            other_sources = {MODEL_SOURCE: other.buckets}
        for source, other_map in other_sources.items():
            if source == MODEL_SOURCE:
                mine_map = self.buckets
            else:
                mine_map = self.sources.setdefault(source, {})
            self._merge_buckets(mine_map, other_map)

    def snapshot(self) -> dict:
        """JSON-able aggregate view (no raw trace).  Model-stream buckets
        keep the historical key format; other sources are suffixed
        ``@source``."""
        buckets = {
            f"{op}/{path}/{tier}/{wi}": b.snapshot()
            for (op, path, tier, wi), b in sorted(self.buckets.items())
        }
        for source in sorted(self.sources):
            if source == MODEL_SOURCE:
                continue
            for (op, path, tier, wi), b in sorted(
                    self.sources[source].items()):
                buckets[f"{op}/{path}/{tier}/{wi}@{source}"] = b.snapshot()
        return {
            "total_count": self.total_count(),
            "total_time": self.total_time(),
            "buckets": buckets,
        }


def replay(records: Iterable[OpRecord],
           sink: Optional[TelemetrySink] = None) -> TelemetrySink:
    """Feed an iterable of records through a (new) sink — used to rebuild
    aggregates from a saved trace."""
    sink = sink or TelemetrySink()
    for rec in records:
        sink.record(rec)
    return sink
