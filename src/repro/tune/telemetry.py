"""Telemetry sink: bounded per-(op, path, tier, work_items) sample aggregation.

Replaces the flat write-only ``ledger`` list that used to live on
``ShmemContext``.  Every recorded op updates

- a bounded *trace* of recent :class:`OpRecord`\\ s (back-compat: the context's
  ``ledger`` property is a view of it, so tests can still inspect the last
  recorded op), and
- an aggregate :class:`StatBucket` keyed by ``(op, path, tier, work_items)``
  holding count / byte / time totals, a log2 message-size histogram, and a
  bounded (nbytes, t_sec) sample reservoir that the estimator fits.

Memory is bounded in both dimensions: the trace drops its oldest half when it
exceeds ``max_trace``, and each bucket's reservoir decimates (keep every other
sample, double the stride) when it reaches ``max_samples`` — so long runs keep
a spread of samples across time instead of only the newest.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

Key = Tuple[str, str, str, int]          # (op, path, tier, work_items)


@dataclasses.dataclass
class OpRecord:
    """One recorded operation (canonical definition; re-exported by
    ``core.context`` for backward compatibility)."""
    op: str
    nbytes: int
    path: str
    tier: str
    t_sec: float
    work_items: int = 1


def _log2_bucket(nbytes: int) -> int:
    return max(0, int(nbytes).bit_length() - 1) if nbytes > 0 else 0


@dataclasses.dataclass
class StatBucket:
    """Aggregate stats for one (op, path, tier, work_items) key."""
    count: int = 0
    bytes_total: int = 0
    time_total: float = 0.0
    t_min: float = float("inf")
    t_max: float = 0.0
    size_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    samples: List[Tuple[int, float]] = dataclasses.field(default_factory=list)
    max_samples: int = 256
    _stride: int = 1
    _seen: int = 0

    def add(self, nbytes: int, t_sec: float) -> None:
        self.count += 1
        self.bytes_total += nbytes
        self.time_total += t_sec
        self.t_min = min(self.t_min, t_sec)
        self.t_max = max(self.t_max, t_sec)
        b = _log2_bucket(nbytes)
        self.size_hist[b] = self.size_hist.get(b, 0) + 1
        if self._seen % self._stride == 0:
            self.samples.append((nbytes, t_sec))
            if len(self.samples) >= self.max_samples:
                self.samples = self.samples[::2]     # decimate, keep spread
                self._stride *= 2
        self._seen += 1

    def mean_time(self) -> float:
        return self.time_total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "bytes_total": self.bytes_total,
            "time_total": self.time_total,
            "t_min": self.t_min if self.count else 0.0,
            "t_max": self.t_max,
            "size_hist": {str(k): v for k, v in sorted(self.size_hist.items())},
            "samples_kept": len(self.samples),
        }


class Sink:
    """Pluggable sink interface consumed by ``ShmemContext.record``."""

    def record(self, rec: OpRecord) -> None:          # pragma: no cover
        raise NotImplementedError


class NullSink(Sink):
    """Discards everything (zero-overhead mode for production serving)."""

    def __init__(self):
        # both per-instance: callers may index the trace or iterate the
        # buckets, and a class-level dict would alias every NullSink (a
        # consumer mutating one sink's view would corrupt all of them)
        self.trace: List[OpRecord] = []
        self.buckets: Dict[Key, StatBucket] = {}

    def record(self, rec: OpRecord) -> None:
        pass

    def total_time(self) -> float:
        return 0.0

    def clear(self) -> None:
        pass


class TelemetrySink(Sink):
    def __init__(self, max_trace: int = 65536,
                 max_samples_per_bucket: int = 256):
        self.max_trace = max_trace
        self.max_samples_per_bucket = max_samples_per_bucket
        self.trace: List[OpRecord] = []
        self.buckets: Dict[Key, StatBucket] = {}

    # -------------------------------------------------------------- record
    def record(self, rec: OpRecord) -> None:
        self.trace.append(rec)
        if len(self.trace) > self.max_trace:
            # amortized drop-oldest — preferring to keep pending nbi markers
            # (rma.quiet() completes them later), but the bound always wins:
            # if pending ops alone overflow it, the oldest are dropped too
            half = len(self.trace) // 2
            pending = [r for r in self.trace[:half]
                       if r.op.endswith("(pending)")]
            self.trace[:half] = pending
            if len(self.trace) > self.max_trace:
                del self.trace[: len(self.trace) - self.max_trace]
        key = (rec.op, rec.path, rec.tier, rec.work_items)
        bucket = self.buckets.get(key)
        if bucket is None:
            bucket = self.buckets[key] = StatBucket(
                max_samples=self.max_samples_per_bucket)
        bucket.add(rec.nbytes, rec.t_sec)

    # --------------------------------------------------------------- query
    def total_time(self) -> float:
        """Total modeled/measured time over ALL recorded ops (stable even
        after the bounded trace has dropped old records)."""
        return sum(b.time_total for b in self.buckets.values())

    def total_count(self) -> int:
        return sum(b.count for b in self.buckets.values())

    def samples(self, *, path: str, tier: str,
                work_items: Optional[int] = None,
                op: Optional[str] = None,
                op_ok=None) -> List[Tuple[int, float]]:
        """All retained (nbytes, t_sec) samples matching the filter.
        ``op_ok`` is an optional predicate over the op name (e.g. to keep
        collective timings out of a point-to-point fit)."""
        out: List[Tuple[int, float]] = []
        for (k_op, k_path, k_tier, k_wi), b in self.buckets.items():
            if k_path != path or k_tier != tier:
                continue
            if work_items is not None and k_wi != work_items:
                continue
            if op is not None and k_op != op:
                continue
            if op_ok is not None and not op_ok(k_op):
                continue
            out.extend(b.samples)
        return out

    def work_item_keys(self, *, path: str, tier: str) -> List[int]:
        """Distinct work-group sizes observed for (path, tier)."""
        keys = {k_wi for (_, k_path, k_tier, k_wi) in self.buckets
                if k_path == path and k_tier == tier}
        return sorted(keys)

    def tiers(self) -> List[str]:
        return sorted({k_tier for (_, _, k_tier, _) in self.buckets})

    # ------------------------------------------------------------ maintain
    def clear(self) -> None:
        self.trace = []
        self.buckets = {}

    def merge(self, other: "TelemetrySink") -> None:
        """Fold another sink's aggregates into this one (trace not merged)."""
        for key, b in other.buckets.items():
            mine = self.buckets.get(key)
            if mine is None:
                mine = self.buckets[key] = StatBucket(
                    max_samples=self.max_samples_per_bucket)
            mine.count += b.count
            mine.bytes_total += b.bytes_total
            mine.time_total += b.time_total
            mine.t_min = min(mine.t_min, b.t_min)
            mine.t_max = max(mine.t_max, b.t_max)
            for h, c in b.size_hist.items():
                mine.size_hist[h] = mine.size_hist.get(h, 0) + c
            # combine reservoirs under the bound WITHOUT over-dropping:
            # decimate the larger side only, so both runs stay represented
            # (concatenate-then-halve could strip one side to nothing when
            # both reservoirs arrive full — stride-2 over an interleave
            # deletes every sample of one parent)
            sa, sb = list(mine.samples), list(b.samples)
            while (len(sa) + len(sb) >= mine.max_samples
                   and (len(sa) > 1 or len(sb) > 1)):
                if len(sa) >= len(sb) and len(sa) > 1:
                    sa = sa[::2]
                else:
                    sb = sb[::2]
            mine.samples = sa + sb
            mine._stride = max(mine._stride, b._stride)
            mine._seen += b._seen

    def snapshot(self) -> dict:
        """JSON-able aggregate view (no raw trace)."""
        return {
            "total_count": self.total_count(),
            "total_time": self.total_time(),
            "buckets": {
                f"{op}/{path}/{tier}/{wi}": b.snapshot()
                for (op, path, tier, wi), b in sorted(self.buckets.items())
            },
        }


def replay(records: Iterable[OpRecord],
           sink: Optional[TelemetrySink] = None) -> TelemetrySink:
    """Feed an iterable of records through a (new) sink — used to rebuild
    aggregates from a saved trace."""
    sink = sink or TelemetrySink()
    for rec in records:
        sink.record(rec)
    return sink
