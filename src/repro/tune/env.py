"""``ISHMEM_*`` environment-variable configuration surface.

Mirrors the knobs the real Intel SHMEM library reads at ``ishmem_init``:

========================  ====================================================
``ISHMEM_ENABLE_CUTOVER`` ``1``/``0`` — enable adaptive transport selection
                          (default on; off pins every intra-fabric op to the
                          direct load/store path)
``ISHMEM_CUTOVER_BYTES``  explicit direct->engine switch size, overriding both
                          the analytic model and any tuning table; accepts
                          ``4096``, ``16K``, ``2M``, ``1G`` suffixes
``ISHMEM_FORCE_PATH``     ``direct`` | ``engine`` | ``proxy`` — pin one path
``ISHMEM_WORK_GROUP_SIZE`` default work-group size for ``ishmemx_*_work_group``
                          — honored by ``core.device.work_group`` AND by every
                          host-side ``choose_path``/collective pricing site
                          that does not pass an explicit width
                          (``cutover.resolve_work_items``), so one variable
                          moves both the device ops and the host cost model
``ISHMEM_TUNING_FILE``    JSON :class:`TuningTable` from a profiling run
                          (``benchmarks.run --json``) — arms measured cutovers
``ISHMEM_NBI_COALESCE``   ``1``/``0`` — write-combine queued nbi ops at
                          quiet/barrier flush (default on; off issues one
                          wire transfer per application call — see
                          ``core/pending.py``)
========================  ====================================================

``context.init`` calls :func:`tuning_from_env` when no explicit ``Tuning`` is
passed, so exporting these variables tunes a run with zero code changes.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping, Optional

from repro.tune.table import INF_CUTOVER, TuningTable

PREFIX = "ISHMEM_"
PATHS = ("direct", "engine", "proxy")

_SUFFIX = {"K": 1 << 10, "M": 1 << 20, "G": 1 << 30}


def parse_bytes(text: str) -> int:
    """``"4096"`` | ``"16K"`` | ``"2M"`` | ``"1G"`` -> bytes."""
    s = text.strip().upper()
    if s and s[-1] in _SUFFIX:
        return int(float(s[:-1]) * _SUFFIX[s[-1]])
    return int(s)


def _parse_bool(text: str, *, var: str) -> bool:
    s = text.strip().lower()
    if s in ("1", "true", "yes", "on"):
        return True
    if s in ("0", "false", "no", "off"):
        return False
    raise ValueError(f"{var}: expected a boolean, got {text!r}")


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    enable_cutover: bool = True
    cutover_bytes: Optional[int] = None
    force_path: Optional[str] = None
    work_group_size: int = 128
    tuning_file: Optional[str] = None
    nbi_coalesce: bool = True


def load_env(environ: Optional[Mapping[str, str]] = None) -> EnvConfig:
    """Parse the ``ISHMEM_*`` variables (defaults match an empty environment)."""
    env = os.environ if environ is None else environ

    def get(name: str) -> Optional[str]:
        val = env.get(PREFIX + name)
        return val if val not in (None, "") else None

    enable = get("ENABLE_CUTOVER")
    force = get("FORCE_PATH")
    if force is not None:
        force = force.strip().lower()
        if force not in PATHS:
            raise ValueError(
                f"ISHMEM_FORCE_PATH must be one of {PATHS}, got {force!r}")
    cutover_bytes = get("CUTOVER_BYTES")
    if cutover_bytes is not None:
        try:
            cutover_bytes = parse_bytes(cutover_bytes)
        except ValueError:
            raise ValueError(
                f"ISHMEM_CUTOVER_BYTES: expected a size like 4096/16K/2M/1G, "
                f"got {env.get(PREFIX + 'CUTOVER_BYTES')!r}") from None
    wgs = get("WORK_GROUP_SIZE")
    if wgs is not None:
        try:
            wgs = int(wgs)
        except ValueError:
            raise ValueError(
                f"ISHMEM_WORK_GROUP_SIZE: expected an integer, "
                f"got {wgs!r}") from None
    coalesce = get("NBI_COALESCE")
    return EnvConfig(
        enable_cutover=(True if enable is None
                        else _parse_bool(enable, var="ISHMEM_ENABLE_CUTOVER")),
        cutover_bytes=cutover_bytes,
        force_path=force,
        work_group_size=128 if wgs is None else wgs,
        tuning_file=get("TUNING_FILE"),
        nbi_coalesce=(True if coalesce is None
                      else _parse_bool(coalesce, var="ISHMEM_NBI_COALESCE")),
    )


def tuning_from_env(environ: Optional[Mapping[str, str]] = None,
                    cfg: Optional[EnvConfig] = None):
    """Build the ``cutover.Tuning`` an ``ishmem_init`` would arm.

    Precedence (most to least specific): ``ISHMEM_FORCE_PATH`` >
    ``ISHMEM_CUTOVER_BYTES`` > ``ISHMEM_TUNING_FILE`` (learned table) >
    analytic model.  Disabling cutover pins the direct path (the engine is
    never offloaded to), unless a force path says otherwise.
    """
    from repro.core import cutover

    cfg = cfg or load_env(environ)
    table = None
    if cfg.tuning_file is not None:
        table = TuningTable.load(cfg.tuning_file)   # missing file: loud error
    cutover_bytes = cfg.cutover_bytes
    if not cfg.enable_cutover and cfg.force_path is None:
        # "never switch to the engine" — expressed as an infinite cutover so
        # the dcn tier still routes to the proxy (force_path would hijack it
        # onto the nonexistent kernel-initiated NIC path)
        cutover_bytes = INF_CUTOVER
    return cutover.Tuning(cutover_bytes=cutover_bytes,
                          force_path=cfg.force_path,
                          work_group_size=cfg.work_group_size, table=table,
                          nbi_coalesce=cfg.nbi_coalesce)
