"""KV / recurrent-state caches.

Cache layout mirrors the model's scan structure: one entry per repeat-unit
position, every leaf stacked over the R unit repeats on axis 0.

Self-attention caches are *dense* (seq_len slots, validity = slot <= pos) or
*ring* (window slots + an explicit per-slot position array) when the
architecture is sub-quadratic at that context length (SWA; hybrid @ 500k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.models import ssm as ssm_mod


def self_cache_len(cfg, seq_len: int) -> int:
    if cfg.attention == "swa":
        return min(cfg.window, seq_len)
    if cfg.family == "hybrid" and seq_len > 65_536:
        return min(cfg.window, seq_len)   # zamba2 shared-attn windowed @ 500k
    return seq_len


def is_ring(cfg, seq_len: int) -> bool:
    return self_cache_len(cfg, seq_len) < seq_len


def _entry(kind, cfg, batch, seq_len, make):
    """make(shape, dtype) -> leaf (ShapeDtypeStruct or zeros)."""
    nkv, hd, dt = cfg.num_kv_heads, cfg.hd, cfg.dtype
    W = self_cache_len(cfg, seq_len)
    if kind in ("attn", "moe", "shared_attn", "encdec"):
        e = {
            "k": make((batch, W, nkv, hd), dt),
            "v": make((batch, W, nkv, hd), dt),
        }
        if is_ring(cfg, seq_len):
            e["kpos"] = make((batch, W), jnp.int32)
        if kind == "encdec":
            e["ck"] = make((batch, cfg.encoder_seq, nkv, hd), dt)
            e["cv"] = make((batch, cfg.encoder_seq, nkv, hd), dt)
        return e
    if kind == "cross":
        return {
            "ck": make((batch, cfg.image_tokens, nkv, hd), dt),
            "cv": make((batch, cfg.image_tokens, nkv, hd), dt),
        }
    if kind == "mamba":
        d_in, p, nh, N = ssm_mod.mamba_dims(cfg)
        conv_dim = d_in + 2 * N
        return {
            "state": make((batch, nh, p, N), jnp.float32),
            "conv": make((batch, cfg.ssm_conv - 1, conv_dim), jnp.float32),
        }
    if kind == "mlstm":
        d_in, nh, dk = ssm_mod.mlstm_dims(cfg)
        return {
            "C": make((batch, nh, dk, dk), jnp.float32),
            "n": make((batch, nh, dk), jnp.float32),
            "m": make((batch, nh), jnp.float32),
        }
    if kind == "slstm":
        d = cfg.d_model
        return {
            "c": make((batch, d), jnp.float32),
            "n": make((batch, d), jnp.float32),
            "m": make((batch, d), jnp.float32),
            "h": make((batch, d), jnp.float32),
        }
    raise ValueError(kind)


def _stacked(cfg, batch, seq_len, make):
    unit, reps = cfgbase.repeat_unit(cfg)
    blocks = []
    for kind in unit:
        entry = _entry(kind, cfg, batch, seq_len, make)
        blocks.append(jax.tree.map(
            lambda leaf: _prepend_axis(leaf, reps, make), entry))
    return {"blocks": blocks}


def _prepend_axis(leaf, reps, make):
    shape = (reps,) + tuple(leaf.shape)
    return make(shape, leaf.dtype)


def cache_struct(cfg, batch: int, seq_len: int):
    make = lambda shape, dtype: jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
    return _stacked(cfg, batch, seq_len, make)


def init_cache(cfg, batch: int, seq_len: int):
    def make(shape, dtype):
        return jnp.zeros(shape, dtype)

    cache = _stacked(cfg, batch, seq_len, make)
    # ring caches track per-slot positions; -1 == empty
    for blk in cache["blocks"]:
        if "kpos" in blk:
            blk["kpos"] = jnp.full(blk["kpos"].shape, -1, jnp.int32)
    return cache
