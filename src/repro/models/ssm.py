"""State-space / recurrent blocks:

- Mamba2 (SSD): chunked scan — intra-chunk quadratic form + inter-chunk linear
  state recurrence (chunk = 64 keeps the (B,nh,L,L) decay tensor honest for
  dry-run memory analysis).
- mLSTM (xLSTM): chunked matrix-memory linear attention with exponential
  gating and a running log-stabilizer (TFLA-style).
- sLSTM (xLSTM): per-timestep `lax.scan` (true recurrent gates through h,
  not parallelizable); the roofline analyzer scales the while body by its
  trip count.

All recurrences accumulate in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm, silu

MAMBA_CHUNK = 64
MLSTM_CHUNK = 64
MAMBA_HEADDIM = 64


def _chunk(s, want):
    c = min(want, s)
    while s % c:
        c -= 1
    return max(c, 1)


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================


def mamba_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    p = MAMBA_HEADDIM if d_in % MAMBA_HEADDIM == 0 else max(
        x for x in (32, 16, 8) if d_in % x == 0)
    return d_in, p, d_in // p, cfg.ssm_state


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    d_in, p, nh, N = mamba_dims(cfg)
    conv_dim = d_in + 2 * N
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((d,), dtype),
        "wz": dense_init(ks[0], (d, d_in), dtype=dtype),
        "wx": dense_init(ks[1], (d, d_in), dtype=dtype),
        "wB": dense_init(ks[2], (d, N), dtype=dtype),
        "wC": dense_init(ks[3], (d, N), dtype=dtype),
        "wdt": dense_init(ks[4], (d, nh), dtype=dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_w": dense_init(ks[5], (cfg.ssm_conv, conv_dim),
                             scale=0.3, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "out_proj": dense_init(ks[6], (d_in, d), dtype=dtype),
    }


def causal_conv(x, w, b):
    """Depthwise causal conv as shifted adds.  x: (B,S,D); w: (K,D)."""
    K = w.shape[0]
    out = jnp.zeros_like(x) + b
    for j in range(K):
        shift = K - 1 - j
        xs = x if shift == 0 else jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :-shift]
        out = out + xs * w[j]
    return out


def _mamba_project(p, x, cfg):
    d_in, hp, nh, N = mamba_dims(cfg)
    z = x @ p["wz"]
    xr = x @ p["wx"]
    Bm = x @ p["wB"]
    Cm = x @ p["wC"]
    dt_raw = x @ p["wdt"]
    return z, xr, Bm, Cm, dt_raw


def mamba_forward(p, x, cfg, state=None, conv_cache=None):
    """Full-sequence Mamba2.  x: (B,S,d).  Returns (y, final_state, conv_tail).

    state: (B,nh,p,N) initial SSM state (zeros if None).
    """
    B, S, d = x.shape
    d_in, hp, nh, N = mamba_dims(cfg)
    z, xr, Bm, Cm, dt_raw = _mamba_project(p, x, cfg)

    xBC = jnp.concatenate([xr, Bm, Cm], axis=-1)
    if conv_cache is not None:                    # continue from cached tail
        xBC_full = jnp.concatenate([conv_cache.astype(xBC.dtype), xBC], 1)
        conv = causal_conv(xBC_full, p["conv_w"], p["conv_b"])[:, conv_cache.shape[1]:]
    else:
        conv = causal_conv(xBC, p["conv_w"], p["conv_b"])
    conv = silu(conv)
    conv_tail = jnp.concatenate([jnp.zeros((B, cfg.ssm_conv - 1, xBC.shape[-1]),
                                           xBC.dtype), xBC], 1)[:, -(cfg.ssm_conv - 1):]
    xr = conv[..., :d_in]
    Bm = conv[..., d_in:d_in + N].astype(jnp.float32)
    Cm = conv[..., d_in + N:].astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,nh)
    a = -jnp.exp(p["A_log"])                                          # (nh,)
    dA = dt * a                                                       # (B,S,nh)
    xh = xr.reshape(B, S, nh, hp).astype(jnp.float32)
    xdt = xh * dt[..., None]                                          # (B,S,nh,p)

    L = _chunk(S, MAMBA_CHUNK)
    nc = S // L
    # reshape into chunks
    dA_c = dA.reshape(B, nc, L, nh)
    x_c = xdt.reshape(B, nc, L, nh, hp)
    B_c = Bm.reshape(B, nc, L, N)
    C_c = Cm.reshape(B, nc, L, N)

    cs = jnp.cumsum(dA_c, axis=2)                                     # (B,nc,L,nh)
    tot = cs[:, :, -1]                                                # (B,nc,nh)

    # intra-chunk (quadratic within chunk, like attention)
    G = jnp.einsum("bcln,bcsn->bcls", C_c, B_c)                       # (B,nc,L,L)
    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])      # (B,nc,L,L,nh)
    W = jnp.where(causal[None, None, :, :, None], G[..., None] * decay, 0.0)
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", W, x_c)

    # per-chunk local end-state: sum_s exp(tot - cs_s) x_s B_s^T
    sdecay = jnp.exp(tot[:, :, None, :] - cs)                         # (B,nc,L,nh)
    local_state = jnp.einsum("bclh,bclhp,bcln->bchpn", sdecay, x_c, B_c)

    # inter-chunk recurrence over nc chunks
    s0 = (jnp.zeros((B, nh, hp, N), jnp.float32) if state is None
          else state.astype(jnp.float32))

    def step(carry, inp):
        loc, ctot = inp                                # (B,nh,p,N), (B,nh)
        new = carry * jnp.exp(ctot)[..., None, None] + loc
        return new, carry                              # emit state *entering* chunk

    final_state, prev_states = jax.lax.scan(
        step, s0,
        (local_state.transpose(1, 0, 2, 3, 4), tot.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,nh,p,N)

    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp",
                         C_c, prev_states, jnp.exp(cs))
    y = (y_intra + y_inter).reshape(B, S, nh, hp)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = y * silu(z)
    return y @ p["out_proj"], final_state, conv_tail


def mamba_decode(p, x, cfg, state, conv_cache):
    """Single-token step.  x: (B,1,d); state: (B,nh,p,N);
    conv_cache: (B,K-1,conv_dim)."""
    B, _, d = x.shape
    d_in, hp, nh, N = mamba_dims(cfg)
    z, xr, Bm, Cm, dt_raw = _mamba_project(p, x, cfg)
    xBC = jnp.concatenate([xr, Bm, Cm], axis=-1)[:, 0]                # (B,conv_dim)
    window = jnp.concatenate([conv_cache.astype(xBC.dtype),
                              xBC[:, None]], 1)                       # (B,K,conv_dim)
    conv = silu((window * p["conv_w"][None]).sum(1) + p["conv_b"])
    new_conv_cache = window[:, 1:]

    xr = conv[:, :d_in]
    Bm = conv[:, d_in:d_in + N].astype(jnp.float32)
    Cm = conv[:, d_in + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    a = -jnp.exp(p["A_log"])
    xh = xr.reshape(B, nh, hp).astype(jnp.float32)

    decay = jnp.exp(dt * a)                                           # (B,nh)
    upd = jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], Bm)
    new_state = state.astype(jnp.float32) * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm, new_state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, d_in).astype(x.dtype) * silu(z[:, 0])
    return (y @ p["out_proj"])[:, None], new_state, new_conv_cache


# ===========================================================================
# mLSTM (xLSTM matrix-memory cell)
# ===========================================================================


def mlstm_dims(cfg):
    d_in = 2 * cfg.d_model
    nh = cfg.num_heads
    return d_in, nh, d_in // nh


def init_mlstm(key, cfg, dtype):
    d = cfg.d_model
    d_in, nh, dk = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((d,), dtype),
        "wx": dense_init(ks[0], (d, d_in), dtype=dtype),
        "wz": dense_init(ks[1], (d, d_in), dtype=dtype),
        "wq": dense_init(ks[2], (d_in, d_in), dtype=dtype),
        "wk": dense_init(ks[3], (d_in, d_in), dtype=dtype),
        "wv": dense_init(ks[4], (d_in, d_in), dtype=dtype),
        "wi": dense_init(ks[5], (d_in, nh), scale=0.02, dtype=jnp.float32),
        "wf": dense_init(ks[6], (d_in, nh), scale=0.02, dtype=jnp.float32),
        "f_bias": jnp.full((nh,), 3.0, jnp.float32),   # open forget gates at init
        "gnorm": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[7], (d_in, d), dtype=dtype),
    }


def _mlstm_qkvif(p, x, cfg):
    B, S, _ = x.shape
    d_in, nh, dk = mlstm_dims(cfg)
    xi = x @ p["wx"]
    z = x @ p["wz"]
    q = (xi @ p["wq"]).reshape(B, S, nh, dk).astype(jnp.float32) * dk ** -0.5
    k = (xi @ p["wk"]).reshape(B, S, nh, dk).astype(jnp.float32)
    v = (xi @ p["wv"]).reshape(B, S, nh, dk).astype(jnp.float32)
    i_g = xi.astype(jnp.float32) @ p["wi"]                          # (B,S,nh)
    f_g = xi.astype(jnp.float32) @ p["wf"] + p["f_bias"]
    return z, q, k, v, i_g, f_g


def mlstm_forward(p, x, cfg, state=None):
    """x: (B,S,d) -> (y, new_state).  state = (C,n,m)."""
    B, S, d = x.shape
    d_in, nh, dk = mlstm_dims(cfg)
    z, q, k, v, i_g, f_g = _mlstm_qkvif(p, x, cfg)
    logf = -jax.nn.softplus(-f_g)                                   # log sigmoid

    L = _chunk(S, MLSTM_CHUNK)
    nc = S // L
    rs = lambda t: t.reshape((B, nc, L) + t.shape[2:])
    qc, kc, vc = rs(q), rs(k), rs(v)
    ic, fc = rs(i_g), rs(logf)
    b = jnp.cumsum(fc, axis=2)                                      # (B,nc,L,nh)
    btot = b[:, :, -1]                                              # (B,nc,nh)

    if state is None:
        C0 = jnp.zeros((B, nh, dk, dk), jnp.float32)
        n0 = jnp.zeros((B, nh, dk), jnp.float32)
        m0 = jnp.full((B, nh), -1e30, jnp.float32)
    else:
        C0, n0, m0 = [s.astype(jnp.float32) for s in state]

    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]

    def step(carry, inp):
        C, n, m = carry
        qq, kk, vv, ii, bb, bt = inp                                 # per-chunk
        # log weights intra: g[t,s] = b_t - b_s + i_s   (s<=t)
        g = bb[:, :, None, :] - bb[:, None, :, :] + ii[:, None, :, :]  # (B,L,L,nh)
        g = jnp.where(causal[None, :, :, None], g, -1e30)
        m_intra = g.max(axis=2)                                      # (B,L,nh)
        m_inter = m[:, None] + bb                                    # (B,L,nh)
        m_t = jnp.maximum(m_intra, m_inter)
        w = jnp.exp(g - m_t[:, :, None, :])                          # (B,L,L,nh)
        qk = jnp.einsum("blhd,bshd->blsh", qq, kk)                   # (B,L,L,nh)
        wqk = qk * w
        num = jnp.einsum("blsh,bshd->blhd", wqk, vv)
        den = wqk.sum(axis=2)                                        # (B,L,nh)
        carry_scale = jnp.exp(m_inter - m_t)                         # (B,L,nh)
        num = num + carry_scale[..., None] * jnp.einsum("blhd,bhde->blhe", qq, C)
        den = den + carry_scale * jnp.einsum("blhd,bhd->blh", qq, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # carry update to end of chunk
        m_loc = (bt[:, None, :] - bb + ii).max(axis=1)               # (B,nh)
        m_new = jnp.maximum(m + bt, m_loc)
        sdecay = jnp.exp(bt[:, None, :] - bb + ii - m_new[:, None, :])  # (B,L,nh)
        C_new = C * jnp.exp(m + bt - m_new)[..., None, None] + \
            jnp.einsum("blh,blhd,blhe->bhde", sdecay, kk, vv)
        n_new = n * jnp.exp(m + bt - m_new)[..., None] + \
            jnp.einsum("blh,blhd->bhd", sdecay, kk)
        return (C_new, n_new, m_new), h

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), ic.transpose(1, 0, 2, 3),
          b.transpose(1, 0, 2, 3), btot.transpose(1, 0, 2))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, dk)
    h = h.reshape(B, S, d_in)
    h = rms_norm(h.astype(x.dtype), p["gnorm"])
    y = (h * silu(z)) @ p["out_proj"]
    return y, (C, n, m)


def mlstm_decode(p, x, cfg, state):
    """x: (B,1,d); state=(C,n,m)."""
    B, _, d = x.shape
    d_in, nh, dk = mlstm_dims(cfg)
    z, q, k, v, i_g, f_g = _mlstm_qkvif(p, x, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                              # (B,nh,dk)
    i_g, f_g = i_g[:, 0], f_g[:, 0]                                  # (B,nh)
    logf = -jax.nn.softplus(-f_g)
    C, n, m = [s.astype(jnp.float32) for s in state]
    m_new = jnp.maximum(logf + m, i_g)
    fs = jnp.exp(logf + m - m_new)
    is_ = jnp.exp(i_g - m_new)
    C_new = C * fs[..., None, None] + is_[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n_new = n * fs[..., None] + is_[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.einsum("bhd,bhd->bh", q, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(B, d_in)
    h = rms_norm(h.astype(x.dtype), p["gnorm"])
    y = (h * silu(z[:, 0])) @ p["out_proj"]
    return y[:, None], (C_new, n_new, m_new)


# ===========================================================================
# sLSTM (xLSTM scalar cell with true recurrence)
# ===========================================================================


def slstm_dims(cfg):
    nh = cfg.num_heads
    return nh, cfg.d_model // nh


def init_slstm(key, cfg, dtype):
    d = cfg.d_model
    nh, hd = slstm_dims(cfg)
    ffp = -(-4 * d // 3 // 8) * 8
    ks = jax.random.split(key, 6)
    return {
        "norm": jnp.ones((d,), dtype),
        "w_in": dense_init(ks[0], (d, 4 * d), dtype=dtype),          # z,i,f,o
        "r": dense_init(ks[1], (4, nh, hd, hd), scale=hd ** -0.5,
                        dtype=jnp.float32),
        "bias": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                                 jnp.full((d,), 3.0, jnp.float32),
                                 jnp.zeros((d,), jnp.float32)]),
        "gnorm": jnp.ones((d,), dtype),
        "ff1": dense_init(ks[2], (d, 2 * ffp), dtype=dtype),
        "ff2": dense_init(ks[3], (ffp, d), dtype=dtype),
    }


def _slstm_cell(p, xg, state, cfg):
    """One timestep.  xg: (B,4d) precomputed input gates; state=(c,n,m,h)."""
    B = xg.shape[0]
    d = cfg.d_model
    nh, hd = slstm_dims(cfg)
    c, n, m, h = state
    hh = h.reshape(B, nh, hd)
    rec = jnp.einsum("bkh,gkhf->bgkf", hh, p["r"]).reshape(B, 4 * d)
    gates = xg.astype(jnp.float32) + rec + p["bias"]
    zr, ir, fr, orr = jnp.split(gates, 4, axis=-1)
    z = jnp.tanh(zr)
    o = jax.nn.sigmoid(orr)
    logf = -jax.nn.softplus(-fr)
    m_new = jnp.maximum(logf + m, ir)
    c_new = jnp.exp(logf + m - m_new) * c + jnp.exp(ir - m_new) * z
    n_new = jnp.exp(logf + m - m_new) * n + jnp.exp(ir - m_new)
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def slstm_forward(p, x, cfg, state=None):
    """x: (B,S,d) -> (y, new_state).  Timestep scan (true recurrence)."""
    B, S, d = x.shape
    xg = x @ p["w_in"]                                               # (B,S,4d)
    if state is None:
        zeros = jnp.zeros((B, d), jnp.float32)
        state = (zeros, zeros, jnp.full((B, d), -1e30, jnp.float32), zeros)

    def step(carry, xt):
        new = _slstm_cell(p, xt, carry, cfg)
        return new, new[3]

    state, hs = jax.lax.scan(step, state, xg.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)                        # (B,S,d)
    h = rms_norm(h, p["gnorm"])
    u, g = jnp.split(h @ p["ff1"], 2, axis=-1)
    y = (jax.nn.gelu(g) * u) @ p["ff2"]
    return y, state


def slstm_decode(p, x, cfg, state):
    B, _, d = x.shape
    xg = (x @ p["w_in"])[:, 0]
    state = _slstm_cell(p, xg, state, cfg)
    h = rms_norm(state[3][:, None].astype(x.dtype), p["gnorm"])
    u, g = jnp.split(h @ p["ff1"], 2, axis=-1)
    y = (jax.nn.gelu(g) * u) @ p["ff2"]
    return y, state
