"""Model assembly for all assigned architecture families.

Pure init/apply: ``init_params(rng, cfg)`` builds a nested-dict pytree whose
per-layer blocks are stacked over the repeat units of ``layer_kinds(cfg)``;
forward passes scan over the repeats (``lax.scan``; the roofline analyzer
accounts for trip counts).

Three step kinds:
  - ``train_loss``   : full-sequence teacher-forced LM loss (chunked CE head)
  - ``prefill``      : full-sequence forward that fills the decode cache
  - ``decode_step``  : ONE token against the cache
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.models import attention as attn_mod
from repro.models import kvcache, moe as moe_mod, ssm as ssm_mod
from repro.models.layers import (apply_mlp, dense_init, init_mlp, rms_norm)
from repro.launch import shardctx

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, kind, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if kind in ("attn", "shared_attn"):
        p = {"norm1": jnp.ones((d,), dtype),
             "attn": attn_mod.init_attn(ks[0], cfg, dtype)}
        if cfg.d_ff:
            p["norm2"] = jnp.ones((d,), dtype)
            p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_type, dtype)
        return p
    if kind == "moe":
        return {"norm1": jnp.ones((d,), dtype),
                "attn": attn_mod.init_attn(ks[0], cfg, dtype),
                "norm2": jnp.ones((d,), dtype),
                "moe": moe_mod.init_moe(ks[1], cfg, dtype)}
    if kind == "encdec":
        return {"norm1": jnp.ones((d,), dtype),
                "attn": attn_mod.init_attn(ks[0], cfg, dtype),
                "norm_x": jnp.ones((d,), dtype),
                "cross": attn_mod.init_attn(ks[1], cfg, dtype),
                "norm2": jnp.ones((d,), dtype),
                "mlp": init_mlp(ks[2], d, cfg.d_ff, cfg.mlp_type, dtype)}
    if kind == "cross":
        return {"norm1": jnp.ones((d,), dtype),
                "cross": attn_mod.init_attn(ks[0], cfg, dtype),
                "gate": jnp.zeros((), jnp.float32),
                "norm2": jnp.ones((d,), dtype),
                "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_type, dtype)}
    if kind == "mamba":
        return ssm_mod.init_mamba(key, cfg, dtype)
    if kind == "mlstm":
        return ssm_mod.init_mlstm(key, cfg, dtype)
    if kind == "slstm":
        return ssm_mod.init_slstm(key, cfg, dtype)
    raise ValueError(kind)


def init_params(rng, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    unit, reps = cfgbase.repeat_unit(cfg)
    keys = jax.random.split(rng, 8)
    params = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                            scale=0.02, dtype=dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), dtype=dtype)

    blocks = []
    for i, kind in enumerate(unit):
        if kind == "shared_attn":
            # zamba2: ONE weight-shared attention block used at every repeat
            params["shared_attn"] = _init_block(
                jax.random.fold_in(keys[2], i), kind, cfg, dtype)
            blocks.append({})          # placeholder slot in the scanned stack
            continue
        bkeys = jax.random.split(jax.random.fold_in(keys[2], i), reps)
        blocks.append(jax.vmap(
            lambda k: _init_block(k, kind, cfg, dtype))(bkeys))
    params["blocks"] = blocks

    if cfg.family == "audio":
        ekeys = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda k: _init_block(k, "attn", cfg, dtype))(ekeys),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Per-block apply
# ---------------------------------------------------------------------------


def _self_attention(bp, x, cfg, mode, positions, cache, pos):
    """Shared self-attention for attn/moe/encdec blocks.

    Returns (attn_out, new_cache_entries|{}).
    """
    from repro.launch import policy as policy_mod
    p = bp["attn"]
    flat = lambda o: o.reshape(o.shape[0], o.shape[1], -1)
    window = cfg.window if cfg.attention == "swa" else None

    def maybe_repeat(k, v):
        # Megatron GQA-TP duplication: replicate KV heads to nq so the head
        # axis divides the model-axis size and attention shards head-local
        if policy_mod.get().attn_repeat_kv and cfg.q_per_kv > 1:
            k = jnp.repeat(k, cfg.q_per_kv, axis=2)
            v = jnp.repeat(v, cfg.q_per_kv, axis=2)
        return k, v

    if mode in ("train", "prefill"):
        q = attn_mod.project_q(p, x, cfg, positions)
        k, v = attn_mod.project_kv(p, x, cfg, positions)
        kr, vr = maybe_repeat(k, v)
        S = x.shape[1]
        use_blockwise = S > 1024 or window is not None
        if (policy_mod.get().attn_impl == "flash" and window is None):
            # fused Pallas kernel: scores never leave VMEM
            from repro.kernels import ops as kops
            pol = policy_mod.get()
            o = kops.flash_attention(q, kr, vr,
                                     block_q=min(pol.attn_block_q, 256),
                                     block_k=min(pol.attn_block_k, 256))
        elif use_blockwise:
            o = attn_mod.blockwise_causal_attn(q, kr, vr, window=window)
        else:
            B = x.shape[0]
            causal = jnp.tril(jnp.ones((S, S), bool))
            o = attn_mod.full_attn(q, kr, vr,
                                   mask=causal[None, None, None])
        new = {}
        if mode == "prefill" and cache is not None:
            W = cache["k"].shape[1]
            if "kpos" not in cache:     # dense cache (W >= S)
                if W == S:
                    new["k"] = k.astype(cache["k"].dtype)
                    new["v"] = v.astype(cache["v"].dtype)
                else:
                    new["k"] = jnp.zeros_like(cache["k"]).at[:, :S].set(
                        k.astype(cache["k"].dtype))
                    new["v"] = jnp.zeros_like(cache["v"]).at[:, :S].set(
                        v.astype(cache["v"].dtype))
            else:                       # ring: keep the last min(W,S) positions
                T = min(W, S)
                kpos = jnp.arange(S - T, S)
                slots = kpos % W
                new["k"] = jnp.zeros_like(cache["k"]).at[:, slots].set(
                    k[:, S - T:].astype(cache["k"].dtype))
                new["v"] = jnp.zeros_like(cache["v"]).at[:, slots].set(
                    v[:, S - T:].astype(cache["v"].dtype))
                new["kpos"] = jnp.full_like(cache["kpos"], -1).at[:, slots].set(
                    kpos.astype(jnp.int32))
        return (flat(o) @ p["wo"]), new

    # ---- decode ------------------------------------------------------------
    B = x.shape[0]
    q = attn_mod.project_q(p, x, cfg, pos[:, None])
    k, v = attn_mod.project_kv(p, x, cfg, pos[:, None])
    W = cache["k"].shape[1]
    onehot_update = policy_mod.get().decode_onehot_update

    def write(buf, value, slot):
        """Insert value (B,nkv,hd) at buf[:, slot] — scatter (baseline) or a
        one-hot masked select that stays shard-local on a seq-sharded cache."""
        if onehot_update:
            hot = jnp.arange(W)[None, :] == slot[:, None]          # (B,W)
            return jnp.where(hot[..., None, None],
                             value[:, None].astype(buf.dtype), buf)
        return buf.at[jnp.arange(B), slot].set(value.astype(buf.dtype))

    if "kpos" in cache:                 # ring (SWA / windowed-hybrid)
        slot = pos % W
        k_cache = write(cache["k"], k[:, 0], slot)
        v_cache = write(cache["v"], v[:, 0], slot)
        if onehot_update:
            hot = jnp.arange(W)[None, :] == slot[:, None]
            kpos = jnp.where(hot, pos[:, None], cache["kpos"])
        else:
            kpos = cache["kpos"].at[jnp.arange(B), slot].set(pos)
        valid = (kpos >= 0) & (kpos > (pos - W)[:, None]) & \
                (kpos <= pos[:, None])
        new = {"k": k_cache, "v": v_cache, "kpos": kpos}
    else:                               # dense
        k_cache = write(cache["k"], k[:, 0], pos)
        v_cache = write(cache["v"], v[:, 0], pos)
        valid = jnp.arange(W)[None, :] <= pos[:, None]
        new = {"k": k_cache, "v": v_cache}
    kr, vr = maybe_repeat(k_cache, v_cache)
    o = attn_mod.decode_attn(q, kr, vr, valid)
    return (flat(o) @ p["wo"]), new


def _cross_attention(bp, x, cfg, mode, kv_source=None, cache=None):
    """Cross-attention (whisper decoder / vlm image layers).

    kv_source: (B, Skv, d) encoder output or image embeddings (prefill/train);
    at decode the projected KV comes from the cache.
    Returns (out, new_cache_entries).
    """
    p = bp["cross"]
    q = attn_mod.project_q(p, x, cfg, None)
    if mode in ("train", "prefill"):
        ck, cv = attn_mod.project_kv(p, kv_source, cfg, None)
        new = {}
        if mode == "prefill" and cache is not None:
            new = {"ck": ck.astype(cache["ck"].dtype),
                   "cv": cv.astype(cache["cv"].dtype)}
    else:
        ck, cv = cache["ck"], cache["cv"]
        new = {"ck": ck, "cv": cv}
    o = attn_mod.full_attn(q, ck, cv)
    return (o.reshape(o.shape[0], o.shape[1], -1) @ p["wo"]), new


def apply_block(kind, bp, x, *, cfg, mode, positions=None, cache=None,
                enc_out=None, image_embeds=None, pos=None):
    """Returns (x_out, new_cache_entry, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}

    if kind in ("attn", "shared_attn", "moe", "encdec"):
        h = rms_norm(x, bp["norm1"])
        o, nc = _self_attention(bp, h, cfg, mode, positions, cache, pos)
        x = x + o
        new_cache.update(nc)
        if kind == "encdec":
            h = rms_norm(x, bp["norm_x"])
            o, nc = _cross_attention(bp, h, cfg, mode, enc_out,
                                     cache)
            x = x + o
            new_cache.update(nc)
        if kind == "moe":
            h = rms_norm(x, bp["norm2"])
            B, S, d = h.shape
            y, aux = moe_mod.moe_ffn(bp["moe"], h.reshape(B * S, d), cfg)
            x = x + y.reshape(B, S, d)
        elif cfg.d_ff:
            h = rms_norm(x, bp["norm2"])
            x = x + apply_mlp(bp["mlp"], h, cfg.mlp_type)
        return x, new_cache, aux

    if kind == "cross":
        h = rms_norm(x, bp["norm1"])
        o, nc = _cross_attention(bp, h, cfg, mode, image_embeds, cache)
        x = x + jnp.tanh(bp["gate"]).astype(x.dtype) * o
        new_cache.update(nc)
        h = rms_norm(x, bp["norm2"])
        x = x + apply_mlp(bp["mlp"], h, cfg.mlp_type)
        return x, new_cache, aux

    if kind == "mamba":
        h = rms_norm(x, bp["norm"])
        if mode == "decode":
            y, state, conv = ssm_mod.mamba_decode(
                bp, h, cfg, cache["state"], cache["conv"])
            return x + y, {"state": state, "conv": conv}, aux
        y, state, conv = ssm_mod.mamba_forward(bp, h, cfg)
        if mode == "prefill":
            new_cache = {"state": state, "conv": conv}
        return x + y, new_cache, aux

    if kind == "mlstm":
        h = rms_norm(x, bp["norm"])
        if mode == "decode":
            y, st = ssm_mod.mlstm_decode(bp, h, cfg,
                                         (cache["C"], cache["n"], cache["m"]))
            return x + y, {"C": st[0], "n": st[1], "m": st[2]}, aux
        y, st = ssm_mod.mlstm_forward(bp, h, cfg)
        if mode == "prefill":
            new_cache = {"C": st[0], "n": st[1], "m": st[2]}
        return x + y, new_cache, aux

    if kind == "slstm":
        h = rms_norm(x, bp["norm"])
        if mode == "decode":
            y, st = ssm_mod.slstm_decode(
                bp, h, cfg, (cache["c"], cache["n"], cache["m"], cache["h"]))
            return x + y, dict(zip("cnmh", st)), aux
        y, st = ssm_mod.slstm_forward(bp, h, cfg)
        if mode == "prefill":
            new_cache = dict(zip("cnmh", st))
        return x + y, new_cache, aux

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Backbone scan over repeat units
# ---------------------------------------------------------------------------


def _encoder_forward(params, cfg, audio_embeds):
    """Whisper audio encoder over stubbed frame embeddings (bidirectional)."""
    enc = params["encoder"]
    x = audio_embeds.astype(cfg.activation_dtype())

    def body(x, bp):
        h = rms_norm(x, bp["norm1"])
        q = attn_mod.project_q(bp["attn"], h, cfg, None)
        k, v = attn_mod.project_kv(bp["attn"], h, cfg, None)
        o = attn_mod.full_attn(q, k, v)
        x = x + o.reshape(o.shape[0], o.shape[1], -1) @ bp["attn"]["wo"]
        h = rms_norm(x, bp["norm2"])
        x = x + apply_mlp(bp["mlp"], h, cfg.mlp_type)
        return x, None

    x, _ = jax.lax.scan(body, x, enc["blocks"])
    return rms_norm(x, enc["final_norm"])


def backbone(params, cfg, x, *, mode, positions=None, cache=None,
             enc_out=None, image_embeds=None, pos=None):
    """x: (B,S,d) embedded inputs.  Returns (x, new_cache, aux)."""
    unit, reps = cfgbase.repeat_unit(cfg)
    shared = params.get("shared_attn")

    from repro.launch import policy as policy_mod

    def unit_body(carry, xs):
        x, aux = carry
        bstack, cstack = xs
        new_entries = []
        for i, kind in enumerate(unit):
            bp = shared if kind == "shared_attn" else bstack[i]
            if policy_mod.get().fsdp_gather_weights:
                bp = jax.tree.map(
                    lambda w: shardctx.constrain(w, "gathered_weight"), bp)
            c = cstack[i] or None
            x, nc, a = apply_block(
                kind, bp, x, cfg=cfg, mode=mode, positions=positions,
                cache=c, enc_out=enc_out, image_embeds=image_embeds, pos=pos)
            new_entries.append(nc)
            aux = aux + a
        x = shardctx.constrain(x, "hidden")
        return (x, aux), new_entries

    if cfg.remat and mode == "train":
        unit_body = jax.checkpoint(unit_body)

    cache_blocks = (cache["blocks"] if cache is not None
                    else [{} for _ in unit])
    xs = (params["blocks"], cache_blocks)
    (x, aux), new_blocks = jax.lax.scan(
        unit_body, (x, jnp.zeros((), jnp.float32)), xs)
    new_cache = {"blocks": new_blocks} if cache is not None else None
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def _embed(params, cfg, tokens):
    x = params["embed"][tokens].astype(cfg.activation_dtype())
    return shardctx.constrain(x, "hidden")


def _frontends(params, cfg, batch):
    enc_out = None
    image_embeds = None
    if cfg.family == "audio":
        enc_out = _encoder_forward(params, cfg, batch["audio_embeds"])
    if cfg.family == "vlm":
        image_embeds = batch["image_embeds"].astype(cfg.activation_dtype())
    return enc_out, image_embeds


def _lm_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def train_loss(params, cfg, batch):
    """batch: tokens (B,S), labels (B,S) [+ frontend embeds].

    Returns (loss, metrics).
    """
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    enc_out, image_embeds = _frontends(params, cfg, batch)
    x = _embed(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _, aux = backbone(params, cfg, x, mode="train", positions=positions,
                         enc_out=enc_out, image_embeds=image_embeds)
    x = rms_norm(x, params["final_norm"])

    from repro.launch import policy as policy_mod
    pol = policy_mod.get()
    W = _lm_matrix(params, cfg)
    want = pol.ce_chunk
    C = S if S <= want else max(c for c in (want, 512, 256, 128)
                                if c <= want and S % c == 0)
    nchunks = S // C
    xc = x.reshape(B, nchunks, C, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nchunks, C).transpose(1, 0, 2)

    def ce_chunk(tot, xs):
        xi, li = xs
        ldt = jnp.bfloat16 if pol.logits_bf16 else jnp.float32
        logits = shardctx.constrain((xi @ W).astype(ldt), "logits")
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None],
                                 axis=-1)[..., 0].astype(jnp.float32)
        return tot + jnp.sum(lse - ll), None

    total, _ = jax.lax.scan(ce_chunk, jnp.zeros((), jnp.float32), (xc, lc))
    ce = total / (B * S)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


def prefill(params, cfg, batch, cache):
    """Fill the cache from a full prompt; returns (last_logits, cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out, image_embeds = _frontends(params, cfg, batch)
    x = _embed(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, new_cache, _ = backbone(params, cfg, x, mode="prefill",
                               positions=positions, cache=cache,
                               enc_out=enc_out, image_embeds=image_embeds)
    x = rms_norm(x[:, -1:], params["final_norm"])
    logits = (x @ _lm_matrix(params, cfg)).astype(jnp.float32)
    return logits[:, 0], new_cache


def decode_step(params, cfg, token, pos, cache):
    """ONE token (B,1) at positions pos (B,) against the cache."""
    x = _embed(params, cfg, token)
    x, new_cache, _ = backbone(params, cfg, x, mode="decode",
                               cache=cache, pos=pos)
    x = rms_norm(x, params["final_norm"])
    logits = (x @ _lm_matrix(params, cfg)).astype(jnp.float32)
    return logits[:, 0], new_cache
