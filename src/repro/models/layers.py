"""Shared neural-net building blocks (pure JAX, init/apply style).

Params are plain nested dicts of jnp arrays.  Math accumulates in float32 and
casts back to the activation dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) <= 2 else shape[-2]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, weight, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(dt)


def head_rms_norm(x, weight, eps=1e-6):
    """Per-head RMS norm over the trailing head_dim (qwen3 qk_norm)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(dt)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = rope_freqs(hd, theta)                      # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]                # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, mlp_type, dtype):
    ks = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }


def apply_mlp(params, x, mlp_type):
    if mlp_type == "swiglu":
        h = silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]
