"""GQA attention: blockwise-causal (train/prefill), full (encoder/cross), and
single-token decode against KV caches (dense or ring/SWA).

Blockwise attention uses an online-softmax scan over KV blocks so that the
lowered HLO never materializes an (S x S) score matrix — required for the
32k-prefill dry-runs.  The KV-block scan is a `lax.scan`; the roofline HLO
analyzer scales while-bodies by their known trip count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, head_rms_norm

NEG_INF = -1e30


def init_attn(key, cfg, dtype, kv_input_dim=None):
    d, nq, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    kvd = kv_input_dim or d
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, nq * hd), dtype=dtype),
        "wk": dense_init(ks[1], (kvd, nkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (kvd, nkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (nq * hd, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def project_q(p, x, cfg, positions=None):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, cfg.hd)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"])
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
    return q


def project_kv(p, x, cfg, positions=None):
    B, S, _ = x.shape
    k = (x @ p["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.hd)
    if cfg.qk_norm:
        k = head_rms_norm(k, p["k_norm"])
    if positions is not None:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def _pick_block(s, want):
    b = min(want, s)
    while s % b:
        b -= 1
    return max(b, 1)


def blockwise_causal_attn(q, k, v, *, window=None, block_q=None,
                          block_k=None):
    """Online-softmax causal attention.  q: (B,S,nq,hd); k,v: (B,S,nkv,hd)."""
    from repro.launch import policy as policy_mod
    pol = policy_mod.get()
    block_q = block_q or pol.attn_block_q
    block_k = block_k or pol.attn_block_k
    p_dtype = jnp.bfloat16 if pol.attn_p_bf16 else jnp.float32
    B, S, nq, hd = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    bq = _pick_block(S, block_q)
    bk = _pick_block(S, block_k)
    nqb, nkb = S // bq, S // bk
    scale = hd ** -0.5
    qb = q.reshape(B, nqb, bq, nkv, g, hd)
    kb = k.reshape(B, nkb, bk, nkv, hd)
    vb = v.reshape(B, nkb, bk, nkv, hd)
    qk_bf16 = pol.attn_qk_bf16
    outs = []
    for qi in range(nqb):
        if qk_bf16:
            q_i = qb[:, qi]                               # bf16 into the MXU
        else:
            q_i = qb[:, qi].astype(jnp.float32) * scale   # (B,bq,nkv,g,hd)
        q_start = qi * bq
        qpos = q_start + jnp.arange(bq)
        k_hi = min(nkb, (q_start + bq + bk - 1) // bk)    # exclusive
        k_lo = 0 if window is None else max(0, q_start - int(window) + 1) // bk

        def step(carry, kj, q_i=q_i, qpos=qpos):
            m, l, acc = carry
            k_j = jax.lax.dynamic_index_in_dim(kb, kj, 1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb, kj, 1, keepdims=False)
            if qk_bf16:
                s = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j,
                               preferred_element_type=jnp.float32) * scale
            else:
                s = jnp.einsum("bqkgh,bskh->bkgqs", q_i,
                               k_j.astype(jnp.float32))
            kpos = kj * bk + jnp.arange(bk)
            mask = kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - int(window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            # policy: the big exp-score tensor may be bf16 (m/l stay f32)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(p_dtype),
                v_j.astype(p_dtype), preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, nkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, nkv, g, bq), jnp.float32)
        a0 = jnp.zeros((B, nkv, g, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      jnp.arange(k_lo, k_hi))
        o = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,nkv,g,bq,hd)
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, bq, nq, hd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def full_attn(q, k, v, mask=None):
    """Unblocked attention for short KV (encoder / cross-attn / decode).

    q: (B,Sq,nq,hd); k,v: (B,Skv,nkv,hd); mask: broadcastable to
    (B,nkv,g,Sq,Skv) or (B,Skv).
    """
    B, Sq, nq, hd = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    qf = q.reshape(B, Sq, nkv, g, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    if mask is not None:
        if mask.ndim == 2:                                # (B,Skv) validity
            mask = mask[:, None, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, nq, hd).astype(q.dtype)


def decode_attn(q, k_cache, v_cache, valid_mask):
    """One-token attention against a cache.  q: (B,1,nq,hd);
    k_cache/v_cache: (B,S,nkv,hd); valid_mask: (B,S) bool."""
    return full_attn(q, k_cache, v_cache, mask=valid_mask)
