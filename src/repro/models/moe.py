"""Mixture-of-Experts FFN with sort-based capacity dispatch (GShard-style
capacity, Megablox-style sort routing — no (T,E,C) one-hot dispatch tensor, so
dry-run memory stays honest and HLO FLOPs ≈ active FLOPs).

Supports top-k routing (k=1 llama4-scout, k=2 arctic) and an optional parallel
dense-residual MLP (arctic) / shared expert (llama4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_mlp, apply_mlp, silu


def init_moe(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), scale=d ** -0.5, dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, ff), dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype=dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype=dtype),
    }
    if cfg.moe_dense_ff:
        p["dense_mlp"] = init_mlp(ks[4], d, cfg.moe_dense_ff, cfg.mlp_type, dtype)
    return p


def capacity(cfg, num_tokens: int) -> int:
    c = int(cfg.capacity_factor * num_tokens * cfg.experts_per_token
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_ffn(p, x, cfg):
    """x: (T, d) -> (y: (T, d), aux_loss: scalar)."""
    T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = capacity(cfg, T)

    logits = (x.astype(jnp.float32) @ p["router"])            # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                # (T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch/GShard form) -------------------
    me = probs.mean(0)                                        # (E,)
    assign = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
    ce = assign / (T * k)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight

    # ---- sort-based dispatch -------------------------------------------------
    flat_e = expert_idx.reshape(-1)                           # (T*k,)
    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))     # (E,)
    rank_sorted = jnp.arange(T * k) - seg_start[sorted_e]
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = rank < C
    slot = jnp.where(keep, rank, C)                           # dropped -> pad slot

    tok_idx = jnp.repeat(jnp.arange(T), k)                    # (T*k,)
    buf = jnp.zeros((E, C + 1, d), x.dtype)
    buf = buf.at[flat_e, slot].set(x[tok_idx])
    buf = buf[:, :C]                                          # (E,C,d)

    # ---- expert computation (batched einsum over sharded expert dim) --------
    if cfg.mlp_type == "swiglu":
        h = silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_up"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])      # (E,C,d)

    # ---- combine -------------------------------------------------------------
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((E, 1, d), out_buf.dtype)], axis=1)
    routed = out_buf[flat_e, slot]                            # (T*k,d)
    routed = jnp.where(keep[:, None], routed, 0)
    y = (routed.reshape(T, k, d)
         * gate[..., None].astype(routed.dtype)).sum(axis=1)

    if "dense_mlp" in p:
        y = y + apply_mlp(p["dense_mlp"], x, cfg.mlp_type)
    return y.astype(x.dtype), aux
