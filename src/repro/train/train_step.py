"""Training step: loss + grad + optimizer update, with optional microbatch
gradient accumulation.  The step function is what the dry-run lowers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model
from repro.train import optimizer as opt_mod


def make_train_step(cfg, opt_cfg: opt_mod.OptConfig, *, grad_accum: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return model.train_loss(params, cfg, batch)

    def step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return (jax.tree.map(jnp.add, acc, g), lsum + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (grads, lsum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = lsum / grad_accum
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        params, opt_state, om = opt_mod.update(
            cfg.optimizer, params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **om, "loss": loss}
        return params, opt_state, metrics

    return step


def init_state(rng, cfg):
    params = model.init_params(rng, cfg)
    opt_state = opt_mod.init(cfg.optimizer, params)
    return params, opt_state
