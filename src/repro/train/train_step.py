"""Training step: loss + grad + optimizer update, with optional microbatch
gradient accumulation.  The step function is what the dry-run lowers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model
from repro.train import optimizer as opt_mod


def make_train_step(cfg, opt_cfg: opt_mod.OptConfig, *, grad_accum: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return model.train_loss(params, cfg, batch)

    def step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return (jax.tree.map(jnp.add, acc, g), lsum + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (grads, lsum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = lsum / grad_accum
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        params, opt_state, om = opt_mod.update(
            cfg.optimizer, params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **om, "loss": loss}
        return params, opt_state, metrics

    return step


def init_state(rng, cfg):
    params = model.init_params(rng, cfg)
    opt_state = opt_mod.init(cfg.optimizer, params)
    return params, opt_state


# ---------------------------------------------------------------------------
# Gradient-reduce <-> optimizer-update overlap (completion-engine schedule)
# ---------------------------------------------------------------------------

# optimizer bytes touched per gradient byte (read p/m/v + write p/m/v ~ adamw)
_OPT_TRAFFIC = 6.0


def grad_reduce_schedule(params, ops, *, policy=None):
    """Model the step's tail: per-leaf gradient reduction pipelined against
    optimizer updates.

    Leaves reduce in traversal order.  With ``policy.overlap_grad_reduce``
    the (k+1)-th leaf's ring allreduce is issued nbi and flies while the
    k-th leaf's optimizer update computes — the trainer's analogue of the
    nbi ring step in ``comms.ShmemOps``.  The sharding policy gates the wire
    cost per leaf: under the default ZeRO rules (DESIGN.md §5) matrix leaves
    are data-sharded, so each PE reduce-scatters only its 1/npes gradient
    shard and the update is shard-local; ``param_tp_only`` turns that off
    (weights replicate over "data") and every leaf pays the full allreduce.

    Returns ``(t_blocking, t_overlapped, nleaves)`` in modeled seconds.
    """
    import jax

    from repro.launch import policy as policy_mod
    pol = policy or policy_mod.get()
    hw = ops.hw
    times = []                                 # (t_reduce, t_update) per leaf
    for leaf in jax.tree.leaves(params):
        nbytes = int(leaf.size * jnp.dtype(leaf.dtype).itemsize)
        zero_sharded = leaf.ndim >= 2 and not pol.param_tp_only
        frac = 1.0 / ops.npes if zero_sharded else 1.0
        t_r = _ring_time(ops, int(nbytes * frac))
        t_u = nbytes * frac * _OPT_TRAFFIC / hw.reduce_bw
        times.append((t_r, t_u))
    t_blocking = sum(t_r + t_u for t_r, t_u in times)
    if not pol.overlap_grad_reduce or len(times) <= 1:
        return t_blocking, t_blocking, len(times)
    # software pipeline: reduce(k+1) in flight during update(k)
    t = times[0][0]
    for i in range(1, len(times)):
        t += max(times[i][0], times[i - 1][1])
    t += times[-1][1]
    return t_blocking, t, len(times)


def _ring_time(ops, nbytes):
    from repro.core import cutover
    return cutover.t_ring_allreduce(nbytes, ops.npes,
                                    work_items=ops.tuning.work_group_size,
                                    tier="ici", hw=ops.hw, tuning=ops.tuning,
                                    overlap=True)
