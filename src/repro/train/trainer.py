"""Training loop: data -> step -> metrics/checkpoints, resumable."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, TokenStream
from repro.train import checkpoint as ckpt_mod
from repro.train import optimizer as opt_mod, train_step as ts_mod


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0            # 0 = disabled
    ckpt_dir: str = "checkpoints"
    seq_len: int = 128
    global_batch: int = 8
    grad_accum: int = 1
    seed: int = 0
    lr: float = 3e-4
    comms_backend: str = "none"    # "shmem": model the device-initiated
                                   # gradient-reduce pipeline (nbi ring steps
                                   # overlapping optimizer updates) and log
                                   # its modeled overlap efficiency
    comms_npes: int = 8


def train(cfg_arch, tcfg: TrainConfig, *, resume: bool = False,
          log_fn=print):
    """Single-host training driver (CPU-scale; the pod launcher wraps this
    same step function with pjit shardings)."""
    opt_cfg = opt_mod.OptConfig(name=cfg_arch.optimizer, lr=tcfg.lr,
                                warmup_steps=max(1, tcfg.steps // 20),
                                total_steps=tcfg.steps)
    params, opt_state = ts_mod.init_state(jax.random.key(tcfg.seed), cfg_arch)
    step_fn = jax.jit(ts_mod.make_train_step(cfg_arch, opt_cfg,
                                             grad_accum=tcfg.grad_accum))
    stream = TokenStream(DataConfig(cfg_arch.vocab_size, tcfg.seq_len,
                                    tcfg.global_batch, seed=tcfg.seed))
    overlap = None
    if tcfg.comms_backend == "shmem":
        # completion-engine view of the step tail: per-leaf grad reduce
        # (nbi ring steps) pipelined under optimizer updates.  The schedule
        # depends only on leaf shapes, so it is priced once up front.
        from repro.comms import api as comms_api
        ops = comms_api.get_ops("shmem", npes=tcfg.comms_npes)
        t_block, t_nbi, nleaves = ts_mod.grad_reduce_schedule(params, ops)
        overlap = {"t_reduce_blocking_s": t_block, "t_reduce_nbi_s": t_nbi,
                   "overlap_eff": t_block / t_nbi if t_nbi else 1.0,
                   "leaves": nleaves}
        log_fn(f"grad-reduce overlap: {nleaves} leaves, modeled "
               f"{t_block * 1e6:.1f}us blocking -> {t_nbi * 1e6:.1f}us nbi "
               f"(x{overlap['overlap_eff']:.2f})")

    start = 0
    if resume:
        last = ckpt_mod.latest_step(tcfg.ckpt_dir)
        if last is not None:
            (params, opt_state), meta = ckpt_mod.restore(
                tcfg.ckpt_dir, last, (params, opt_state))
            start = meta["step"]
            log_fn(f"resumed from step {start}")

    history = []
    t0 = time.time()
    for step in range(start, tcfg.steps):
        batch = stream.batch(step)
        batch.update(stream.frontend(step, cfg_arch, tcfg.global_batch))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = round(time.time() - t0, 2)
            if overlap is not None:
                m["overlap_eff"] = round(overlap["overlap_eff"], 3)
            history.append(m)
            log_fn(f"step {step:5d} loss {m['loss']:.4f} "
                   f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f}")
        if tcfg.ckpt_every and (step + 1) % tcfg.ckpt_every == 0:
            ckpt_mod.save(tcfg.ckpt_dir, step + 1, (params, opt_state))
    return params, opt_state, history
