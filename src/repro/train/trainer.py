"""Training loop: data -> step -> metrics/checkpoints, resumable."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, TokenStream
from repro.train import checkpoint as ckpt_mod
from repro.train import optimizer as opt_mod, train_step as ts_mod


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0            # 0 = disabled
    ckpt_dir: str = "checkpoints"
    seq_len: int = 128
    global_batch: int = 8
    grad_accum: int = 1
    seed: int = 0
    lr: float = 3e-4


def train(cfg_arch, tcfg: TrainConfig, *, resume: bool = False,
          log_fn=print):
    """Single-host training driver (CPU-scale; the pod launcher wraps this
    same step function with pjit shardings)."""
    opt_cfg = opt_mod.OptConfig(name=cfg_arch.optimizer, lr=tcfg.lr,
                                warmup_steps=max(1, tcfg.steps // 20),
                                total_steps=tcfg.steps)
    params, opt_state = ts_mod.init_state(jax.random.key(tcfg.seed), cfg_arch)
    step_fn = jax.jit(ts_mod.make_train_step(cfg_arch, opt_cfg,
                                             grad_accum=tcfg.grad_accum))
    stream = TokenStream(DataConfig(cfg_arch.vocab_size, tcfg.seq_len,
                                    tcfg.global_batch, seed=tcfg.seed))
    start = 0
    if resume:
        last = ckpt_mod.latest_step(tcfg.ckpt_dir)
        if last is not None:
            (params, opt_state), meta = ckpt_mod.restore(
                tcfg.ckpt_dir, last, (params, opt_state))
            start = meta["step"]
            log_fn(f"resumed from step {start}")

    history = []
    t0 = time.time()
    for step in range(start, tcfg.steps):
        batch = stream.batch(step)
        batch.update(stream.frontend(step, cfg_arch, tcfg.global_batch))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = round(time.time() - t0, 2)
            history.append(m)
            log_fn(f"step {step:5d} loss {m['loss']:.4f} "
                   f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f}")
        if tcfg.ckpt_every and (step + 1) % tcfg.ckpt_every == 0:
            ckpt_mod.save(tcfg.ckpt_dir, step + 1, (params, opt_state))
    return params, opt_state, history
