"""Checkpointing: flat .npz payload + JSON metadata, atomic rename, retention.

Pure numpy/np.savez (no orbax dependency); pytree structure is recorded as
flattened key paths so restore round-trips exactly.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3, extra: dict = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    meta = {
        "step": int(step),
        "keys": list(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    tmp = tempfile.mkdtemp(dir=ckpt_dir)
    np.savez(os.path.join(tmp, "payload.npz"),
             **{f"a{i}": v for i, v in enumerate(flat.values())})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, step: int, tree_like):
    """Restore into the structure of ``tree_like`` (validates key paths)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    meta = json.load(open(os.path.join(d, "meta.json")))
    payload = np.load(os.path.join(d, "payload.npz"))
    arrays = [payload[f"a{i}"] for i in range(len(meta["keys"]))]
    by_key = dict(zip(meta["keys"], arrays))

    leaves_paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    restored = []
    for path, leaf in leaves_paths:
        k = jax.tree_util.keystr(path)
        if k not in by_key:
            raise KeyError(f"checkpoint missing {k}")
        arr = by_key[k]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {k}: "
                             f"{arr.shape} vs {leaf.shape}")
        restored.append(jnp.asarray(arr, leaf.dtype))
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, restored), meta
