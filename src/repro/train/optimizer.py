"""Optimizers (pure JAX): AdamW and Adafactor (factored second moment — the
production choice for the 480B/90B assigned models, where fp32 Adam state
cannot fit a single v5e pod).  Plus cosine LR schedule and global-norm clip.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    eps2: float = 1e-30


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                     state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"step": step, "m": m, "v": v}, \
        {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), factored for ndim>=2 over last two dims
# ---------------------------------------------------------------------------


def _factored(p):
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def adafactor_init(params):
    def st(p):
        if _factored(p):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"step": jnp.zeros((), jnp.int32),
            "v": jax.tree.map(st, params,
                              is_leaf=lambda x: isinstance(x, jnp.ndarray))}


def adafactor_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    beta2 = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-cfg.decay_rate)

    def upd(p, g, st):
        g2 = g * g + cfg.eps2
        if _factored(p):
            vr = beta2 * st["vr"] + (1 - beta2) * g2.mean(-1)
            vc = beta2 * st["vc"] + (1 - beta2) * g2.mean(-2)
            denom = vr.mean(-1, keepdims=True)
            rfac = jax.lax.rsqrt(vr / jnp.maximum(denom, cfg.eps2))
            cfac = jax.lax.rsqrt(vc)
            u = g * rfac[..., None] * cfac[..., None, :]
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(v)
            new_st = {"v": v}
        # update clipping (RMS <= 1) as in the paper
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_st

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_params, {"step": step, "v": new_v}, \
        {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def init(name: str, params):
    return adamw_init(params) if name == "adamw" else adafactor_init(params)


def update(name: str, params, grads, state, cfg: OptConfig):
    if name == "adamw":
        return adamw_update(params, grads, state, cfg)
    return adafactor_update(params, grads, state, cfg)
