"""Remote Memory Access: put/get (+p/g scalars, iput/iget strided, nbi, and
the thread-collaborative ``work_group`` extensions — paper §III-F/G1).

Semantics are one-sided: ``put`` stores into the *destination PE's* row of the
symmetric heap; ``get`` loads from the source PE's row.  Every op picks a
transport via the cutover engine and records it on the context ledger; when
``ctx.use_kernels`` is set, direct-path copies run through the Pallas
work-group copy kernel (interpret mode on CPU, RDMA on TPU).

Non-blocking ops (``*_nbi``) go through the context's
:class:`~repro.core.pending.CompletionQueue`: the target row is untouched
until ``quiet``/``barrier`` flushes, ``fence`` closes an ordering epoch, and
a blocking ``put`` supersedes pending nbi puts to the same buffer.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cutover, pending as pending_mod
from repro.core.heap import SymPtr, SymmetricHeap
from repro.core.pending import write_row as _kernel_write_row


def _pick(ctx, nbytes, work_items, tier):
    # the single chooser: FORCE_PATH > CUTOVER_BYTES > table > analytic
    return cutover.choose_path(nbytes, work_items=work_items, tier=tier,
                               hw=ctx.hw, tuning=ctx.tuning)


def _write_row(ctx, heap, ptr, pe, flat_value):
    return _kernel_write_row(ctx, heap, ptr, pe, flat_value)


# ---------------------------------------------------------------------------
# blocking RMA
# ---------------------------------------------------------------------------


def put(ctx, heap: SymmetricHeap, dest: SymPtr, value, dst_pe, *,
        src_pe: int = 0, work_items: int = 1) -> SymmetricHeap:
    """ishmem_put (work_items=1) / ishmemx_put_work_group (work_items>1)."""
    value = jnp.asarray(value, jnp.dtype(dest.dtype)).reshape((dest.size,))
    tier = ctx.tier(src_pe, dst_pe)
    path = _pick(ctx, dest.nbytes, work_items, tier)
    ctx.record("put", dest.nbytes, path, tier, work_items)
    # blocking store vs pending nbi ops on the same bytes is an unordered
    # race; the simulator linearizes it as program order (fully-covered
    # deferred stores are dropped, partial overlaps complete first, the
    # blocking store always lands last)
    heap = ctx.pending.resolve_store_conflicts(ctx, heap, dest, dst_pe)
    return _write_row(ctx, heap, dest, dst_pe, value)


def get(ctx, heap: SymmetricHeap, src: SymPtr, src_pe_remote, *,
        src_pe: int = 0, work_items: int = 1):
    """ishmem_get / ishmemx_get_work_group: one-sided load from a remote PE."""
    tier = ctx.tier(src_pe, src_pe_remote)
    path = _pick(ctx, src.nbytes, work_items, tier)
    ctx.record("get", src.nbytes, path, tier, work_items)
    return heap.read(src, src_pe_remote)


def p(ctx, heap, dest: SymPtr, scalar, dst_pe, *, src_pe: int = 0):
    """ishmem_p: blocking scalar store — always the direct path (a single
    remote store; this is the op the paper uses to motivate load/store)."""
    tier = ctx.tier(src_pe, dst_pe)
    path = "proxy" if tier == "dcn" else "direct"
    ctx.record("p", jnp.dtype(dest.dtype).itemsize, path, tier, 1)
    heap = ctx.pending.resolve_store_conflicts(ctx, heap, dest, dst_pe)
    return heap.write(dest, dst_pe, jnp.asarray(scalar))


def g(ctx, heap, src: SymPtr, src_pe_remote, *, src_pe: int = 0):
    """ishmem_g: blocking scalar fetch."""
    tier = ctx.tier(src_pe, src_pe_remote)
    path = "proxy" if tier == "dcn" else "direct"
    ctx.record("g", jnp.dtype(src.dtype).itemsize, path, tier, 1)
    return heap.read(src, src_pe_remote).reshape(())


# ---------------------------------------------------------------------------
# strided RMA (iput/iget)
# ---------------------------------------------------------------------------


def iput(ctx, heap, dest: SymPtr, value, dst_pe, *, dst_stride: int = 1,
         src_stride: int = 1, nelems: int = None, src_pe: int = 0):
    """ishmem_iput: strided store (SYCL-vectorized on device, §III-G1)."""
    value = jnp.asarray(value, jnp.dtype(dest.dtype)).reshape((-1,))
    n = nelems if nelems is not None else (value.size + src_stride - 1) // src_stride
    picked = value[::src_stride][:n]
    heap = ctx.pending.resolve_store_conflicts(ctx, heap, dest, dst_pe,
                                               covers=False)
    cur = heap.read(dest, dst_pe).reshape((-1,))
    idx = jnp.arange(n) * dst_stride
    newv = cur.at[idx].set(picked)
    nbytes = int(n) * jnp.dtype(dest.dtype).itemsize
    tier = ctx.tier(src_pe, dst_pe)
    ctx.record("iput", nbytes, _pick(ctx, nbytes, 1, tier), tier, 1)
    return heap.write(dest, dst_pe, newv)


def iget(ctx, heap, src: SymPtr, src_pe_remote, *, src_stride: int = 1,
         nelems: int = None, src_pe: int = 0):
    data = heap.read(src, src_pe_remote).reshape((-1,))
    n = nelems if nelems is not None else data.size // max(1, src_stride)
    out = data[::src_stride][:n]
    nbytes = int(n) * jnp.dtype(src.dtype).itemsize
    tier = ctx.tier(src_pe, src_pe_remote)
    ctx.record("iget", nbytes, _pick(ctx, nbytes, 1, tier), tier, 1)
    return out


# ---------------------------------------------------------------------------
# non-blocking (nbi) + ordering
# ---------------------------------------------------------------------------


def put_nbi(ctx, heap, dest, value, dst_pe, *, src_pe: int = 0,
            work_items: int = 1):
    """ishmem_put_nbi: non-blocking put.  The destination row is NOT written
    here — the op is deferred onto the context's CompletionQueue and lands at
    the next completion point (``quiet``/``barrier``/a dependent
    ``signal_wait_until``).  The transport is chosen at flush time on the
    *coalesced* transfer size (the paper: copy engines overlap with compute;
    completion at quiet)."""
    value = jnp.asarray(value, jnp.dtype(dest.dtype)).reshape((dest.size,))
    tier = ctx.tier(src_pe, dst_pe)
    path = "proxy" if tier == "dcn" else "engine"
    # trace marker only (t=0): the completed transfer is priced at flush
    ctx.record("put_nbi(pending)", dest.nbytes, path, tier, work_items,
               t_sec=0.0)
    ctx.pending.submit(pending_mod.PUT, "put_nbi", dest, dst_pe, tier,
                       src_pe=src_pe, work_items=work_items, value=value,
                       marker=ctx.ledger[-1] if ctx.ledger else None)
    return heap


def get_nbi(ctx, heap, src, src_pe_remote, *, src_pe: int = 0,
            work_items: int = 1):
    """ishmem_get_nbi: non-blocking get.  The returned buffer is undefined
    until ``quiet``; the simulator linearizes the fetch at submission (any
    point in [call, quiet] is a legal read), while the completion cost is
    accounted when the queue flushes."""
    tier = ctx.tier(src_pe, src_pe_remote)
    path = "proxy" if tier == "dcn" else "engine"
    ctx.record("get_nbi(pending)", src.nbytes, path, tier, work_items,
               t_sec=0.0)
    ctx.pending.submit(pending_mod.GET, "get_nbi", src, src_pe_remote, tier,
                       src_pe=src_pe, work_items=work_items,
                       marker=ctx.ledger[-1] if ctx.ledger else None)
    return heap.read(src, src_pe_remote)


def quiet(ctx, heap, *, proxy=None):
    """ishmem_quiet: completes all pending nbi ops (memory ordering).  When a
    ``HostProxy`` is given, dcn-tier pending ops travel through its ring and
    one drain; otherwise the modeled proxy path executes them directly.
    Idempotent: a second quiet with an empty queue flushes nothing."""
    heap = ctx.pending.flush(ctx, heap, proxy=proxy)
    ctx.record("quiet", 0, "direct", "local", 1)
    return heap


def fence(ctx, heap):
    """ishmem_fence: orders (but does not complete) pending ops — closes the
    queue's coalescing epoch, so ops across the fence never merge or reorder."""
    ctx.pending.fence()
    ctx.record("fence", 0, "direct", "local", 1)
    return heap
