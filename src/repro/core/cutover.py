"""Adaptive transport selection — the paper's "cutover" engine (§III-B, §IV).

Three transports, mirroring Xe-Link load/store vs copy-engine vs host proxy,
adapted to the TPU tiering (see DESIGN.md §2):

  - ``direct``  : kernel-initiated remote stores (Pallas `make_async_remote_copy`
                  issued from a running kernel).  Near-zero startup; bandwidth
                  scales with the number of concurrent "work items" (grid
                  programs × outstanding DMA descriptors) up to a cap below
                  peak link speed — the compute cores are busy issuing.
  - ``engine``  : DMA/copy-engine transfer scheduled outside the kernel (an
                  XLA collective).  Full link bandwidth, but pays a startup
                  that includes the reverse-offload round trip when initiated
                  from device code (paper: ~5 us).
  - ``proxy``   : host-proxy scale-out path over the NIC/DCN (cross-pod).

The cutover point — the message size where ``engine`` overtakes ``direct`` —
is a function of BOTH the message size and the work-group size (paper Fig. 4a:
store bandwidth varies with #work-items, engine bandwidth does not, Fig. 4b),
and for collectives also the number of PEs (Fig. 6).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HwParams:
    """TPU v5e-flavored transport constants (per chip)."""
    hbm_bw: float = 819e9            # B/s — local copies (same-PE tier)
    ici_bw: float = 50e9             # B/s per link — engine path peak
    dcn_bw: float = 25e9             # B/s — cross-pod NIC tier
    direct_bw_cap: float = 45e9      # B/s — kernel-issued stores saturate below peak
    direct_bw_per_item: float = 1.6e9  # B/s per concurrent work item
    alpha_direct: float = 1.2e-6     # s — in-kernel DMA issue latency
    alpha_engine: float = 4.5e-6     # s — engine startup incl. reverse offload
    alpha_proxy: float = 8.0e-6      # s — ring-buffer RTT + NIC doorbell
    ring_msg_bytes: int = 64         # reverse-offload message size (§III-D)
    ring_rate: float = 20e6          # msgs/s through one host proxy thread
    reduce_bw: float = 200e9         # B/s — effective tile-compute throughput
                                     # (3-stream elementwise on the VPU;
                                     # prices the compute half of the
                                     # comm/compute overlap model)


@dataclasses.dataclass(frozen=True)
class Tuning:
    """User-tunable cutover policy (ISHMEM_* env-vars in the real library;
    parsed by ``repro.tune.env``)."""
    cutover_bytes: int | None = None   # None -> model-derived
    force_path: str | None = None      # "direct" | "engine" | "proxy"
    work_group_size: int = 128
    # A learned ``repro.tune.table.TuningTable`` (duck-typed via .lookup so
    # core has no import edge into the tuner).  When armed, measured cutovers
    # override the analytic model wherever the table has coverage.
    table: object | None = None
    # Write-combine queued nbi puts at flush (ISHMEM_NBI_COALESCE; see
    # core/pending.py — off gives one wire transfer per application call)
    nbi_coalesce: bool = True


TIERS = ("local", "ici", "dcn")


def resolve_work_items(work_items, tuning: Tuning) -> int:
    """``None`` means "the configured work-group size": host-side call sites
    that don't pick an explicit collaboration width inherit
    ``Tuning.work_group_size`` (the ``ISHMEM_WORK_GROUP_SIZE`` knob) instead
    of a hardcoded 128."""
    return tuning.work_group_size if work_items is None else work_items


def direct_bw(hw: HwParams, work_items: int) -> float:
    return min(hw.direct_bw_cap, max(1, work_items) * hw.direct_bw_per_item)


def t_direct(hw: HwParams, nbytes: int, work_items: int, tier: str) -> float:
    if tier == "dcn":
        return math.inf                      # no kernel-initiated NIC path
    bw = direct_bw(hw, work_items)
    if tier == "local":
        bw = min(hw.hbm_bw, max(bw, work_items * 4 * hw.direct_bw_per_item))
    return hw.alpha_direct + nbytes / bw


def t_engine(hw: HwParams, nbytes: int, tier: str) -> float:
    bw = {"local": hw.hbm_bw, "ici": hw.ici_bw, "dcn": hw.dcn_bw}[tier]
    return hw.alpha_engine + nbytes / bw


def t_proxy(hw: HwParams, nbytes: int, tier: str) -> float:
    bw = hw.dcn_bw if tier == "dcn" else hw.ici_bw
    return hw.alpha_proxy + nbytes / bw + hw.ring_msg_bytes / hw.dcn_bw


def choose_path(nbytes: int, *, work_items: int | None = None,
                tier: str = "ici", hw: HwParams = HwParams(),
                tuning: Tuning = Tuning()) -> str:
    """Pick the transport for one RMA op (the paper's tuned cutover)."""
    work_items = resolve_work_items(work_items, tuning)
    if tuning.force_path:
        return tuning.force_path
    if tier == "dcn":
        return "proxy"
    if tuning.cutover_bytes is not None:
        return "direct" if nbytes <= tuning.cutover_bytes else "engine"
    if tuning.table is not None:
        learned = tuning.table.lookup(tier, work_items)
        if learned is not None:
            return "direct" if nbytes <= learned else "engine"
    td = t_direct(hw, nbytes, work_items, tier)
    te = t_engine(hw, nbytes, tier)
    return "direct" if td <= te else "engine"


def choose_collective_path(kind: str, nbytes: int, npes: int, *,
                           work_items: int | None = None, tier: str = "ici",
                           hw: HwParams = HwParams(),
                           tuning: Tuning = Tuning()) -> str:
    """The single chooser for collectives — same precedence as
    :func:`choose_path` (FORCE_PATH > CUTOVER_BYTES > learned table >
    analytic), but the analytic fallback compares the *collective* cost
    models (Fig. 6 crossovers), not the point-to-point ones.

    An explicit/learned per-message cutover (ISHMEM_CUTOVER_BYTES or a
    measured TuningTable with coverage for this tier) overrides the analytic
    collective model; an armed table WITHOUT coverage for this tier must not
    reroute collectives through the point-to-point model.
    """
    work_items = resolve_work_items(work_items, tuning)
    if tuning.force_path:
        return tuning.force_path
    if tuning.cutover_bytes is not None or (
            tuning.table is not None
            and tuning.table.lookup(tier, work_items) is not None):
        return choose_path(nbytes, work_items=work_items, tier=tier, hw=hw,
                           tuning=tuning)
    td = t_collective(kind, nbytes, npes, work_items=work_items,
                      path="direct", hw=hw)
    te = t_collective(kind, nbytes, npes, path="engine", hw=hw)
    return "direct" if td <= te else "engine"


def cutover_bytes(*, work_items: int = 128, tier: str = "ici",
                  hw: HwParams = HwParams()) -> int:
    """Closed-form crossing point of t_direct and t_engine.

    alpha_d + n/bw_d = alpha_e + n/bw_e  =>  n* = (alpha_e - alpha_d) /
                                                   (1/bw_d - 1/bw_e)
    If the direct path is at least as fast at all sizes (bw_d >= bw_e), the
    cutover is infinite (never switch).
    """
    bw_d = direct_bw(hw, work_items)
    bw_e = {"local": hw.hbm_bw, "ici": hw.ici_bw, "dcn": hw.dcn_bw}[tier]
    if tier == "local":
        bw_d = min(hw.hbm_bw, max(bw_d, work_items * 4 * hw.direct_bw_per_item))
    if bw_d >= bw_e:
        return 1 << 62
    n = (hw.alpha_engine - hw.alpha_direct) / (1.0 / bw_d - 1.0 / bw_e)
    return max(0, int(n))


def op_time(nbytes: int, path: str, *, work_items: int = 128,
            tier: str = "ici", hw: HwParams = HwParams()) -> float:
    if path == "direct":
        return t_direct(hw, nbytes, work_items, tier)
    if path == "engine":
        return t_engine(hw, nbytes, tier)
    if path == "proxy":
        return t_proxy(hw, nbytes, tier)
    raise ValueError(path)


# ---------------------------------------------------------------------------
# Collective cost models (push-style, §III-G2) — used by the benchmarks to
# reproduce the shapes of paper Figs. 6-7 and by the shmem comms backend to
# pick collective algorithms.
# ---------------------------------------------------------------------------


def t_collective(kind: str, nbytes_per_pe: int, npes: int, *,
                 work_items: int = 128, path: str = "direct",
                 hw: HwParams = HwParams()) -> float:
    """Time for one intra-node collective on an all-to-all-connected tier."""
    if kind == "sync":
        # pipelined remote atomic increments, then a local wait
        return hw.alpha_direct + (npes - 1) * 64 / direct_bw(hw, work_items) \
            + hw.alpha_direct
    if kind in ("broadcast", "fcollect"):
        # push: inner loop over destinations pipelines stores across all
        # links, but every store still consumes the initiator's issue
        # bandwidth -> aggregate direct_bw(wi), one startup
        total = nbytes_per_pe * (npes - 1)
        if path == "direct":
            return hw.alpha_direct + total / direct_bw(hw, work_items)
        return hw.alpha_engine * (npes - 1) + total / hw.ici_bw
    if kind == "alltoall":
        # pairwise exchange: each PE sends npes-1 distinct chunks
        total = nbytes_per_pe * (npes - 1) / max(1, npes)
        if path == "direct":
            return hw.alpha_direct + total / direct_bw(hw, work_items)
        return hw.alpha_engine * (npes - 1) + total / hw.ici_bw
    if kind == "reduce":
        # address-split across threads; each PE reads npes rows, computes, stores
        loads = nbytes_per_pe * npes
        if path == "direct":
            return hw.alpha_direct + loads / direct_bw(hw, work_items)
        return hw.alpha_engine * npes + loads / hw.ici_bw
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Comm-compute overlap model (§III-F / §IV: "overlap communications and
# computation") — prices a ring allreduce whose per-step neighbor transfer is
# issued nbi and completed one step later, so the tile-add of step k runs
# while step k+1's chunk is on the wire.  Used by comms.ShmemOps' nbi ring
# step, the trainer's gradient-reduce/optimizer-update pipeline, and
# benchmarks/bench_overlap.py.
# ---------------------------------------------------------------------------


def t_ring_step(chunk_bytes: float, *, work_items: int | None = None,
                tier: str = "ici", hw: HwParams = HwParams(),
                tuning: Tuning = Tuning()) -> float:
    """One neighbor transfer of the ring (path picked per chunk size)."""
    work_items = resolve_work_items(work_items, tuning)
    path = choose_path(max(1, int(chunk_bytes)), work_items=work_items,
                       tier=tier, hw=hw, tuning=tuning)
    if path == "proxy":
        return t_proxy(hw, int(chunk_bytes), tier)
    return op_time(int(chunk_bytes), path, work_items=work_items, tier=tier,
                   hw=hw)


def t_ring_allreduce(nbytes: int, npes: int, *, work_items: int | None = None,
                     tier: str = "ici", hw: HwParams = HwParams(),
                     tuning: Tuning = Tuning(), overlap: bool = False,
                     step_compute_bytes: float = 0.0) -> float:
    """Ring allreduce = (npes-1) reduce-scatter steps (transfer + tile-add)
    then (npes-1) all-gather steps (transfer + consumer compute).

    ``step_compute_bytes`` is the application tile compute consuming each
    arriving chunk (the "next tile" of the nbi ring step — a GEMM tile, an
    optimizer-update shard, a flash-decode block), priced at ``reduce_bw``.

    blocking : each step serializes its transfer and its compute.
    overlap  : the nbi schedule — step k's compute runs under step k+1's
               in-flight transfer, so a step costs max(t_xfer, t_compute);
               the pipeline pays one fill (first transfer) and one drain
               (last compute), plus the quiet that closes each phase.
    """
    if npes <= 1:
        return 0.0
    chunk = nbytes / npes
    t_x = t_ring_step(chunk, work_items=work_items, tier=tier, hw=hw,
                      tuning=tuning)
    t_rs_c = (chunk + step_compute_bytes) / hw.reduce_bw   # add + app tile
    t_ag_c = step_compute_bytes / hw.reduce_bw             # app tile only
    steps = npes - 1

    def phase(t_c):
        if not overlap:
            return steps * (t_x + t_c)
        return t_x + max(0, steps - 1) * max(t_x, t_c) + t_c

    quiet = 0.0 if not overlap else 2 * hw.alpha_direct
    return phase(t_rs_c) + phase(t_ag_c) + quiet


def overlap_efficiency(nbytes: int, npes: int, *, work_items: int | None = None,
                       tier: str = "ici", hw: HwParams = HwParams(),
                       tuning: Tuning = Tuning(),
                       step_compute_bytes: float = 0.0) -> float:
    """Modeled speedup of the nbi ring schedule over the blocking one
    (> 1.0 whenever there is compute to hide)."""
    kw = dict(work_items=work_items, tier=tier, hw=hw, tuning=tuning,
              step_compute_bytes=step_compute_bytes)
    tb = t_ring_allreduce(nbytes, npes, overlap=False, **kw)
    tn = t_ring_allreduce(nbytes, npes, overlap=True, **kw)
    return tb / tn if tn > 0 else 1.0


def t_ring_attention(kv_bytes_per_shard: int, compute_bytes_per_step: float,
                     npes: int, *, overlap: bool = True,
                     work_items: int | None = None, tier: str = "ici",
                     hw: HwParams = HwParams(),
                     tuning: Tuning = Tuning()) -> float:
    """Sequence-parallel ring attention over ``npes`` decode PEs: each PE
    holds one KV shard, computes a partial flash step against the resident
    shard, and rotates shards around the ring ``npes - 1`` times.

    ``overlap=False`` serializes each rotation's transfer and partial-attn
    compute; ``overlap=True`` is the device-initiated schedule — the
    work-group issues step k+1's K/V rotation (nbi put_signal) before
    consuming step k's shard, so a steady-state step costs
    ``max(t_xfer, t_compute)``.  The quiet closing the ring is two direct
    launch latencies (issue + final signal wait), same as the allreduce
    overlap model."""
    work_items = resolve_work_items(work_items, tuning)
    t_c = compute_bytes_per_step / hw.reduce_bw
    if npes <= 1:
        return t_c
    t_x = t_ring_step(kv_bytes_per_shard, work_items=work_items, tier=tier,
                      hw=hw, tuning=tuning)
    if not overlap:
        return t_c + (npes - 1) * (t_x + t_c)
    return t_c + (npes - 1) * max(t_x, t_c) + 2 * hw.alpha_direct


def ring_attention_overlap(kv_bytes_per_shard: int,
                           compute_bytes_per_step: float, npes: int, *,
                           work_items: int | None = None, tier: str = "ici",
                           hw: HwParams = HwParams(),
                           tuning: Tuning = Tuning()) -> float:
    """Modeled speedup of device-initiated ring attention over the blocking
    rotate-then-compute schedule (the ci.sh long-context gate)."""
    kw = dict(work_items=work_items, tier=tier, hw=hw, tuning=tuning)
    tb = t_ring_attention(kv_bytes_per_shard, compute_bytes_per_step, npes,
                          overlap=False, **kw)
    tn = t_ring_attention(kv_bytes_per_shard, compute_bytes_per_step, npes,
                          overlap=True, **kw)
    return tb / tn if tn > 0 else 1.0


def collective_cutover_elems(kind: str, npes: int, elem_bytes: int, *,
                             work_items: int = 128,
                             hw: HwParams = HwParams()) -> int:
    """Smallest nelems where the engine path beats direct (Fig. 6 crossover)."""
    lo, hi = 1, 1 << 30
    f = lambda n: (t_collective(kind, n * elem_bytes, npes,
                                work_items=work_items, path="direct", hw=hw)
                   <= t_collective(kind, n * elem_bytes, npes,
                                   work_items=work_items, path="engine", hw=hw))
    if not f(lo):
        return 0
    if f(hi):
        return 1 << 62
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if f(mid):
            lo = mid
        else:
            hi = mid
    return hi
