"""Host proxy: executes reverse-offloaded device ops (paper §III-C/D).

When a device-initiated op targets a PE that is not directly reachable over
the fabric (the ``dcn`` tier — paper: a remote node over Slingshot; here: a
remote pod over DCN), the device composes a fixed 64-byte request message,
pushes it through the lock-free ring (``core.ring``) and the host proxy thread
executes it via the host-initiated path, posting a completion.

The proxy is a real consumer of the ring protocol: ops are *deferred* at
submit time and only change the heap when the proxy drains the ring, so tests
can observe the intermediate (submitted-but-not-executed) state.
"""
from __future__ import annotations

import struct

import jax.numpy as jnp

from repro.core import ring as ring_mod
from repro.core.heap import SymPtr

# op codes in the 64-byte message
OP_PUT, OP_GET, OP_AMO_ADD, OP_AMO_CSWAP, OP_QUIET = range(5)
_DTYPES = ["float32", "int32", "int64", "uint32", "float64", "uint64",
           "int8", "uint8", "float16", "bfloat16"]
_HDR = struct.Struct("<BBHiqi")  # op, dtype, _, pe, offset, size  (<=20 B)


class HostProxy:
    def __init__(self, ctx, slots: int = 128):
        self.ctx = ctx
        self.ring = ring_mod.RingBuffer(slots=slots)
        self._staging = {}       # msg idx -> payload too big for 56 B inline
        self._seq = 0
        self._pid = 0
        self.backpressure = 0    # producer waits absorbed by a mid-run drain

    def ring_full(self) -> bool:
        """True when the next submit would spin on flow control: every slot
        looks occupied against the (possibly stale) published consumed
        count.  Callers holding the heap should ``drain`` and retry — that
        is the backpressure path a migration storm takes instead of
        wedging (see ``core.pending.CompletionQueue._issue``)."""
        return (self.ring.write_reserve - self.ring.consumed_published
                >= self.ring.slots)

    # ------------------------------------------------------------- submit
    def _submit(self, op, ptr: SymPtr, pe, data=None):
        hdr = _HDR.pack(op, _DTYPES.index(ptr.dtype), 0, pe, ptr.offset,
                        ptr.size)
        pid = f"wi{self._pid}"
        self._pid += 1
        msg = ring_mod.Message(op=str(op), payload=hdr)
        self.ring.start(pid, msg)
        # drive this producer's micro-steps until the message is visible;
        # wedge detection is relative to THIS submit (the shared spin counter
        # is cumulative — an earlier wedge must not poison later submits)
        idx = None
        spins_at_start = self.ring.spin_count
        while idx is None:
            idx = self.ring.producer_step(pid)
            if idx is None and self.ring.spin_count - spins_at_start > 10_000:
                self.ring._prod.pop(pid, None)   # abandon, don't leak the pid
                raise RuntimeError("ring wedged: no consumer progress")
        if data is not None:
            # payloads beyond the inline 56 B ride in registered device
            # memory that the NIC reads directly (FI_HMEM); model as staging
            self._staging[idx] = data
        return pid, idx

    def put(self, ptr: SymPtr, value, pe):
        value = jnp.asarray(value, jnp.dtype(ptr.dtype)).reshape((ptr.size,))
        return self._submit(OP_PUT, ptr, pe, data=value)

    def put_nbi(self, ptr: SymPtr, value, pe, *, src_pe: int = -1):
        """Deferred reverse-offload put: parks on the context's
        CompletionQueue as the same PendingOp record every other nbi op uses
        (tier pinned to dcn); ``quiet(ctx, heap, proxy=self)`` routes it
        through the ring and drains — completion exactly at quiet, like the
        paper's proxy-mediated nbi ops."""
        from repro.core import pending as pending_mod
        value = jnp.asarray(value, jnp.dtype(ptr.dtype)).reshape((ptr.size,))
        self.ctx.record("put_nbi(pending)", ptr.nbytes, "proxy", "dcn", 1,
                        t_sec=0.0)
        self.ctx.pending.submit(
            pending_mod.PUT, "put_nbi", ptr, pe, "dcn", src_pe=src_pe,
            value=value,
            marker=self.ctx.ledger[-1] if self.ctx.ledger else None)

    def amo_add(self, ptr: SymPtr, value, pe):
        return self._submit(OP_AMO_ADD, ptr, pe,
                            data=jnp.asarray(value, jnp.dtype(ptr.dtype)))

    def quiet(self):
        return self._submit(OP_QUIET, SymPtr("int32", 0, ()), 0)

    # -------------------------------------------------------------- drain
    def drain(self, heap):
        """Host proxy thread: consume every visible message, executing each
        against the heap via the host-initiated path.  Returns the new heap."""
        state = {"heap": heap}

        def executor(msg):
            op, dt, _, pe, off, size = _HDR.unpack(msg.payload[:_HDR.size])
            ptr = SymPtr(_DTYPES[dt], off, (size,) if size else ())
            idx = self.ring.read_index
            if op == OP_PUT:
                data = self._staging.pop(idx)
                state["heap"] = state["heap"].write(ptr, pe, data)
                self.ctx.record("proxy_put", ptr.nbytes, "proxy", "dcn", 1)
            elif op == OP_AMO_ADD:
                data = self._staging.pop(idx)
                old = state["heap"].read(ptr, pe)
                state["heap"] = state["heap"].write(ptr, pe, old + data)
                self.ctx.record("proxy_amo", ptr.nbytes, "proxy", "dcn", 1)
                return old.reshape(()) if old.size == 1 else old
            elif op == OP_QUIET:
                self.ctx.record("proxy_quiet", 0, "proxy", "dcn", 1)
            return None

        while self.ring.consumer_step(executor) is not None:
            pass
        self.ring.publish()
        # reap completions
        for pid in list(self.ring._prod):
            self.ring.producer_done(pid)
        return state["heap"]
