"""Lock-free reverse-offload ring buffer (paper §III-D).

Faithful protocol model of the GPU->CPU request ring:

  - fixed 64-byte messages;
  - transmit-slot allocation by a single atomic fetch-and-increment, arbitrating
    any number of producer threads;
  - slot readiness signaled by a per-slot *lap tag* (store-only, fire-and-forget:
    the producer stores payload, then stores tag = lap+1; the consumer polls the
    tag — no producer-side progress thread);
  - reverse flow control OFF the critical path: the consumer republishes its
    consumed count only every ``publish_every`` messages; producers spin only
    when the ring looks full against that (stale) count;
  - completions are allocated independently, permitting out-of-order replies.

The class is a *step machine*: every micro-step (reserve / write / tag /
consume / publish) is an explicit method, so property tests can interleave
thousands of schedules and assert exactly-once delivery and no-overwrite.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

MSG_BYTES = 64
HEADER_BYTES = 8
PAYLOAD_BYTES = MSG_BYTES - HEADER_BYTES

# producer micro-states
IDLE, RESERVED, WRITTEN, TAGGED = range(4)


@dataclasses.dataclass
class Message:
    op: str
    payload: bytes = b""

    def __post_init__(self):
        if len(self.payload) > PAYLOAD_BYTES:
            raise ValueError("message exceeds the fixed 64-byte format")


class RingBuffer:
    def __init__(self, slots: int = 128, publish_every: int = 16):
        assert slots > 0 and (slots & (slots - 1)) == 0, "power-of-two ring"
        self.slots = slots
        self.publish_every = publish_every
        # shared memory (what would live in host-visible memory)
        self.write_reserve = 0            # atomic fetch-inc counter
        self.consumed_published = 0       # consumer's (lazily) published count
        self.slot_tag = [0] * slots       # lap tags (0 = never written)
        self.slot_data: list = [None] * slots
        self.completions: dict = {}       # msg index -> result (out of order)
        # consumer private state
        self.read_index = 0
        self._since_publish = 0
        # producers' private state: pid -> (state, idx, msg)
        self._prod: dict = {}
        # instrumentation
        self.delivered: list = []
        self.spin_count = 0
        self.store_ops = 0                # bus stores (fire-and-forget)
        self.publish_ops = 0
        self.overwrite_errors = 0

    # ------------------------------------------------------------ producers
    def start(self, pid, msg: Message):
        assert self._prod.get(pid, (IDLE,))[0] == IDLE, "one msg at a time"
        self._prod[pid] = (IDLE, None, msg)

    def producer_step(self, pid) -> Optional[int]:
        """Advance producer ``pid`` one micro-step.  Returns the message index
        once the message becomes visible (TAGGED), else None."""
        if pid not in self._prod:
            return None
        state, idx, msg = self._prod[pid]
        if state == IDLE:
            # flow control against the *published* (possibly stale) count —
            # never in the critical path unless the ring looks full
            if self.write_reserve - self.consumed_published >= self.slots:
                self.spin_count += 1
                return None
            idx = self.write_reserve
            self.write_reserve += 1       # single atomic fetch-and-increment
            self._prod[pid] = (RESERVED, idx, msg)
            return None
        if state == RESERVED:
            slot = idx % self.slots
            lap = idx // self.slots
            # the no-overwrite invariant: the previous occupant must have been
            # consumed.  Flow control guarantees this; check it explicitly.
            if self.slot_tag[slot] > lap:
                self.overwrite_errors += 1
            self.slot_data[slot] = (idx, msg)
            self.store_ops += 1           # payload store (one bus op: 64 B)
            self._prod[pid] = (WRITTEN, idx, msg)
            return None
        if state == WRITTEN:
            slot = idx % self.slots
            self.slot_tag[slot] = idx // self.slots + 1   # release store
            self.store_ops += 1
            self._prod[pid] = (TAGGED, idx, msg)
            return idx
        return None                        # TAGGED: waiting for completion

    def producer_done(self, pid) -> bool:
        state, idx, _ = self._prod.get(pid, (IDLE, None, None))
        if state == TAGGED and idx in self.completions:
            del self._prod[pid]
            return True
        return False

    # ------------------------------------------------------------- consumer
    def consumer_step(self, executor=None) -> Optional[int]:
        """Process one ready message (single consumer thread).  Returns the
        consumed message index or None if the head slot isn't ready."""
        idx = self.read_index
        slot = idx % self.slots
        if self.slot_tag[slot] != idx // self.slots + 1:
            return None                   # head not ready yet
        stored_idx, msg = self.slot_data[slot]
        assert stored_idx == idx, "ring ordering violated"
        result = executor(msg) if executor else None
        self.delivered.append((idx, msg))
        self.completions[idx] = result    # independently allocated, OOO replies
        self.read_index += 1
        self._since_publish += 1
        if self._since_publish >= self.publish_every:
            self.publish()
        return idx

    def publish(self):
        """Publish the consumed count (reverse flow control, off critical path)."""
        self.consumed_published = self.read_index
        self._since_publish = 0
        self.publish_ops += 1

    # -------------------------------------------------------------- metrics
    def flow_control_overhead(self) -> float:
        total = self.store_ops + self.publish_ops
        return self.publish_ops / total if total else 0.0
