"""ishmem_init / library context.

Holds everything the paper's runtime sets up host-side: the device-resident
symmetric heap, PE topology (which PEs share a fabric tier), transport tuning,
and the telemetry sink that feeds the online autotuner (``repro.tune``).

The old flat write-only ``ledger`` list is now a bounded view over the
telemetry trace: every ``record`` both appends an :class:`OpRecord` (so tests
and examples can inspect recent ops) and aggregates into per-(op, path, tier,
work_items) buckets that ``repro.tune.estimator`` fits measured transport
profiles from.  ``init`` reads the ``ISHMEM_*`` environment variables (see
``repro.tune.env``) when no explicit tuning is given — including warm-starting
a learned cutover table from ``ISHMEM_TUNING_FILE``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Set

from repro.core import cutover, heap as heap_mod, pending as pending_mod, \
    teams
from repro.obs import tracer as tracer_mod
from repro.tune import env as env_mod, telemetry as telemetry_mod

# canonical definition lives in the telemetry module; re-exported here for
# backward compatibility (collectives/tests used to import it from context)
OpRecord = telemetry_mod.OpRecord


@dataclasses.dataclass
class FaultState:
    """Host-side failure-domain view (DESIGN.md §14).

    ``dead_pes`` holds PEs whose device is gone: their heap rows are
    garbage, pending traffic touching them (as source or destination)
    must cancel with an error rather than complete, and new traffic to
    them is a protocol bug.  ``dcn_down`` models a partitioned proxy
    ring: cross-pod (dcn-tier) ops stay queued — neither lost nor
    delivered — until the partition heals."""
    dead_pes: Set[int] = dataclasses.field(default_factory=set)
    dcn_down: bool = False

    def alive(self, pe: int) -> bool:
        return int(pe) not in self.dead_pes

    def kill(self, pe: int) -> None:
        self.dead_pes.add(int(pe))


@dataclasses.dataclass
class ShmemContext:
    npes: int
    node_size: int                      # PEs per shared-fabric node (pod)
    hw: cutover.HwParams
    tuning: cutover.Tuning
    use_kernels: bool = False           # route direct-path copies via Pallas
    telemetry: telemetry_mod.TelemetrySink = dataclasses.field(
        default_factory=telemetry_mod.TelemetrySink)
    # deferred-completion queue: every *_nbi op parks here until a completion
    # point (quiet/barrier/dependent signal_wait) flushes it — see pending.py
    pending: pending_mod.CompletionQueue = dataclasses.field(
        default_factory=pending_mod.CompletionQueue)
    # span tracer (repro.obs): the shared Null tracer unless a driver
    # attaches a recording one — hot paths guard on ``tracer.enabled``
    tracer: tracer_mod.Tracer = tracer_mod.NULL_TRACER
    # wall-clock profiler (repro.obs.prof): None unless a driver attaches
    # one — hot paths guard on ``prof is not None and prof.enabled``.  Its
    # perf_counter clock is strictly segregated from the step clock above:
    # measured seconds only ever land in wallclock-source telemetry buckets
    # and profiler samples, never in deterministic trace timestamps
    prof: Optional[object] = None
    # failure-domain state: which PEs are dead, whether the proxy ring is
    # partitioned — consulted by the completion queue at flush time
    fault: FaultState = dataclasses.field(default_factory=FaultState)

    # ------------------------------------------------------------ topology
    def node_of(self, pe: int) -> int:
        return pe // self.node_size

    def tier(self, src_pe: int, dst_pe: int) -> str:
        if src_pe == dst_pe:
            return "local"
        if self.node_of(src_pe) == self.node_of(dst_pe):
            return "ici"
        return "dcn"

    @property
    def team_world(self) -> teams.Team:
        return teams.world(self.npes)

    def team_shared(self, pe: int = 0) -> teams.Team:
        return teams.shared(self.npes, self.node_size, self.node_of(pe))

    # ----------------------------------------------------------- telemetry
    @property
    def ledger(self) -> list:
        """Recent-ops view (bounded trace) — back-compat with the old flat
        ledger list; long-run aggregates live in ``self.telemetry``."""
        return self.telemetry.trace

    def record(self, op: str, nbytes: int, path: str, tier: str,
               work_items: int = 1, t_sec: Optional[float] = None,
               source: str = telemetry_mod.MODEL_SOURCE) -> None:
        """Record one op into the sink.  ``t_sec`` carries a measured (or
        pre-modeled collective) time; when omitted the analytic RMA cost
        model prices the op — so cold runs still populate the tuner.
        ``source`` tags provenance: the default ``"model"`` stream is the
        deterministic comm clock; ``"wallclock"`` records (profiler,
        measured benches) aggregate into their own buckets."""
        if t_sec is None:
            t_sec = cutover.op_time(nbytes, path, work_items=work_items,
                                    tier=tier if path != "proxy" else "dcn",
                                    hw=self.hw)
        self.telemetry.record(OpRecord(op, nbytes, path, tier, t_sec,
                                       work_items, source))

    def total_time(self) -> float:
        return self.telemetry.total_time()

    def reset_ledger(self) -> None:
        self.telemetry.clear()

    def fit_tuning_table(self, *, arm: bool = True,
                         sample_source: Optional[str] = None):
        """Fit a measured cutover table from everything recorded so far
        (``repro.tune.estimator``); when ``arm`` is set the table is installed
        on ``self.tuning`` so subsequent ``choose_path`` calls use it.
        ``sample_source`` restricts the fit to one telemetry provenance
        stream (``"wallclock"`` = measured profiler samples only) and labels
        the resulting table with it."""
        from repro.tune import estimator, table as table_mod
        if not isinstance(self.telemetry, telemetry_mod.TelemetrySink):
            return table_mod.TuningTable(source="empty")  # e.g. NullSink
        tbl = estimator.build_table(self.telemetry,
                                    source=sample_source or "measured",
                                    sample_source=sample_source)
        if arm and (tbl.cutovers or tbl.profiles):
            self.tuning = dataclasses.replace(self.tuning, table=tbl)
        return tbl


def init(npes: int, node_size: Optional[int] = None,
         hw: Optional[cutover.HwParams] = None,
         tuning: Optional[cutover.Tuning] = None,
         heap_words: int = 1 << 20,
         use_kernels: bool = False,
         telemetry: Optional[telemetry_mod.TelemetrySink] = None):
    """ishmem_init: returns (ctx, heap).  1 PE : 1 device (paper §III-E).

    When ``tuning`` is not given, the ``ISHMEM_*`` environment variables are
    consulted (mirroring the real library's init-time knob parsing), which may
    also arm a persisted tuning table via ``ISHMEM_TUNING_FILE``.
    """
    ctx = ShmemContext(
        npes=npes,
        node_size=node_size or npes,
        hw=hw or cutover.HwParams(),
        tuning=tuning if tuning is not None else env_mod.tuning_from_env(),
        use_kernels=use_kernels,
        telemetry=telemetry or telemetry_mod.TelemetrySink(),
    )
    return ctx, heap_mod.create(npes, heap_words)
