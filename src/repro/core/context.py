"""ishmem_init / library context.

Holds everything the paper's runtime sets up host-side: the device-resident
symmetric heap, PE topology (which PEs share a fabric tier), transport tuning,
and an operation ledger used by the benchmarks for the analytic cost curves.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import cutover, heap as heap_mod, teams


@dataclasses.dataclass
class OpRecord:
    op: str
    nbytes: int
    path: str
    tier: str
    t_sec: float
    work_items: int = 1


@dataclasses.dataclass
class ShmemContext:
    npes: int
    node_size: int                      # PEs per shared-fabric node (pod)
    hw: cutover.HwParams
    tuning: cutover.Tuning
    use_kernels: bool = False           # route direct-path copies via Pallas
    ledger: list = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------ topology
    def node_of(self, pe: int) -> int:
        return pe // self.node_size

    def tier(self, src_pe: int, dst_pe: int) -> str:
        if src_pe == dst_pe:
            return "local"
        if self.node_of(src_pe) == self.node_of(dst_pe):
            return "ici"
        return "dcn"

    @property
    def team_world(self) -> teams.Team:
        return teams.world(self.npes)

    def team_shared(self, pe: int = 0) -> teams.Team:
        return teams.shared(self.npes, self.node_size, self.node_of(pe))

    # ------------------------------------------------------------ ledger
    def record(self, op: str, nbytes: int, path: str, tier: str,
               work_items: int = 1) -> None:
        t = cutover.op_time(nbytes, path, work_items=work_items,
                            tier=tier if path != "proxy" else "dcn",
                            hw=self.hw)
        self.ledger.append(OpRecord(op, nbytes, path, tier, t, work_items))

    def total_time(self) -> float:
        return sum(r.t_sec for r in self.ledger)

    def reset_ledger(self) -> None:
        self.ledger = []


def init(npes: int, node_size: Optional[int] = None,
         hw: Optional[cutover.HwParams] = None,
         tuning: Optional[cutover.Tuning] = None,
         heap_words: int = 1 << 20,
         use_kernels: bool = False):
    """ishmem_init: returns (ctx, heap).  1 PE : 1 device (paper §III-E)."""
    ctx = ShmemContext(
        npes=npes,
        node_size=node_size or npes,
        hw=hw or cutover.HwParams(),
        tuning=tuning or cutover.Tuning(),
        use_kernels=use_kernels,
    )
    return ctx, heap_mod.create(npes, heap_words)
