"""OpenSHMEM 1.5 teams (paper §I: "teams API"-aligned collectives).

A team is a (start, stride, size) slice of the world PE set, exactly the
``shmem_team_split_strided`` model.  ``TEAM_SHARED`` is the set of PEs that
share one node's fabric (one pod / Xe-Link group).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Team:
    start: int
    stride: int
    size: int

    def pes(self) -> list:
        return [self.start + i * self.stride for i in range(self.size)]

    def translate(self, team_pe: int) -> int:
        """team-relative rank -> world PE."""
        if not 0 <= team_pe < self.size:
            raise ValueError(f"rank {team_pe} outside team of size {self.size}")
        return self.start + team_pe * self.stride

    def rank_of(self, world_pe: int) -> int:
        """world PE -> team rank, or -1 if not a member."""
        d = world_pe - self.start
        if d < 0 or d % self.stride or d // self.stride >= self.size:
            return -1
        return d // self.stride

    def split_strided(self, start: int, stride: int, size: int) -> "Team":
        """shmem_team_split_strided relative to this team."""
        if start < 0 or stride < 1 or size < 1:
            raise ValueError(
                f"invalid split (start={start}, stride={stride}, size={size})")
        if start + (size - 1) * stride >= self.size:
            raise ValueError("child team exceeds parent")
        return Team(self.translate(start), self.stride * stride, size)


def world(npes: int) -> Team:
    return Team(0, 1, npes)


def shared(npes: int, node_size: int, node_id: int) -> Team:
    """ISHMEM_TEAM_SHARED: the PEs of one shared-fabric node/pod."""
    if node_size * (node_id + 1) > npes:
        raise ValueError("node beyond world")
    return Team(node_id * node_size, 1, node_size)


def pods_partition(team: Team, pod_sizes) -> list:
    """Split a team into contiguous pods of the given sizes (uneven sizes
    allowed) — the fleet frontend's N-pod topology.  Each pod team can then
    be ``disagg_partition``-ed into its prefill/decode fleets; pods need not
    cover the whole team (leftover PEs stay unassigned)."""
    sizes = list(pod_sizes)
    if not sizes or any(s < 1 for s in sizes):
        raise ValueError(f"pod sizes must be positive, got {sizes}")
    if sum(sizes) > team.size:
        raise ValueError(
            f"pods need {sum(sizes)} PEs but the team holds {team.size}")
    out, off = [], 0
    for s in sizes:
        out.append(team.split_strided(off, 1, s))
        off += s
    return out


def disagg_partition(team: Team, n_prefill: int) -> tuple:
    """Split a team into contiguous (prefill, decode) sub-teams for
    disaggregated serving — the prefill fleet owns the first ``n_prefill``
    ranks, the decode fleet the rest.  Built on ``split_strided`` so it works
    on ``world`` and on a ``shared()`` pod team alike (the intra-pod split
    the serve launcher uses when prefill and decode share one fabric)."""
    if not 0 < n_prefill < team.size:
        raise ValueError(
            f"need 0 < n_prefill < {team.size}, got {n_prefill}")
    return (team.split_strided(0, 1, n_prefill),
            team.split_strided(n_prefill, 1, team.size - n_prefill))
