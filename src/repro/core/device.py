"""Device-initiated, work-group-collaborative SHMEM ops (paper §III-F/G).

The paper's headline extension is ``ishmemx_*_work_group``: SHMEM calls made
*from inside a running kernel*, where all work-items of one work-group
cooperate to move a block — and the runtime adapts between direct
load/store (the work-items issue the remote stores themselves; bandwidth
scales with the collaboration width) and the copy engine (reverse-offload
a DMA descriptor; full link bandwidth but extra startup).

This module is the host-visible simulation of that surface, structured the
way a kernel would use it:

- A :class:`WorkGroup` is the device-side caller identity: *which* PE the
  kernel runs on and *how many* work-items collaborate
  (``ISHMEM_WORK_GROUP_SIZE`` via ``Tuning.work_group_size`` by default).
- Every op prices the direct-vs-engine decision **per collaborative op** via
  ``cutover.choose_path(..., work_items=wg.size)`` and records ``device_*``
  telemetry at that width, so the autotuner (``tune/estimator.py``) fits
  work-group-resolved transport profiles and cutovers.
- Non-blocking variants ride the same :class:`~repro.core.pending.
  CompletionQueue` as the host ops — device and host nbi traffic share one
  ordered stream per context, exactly like the real runtime's single
  completion domain.
- ``signal_wait_until`` differs from the host wait on purpose: a device
  work-group *spins* on the signal word, so it forces only the MINIMAL
  pending prefix that can advance the word (``pending_first``), one step per
  spin, instead of the whole dependency prefix.  That is what lets a fused
  kernel consume block k's bytes the moment block k's signal lands while
  blocks k+1.. stay on the wire (see ``serve/kvxfer.py`` ``migrate_fused``).

The Pallas kernels that *consume* these semantics (fused paged-attention
gather, sequence-parallel ring attention) live in
``repro.kernels.ishmem_device``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import cutover, pending as pending_mod
from repro.core.heap import SymPtr
from repro.core.pending import write_row
from repro.core.signal import SIGNAL_ADD, SIGNAL_SET, _CMP, _sig_apply
from repro.core.teams import Team

__all__ = [
    "WorkGroup", "work_group", "put", "get", "put_nbi", "put_signal_nbi",
    "signal_wait_until", "broadcast", "reduce", "SIGNAL_SET", "SIGNAL_ADD",
]


@dataclasses.dataclass
class WorkGroup:
    """Device-side caller identity: a work-group of ``size`` work-items
    executing on PE ``pe``.  All collaborative ops below take this first —
    the device analog of passing ``ctx`` to a host op."""
    ctx: object                      # ShmemContext
    size: int                        # collaborating work-items
    pe: int = 0                      # PE the kernel is running on

    def tier(self, other_pe: int) -> str:
        return self.ctx.tier(self.pe, other_pe)

    # trace-track identity: device ops render on the issuing PE's lane
    @property
    def pid(self) -> str:
        return f"pod{self.ctx.node_of(self.pe)}"

    @property
    def tid(self) -> str:
        return f"pe{self.pe}"


def work_group(ctx, size: int | None = None, pe: int = 0) -> WorkGroup:
    """Enter a device work-group scope.  ``size=None`` inherits the
    configured ``ISHMEM_WORK_GROUP_SIZE`` (``Tuning.work_group_size``)."""
    if size is None:
        size = ctx.tuning.work_group_size
    return WorkGroup(ctx=ctx, size=int(size), pe=int(pe))


def _instant(wg: WorkGroup, name: str, **args) -> None:
    tracer = wg.ctx.tracer
    if tracer.enabled:
        tracer.instant(name, "dev", wg.pid, wg.tid, **args)


# ---------------------------------------------------------------------------
# collaborative RMA
# ---------------------------------------------------------------------------


def put(wg: WorkGroup, heap, dest: SymPtr, value, dst_pe: int):
    """ishmemx_put_work_group: the work-group cooperatively stores a block
    into ``dst_pe``'s row.  Direct vs copy-engine is decided at the group's
    collaboration width — wider groups keep larger blocks on the
    load/store path (paper Fig. 4a)."""
    ctx = wg.ctx
    value = jnp.asarray(value, jnp.dtype(dest.dtype)).reshape((dest.size,))
    tier = wg.tier(dst_pe)
    path = cutover.choose_path(dest.nbytes, work_items=wg.size, tier=tier,
                               hw=ctx.hw, tuning=ctx.tuning)
    ctx.record("device_put", dest.nbytes, path, tier, wg.size)
    _instant(wg, "device_put", path=path, tier=tier, nbytes=dest.nbytes,
             pe=dst_pe, work_items=wg.size)
    heap = ctx.pending.resolve_store_conflicts(ctx, heap, dest, dst_pe)
    return write_row(ctx, heap, dest, dst_pe, value)


def get(wg: WorkGroup, heap, src: SymPtr, src_pe_remote: int):
    """ishmemx_get_work_group: cooperative one-sided load."""
    ctx = wg.ctx
    tier = wg.tier(src_pe_remote)
    path = cutover.choose_path(src.nbytes, work_items=wg.size, tier=tier,
                               hw=ctx.hw, tuning=ctx.tuning)
    ctx.record("device_get", src.nbytes, path, tier, wg.size)
    _instant(wg, "device_get", path=path, tier=tier, nbytes=src.nbytes,
             pe=src_pe_remote, work_items=wg.size)
    return heap.read(src, src_pe_remote)


def put_nbi(wg: WorkGroup, heap, dest: SymPtr, value, dst_pe: int):
    """ishmemx_put_nbi_work_group: deferred collaborative put.  Parks on the
    context's completion queue at the group's width; the transport is chosen
    at flush time on the coalesced transfer size."""
    ctx = wg.ctx
    value = jnp.asarray(value, jnp.dtype(dest.dtype)).reshape((dest.size,))
    tier = wg.tier(dst_pe)
    marker_path = "proxy" if tier == "dcn" else "engine"
    ctx.record("device_put_nbi(pending)", dest.nbytes, marker_path, tier,
               wg.size, t_sec=0.0)
    ctx.pending.submit(pending_mod.PUT, "device_put_nbi", dest, dst_pe, tier,
                       work_items=wg.size, value=value,
                       marker=ctx.ledger[-1] if ctx.ledger else None)
    return heap


def put_signal_nbi(wg: WorkGroup, heap, dest: SymPtr, value, sig_ptr: SymPtr,
                   signal, sig_op: int, dst_pe: int):
    """ishmemx_put_signal_nbi_work_group: deferred data put + deferred signal
    update, ordered data-before-flag inside the flush (the signal entry is a
    non-coalescible barrier right behind its data, so write combining can
    never lift a later put across it)."""
    ctx = wg.ctx
    heap = put_nbi(wg, heap, dest, value, dst_pe)
    tier = wg.tier(dst_pe)
    ctx.record("signal(pending)", jnp.dtype(sig_ptr.dtype).itemsize,
               "direct", tier, 1, t_sec=0.0)
    ctx.pending.submit(pending_mod.SIGNAL, "signal", sig_ptr, dst_pe, tier,
                       apply=_sig_apply(signal, sig_op),
                       marker=ctx.ledger[-1] if ctx.ledger else None)
    return heap


# ---------------------------------------------------------------------------
# device-side signal wait
# ---------------------------------------------------------------------------


def signal_wait_until(wg: WorkGroup, heap, sig_ptr: SymPtr, pe: int,
                      cmp: str, value):
    """ishmemx_signal_wait_until_work_group: the work-group spins on the
    signal word until the predicate holds.

    Completion forcing is MINIMAL: each spin forces only the FIRST pending
    op that can advance the waited word and its preceding prefix
    (``pending_first`` + ``flush_prefix``), then re-reads.  Contrast the
    host-side wait, which completes the whole dependency prefix in one shot.
    This is what makes per-block fusion real — waiting for block k's signal
    completes exactly the queue prefix through block k, leaving blocks
    k+1.. pending on the wire for later waits.

    Returns ``(heap, last_value, satisfied)``; ``satisfied=False`` means no
    pending traffic can ever satisfy the predicate (the caller's spin would
    deadlock — the property tests assert gating on exactly this)."""
    ctx = wg.ctx
    target = None
    spins = 0
    while True:
        cur = heap.read(sig_ptr, pe).reshape(())
        if target is None:
            target = jnp.asarray(value, cur.dtype)
        if _CMP[cmp](cur, target):
            ok = True
            break
        dep = ctx.pending.pending_first(sig_ptr, pe)
        if dep is None:
            ok = False
            break
        heap = ctx.pending.flush_prefix(ctx, heap, dep)
        spins += 1
    ctx.record("device_signal_wait", 0, "direct", "local", wg.size)
    _instant(wg, "device_signal_wait", cmp=cmp, value=int(value),
             observed=int(cur), spins=spins, ok=bool(ok))
    return heap, cur, ok


# ---------------------------------------------------------------------------
# collaborative collectives
# ---------------------------------------------------------------------------


def broadcast(wg: WorkGroup, heap, ptr: SymPtr, root: int, team: Team):
    """ishmemx_broadcast_work_group: root's work-group pushes its buffer to
    every teammate (store inner loop over destinations), priced at the
    group's collaboration width."""
    ctx = wg.ctx
    path = cutover.choose_collective_path(
        "broadcast", ptr.nbytes, team.size, work_items=wg.size, tier="ici",
        hw=ctx.hw, tuning=ctx.tuning)
    src = heap.read(ptr, team.translate(root))
    data = heap.read_all(ptr)
    vals = jnp.broadcast_to(src[None], (team.size,) + ptr.shape)
    data = data.at[jnp.array(team.pes())].set(vals)
    heap = heap.write_all(ptr, data)
    t = cutover.t_collective("broadcast", ptr.nbytes, team.size,
                             work_items=wg.size, path=path, hw=ctx.hw)
    ctx.record("device_broadcast", ptr.nbytes, path, "ici", wg.size, t_sec=t)
    _instant(wg, "device_broadcast", path=path, nbytes=ptr.nbytes,
             npes=team.size, work_items=wg.size)
    return heap


def reduce(wg: WorkGroup, heap, dest: SymPtr, src: SymPtr, op: str,
           team: Team):
    """ishmemx_<op>_reduce_work_group: address-split across the group's
    work-items — every PE pulls all rows and reduces its slice locally."""
    from repro.core.collectives import REDUCE_OPS
    ctx = wg.ctx
    fn, _ = REDUCE_OPS[op]
    data = heap.read_all(src)
    rows = data[jnp.array(team.pes())]
    acc = rows[0]
    for i in range(1, team.size):
        acc = fn(acc, rows[i])
    out = heap.read_all(dest)
    vals = jnp.broadcast_to(acc[None], (team.size,) + src.shape)
    out = out.at[jnp.array(team.pes())].set(
        vals.reshape((team.size,) + dest.shape))
    heap = heap.write_all(dest, out)
    path = cutover.choose_collective_path(
        "reduce", src.nbytes, team.size, work_items=wg.size, tier="ici",
        hw=ctx.hw, tuning=ctx.tuning)
    t = cutover.t_collective("reduce", src.nbytes, team.size,
                             work_items=wg.size, path=path, hw=ctx.hw)
    ctx.record("device_reduce", src.nbytes, path, "ici", wg.size, t_sec=t)
    _instant(wg, "device_reduce", path=path, op=op, nbytes=src.nbytes,
             npes=team.size, work_items=wg.size)
    return heap
