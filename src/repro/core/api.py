"""Paper-faithful API facade: `ishmem_*` / `ishmemx_*` names over the core
library (the paper prefixes host/device APIs with ``ishmem`` and the
device-only work_group extensions with ``ishmemx``, §III-A/F).

Stateful convenience wrapper — the functional core stays the source of
truth; this class threads (ctx, heap) so application code reads like the
paper's listings:

    sh = Ishmem(npes=8, node_size=4)
    buf = sh.ishmem_malloc((1024,), "float32")
    sh.ishmem_put(buf, data, pe=3)
    sh.ishmemx_put_work_group(buf, data, pe=1, work_group_size=1024)
    sh.ishmem_barrier_all()
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import amo, collectives, context, rma, signal
from repro.core.teams import Team


class Ishmem:
    def __init__(self, npes: int, node_size: int = None, **kw):
        self.ctx, self.heap = context.init(npes, node_size, **kw)
        self._psync = self.heap.malloc((), "int32")

    # ------------------------------------------------------------ setup
    def ishmem_n_pes(self) -> int:
        return self.ctx.npes

    def ishmem_team_n_pes(self, team: Team) -> int:
        return team.size

    def ishmem_malloc(self, shape, dtype):
        return self.heap.malloc(shape, dtype)

    def ishmem_calloc(self, shape, dtype):
        return self.heap.calloc(shape, dtype)

    def ishmem_free(self, ptr):
        self.heap.free(ptr)

    # ------------------------------------------------------------ RMA
    def ishmem_put(self, dest, value, pe, **kw):
        self.heap = rma.put(self.ctx, self.heap, dest, value, pe, **kw)

    def ishmem_get(self, src, pe, **kw):
        return rma.get(self.ctx, self.heap, src, pe, **kw)

    def ishmem_p(self, dest, scalar, pe):
        self.heap = rma.p(self.ctx, self.heap, dest, scalar, pe)

    def ishmem_g(self, src, pe):
        return rma.g(self.ctx, self.heap, src, pe)

    def ishmem_iput(self, dest, value, pe, **kw):
        self.heap = rma.iput(self.ctx, self.heap, dest, value, pe, **kw)

    def ishmem_put_nbi(self, dest, value, pe, **kw):
        self.heap = rma.put_nbi(self.ctx, self.heap, dest, value, pe, **kw)

    def ishmem_get_nbi(self, src, pe, **kw):
        return rma.get_nbi(self.ctx, self.heap, src, pe, **kw)

    def ishmem_quiet(self, proxy=None):
        self.heap = rma.quiet(self.ctx, self.heap, proxy=proxy)

    def ishmem_fence(self):
        self.heap = rma.fence(self.ctx, self.heap)

    def ishmem_pending_ops(self) -> int:
        """Deferred (not yet completed) op count — 0 right after quiet."""
        return len(self.ctx.pending)

    # device extensions (§III-F)
    def ishmemx_put_work_group(self, dest, value, pe, work_group_size=128):
        self.heap = rma.put(self.ctx, self.heap, dest, value, pe,
                            work_items=work_group_size)

    def ishmemx_get_work_group(self, src, pe, work_group_size=128):
        return rma.get(self.ctx, self.heap, src, pe,
                       work_items=work_group_size)

    # ------------------------------------------------------------ AMO
    def ishmem_atomic_fetch_add(self, ptr, value, pe):
        self.heap, old = amo.fetch_add(self.ctx, self.heap, ptr, value, pe)
        return old

    def ishmem_atomic_inc(self, ptr, pe):
        self.heap = amo.inc(self.ctx, self.heap, ptr, pe)

    def ishmem_atomic_compare_swap(self, ptr, cond, value, pe):
        self.heap, old = amo.compare_swap(self.ctx, self.heap, ptr, cond,
                                          value, pe)
        return old

    def ishmem_atomic_fetch(self, ptr, pe):
        return amo.fetch(self.ctx, self.heap, ptr, pe)

    def ishmem_atomic_set(self, ptr, value, pe):
        self.heap = amo.set_(self.ctx, self.heap, ptr, value, pe)

    def ishmem_atomic_add_nbi(self, ptr, value, pe):
        self.heap = amo.add_nbi(self.ctx, self.heap, ptr, value, pe)

    # ------------------------------------------------------------ signal
    def ishmem_put_signal(self, dest, value, sig, signal_val, sig_op, pe):
        self.heap = signal.put_signal(self.ctx, self.heap, dest, value, sig,
                                      signal_val, sig_op, pe)

    def ishmem_put_signal_nbi(self, dest, value, sig, signal_val, sig_op, pe):
        self.heap = signal.put_signal_nbi(self.ctx, self.heap, dest, value,
                                          sig, signal_val, sig_op, pe)

    def ishmem_signal_wait_until(self, sig, pe, cmp, value):
        self.heap, cur, ok = signal.signal_wait_until(
            self.ctx, self.heap, sig, pe, cmp, value)
        return cur, ok

    # ------------------------------------------------------------ collectives
    def _team(self, team):
        return team or self.ctx.team_world

    def ishmem_team_sync(self, team=None):
        self.heap, sat = collectives.sync(self.ctx, self.heap, self._psync,
                                          self._team(team))
        return sat

    def ishmem_barrier_all(self):
        self.heap, sat = collectives.barrier(self.ctx, self.heap,
                                             self._psync, self.ctx.team_world)
        return sat

    def ishmem_broadcast(self, ptr, root, team=None, **kw):
        self.heap = collectives.broadcast(self.ctx, self.heap, ptr, root,
                                          self._team(team), **kw)

    def ishmem_fcollect(self, dest, src, team=None, **kw):
        self.heap = collectives.fcollect(self.ctx, self.heap, dest, src,
                                         self._team(team), **kw)

    def ishmem_sum_reduce(self, dest, src, team=None, **kw):
        self.heap = collectives.reduce(self.ctx, self.heap, dest, src, "sum",
                                       self._team(team), **kw)

    def ishmem_max_reduce(self, dest, src, team=None, **kw):
        self.heap = collectives.reduce(self.ctx, self.heap, dest, src, "max",
                                       self._team(team), **kw)

    def ishmem_alltoall(self, dest, src, team=None, **kw):
        self.heap = collectives.alltoall(self.ctx, self.heap, dest, src,
                                         self._team(team), **kw)

    # work_group collective extensions
    def ishmemx_broadcast_work_group(self, ptr, root, team=None,
                                     work_group_size=128):
        self.ishmem_broadcast(ptr, root, team, work_items=work_group_size)

    def ishmemx_fcollect_work_group(self, dest, src, team=None,
                                    work_group_size=128):
        self.ishmem_fcollect(dest, src, team, work_items=work_group_size)

    def ishmemx_sum_reduce_work_group(self, dest, src, team=None,
                                      work_group_size=128):
        self.ishmem_sum_reduce(dest, src, team, work_items=work_group_size)

    def ishmemx_barrier_all_work_group(self, work_group_size=128):
        return self.ishmem_barrier_all()
