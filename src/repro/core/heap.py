"""Device-resident symmetric heap (paper §III-E).

The PGAS address space is modeled as per-dtype pools of shape
``(npes, words)``: every PE sees an identically laid-out region (symmetric),
and a ``SymPtr`` (dtype, offset, shape) is valid at *every* PE — exactly the
OpenSHMEM symmetric-heap contract.  Allocation metadata lives host-side (the
paper: "memory management APIs are host-only"); data updates are functional.

On real hardware the ``npes`` axis is the mesh: each PE owns its row, and the
kernels in ``repro.kernels`` move rows across chips.  On CPU the whole array
is materialized, which makes every op testable against a numpy oracle.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

ALIGN = 128  # lane-aligned allocations (TPU minor dim = 128)


class SymPtr(NamedTuple):
    dtype: str
    offset: int
    shape: tuple

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= int(s)
        return n

    @property
    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize

    def index(self, i: int) -> "SymPtr":
        """Pointer to element i of a flattened buffer (for AMOs)."""
        if not 0 <= i < self.size:
            raise IndexError(i)
        return SymPtr(self.dtype, self.offset + i, ())


@dataclasses.dataclass
class SymmetricHeap:
    """Functional symmetric heap.  Mutating ops return a new heap."""

    npes: int
    pools: dict                    # dtype str -> (npes, words) jnp array
    _cursor: dict = dataclasses.field(default_factory=dict)
    _free: dict = dataclasses.field(default_factory=dict)
    words_per_pool: int = 1 << 20

    # ----------------------------------------------------------- allocation
    def malloc(self, shape, dtype) -> SymPtr:
        """shmem_malloc: symmetric, collective over all PEs (host-only API).

        Contents of a reused free-list region are UNDEFINED (the OpenSHMEM
        contract); use :meth:`calloc` for guaranteed zeros."""
        # canonicalize (JAX without x64: 64-bit symmetric objects narrow to
        # 32-bit — documented TPU adaptation; TPUs natively prefer 32-bit)
        dt = jax.dtypes.canonicalize_dtype(jnp.dtype(dtype)).name
        shape = tuple(int(s) for s in shape)
        n = 1
        for s in shape:
            n *= s
        n_aligned = max(ALIGN, -(-n // ALIGN) * ALIGN)
        # first-fit over the free list
        for i, (off, sz) in enumerate(self._free.get(dt, [])):
            if sz >= n_aligned:
                self._free[dt].pop(i)
                if sz > n_aligned:
                    self._free[dt].append((off + n_aligned, sz - n_aligned))
                return SymPtr(dt, off, shape)
        cur = self._cursor.get(dt, 0)
        if dt not in self.pools:
            self.pools[dt] = jnp.zeros((self.npes, self.words_per_pool),
                                       jnp.dtype(dt))
        if cur + n_aligned > self.pools[dt].shape[1]:
            # grow the pool (doubling)
            new_words = max(self.pools[dt].shape[1] * 2,
                            cur + n_aligned)
            pad = jnp.zeros((self.npes, new_words - self.pools[dt].shape[1]),
                            jnp.dtype(dt))
            self.pools[dt] = jnp.concatenate([self.pools[dt], pad], axis=1)
        self._cursor[dt] = cur + n_aligned
        return SymPtr(dt, cur, shape)

    def calloc(self, shape, dtype) -> SymPtr:
        """shmem_calloc: like malloc but the region reads zero at every PE.

        malloc may hand back a reused free-list region still holding a freed
        buffer's bytes, so the whole aligned span is explicitly zeroed here.
        The pool update mutates this heap in place — allocation is a host-only
        collective, not a one-sided data op, so the functional-update rule for
        data movement does not apply (snapshots taken via replace_pool/write
        keep their own pools dict and are unaffected)."""
        ptr = self.malloc(shape, dtype)
        n_aligned = max(ALIGN, -(-ptr.size // ALIGN) * ALIGN)
        pool = self.pools[ptr.dtype]
        self.pools[ptr.dtype] = pool.at[
            :, ptr.offset:ptr.offset + n_aligned].set(0)
        return ptr

    def free(self, ptr: SymPtr) -> None:
        """Return the aligned span to the free list, coalescing with adjacent
        free entries so repeated alloc/free cycles don't fragment the pool."""
        n = max(ALIGN, -(-ptr.size // ALIGN) * ALIGN)
        entries = sorted(self._free.setdefault(ptr.dtype, [])
                         + [(ptr.offset, n)])
        merged = [entries[0]]
        for off, sz in entries[1:]:
            last_off, last_sz = merged[-1]
            if last_off + last_sz == off:
                merged[-1] = (last_off, last_sz + sz)
            else:
                merged.append((off, sz))
        self._free[ptr.dtype] = merged

    # ----------------------------------------------------------- accounting
    def stats(self) -> dict:
        """Allocator accounting: bytes in use / free / reserved plus a
        fragmentation index per dtype pool (0 = one free extent, ->1 = free
        space shattered across many extents).  Consumed by the paged KV pool
        and the serving benchmarks."""
        per_dtype = {}
        tot_used = tot_free = tot_reserved = 0
        for dt, pool in self.pools.items():
            item = jnp.dtype(dt).itemsize
            cursor = self._cursor.get(dt, 0)
            free_spans = self._free.get(dt, [])
            free_words = sum(sz for _, sz in free_spans)
            largest = max((sz for _, sz in free_spans), default=0)
            used_words = cursor - free_words
            frag = 1.0 - largest / free_words if free_words else 0.0
            per_dtype[dt] = {
                "bytes_in_use": used_words * item,
                "bytes_free": free_words * item,
                "bytes_reserved": cursor * item,
                "capacity_bytes": pool.shape[1] * item,
                "free_extents": len(free_spans),
                "largest_free_bytes": largest * item,
                "fragmentation": frag,
            }
            tot_used += used_words * item
            tot_free += free_words * item
            tot_reserved += cursor * item
        return {
            "npes": self.npes,
            "bytes_in_use": tot_used,
            "bytes_free": tot_free,
            "bytes_reserved": tot_reserved,
            "pools": per_dtype,
        }

    # ----------------------------------------------------------- access
    def read(self, ptr: SymPtr, pe) -> jnp.ndarray:
        """Local load of the buffer as seen at PE ``pe``."""
        flat = jax.lax.dynamic_slice(
            self.pools[ptr.dtype][pe], (ptr.offset,), (max(ptr.size, 1),))
        return flat[: ptr.size].reshape(ptr.shape)

    def write(self, ptr: SymPtr, pe, value) -> "SymmetricHeap":
        value = jnp.asarray(value, jnp.dtype(ptr.dtype)).reshape((ptr.size,))
        pool = self.pools[ptr.dtype].at[pe, ptr.offset:ptr.offset + ptr.size] \
            .set(value)
        return self.replace_pool(ptr.dtype, pool)

    def read_all(self, ptr: SymPtr) -> jnp.ndarray:
        """(npes, *shape) view of the buffer across every PE."""
        flat = self.pools[ptr.dtype][:, ptr.offset:ptr.offset + ptr.size]
        return flat.reshape((self.npes,) + ptr.shape)

    def write_all(self, ptr: SymPtr, values) -> "SymmetricHeap":
        values = jnp.asarray(values, jnp.dtype(ptr.dtype)).reshape(
            (self.npes, ptr.size))
        pool = self.pools[ptr.dtype].at[:, ptr.offset:ptr.offset + ptr.size] \
            .set(values)
        return self.replace_pool(ptr.dtype, pool)

    def replace_pool(self, dt, pool) -> "SymmetricHeap":
        pools = dict(self.pools)
        pools[dt] = pool
        new = SymmetricHeap(self.npes, pools, dict(self._cursor),
                            {k: list(v) for k, v in self._free.items()},
                            self.words_per_pool)
        return new


def create(npes: int, words_per_pool: int = 1 << 20) -> SymmetricHeap:
    """shmemx_heap_create analogue: device-resident symmetric heap."""
    return SymmetricHeap(npes, {}, {}, {}, words_per_pool)
