"""Atomic Memory Operations on symmetric scalars (OpenSHMEM 1.5 AMO set).

The paper's AMOs are single-element remote atomics over Xe-Link (no
``work_group`` variants — "scalar operations that would not benefit from
group optimizations").  On TPU the device-side analogue is leader-issued
(one program per chip, see DESIGN.md); semantically they are linearizable
read-modify-writes on one element of the symmetric heap, which is what this
module implements (and what the property tests check under permuted
schedules).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import pending as pending_mod
from repro.core.heap import SymPtr, SymmetricHeap


def _rmw(ctx, heap, ptr: SymPtr, pe, fn, opname, src_pe=0):
    # a blocking atomic linearizes after everything already queued on this
    # element: complete pending ops first (RMW reads, so nothing may drop)
    heap = ctx.pending.resolve_store_conflicts(ctx, heap, ptr, pe,
                                               covers=False)
    old = heap.read(ptr, pe).reshape(())
    new = fn(old)
    tier = ctx.tier(src_pe, pe)
    path = "proxy" if tier == "dcn" else "direct"
    ctx.record(f"amo_{opname}", jnp.dtype(ptr.dtype).itemsize, path, tier, 1)
    return heap.write(ptr, pe, new), old


def _rmw_nbi(ctx, heap, ptr: SymPtr, pe, fn, opname, src_pe=0, delta=None):
    """Deferred (non-fetching) AMO: the read-modify-write is queued and runs
    at the next completion point.  Fetching AMOs cannot defer — their return
    value is the pre-image — which mirrors the OpenSHMEM 1.5 nbi AMO set.
    Adjacent queued adds on the same element merge (delta sums compose)."""
    tier = ctx.tier(src_pe, pe)
    ctx.record(f"amo_{opname}(pending)", jnp.dtype(ptr.dtype).itemsize,
               "proxy" if tier == "dcn" else "direct", tier, 1, t_sec=0.0)
    ctx.pending.submit(pending_mod.AMO, f"amo_{opname}", ptr, pe, tier,
                       apply=fn, delta=delta,
                       marker=ctx.ledger[-1] if ctx.ledger else None)
    return heap


def fetch(ctx, heap, ptr, pe, *, src_pe=0):
    heap2, old = _rmw(ctx, heap, ptr, pe, lambda o: o, "fetch", src_pe)
    return old


def set_(ctx, heap, ptr, value, pe, *, src_pe=0):
    heap2, _ = _rmw(ctx, heap, ptr, pe,
                    lambda o: jnp.asarray(value, o.dtype), "set", src_pe)
    return heap2


def swap(ctx, heap, ptr, value, pe, *, src_pe=0):
    return _rmw(ctx, heap, ptr, pe,
                lambda o: jnp.asarray(value, o.dtype), "swap", src_pe)


def compare_swap(ctx, heap, ptr, cond, value, pe, *, src_pe=0):
    def fn(old):
        return jnp.where(old == jnp.asarray(cond, old.dtype),
                         jnp.asarray(value, old.dtype), old)
    return _rmw(ctx, heap, ptr, pe, fn, "cswap", src_pe)


def fetch_add(ctx, heap, ptr, value, pe, *, src_pe=0):
    return _rmw(ctx, heap, ptr, pe,
                lambda o: o + jnp.asarray(value, o.dtype), "fadd", src_pe)


def add(ctx, heap, ptr, value, pe, *, src_pe=0):
    heap2, _ = fetch_add(ctx, heap, ptr, value, pe, src_pe=src_pe)
    return heap2


def fetch_inc(ctx, heap, ptr, pe, *, src_pe=0):
    return fetch_add(ctx, heap, ptr, 1, pe, src_pe=src_pe)


def inc(ctx, heap, ptr, pe, *, src_pe=0):
    return add(ctx, heap, ptr, 1, pe, src_pe=src_pe)


# ------------------------------------------------------------------ nbi AMOs


def add_nbi(ctx, heap, ptr, value, pe, *, src_pe=0):
    """Deferred shmem_atomic_add: lands at quiet/barrier; queue-adjacent adds
    to the same element coalesce into one wire atomic."""
    return _rmw_nbi(ctx, heap, ptr, pe,
                    lambda o: o + jnp.asarray(value, o.dtype), "add_nbi",
                    src_pe, delta=value)


def inc_nbi(ctx, heap, ptr, pe, *, src_pe=0):
    return add_nbi(ctx, heap, ptr, 1, pe, src_pe=src_pe)


def set_nbi(ctx, heap, ptr, value, pe, *, src_pe=0):
    return _rmw_nbi(ctx, heap, ptr, pe,
                    lambda o: jnp.asarray(value, o.dtype), "set_nbi", src_pe)


def fetch_and(ctx, heap, ptr, value, pe, *, src_pe=0):
    return _rmw(ctx, heap, ptr, pe,
                lambda o: o & jnp.asarray(value, o.dtype), "fand", src_pe)


def fetch_or(ctx, heap, ptr, value, pe, *, src_pe=0):
    return _rmw(ctx, heap, ptr, pe,
                lambda o: o | jnp.asarray(value, o.dtype), "for", src_pe)


def fetch_xor(ctx, heap, ptr, value, pe, *, src_pe=0):
    return _rmw(ctx, heap, ptr, pe,
                lambda o: o ^ jnp.asarray(value, o.dtype), "fxor", src_pe)
