"""Signaling ops: put_signal (+work_group) and signal_wait_until.

``put_signal`` is the paper's ordered "data then flag" primitive: the data put
completes at the target before the signal word updates (on TPU: the remote DMA
completion semaphore gates the signal store).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import rma

SIGNAL_SET = 0
SIGNAL_ADD = 1

_CMP = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
}


def put_signal(ctx, heap, dest, value, sig_ptr, signal, sig_op, dst_pe, *,
               src_pe: int = 0, work_items: int = 1):
    """ishmem_put_signal / ishmemx_put_signal_work_group."""
    heap = rma.put(ctx, heap, dest, value, dst_pe, src_pe=src_pe,
                   work_items=work_items)
    old = heap.read(sig_ptr, dst_pe).reshape(())
    new = (jnp.asarray(signal, old.dtype) if sig_op == SIGNAL_SET
           else old + jnp.asarray(signal, old.dtype))
    ctx.record("signal", jnp.dtype(sig_ptr.dtype).itemsize, "direct",
               ctx.tier(src_pe, dst_pe), 1)
    return heap.write(sig_ptr, dst_pe, new)


def signal_fetch(ctx, heap, sig_ptr, pe):
    return heap.read(sig_ptr, pe).reshape(())


def signal_wait_until(ctx, heap, sig_ptr, pe, cmp: str, value):
    """Local wait; in the sequential simulation this is a satisfiability check
    (the caller drives progress).  Returns the satisfied signal value."""
    cur = heap.read(sig_ptr, pe).reshape(())
    ok = _CMP[cmp](cur, jnp.asarray(value, cur.dtype))
    ctx.record("signal_wait", 0, "direct", "local", 1)
    return cur, ok
