"""Signaling ops: put_signal (+work_group, +nbi) and signal_wait_until.

``put_signal`` is the paper's ordered "data then flag" primitive: the data put
completes at the target before the signal word updates (on TPU: the remote DMA
completion semaphore gates the signal store).

``put_signal_nbi`` defers BOTH halves onto the completion queue as an ordered
pair: within a flush the data transfer executes before the signal update (the
signal op is a non-coalescible queue entry submitted immediately after its
data put, so write combining can never lift a later put across it).
``signal_wait_until`` is the completion point that makes the pair observable:
it forces the queue prefix the waited signal word depends on.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import pending as pending_mod, rma

SIGNAL_SET = 0
SIGNAL_ADD = 1

_CMP = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
}


def _sig_apply(signal, sig_op):
    def apply(old):
        sv = jnp.asarray(signal, old.dtype)
        return sv if sig_op == SIGNAL_SET else old + sv
    return apply


def put_signal(ctx, heap, dest, value, sig_ptr, signal, sig_op, dst_pe, *,
               src_pe: int = 0, work_items: int = 1):
    """ishmem_put_signal / ishmemx_put_signal_work_group."""
    heap = rma.put(ctx, heap, dest, value, dst_pe, src_pe=src_pe,
                   work_items=work_items)
    # the blocking flag update linearizes after queued ops on the flag word
    heap = ctx.pending.resolve_store_conflicts(ctx, heap, sig_ptr, dst_pe,
                                               covers=False)
    old = heap.read(sig_ptr, dst_pe).reshape(())
    new = _sig_apply(signal, sig_op)(old)
    ctx.record("signal", jnp.dtype(sig_ptr.dtype).itemsize, "direct",
               ctx.tier(src_pe, dst_pe), 1)
    return heap.write(sig_ptr, dst_pe, new)


def put_signal_nbi(ctx, heap, dest, value, sig_ptr, signal, sig_op, dst_pe, *,
                   src_pe: int = 0, work_items: int = 1):
    """ishmem_put_signal_nbi: deferred data put + deferred signal update,
    ordered data-before-flag inside the flush."""
    heap = rma.put_nbi(ctx, heap, dest, value, dst_pe, src_pe=src_pe,
                       work_items=work_items)
    tier = ctx.tier(src_pe, dst_pe)
    ctx.record("signal(pending)", jnp.dtype(sig_ptr.dtype).itemsize,
               "direct", tier, 1, t_sec=0.0)
    ctx.pending.submit(pending_mod.SIGNAL, "signal", sig_ptr, dst_pe, tier,
                       src_pe=src_pe, apply=_sig_apply(signal, sig_op),
                       marker=ctx.ledger[-1] if ctx.ledger else None)
    return heap


def signal_fetch(ctx, heap, sig_ptr, pe):
    return heap.read(sig_ptr, pe).reshape(())


def signal_wait_until(ctx, heap, sig_ptr, pe, cmp: str, value):
    """Local wait; in the sequential simulation this is a satisfiability check
    (the caller drives progress).  Completion forcing: any pending op the
    waited word depends on — the last queued update of (sig_ptr, pe) and
    everything submitted before it, which covers the data half of a
    put_signal_nbi — is flushed first.  Returns (heap, value, satisfied)."""
    heap = ctx.pending.flush_dependency(ctx, heap, sig_ptr, pe)
    cur = heap.read(sig_ptr, pe).reshape(())
    ok = _CMP[cmp](cur, jnp.asarray(value, cur.dtype))
    ctx.record("signal_wait", 0, "direct", "local", 1)
    return heap, cur, ok
