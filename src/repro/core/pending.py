"""Deferred-completion engine: the queue behind every non-blocking op.

This is the part of the runtime the paper's overlap story lives in (§III-F,
§IV): ``put_nbi``/``get_nbi``/``put_signal_nbi``/deferred AMOs do *not* touch
the target heap row at call time.  They append a :class:`PendingOp` to the
per-context :class:`CompletionQueue`, and the row changes only when a
completion point flushes the queue:

- ``quiet``  — flushes everything (full completion + memory ordering);
- ``barrier``— quiet + sync (``collectives.barrier``);
- ``signal_wait_until`` — flushes the queue *prefix* up to the op the waited
  signal word depends on (put_signal orders data before flag);
- a blocking ``put`` to the same (ptr, pe) supersedes pending nbi puts there
  (the simulator linearizes the unordered race as program order).

``fence`` does not flush: it closes the current *epoch*.  Ops in different
epochs may never coalesce or reorder past each other — exactly the OpenSHMEM
fence contract (ordering without completion).

Write combining happens at flush time: runs of queue-adjacent puts with the
same (pe, dtype, epoch) whose offset ranges are contiguous (or identical —
last writer wins) merge into ONE transfer, and only then does the cutover
engine pick a path for the *coalesced* size.  The telemetry the autotuner
fits therefore sees the transfer sizes the wire would see, not the
application's call sizes.  ``ISHMEM_NBI_COALESCE=0`` (``Tuning.nbi_coalesce``)
turns combining off for A/B runs.

Proxy unification: dcn-tier pending ops are the same :class:`PendingOp`
records; at flush they are either submitted through a caller-provided
:class:`~repro.core.proxy.HostProxy` (ring messages + one drain — the real
reverse-offload machinery) or executed via the modeled proxy path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax.numpy as jnp

from repro.core import cutover
from repro.core.heap import SymPtr

# PendingOp kinds
PUT, GET, AMO, SIGNAL = "put", "get", "amo", "signal"


@dataclasses.dataclass
class PendingOp:
    """One deferred operation, unified across RMA/AMO/signal/proxy layers."""
    kind: str                      # PUT | GET | AMO | SIGNAL
    op: str                        # ledger name ("put_nbi", "amo_add_nbi", ...)
    ptr: SymPtr
    pe: int
    tier: str
    epoch: int
    seq: int
    src_pe: int = -1               # initiating PE (-1: unknown/host driver)
    work_items: int = 1
    value: Optional[object] = None          # PUT: flat payload row
    apply: Optional[Callable] = None        # AMO/SIGNAL: old -> new
    delta: Optional[object] = None          # AMO add: mergeable increment
    marker: Optional[object] = None         # the "(pending)" trace OpRecord

    @property
    def end(self) -> int:
        return self.ptr.offset + self.ptr.size


def write_row(ctx, heap, ptr: SymPtr, pe, flat_value):
    """Direct-path row store; routes through the Pallas work-group copy
    kernel when the context asks for kernel-backed copies."""
    if ctx.use_kernels:
        from repro.kernels import ops as kops
        pool = heap.pools[ptr.dtype]
        row = kops.copy_into(pool[pe], flat_value, ptr.offset)
        return heap.replace_pool(ptr.dtype, pool.at[pe].set(row))
    return heap.write(ptr, pe, flat_value)


@dataclasses.dataclass
class FlushStats:
    """Per-queue lifetime counters (coalescing ratio = ops / transfers)."""
    submitted: int = 0
    flushed_ops: int = 0
    transfers: int = 0
    flushed_bytes: int = 0         # sum of op sizes completed
    transfer_bytes: int = 0        # sum of wire transfer sizes issued
    flushes: int = 0
    cancelled: int = 0             # ops cancelled-with-error (dead peer)

    def coalescing_ratio(self) -> float:
        return self.flushed_ops / self.transfers if self.transfers else 1.0


class CompletionQueue:
    """Per-context FIFO of deferred ops with epoch-scoped write combining."""

    def __init__(self):
        self.ops: List[PendingOp] = []
        self.epoch: int = 0
        self._seq: int = 0
        self.stats = FlushStats()
        # cancel-with-error ledger: one record per pending op that could
        # not complete because its peer died (DESIGN.md §14) — quiet()
        # completes instead of wedging, and the caller reads the errors
        self.errors: List[dict] = []

    # ------------------------------------------------------------- submit
    def submit(self, kind: str, op: str, ptr: SymPtr, pe: int, tier: str, *,
               src_pe: int = -1, work_items: int = 1, value=None, apply=None,
               delta=None, marker=None) -> PendingOp:
        rec = PendingOp(kind=kind, op=op, ptr=ptr, pe=int(pe), tier=tier,
                        epoch=self.epoch, seq=self._seq, src_pe=int(src_pe),
                        work_items=work_items, value=value, apply=apply,
                        delta=delta, marker=marker)
        self._seq += 1
        self.ops.append(rec)
        self.stats.submitted += 1
        return rec

    def fence(self) -> None:
        """Close the current epoch: later ops may not coalesce with or
        reorder past anything already queued."""
        if any(o.epoch == self.epoch for o in self.ops):
            self.epoch += 1

    def supersede(self, ptr: SymPtr, pe: int) -> int:
        """A blocking store to (ptr, pe) wins the unordered race against
        pending nbi puts it fully covers: drop them.  Returns the number of
        ops dropped."""
        pe = int(pe)
        lo, hi = ptr.offset, ptr.offset + ptr.size
        keep, dropped = [], 0
        for o in self.ops:
            if (o.kind == PUT and o.pe == pe and o.ptr.dtype == ptr.dtype
                    and lo <= o.ptr.offset and o.end <= hi):
                _retag_marker(o, "dropped")
                dropped += 1
            else:
                keep.append(o)
        self.ops = keep
        return dropped

    def resolve_store_conflicts(self, ctx, heap, ptr: SymPtr, pe: int, *,
                                covers: bool = True):
        """Linearize a blocking store to (ptr, pe) as program order: pending
        puts it fully covers are superseded (dropped), and pending ops that
        only *partially* overlap the range are completed first (completing a
        queue prefix early is always legal), so the blocking store lands
        last either way.  ``covers=False`` is for read-modify-write stores
        (iput): nothing may be dropped, every overlapping op completes
        first.  Returns the (possibly flushed) heap."""
        pe = int(pe)
        lo, hi = ptr.offset, ptr.offset + max(1, ptr.size)
        last_flush = None
        for i, o in enumerate(self.ops):
            if (o.pe == pe and o.ptr.dtype == ptr.dtype
                    and o.ptr.offset < hi and lo < o.end
                    and not (covers and o.kind == PUT
                             and lo <= o.ptr.offset and o.end <= hi)):
                last_flush = i
        if last_flush is not None:
            heap = self.flush_prefix(ctx, heap, last_flush)
        if covers:
            self.supersede(ptr, pe)
        return heap

    # -------------------------------------------------------------- query
    def __len__(self) -> int:
        return len(self.ops)

    def pending_for(self, ptr: SymPtr, pe: int) -> Optional[int]:
        """Index (into ops) of the LAST pending op whose target overlaps one
        element at (ptr, pe) — the dependency ``signal_wait_until`` forces."""
        pe = int(pe)
        last = None
        for i, o in enumerate(self.ops):
            if (o.pe == pe and o.ptr.dtype == ptr.dtype
                    and o.ptr.offset < ptr.offset + max(1, ptr.size)
                    and ptr.offset < o.end):
                last = i
        return last

    def pending_first(self, ptr: SymPtr, pe: int) -> Optional[int]:
        """Index (into ops) of the FIRST pending op whose target overlaps one
        element at (ptr, pe).  A device-side ``signal_wait_until`` spins on
        the word, so it only needs to force the MINIMAL prefix that can
        advance the signal — one pending update at a time — instead of the
        whole stream the host-side wait (``pending_for``) completes."""
        pe = int(pe)
        for i, o in enumerate(self.ops):
            if (o.pe == pe and o.ptr.dtype == ptr.dtype
                    and o.ptr.offset < ptr.offset + max(1, ptr.size)
                    and ptr.offset < o.end):
                return i
        return None

    # ------------------------------------------------------ fault handling
    @staticmethod
    def _dead_pes(ctx):
        fault = getattr(ctx, "fault", None)
        return fault.dead_pes if fault is not None else ()

    @staticmethod
    def _touches(op: PendingOp, pes) -> bool:
        return op.pe in pes or op.src_pe in pes

    def cancel_pe(self, ctx, pe: int) -> int:
        """Cancel-with-error every queued op touching ``pe`` as source or
        destination (the peer died: its heap row is garbage and nothing may
        land there or be fetched from there).  Each cancelled op leaves a
        structured record on ``self.errors``; later quiet()/flush() calls
        then complete normally instead of wedging on undeliverable traffic.
        Returns the number of ops cancelled."""
        pes = {int(pe)}
        keep, dead = [], []
        for o in self.ops:
            (dead if self._touches(o, pes) else keep).append(o)
        self.ops = keep
        for o in dead:
            self._cancel(ctx, o, f"pe {int(pe)} died")
        return len(dead)

    def _cancel(self, ctx, op: PendingOp, reason: str) -> None:
        self.errors.append({
            "op": op.op, "kind": op.kind, "pe": op.pe, "src_pe": op.src_pe,
            "tier": op.tier, "dtype": op.ptr.dtype, "offset": op.ptr.offset,
            "nbytes": op.ptr.nbytes, "reason": reason,
        })
        self.stats.cancelled += 1
        _retag_marker(op, "cancelled")
        tracer = getattr(ctx, "tracer", None)
        if tracer is not None and tracer.enabled:
            tracer.instant("op_cancelled", "cq", "core", "cq",
                           op=op.op, pe=op.pe, reason=reason)

    def _partition_limit(self, ctx, ops) -> Optional[int]:
        """Index of the first dcn-tier op in ``ops`` while the proxy ring is
        partitioned — nothing at or past it may complete (cross-pod traffic
        is neither lost nor delivered until the partition heals).  None when
        the ring is healthy."""
        fault = getattr(ctx, "fault", None)
        if fault is None or not fault.dcn_down:
            return None
        for i, o in enumerate(ops):
            if o.tier == "dcn":
                return i
        return None

    # -------------------------------------------------------------- flush
    def flush(self, ctx, heap, *, proxy=None):
        """Complete every pending op, in order, coalescing within epochs.
        Returns the new heap.  While the proxy ring is partitioned, only
        the queue prefix before the first cross-pod op completes — the
        rest stays pending until the partition heals."""
        limit = self._partition_limit(ctx, self.ops)
        if limit is not None:
            return self._flush_ops(ctx, heap, self.ops[:limit], proxy=proxy,
                                   keep_from=limit)
        return self._flush_ops(ctx, heap, self.ops, proxy=proxy,
                               keep_from=len(self.ops))

    def flush_prefix(self, ctx, heap, upto: int, *, proxy=None):
        """Complete ops[0..upto] (inclusive), keep the rest pending.
        Flushing a queue prefix in order is always a legal completion
        schedule, so partial completion never violates fence epochs."""
        limit = self._partition_limit(ctx, self.ops[:upto + 1])
        if limit is not None:
            upto = limit - 1                   # clamp below the partition
        return self._flush_ops(ctx, heap, self.ops[:upto + 1], proxy=proxy,
                               keep_from=upto + 1)

    def flush_dependency(self, ctx, heap, ptr: SymPtr, pe: int, *,
                         proxy=None):
        """Complete the queue prefix the word at (ptr, pe) depends on: the
        last pending op overlapping it and everything submitted before.

        This is the one completion primitive streamed migrations need: each
        chunk of a chunked prefill ends in a ``put_signal_nbi`` on the same
        slot signal word, so flushing the signal's dependency after chunk k
        lands exactly chunks [0..k] — data before each chunk's flag, later
        chunks (and unrelated requests' traffic) stay deferred.  A no-op
        when nothing pending targets the word."""
        dep = self.pending_for(ptr, pe)
        if dep is not None:
            heap = self.flush_prefix(ctx, heap, dep, proxy=proxy)
        return heap

    def _flush_ops(self, ctx, heap, ops, *, proxy, keep_from):
        if not ops:
            return heap
        remainder = self.ops[keep_from:]
        dead = self._dead_pes(ctx)
        if dead:
            live = []
            for o in ops:
                if self._touches(o, dead):
                    self._cancel(ctx, o, "peer died with op in flight")
                else:
                    live.append(o)
            ops = live
            if not ops:
                self.ops = remainder
                return heap
        coalesce = getattr(ctx.tuning, "nbi_coalesce", True)
        transfers = _combine(ops) if coalesce else [[o] for o in ops]
        tracer = getattr(ctx, "tracer", None)
        traced = tracer is not None and tracer.enabled
        if traced:
            tracer.begin("flush", "cq", "core", "cq",
                         ops=len(ops), transfers=len(transfers))
        undrained = False
        for group in transfers:
            if undrained and not self._routes_to_proxy(group, proxy):
                # a directly-applied op must observe every ring message
                # submitted before it — drain before leaving the proxy run
                heap = proxy.drain(heap)
                undrained = False
            heap, used_proxy = self._issue(ctx, heap, group, proxy)
            undrained = undrained or used_proxy
        if undrained:
            heap = proxy.drain(heap)
        self.stats.flushed_ops += len(ops)
        self.stats.flushed_bytes += sum(o.ptr.nbytes for o in ops)
        self.stats.transfers += len(transfers)
        self.stats.transfer_bytes += sum(
            _group_nbytes(g) for g in transfers)
        self.stats.flushes += 1
        self.ops = remainder
        for o in ops:
            _retag_marker(o, "done")
        if traced:
            tracer.end("flush", "cq", "core", "cq",
                       bytes=sum(_group_nbytes(g) for g in transfers))
            tracer.counter("cq_pending", "core", "cq",
                           pending=len(remainder))
        return heap

    @staticmethod
    def _routes_to_proxy(group, proxy) -> bool:
        return (proxy is not None and group[0].kind == PUT
                and group[0].tier == "dcn")

    # one coalesced transfer (or a single non-put op)
    def _issue(self, ctx, heap, group, proxy):
        head = group[0]
        if head.kind == GET:
            # the fetch completes now; cost accrues at the completion point
            path = "proxy" if head.tier == "dcn" else "engine"
            ctx.record(head.op, head.ptr.nbytes, path, head.tier,
                       head.work_items)
            return heap, False
        if head.kind in (AMO, SIGNAL):
            old = heap.read(head.ptr, head.pe).reshape(())
            new = old
            for o in group:                   # merged adds compose in order
                new = o.apply(new)
            path = "proxy" if head.tier == "dcn" else "direct"
            ctx.record(head.op, jnp.dtype(head.ptr.dtype).itemsize, path,
                       head.tier, head.work_items)
            return heap.write(head.ptr, head.pe, new), False
        # PUT: materialize the coalesced payload
        tracer = getattr(ctx, "tracer", None)
        traced = tracer is not None and tracer.enabled
        ptr, value = _merge_puts(group)
        if head.tier == "dcn" and proxy is not None:
            if proxy.ring_full():
                # migration storm: the ring is at capacity, so the producer
                # must wait for consumer progress.  We ARE holding the heap
                # here, so model the host proxy thread catching up (drain)
                # instead of spinning to the wedge detector — backpressure,
                # not message loss.  Draining a queue prefix early is always
                # a legal completion schedule.
                heap = proxy.drain(heap)
                proxy.backpressure += 1
                if traced:
                    tracer.instant("ring_backpressure", "cq", "core", "cq",
                                   pe=head.pe)
            proxy.put(ptr, value, head.pe)    # ring message; drained once
            if traced:
                tracer.instant("xfer", "cq", "core", "cq", path="proxy",
                               tier="dcn", nbytes=ptr.nbytes, pe=head.pe,
                               coalesced=len(group))
            return heap, True
        wi = max(o.work_items for o in group)
        if head.tier == "dcn":
            path = "proxy"
        else:
            path = cutover.choose_path(ptr.nbytes, work_items=wi,
                                       tier=head.tier, hw=ctx.hw,
                                       tuning=ctx.tuning)
        ctx.record(head.op, ptr.nbytes, path, head.tier, wi)
        if traced:
            tracer.instant("xfer", "cq", "core", "cq", path=path,
                           tier=head.tier, nbytes=ptr.nbytes, pe=head.pe,
                           work_items=wi, coalesced=len(group))
        return write_row(ctx, heap, ptr, head.pe, value), False


# ---------------------------------------------------------------------------
# write combining
# ---------------------------------------------------------------------------


def _combinable(a: PendingOp, b: PendingOp) -> bool:
    """b may join a's transfer: queue-adjacent puts, same destination row and
    epoch, and byte ranges that abut or coincide."""
    return (a.kind == PUT and b.kind == PUT
            and a.pe == b.pe and a.epoch == b.epoch
            and a.ptr.dtype == b.ptr.dtype
            and (b.ptr.offset == a.end                      # contiguous
                 or (b.ptr.offset == a.ptr.offset           # identical range:
                     and b.ptr.size == a.ptr.size)))        # last write wins


def _amo_mergeable(a: PendingOp, b: PendingOp) -> bool:
    return (a.kind == AMO and b.kind == AMO
            and a.delta is not None and b.delta is not None
            and a.pe == b.pe and a.epoch == b.epoch and a.ptr == b.ptr)


def _combine(ops: List[PendingOp]) -> List[List[PendingOp]]:
    groups: List[List[PendingOp]] = []
    for o in ops:
        if groups and (_combinable(groups[-1][-1], o)
                       or _amo_mergeable(groups[-1][-1], o)):
            groups[-1].append(o)
        else:
            groups.append([o])
    return groups


def _merge_puts(group: List[PendingOp]):
    """Fold a combinable run into one (ptr, flat_value) transfer."""
    head = group[0]
    if len(group) == 1:
        return head.ptr, head.value
    lo = min(o.ptr.offset for o in group)
    hi = max(o.end for o in group)
    dtype = head.ptr.dtype
    buf = jnp.zeros((hi - lo,), jnp.dtype(dtype))
    for o in group:                            # queue order: last write wins
        s = o.ptr.offset - lo
        buf = buf.at[s:s + o.ptr.size].set(
            jnp.asarray(o.value, jnp.dtype(dtype)).reshape((o.ptr.size,)))
    return SymPtr(dtype, lo, (hi - lo,)), buf


def _group_nbytes(group: List[PendingOp]) -> int:
    head = group[0]
    if head.kind != PUT:
        return head.ptr.nbytes
    lo = min(o.ptr.offset for o in group)
    hi = max(o.end for o in group)
    return (hi - lo) * jnp.dtype(head.ptr.dtype).itemsize


def _retag_marker(op: PendingOp, state: str) -> None:
    """Retag the op's own "(pending)" trace marker (debugging view only —
    aggregates are keyed by the flush-time records)."""
    rec = op.marker
    if rec is not None and rec.op.endswith("(pending)"):
        rec.op = rec.op[: -len("(pending)")] + f"({state})"
