"""Team collectives (paper §III-G2) with the paper's algorithm choices:

- ``sync``      — push: every PE fires an atomic increment at every teammate's
                  counter, then waits locally (pipelined fire-and-forget
                  remote atomics + cached local wait).
- ``broadcast`` / ``fcollect`` — push-style remote *stores* with the inner
                  loop over destinations (stores beat loads; load-shares all
                  links).
- ``reduce``    — small/medium: address-split across threads, each PE pulls
                  all rows with vector loads and reduces locally (duplicated
                  compute avoids inter-PE synchronization).  Large: ring
                  reduce-scatter + all-gather.
- ``alltoall``  — pairwise exchange.

Every op is functional over the heap, selects a path via the cutover engine,
and records cost on the ledger.  ``work_items`` is the SYCL work-group size
knob of the ``ishmemx_*_work_group`` variants.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cutover
from repro.core.heap import SymPtr
from repro.core.teams import Team

REDUCE_OPS = {
    "sum": (jnp.add, 0),
    "prod": (jnp.multiply, 1),
    "min": (jnp.minimum, None),
    "max": (jnp.maximum, None),
    "and": (jnp.bitwise_and, None),
    "or": (jnp.bitwise_or, None),
    "xor": (jnp.bitwise_xor, None),
}

# messages larger than this per PE use the ring algorithm for reductions
RING_REDUCE_BYTES = 1 << 20


def _team_rows(heap, ptr: SymPtr, team: Team):
    data = heap.read_all(ptr)                       # (npes, *shape)
    return data[jnp.array(team.pes())]              # (team.size, *shape)


def _scatter_team(heap, ptr: SymPtr, team: Team, values):
    data = heap.read_all(ptr)
    data = data.at[jnp.array(team.pes())].set(values)
    return heap.write_all(ptr, data)


def _path(ctx, kind, nbytes, npes, work_items):
    # thin context adapter over the single chooser in core.cutover
    return cutover.choose_collective_path(kind, nbytes, npes,
                                          work_items=work_items, tier="ici",
                                          hw=ctx.hw, tuning=ctx.tuning)


def _record(ctx, kind, nbytes, team, path, work_items):
    base_kind = kind.split("[")[0]
    t = cutover.t_collective(base_kind, nbytes, team.size,
                             work_items=work_items, path=path, hw=ctx.hw)
    ctx.record(kind, nbytes, path, "ici", work_items, t_sec=t)


# ---------------------------------------------------------------------------
# synchronization
# ---------------------------------------------------------------------------


def sync(ctx, heap, counter: SymPtr, team: Team, *, work_items: int = 1):
    """ishmem_team_sync: push atomic increments, local wait.

    ``counter`` is a symmetric int buffer.  Returns (heap, satisfied: bool
    array over team) — in the full simulation all waits are satisfied after
    the pushes land; the property tests drive partial schedules through
    the AMO layer instead.
    """
    rows = heap.read_all(counter)                   # (npes, 1)
    pes = jnp.array(team.pes())
    rows = rows.at[pes].add(team.size)              # team.size increments each
    heap = heap.write_all(counter, rows)
    target = rows[pes].reshape(team.size)
    satisfied = target >= team.size
    _record(ctx, "sync", 8, team, "direct", work_items)
    return heap, satisfied


def barrier(ctx, heap, counter: SymPtr, team: Team, *, work_items: int = 1):
    """barrier = quiet + sync."""
    from repro.core import rma
    heap = rma.quiet(ctx, heap)
    return sync(ctx, heap, counter, team, work_items=work_items)


# ---------------------------------------------------------------------------
# data collectives
# ---------------------------------------------------------------------------


def broadcast(ctx, heap, ptr: SymPtr, root: int, team: Team, *,
              work_items: int = 1):
    """ishmem_broadcast: root pushes its buffer to every teammate (stores,
    inner loop over destinations)."""
    path = _path(ctx, "broadcast", ptr.nbytes, team.size, work_items)
    src = heap.read(ptr, team.translate(root))
    vals = jnp.broadcast_to(src[None], (team.size,) + ptr.shape)
    heap = _scatter_team(heap, ptr, team, vals)
    _record(ctx, "broadcast", ptr.nbytes, team, path, work_items)
    return heap


def fcollect(ctx, heap, dest: SymPtr, src: SymPtr, team: Team, *,
             work_items: int = 1):
    """ishmem_fcollect (allgather): every PE pushes its src chunk into the
    right slot of every teammate's dest.  dest.size == team.size * src.size."""
    assert dest.size == team.size * src.size, "fcollect size mismatch"
    chunks = _team_rows(heap, src, team)            # (team, *src.shape)
    gathered = chunks.reshape((team.size * src.size,))
    vals = jnp.broadcast_to(gathered[None],
                            (team.size, team.size * src.size))
    heap = _scatter_team(heap, dest, team, vals.reshape(
        (team.size,) + dest.shape))
    path = _path(ctx, "fcollect", src.nbytes, team.size, work_items)
    _record(ctx, "fcollect", src.nbytes, team, path, work_items)
    return heap


def collect(ctx, heap, dest: SymPtr, src: SymPtr, nelems_per_pe, team: Team, *,
            work_items: int = 1):
    """ishmem_collect: variable contribution sizes (ragged allgather)."""
    rows = _team_rows(heap, src, team)
    parts = [rows[i, :int(nelems_per_pe[i])] for i in range(team.size)]
    gathered = jnp.concatenate(parts)
    total = int(sum(nelems_per_pe))
    assert total <= dest.size
    cur = _team_rows(heap, dest, team).reshape(team.size, dest.size)
    vals = cur.at[:, :total].set(jnp.broadcast_to(gathered[None],
                                                  (team.size, total)))
    heap = _scatter_team(heap, dest, team, vals.reshape(
        (team.size,) + dest.shape))
    path = _path(ctx, "fcollect", int(max(nelems_per_pe)) * 4, team.size,
                 work_items)
    _record(ctx, "fcollect", total * 4, team, path, work_items)
    return heap


def reduce(ctx, heap, dest: SymPtr, src: SymPtr, op: str, team: Team, *,
           work_items: int = 1):
    """ishmem_<op>_reduce.  Address-split duplicated compute (small/medium)
    or ring reduce-scatter + all-gather (large) — identical results, different
    cost/collective schedule (the kernels implement both tile computations)."""
    fn, _ = REDUCE_OPS[op]
    rows = _team_rows(heap, src, team)              # (team, *shape)
    acc = rows[0]
    for i in range(1, team.size):                   # vector binary ops
        acc = fn(acc, rows[i])
    vals = jnp.broadcast_to(acc[None], (team.size,) + src.shape)
    heap = _scatter_team(heap, dest, team, vals.reshape(
        (team.size,) + dest.shape))
    algo = "ring" if src.nbytes > RING_REDUCE_BYTES else "flat"
    path = _path(ctx, "reduce", src.nbytes, team.size, work_items)
    _record(ctx, f"reduce[{algo}]", src.nbytes, team, path, work_items)
    return heap


def alltoall(ctx, heap, dest: SymPtr, src: SymPtr, team: Team, *,
             work_items: int = 1):
    """ishmem_alltoall: PE i's chunk j lands in PE j's slot i."""
    assert src.size == dest.size and src.size % team.size == 0
    chunk = src.size // team.size
    rows = _team_rows(heap, src, team).reshape(team.size, team.size, chunk)
    out = rows.transpose(1, 0, 2).reshape(team.size, dest.size)
    heap = _scatter_team(heap, dest, team, out.reshape(
        (team.size,) + dest.shape))
    path = _path(ctx, "broadcast", chunk * 4, team.size, work_items)
    _record(ctx, "alltoall", src.nbytes, team, path, work_items)
    return heap
