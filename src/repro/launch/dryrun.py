import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (architecture x input-shape x mesh)
combination against the production meshes, with NO real allocation
(ShapeDtypeStruct inputs), and record memory/cost/collective analysis.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod both] --out experiments/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.launch import mesh as mesh_mod, policy as policy_mod, sharding, \
    shardctx
from repro.models import model
from repro.roofline import hlo_parser
from repro.train import optimizer as opt_mod, train_step as ts_mod


def _eval_struct(fn, *args):
    return jax.eval_shape(fn, *args)


def build_step(cfg, shape, mesh):
    """Returns (jitted_fn, example_args (SDS pytree), donate)."""
    specs = cfgbase.input_specs(cfg, shape)
    if shape.kind == "train":
        params_s = _eval_struct(
            lambda: model.init_params(jax.random.key(0), cfg))
        opt_s = _eval_struct(lambda: opt_mod.init(cfg.optimizer,
                                                  params_s))
        step = ts_mod.make_train_step(
            cfg, opt_mod.OptConfig(name=cfg.optimizer))
        p_sh = sharding.param_shardings(cfg, mesh, params_s)
        o_sh = sharding.opt_shardings(cfg, mesh, opt_s)
        b_sh = sharding.batch_shardings(cfg, mesh, specs)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        return fn, (params_s, opt_s, specs)
    params_s = _eval_struct(lambda: model.init_params(jax.random.key(0), cfg))
    p_sh = sharding.param_shardings(cfg, mesh, params_s)
    if shape.kind == "prefill":
        cache_s = cfgbase.cache_specs(cfg, shape.global_batch, shape.seq_len)
        c_sh = sharding.cache_shardings(cfg, mesh, cache_s)
        batch = {k: v for k, v in specs.items()}
        b_sh = sharding.batch_shardings(cfg, mesh, batch)

        def step(params, batch, cache):
            return model.prefill(params, cfg, batch, cache)

        fn = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(2,))
        return fn, (params_s, batch, cache_s)
    # decode
    cache_s = specs["cache"]
    c_sh = sharding.cache_shardings(cfg, mesh, cache_s)
    tok_sh = sharding.batch_shardings(cfg, mesh, {
        "token": specs["token"], "pos": specs["pos"]})

    def step(params, token, pos, cache):
        return model.decode_step(params, cfg, token, pos, cache)

    fn = jax.jit(step,
                 in_shardings=(p_sh, tok_sh["token"], tok_sh["pos"], c_sh),
                 out_shardings=(None, c_sh), donate_argnums=(3,))
    return fn, (params_s, specs["token"], specs["pos"], cache_s)


def model_flops(cfg, shape):
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch   # decode: 1 token per seq


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            save_hlo: bool = False, policy: "policy_mod.PerfPolicy" = None,
            tag: str = ""):
    cfg = cfgbase.get_config(arch)
    shape = cfgbase.SHAPES[shape_name]
    mesh_tag = "pod2" if multi_pod else "pod1"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag}
    if not cfgbase.shape_applicable(cfg, shape):
        rec["status"] = "skipped (full-attention arch at 500k context)"
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                    out_dir, f"{arch}.{shape_name}.{mesh_tag}{tag}.json"),
                    "w") as f:
                json.dump(rec, f, indent=2)
        return rec
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    nchips = mesh.devices.size
    t0 = time.time()
    pol = policy or policy_mod.PerfPolicy()
    rec["policy"] = dataclasses_asdict(pol)
    try:
        with mesh, shardctx.rules(sharding.activation_rules(cfg, mesh)), \
                policy_mod.use(pol):
            fn, args = build_step(cfg, shape, mesh)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        parsed = hlo_parser.analyze(hlo, num_partitions=nchips)
        rec.update({
            "status": "ok",
            "chips": nchips,
            "t_lower_s": round(t_lower, 2),
            "t_compile_s": round(t_compile, 2),
            "memory": {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes")
                if hasattr(mem, k)
            },
            "cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                              if k in ("flops", "bytes accessed",
                                       "transcendentals", "optimal_seconds")},
            "hlo_parsed": parsed,
            "model_flops": model_flops(cfg, shape),
            "params_total": cfg.param_count(),
            "params_active": cfg.param_count(active_only=True),
            "hlo_chars": len(hlo),
        })
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                    out_dir,
                    f"{arch}.{shape_name}.{mesh_tag}{tag}.hlo"), "w") as f:
                f.write(hlo)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}: OK "
              f"(compile {t_compile:.1f}s, "
              f"temp {rec['memory'].get('temp_size_in_bytes', 0)/2**30:.2f} GiB)",
              flush=True)
    except Exception as e:
        rec["status"] = f"error: {type(e).__name__}: {str(e)[:2000]}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}: "
              f"FAILED {type(e).__name__}: {str(e)[:200]}", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
                out_dir, f"{arch}.{shape_name}.{mesh_tag}{tag}.json"),
                "w") as f:
            json.dump(rec, f, indent=2, default=str)
    return rec


def dataclasses_asdict(pol):
    import dataclasses as _dc
    return _dc.asdict(pol)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", default="no", choices=["no", "yes", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--policy", action="append", default=None,
                    help="PerfPolicy override k=v (repeatable)")
    ap.add_argument("--tag", default="",
                    help="artifact suffix for policy experiments")
    args = ap.parse_args()
    pol = policy_mod.parse_overrides(args.policy) if args.policy else None

    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multipod]
    archs = cfgbase.ARCH_NAMES if args.all or not args.arch else [args.arch]
    shapes = (list(cfgbase.SHAPES) if args.all or not args.shape
              else [args.shape])
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                results.append(run_one(arch, shape, mp, args.out,
                                       args.save_hlo, policy=pol,
                                       tag=args.tag))
    ok = sum(1 for r in results if r.get("status") == "ok")
    skipped = sum(1 for r in results
                  if str(r.get("status", "")).startswith("skipped"))
    print(f"[dryrun] done: {ok} ok, {skipped} skipped, "
          f"{len(results) - ok - skipped} failed / {len(results)} total")


if __name__ == "__main__":
    main()
