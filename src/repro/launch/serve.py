"""Serving launcher: batched generation on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-2.7b --batch 4
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--overlap-report", action="store_true",
                    help="model the decode-step collectives under the nbi "
                         "(completion-engine) schedule vs blocking")
    ap.add_argument("--comms-npes", type=int, default=8)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import base as cfgbase
    from repro.models import model
    from repro.serve.engine import Engine, ServeConfig

    cfg = cfgbase.reduced(cfgbase.get_config(args.arch))
    params = model.init_params(jax.random.key(0), cfg)
    eng = Engine(cfg, params, max_len=args.prompt_len + args.max_new)
    batch = {"tokens": jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.key(2), (args.batch, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.key(2), (args.batch, cfg.image_tokens, cfg.d_model))
    out = eng.generate(batch, ServeConfig(max_new_tokens=args.max_new,
                                          temperature=args.temperature))
    print(f"[serve] arch={cfg.name} generated {out.shape}:")
    print(out)

    if args.overlap_report:
        # decode is latency-bound: each step all-reduces the TP-sharded
        # logits/hidden.  Under the completion engine the step's collective
        # is issued nbi and completes while sampling/embedding of the
        # previous token computes — report the modeled gain per step.
        from repro.comms import api as comms_api
        ops = comms_api.get_ops("shmem", npes=args.comms_npes)
        for name, nbytes in (
                ("hidden", args.batch * cfg.d_model * 4),
                ("logits", args.batch * cfg.vocab_size * 4)):
            eff = ops.modeled_overlap_efficiency(nbytes)
            verdict = "use nbi" if eff > 1.0 else "stay blocking (alpha-bound)"
            print(f"[serve] decode {name} allreduce ({nbytes} B): "
                  f"modeled nbi overlap x{eff:.2f} vs blocking -> {verdict}")


if __name__ == "__main__":
    main()
