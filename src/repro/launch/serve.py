"""Serving launcher: batched generation, and disaggregated prefill/decode
with SHMEM paged-KV migration, paged decode attention, chunked prefill
streaming, and shared-prefix block reuse.

  # lockstep batch (original mode)
  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-2.7b --batch 4

  # disaggregated: 2 prefill PEs stream paged KV to 2 decode PEs; decode
  # consumes blocks straight from the pool (paged attention, the default)
  PYTHONPATH=src python -m repro.launch.serve --disagg \\
      --prefill-pes 2 --decode-pes 2 --requests 8 --slots 3

  # chunked prefill streaming: 2 blocks per installment hit the wire
  # mid-prefill, admission gates on the monotonic signal threshold
  PYTHONPATH=src python -m repro.launch.serve --disagg --stream-chunks 2

  # many samples of one prompt share prefix blocks (copy-on-write on the
  # first divergent decode write)
  PYTHONPATH=src python -m repro.launch.serve --disagg --shared-prefix \\
      --requests 6 --temperature 0.8

  # cross-pod hand-off (prefill pod -> decode pod over the host proxy)
  PYTHONPATH=src python -m repro.launch.serve --disagg --cross-pod ...

  # cluster frontend: open-loop traffic over 2 pods, SLO admission,
  # prefix-affinity routing (knob defaults: ISHMEM_FLEET_*)
  PYTHONPATH=src python -m repro.launch.serve --fleet --rate 1.2 \\
      --fleet-steps 24 --admission slo --router affinity

  # chaos: kill pod1 mid-run, partition the dcn fabric for 3 steps —
  # surviving requests recover (re-migrate/recompute + replay) bitwise
  PYTHONPATH=src python -m repro.launch.serve --fleet \\
      --chaos 'kill_pod=pod1@10,partition=3@14'
"""
from __future__ import annotations

import argparse


def _overlap_report(args) -> None:
    """Production-shape nbi-vs-blocking report for the decode collectives.

    The ROADMAP open item: at toy (reduced-config) sizes the decode
    allreduces are alpha-bound and nbi loses.  Here the *full* architecture
    config prices the sweep — real vocab (the logits reduce) and real
    d_model (the hidden reduce) over a batch sweep — and the report prints
    the crossover batch where the completion-engine schedule starts to win.
    """
    from repro.comms import api as comms_api
    from repro.configs import base as cfgbase

    full = cfgbase.get_config(args.arch)
    ops = comms_api.get_ops("shmem", npes=args.comms_npes)
    print(f"[serve] overlap report — production shapes for {full.name}: "
          f"d_model={full.d_model} vocab={full.vocab_size} "
          f"npes={args.comms_npes}")
    batches = [1, 2, 4, 8, 16, 32, 64, 128, 256]
    for name, per_tok in (("hidden", full.d_model * 4),
                          ("logits", full.vocab_size * 4)):
        crossover = None
        rows = []
        for B in batches:
            nbytes = B * per_tok
            eff = ops.modeled_overlap_efficiency(nbytes)
            rows.append((B, nbytes, eff))
            if crossover is None and eff > 1.0:
                crossover = B
        for B, nbytes, eff in rows:
            verdict = "nbi" if eff > 1.0 else "blocking"
            print(f"[serve]   {name:6s} B={B:<4d} {nbytes:>12d} B  "
                  f"overlap x{eff:.2f} -> {verdict}")
        if crossover is None:
            print(f"[serve]   {name}: alpha-bound at every swept batch "
                  f"-> stay blocking")
        else:
            print(f"[serve]   {name}: nbi wins from batch {crossover} "
                  f"({crossover * per_tok} B per decode step)")


def _seq_parallel_report(args, cfg) -> None:
    """Sequence-parallel ring attention demo (DESIGN.md §12.4): the context
    is sharded across N decode PEs, each ring step's K/V rotation is issued
    DEVICE-SIDE (work-group ``put_signal_nbi`` to the left neighbor, device
    ``signal_wait_until`` before the partial-attention kernel reads the
    landed shard), and the result is checked against single-PE flash
    attention.  Ends with the modeled blocking-vs-overlapped step pricing
    (``cutover.t_ring_attention``)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import context, device as device_mod
    from repro.core.cutover import ring_attention_overlap, t_ring_attention
    from repro.core.signal import SIGNAL_ADD
    from repro.kernels import ishmem_device as dev_kern
    from repro.kernels import ops

    npes = args.seq_parallel
    B, H, hd = 1, 4, 32
    S = ((max(args.prompt_len, 8 * npes) + npes - 1) // npes) * npes
    Sh = S // npes
    key = jax.random.key(args.seed)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                 (B, S, H, hd), jnp.float32) * 0.1
               for i in range(3))
    ctx, heap = context.init(npes=npes, node_size=npes)
    shard_words = 2 * B * Sh * H * hd               # k + v, one shard
    buf = heap.malloc((shard_words,), jnp.float32)
    sig = heap.malloc((1,), jnp.int32)

    def pack(j):
        return jnp.concatenate([k[:, j * Sh:(j + 1) * Sh].reshape(-1),
                                v[:, j * Sh:(j + 1) * Sh].reshape(-1)])

    def unpack(flat):
        kv = flat.reshape(2, B, Sh, H, hd)
        return kv[0], kv[1]

    for i in range(npes):                           # shard i starts at PE i
        heap = heap.write(buf, i, pack(i))
        heap = heap.write(sig, i, jnp.zeros((1,), jnp.int32))
    parts = [[] for _ in range(npes)]
    for t in range(npes):
        for i in range(npes):
            j = (i - t) % npes                      # shard resident at PE i
            if j <= i:                              # causal: skip future kv
                kj, vj = unpack(heap.read(buf, i))
                parts[i].append(dev_kern.flash_partial(
                    q[:, i * Sh:(i + 1) * Sh], kj, vj,
                    q_off=i * Sh, k_off=j * Sh))
        if t == npes - 1:
            break
        # device-side rotation: every PE's work-group pushes its current
        # shard to the RIGHT neighbor with a signal (PE i then holds shard
        # (i - t) % npes), then waits for the shard arriving from the left
        # before the next step reads it
        shards = [heap.read(buf, i) for i in range(npes)]
        for i in range(npes):
            wg = device_mod.work_group(ctx, pe=i)
            heap = device_mod.put_signal_nbi(
                wg, heap, buf, shards[i], sig, 1, SIGNAL_ADD,
                (i + 1) % npes)
        for i in range(npes):
            wg = device_mod.work_group(ctx, pe=i)
            heap, _, ok = device_mod.signal_wait_until(
                wg, heap, sig, i, "ge", t + 1)
            assert ok, "ring neighbor's shard never landed"
    out = jnp.concatenate(
        [dev_kern.merge_partials(parts[i]) for i in range(npes)], axis=1)
    ref = ops.flash_attention(q, k, v)
    err = float(jnp.abs(out - ref.astype(out.dtype)).max())
    print(f"[serve] seq-parallel ring attention: npes={npes} S={S} "
          f"(shard {Sh}) max|err| vs single-PE flash = {err:.2e}")
    dev_ops = sorted({key[0] for key in ctx.telemetry.buckets
                      if key[0].startswith("device_")})
    print(f"[serve]   device ops on the wire: {', '.join(dev_ops)}")
    # modeled step pricing at the FULL architecture's shapes and a
    # production context length (the reduced demo above only checks math)
    from repro.configs import base as cfgbase
    full_cfg = cfgbase.get_config(args.arch)
    S_prod = max(args.prompt_len, 32768)
    # per ring step each PE moves one K/V shard and runs one partial-flash
    # tile over it; flash is bandwidth-bound at these shapes, so the compute
    # term is the q + k + v + o bytes the kernel touches
    kv_bytes = 2 * (S_prod // npes) * full_cfg.d_model * 4
    compute = 4 * (S_prod // npes) * full_cfg.d_model * 4
    tb = t_ring_attention(kv_bytes, compute, npes, overlap=False,
                          tuning=ctx.tuning)
    to = t_ring_attention(kv_bytes, compute, npes, overlap=True,
                          tuning=ctx.tuning)
    ratio = ring_attention_overlap(kv_bytes, compute, npes,
                                   tuning=ctx.tuning)
    print(f"[serve]   modeled ring step: blocking {tb * 1e6:.1f} us vs "
          f"overlapped {to * 1e6:.1f} us -> x{ratio:.2f} "
          f"({'overlap wins' if ratio > 1 else 'alpha-bound'})")


def _make_batch(cfg, key, batch: int, prompt_len: int) -> dict:
    """Random request batch with whatever frontend embeds the family needs."""
    import jax
    b = {"tokens": jax.random.randint(key, (batch, prompt_len), 0,
                                      cfg.vocab_size)}
    if cfg.family == "audio":
        b["audio_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (batch, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (batch, cfg.image_tokens, cfg.d_model))
    return b


def _make_obs(args):
    """Build the observability bundle from ISHMEM_OBS_* merged with the CLI
    flags (CLI wins).  Returns (obs|None, trace_path, metrics_path,
    prof_path, calibration_path)."""
    from repro import obs as obs_mod

    cfg = obs_mod.load_obs_env()
    trace = bool(args.trace) or cfg.trace
    metrics = bool(args.metrics) or cfg.metrics
    refit = args.refit if args.refit is not None else cfg.refit_period
    audit = args.audit if args.audit is not None else cfg.audit_period
    recorder = (args.recorder if args.recorder is not None
                else cfg.recorder_window)
    alerts = bool(args.alerts) or cfg.alerts
    # --profile/--calibration use "1" as the bare-flag sentinel (same
    # convention as the env vars); anything else is an output path
    prof_cli_path = args.profile if args.profile not in (None, "1") else None
    cal_cli_path = (args.calibration
                    if args.calibration not in (None, "1") else None)
    calibration = bool(args.calibration) or cfg.calibration
    prof = bool(args.profile) or cfg.prof or calibration
    if not (trace or metrics or refit > 0 or audit > 0 or recorder > 0
            or alerts or prof):
        return None, None, None, None, None
    obs = obs_mod.Obs(
        trace=trace, metrics=metrics, refit_period=refit,
        refit_min_samples=(args.refit_min_samples
                           if args.refit_min_samples is not None
                           else cfg.refit_min_samples),
        trace_limit=cfg.trace_limit,
        audit_period=audit,
        recorder_window=recorder,
        recorder_path=cfg.recorder_path,
        alerts=alerts, alert_target=cfg.alert_target,
        alert_windows=cfg.alert_windows,
        prof=prof, calibration=calibration)
    return obs, (args.trace or cfg.trace_path), \
        (args.metrics or cfg.metrics_path), \
        (prof_cli_path or cfg.prof_path), \
        (cal_cli_path or cfg.calibration_path)


def _emit_obs(obs, trace_path, metrics_path,
              prof_path=None, calibration_path=None) -> None:
    if obs is None:
        return
    if trace_path:
        doc = obs.write_trace(trace_path,
                              measured=obs.prof is not None
                              and bool(obs.prof.samples))
        print(f"[serve]   trace: {len(doc['traceEvents'])} events -> "
              f"{trace_path} (load in ui.perfetto.dev)")
    if metrics_path:
        obs.write_metrics(metrics_path)
        print(f"[serve]   metrics: {len(obs.metrics.series)} step rows -> "
              f"{metrics_path}")
    if obs.refitter is not None and obs.refitter.history:
        n = obs.refitter.decisions_changed()
        print(f"[serve]   online re-fit: {len(obs.refitter.history)} "
              f"re-fit(s), {n} cutover decision(s) changed")
    if obs.auditor is not None:
        a = obs.auditor.summary()
        print(f"[serve]   audit: {a['checks']} sweep(s), "
              f"{a['violations']} violation(s), "
              f"{a['audit_seconds'] * 1e3:.1f} ms auditing")
    if obs.monitor is not None:
        m = obs.monitor.summary()
        print(f"[serve]   slo burn-rate: {m['observations']} checks, "
              f"{len(m['alerts'])} alert(s) "
              f"(target {m['target']}, windows {m['windows']})")
        for al in m["alerts"]:
            worst = al["offenders"][0] if al["offenders"] else None
            tail = (f"; worst rid {worst['rid']} ({worst['outcome']}, "
                    f"+{worst['overshoot_steps']} steps past deadline)"
                    if worst else "")
            print(f"[serve]     ALERT class={al['cls']} step={al['step']} "
                  f"burn={al['burn']}{tail}")
    if obs.recorder is not None:
        r = obs.recorder.summary()
        if r["dumps"]:
            print(f"[serve]   flight recorder: postmortem dump(s) -> "
                  f"{', '.join(r['dumps'])}")
        else:
            print(f"[serve]   flight recorder: armed, "
                  f"{r['buffered_events']} span(s) in the "
                  f"{r['window_steps']}-step window, no incident")
    if obs.prof is not None:
        ps = obs.prof.summary()
        print(f"[serve]   profiler: {ps['samples']} measured sample(s) "
              f"({ps['wall_s'] * 1e3:.1f} ms wall, "
              f"{ps['model_s'] * 1e3:.3f} ms modeled) over "
              f"ops {', '.join(ps['ops']) or 'none'}")
        if prof_path:
            obs.write_prof(prof_path)
            print(f"[serve]   profiler samples -> {prof_path} "
                  f"(analyze with --calibration)")
        if obs.calibration:
            from repro.obs import calibrate as calibrate_mod
            report = obs.calibration_report()
            sink_rows = None
            ctx = obs.prof.ctx
            if ctx is not None:
                sink_rows = calibrate_mod.sink_join(ctx.telemetry)
            for line in calibrate_mod.render(
                    report, sink_rows=sink_rows).splitlines():
                print(f"[serve]   {line}")
            if calibration_path:
                import json as json_mod
                with open(calibration_path, "w") as f:
                    json_mod.dump(report, f, indent=2, sort_keys=True)
                    f.write("\n")
                print(f"[serve]   calibration report -> {calibration_path}")


def _run_disagg(args, cfg, params) -> None:
    import jax
    from repro.core import context, teams
    from repro.core.proxy import HostProxy
    from repro.serve.engine import Engine, ServeConfig
    from repro.serve.kvpool import KVPool
    from repro.serve.kvxfer import KVMigrator
    from repro.serve.scheduler import DisaggScheduler

    npes = args.prefill_pes + args.decode_pes
    node_size = args.prefill_pes if args.cross_pod else npes
    ctx, heap = context.init(npes=npes, node_size=node_size)
    obs, trace_path, metrics_path, prof_path, calibration_path = \
        _make_obs(args)
    if obs is not None:
        obs.attach(ctx)
    pre, dec = teams.disagg_partition(teams.world(npes), args.prefill_pes)
    max_len = args.prompt_len + args.max_new
    eng = Engine(cfg, params, max_len=max_len)
    pool = KVPool.create(heap, cfg, max_len,
                         num_blocks=args.kv_blocks, max_slots=args.slots,
                         block_tokens=args.block_tokens)
    proxy = HostProxy(ctx) if args.cross_pod else None
    mig = KVMigrator(ctx, pool, proxy=proxy)
    sched = DisaggScheduler(
        ctx, heap, eng, pool, mig, prefill_pes=pre.pes(),
        decode_pes=dec.pes(), num_slots=args.slots,
        scfg=ServeConfig(max_new_tokens=args.max_new,
                         temperature=args.temperature),
        admit_delay_steps=args.admit_delay,
        paged=not args.dense_rehydrate,
        stream_chunks=args.stream_chunks,
        fused_attn=args.fused_attn,
        shared_prefix=args.shared_prefix)
    base = _make_batch(cfg, jax.random.key(1), 1, args.prompt_len)
    for i in range(args.requests):
        if args.shared_prefix:
            # many-samples-one-prompt: every request maps the same prefix
            sched.submit(dict(base), prefix_len=args.prompt_len)
        else:
            sched.submit(_make_batch(
                cfg, jax.random.fold_in(jax.random.key(1), i), 1,
                args.prompt_len))
    outs = sched.run()
    st = sched.stats
    tier = "dcn (host proxy)" if args.cross_pod else "ici"
    mode = "paged" if not args.dense_rehydrate else "dense-rehydrate"
    print(f"[serve] disagg arch={cfg.name} prefill={pre.pes()} "
          f"decode={dec.pes()} tier={tier} decode-cache={mode}")
    print(f"[serve]   {st.prefills} prefills, {st.migrations} migrations "
          f"({st.bytes_migrated} B), {st.admissions} admissions, "
          f"{st.evictions} evictions over {st.decode_steps} decode steps")
    if st.ttfd_steps:
        avg_steps = sum(st.ttfd_steps) / len(st.ttfd_steps)
        avg_t = sum(st.ttfd_model_s) / len(st.ttfd_model_s)
        print(f"[serve]   time-to-first-decode-token: {avg_steps:.1f} sched "
              f"steps / {avg_t * 1e6:.1f} us modeled comm window")
    if st.ttfd_first_block_steps:
        avg_fb = (sum(st.ttfd_first_block_steps)
                  / len(st.ttfd_first_block_steps))
        mode_tag = "fused admission gate" if args.fused_attn else "observed"
        print(f"[serve]   time-to-first-resident-block: {avg_fb:.1f} sched "
              f"steps ({mode_tag})")
    if args.stream_chunks:
        print(f"[serve]   streaming: {st.stream_chunks} wire installments "
              f"of {args.stream_chunks} block(s)")
    if args.shared_prefix:
        print(f"[serve]   shared prefix: {st.prefix_hits} hits, "
              f"{st.blocks_prefix_shared} blocks mapped, "
              f"{st.bytes_wire_saved} wire B saved, "
              f"{st.cow_copies} copy-on-writes")
    print(f"[serve]   stalls: pool={st.stalled_on_pool} "
          f"slots={st.stalled_on_slots}; coalescing ratio "
          f"{ctx.pending.stats.coalescing_ratio():.2f}")
    ps = pool.stats(sched.heap)
    print(f"[serve]   pool: {ps['blocks_in_use']}/{ps['blocks_total']} "
          f"blocks in use; heap: {ps['heap']['bytes_in_use']} B in use, "
          f"{ps['heap']['bytes_free']} B free")
    for rid in sorted(outs)[:4]:
        print(f"[serve]   req {rid}: {outs[rid].tolist()}")
    _emit_obs(obs, trace_path, metrics_path, prof_path, calibration_path)


def _run_fleet(args, cfg, params) -> None:
    from repro.serve.engine import Engine
    from repro.serve.fault import FaultPlan, load_fault_env
    from repro.serve.frontend import (Fleet, FleetConfig, TenantSpec,
                                      TrafficEngine)

    fault_plan = None
    if args.chaos is not None:
        fenv = load_fault_env()
        spec = args.chaos or fenv.plan          # CLI plan wins over env
        if not spec:
            raise SystemExit(
                "--chaos needs a fault plan: pass one inline "
                "(--chaos 'kill_pod=pod1@10') or set ISHMEM_FAULT_PLAN")
        fault_plan = FaultPlan.parse(spec, seed=fenv.seed)

    fcfg = FleetConfig(
        arch=args.arch, n_pods=args.pods,
        prefill_per_pod=args.pod_prefill, decode_per_pod=args.pod_decode,
        num_slots=args.slots, kv_blocks=args.kv_blocks,
        block_tokens=args.block_tokens,
        max_len=args.prompt_len + args.max_new, max_new=args.max_new,
        temperature=args.temperature, stream_chunks=args.stream_chunks,
        fused_attn=args.fused_attn, shared_prefix=True,
        admit_delay=args.admit_delay, admission=args.admission,
        queue_bound=args.queue_bound, router=args.router, seed=args.seed)
    engine = Engine(cfg, params, max_len=fcfg.max_len)
    obs, trace_path, metrics_path, prof_path, calibration_path = \
        _make_obs(args)
    fleet = Fleet(fcfg, engine=engine, obs=obs, fault_plan=fault_plan)
    tenants = [
        TenantSpec("chat", weight=2.0, prompt_lens=(args.prompt_len,),
                   max_new=(args.max_new,), slo="interactive"),
        TenantSpec("api", weight=1.0, prompt_lens=(args.prompt_len,),
                   max_new=(args.max_new,), slo="standard",
                   shared_prefix_prob=0.5, prefix_groups=2),
        TenantSpec("scan", weight=1.0, prompt_lens=(args.prompt_len,),
                   max_new=(min(3 * args.max_new, fcfg.max_len
                                - args.prompt_len),), slo="batch"),
    ]
    traffic = TrafficEngine(tenants, rate=args.rate,
                            vocab=cfg.vocab_size, seed=args.seed,
                            process=args.traffic)
    specs = traffic.schedule(args.fleet_steps)
    offered = traffic.offered_load(specs)
    print(f"[serve] fleet arch={cfg.name} pods={fcfg.n_pods} "
          f"({fcfg.prefill_per_pod}P+{fcfg.decode_per_pod}D x "
          f"{fcfg.num_slots} slots) router={fcfg.router} "
          f"admission={fcfg.admission}")
    print(f"[serve]   offered: {offered['requests']} requests over "
          f"{args.fleet_steps} steps ({args.traffic}, rate {args.rate}) "
          f"by class {offered['by_slo']}")
    rep = fleet.run(specs)
    lat = rep["latency"]
    print(f"[serve]   {rep['completed']}/{rep['offered']} completed, "
          f"{rep['shed']} shed, {rep['preempts']} preempted "
          f"({rep['resumes']} resumed) in {rep['elapsed_steps']} steps")
    print(f"[serve]   TTFD p50/p99 {lat['ttfd_p50_steps']:.1f}/"
          f"{lat['ttfd_p99_steps']:.1f} steps "
          f"({lat['ttfd_p50_model_s'] * 1e6:.1f}/"
          f"{lat['ttfd_p99_model_s'] * 1e6:.1f} us modeled); e2e p99 "
          f"{lat['e2e_p99_steps']:.1f} steps; goodput "
          f"{rep['goodput']:.2f} ({rep['goodput_per_step']:.3f}/step)")
    for name, b in sorted(rep["by_class"].items()):
        print(f"[serve]     {name:12s} {b['completed']}/{b['offered']} "
              f"done, p99 TTFD {b['ttfd_p99_steps']:.1f} steps, "
              f"goodput {b['goodput']:.2f}")
    wire = rep["wire"]
    print(f"[serve]   wire: {wire['bytes_migrated']} B migrated, "
          f"{wire['bytes_cross_pod']} B cross-pod, "
          f"{wire['bytes_wire_saved']} B saved by residency; router "
          f"{rep['router']}")
    if "proxy" in rep:
        print(f"[serve]   proxy ring: {rep['proxy']['delivered']} messages, "
              f"{rep['proxy']['backpressure']} backpressure drains")
    if fault_plan is not None:
        flt = rep.get("fault", {})
        rec = rep["recovered"]
        fired = ", ".join(f"{e['kind']}={e['arg']}@{e['step']}"
                          for e in flt.get("events", ())) or "none fired"
        print(f"[serve]   chaos: plan [{fault_plan.spec()}] -> {fired}")
        print(f"[serve]   chaos: dead PEs {flt.get('dead_pes', [])}, dead "
              f"pods {flt.get('dead_pods', [])}, "
              f"{flt.get('cancelled_ops', 0)} in-flight ops cancelled")
        print(f"[serve]   recovery: {rec['recovered_requests']} requests "
              f"re-admitted ({rec['remigrated']} re-migrated, "
              f"{rec['recomputed']} recomputed from prompt, "
              f"{rec['replayed_tokens']} tokens replayed)")
    _emit_obs(obs, trace_path, metrics_path, prof_path, calibration_path)


def main():
    from repro.serve.frontend.env import FleetEnv, load_fleet_env
    # a malformed ISHMEM_FLEET_* variable must only fail runs that use the
    # fleet — other serve modes ignore every fleet knob
    try:
        fenv, fenv_err = load_fleet_env(), None
    except ValueError as e:
        fenv, fenv_err = FleetEnv(), e
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--overlap-report", action="store_true",
                    help="model the decode-step collectives under the nbi "
                         "schedule vs blocking at PRODUCTION shapes (full "
                         "vocab/d_model, batch sweep) and print the "
                         "crossover where nbi wins")
    ap.add_argument("--comms-npes", type=int, default=8)
    # --- disaggregated serving -------------------------------------------
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated prefill/decode with SHMEM paged-KV "
                         "migration")
    ap.add_argument("--prefill-pes", type=int, default=2)
    ap.add_argument("--decode-pes", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3,
                    help="decode slots per decode PE")
    ap.add_argument("--kv-blocks", type=int, default=64,
                    help="paged KV pool size in blocks")
    ap.add_argument("--block-tokens", type=int, default=16)
    ap.add_argument("--admit-delay", type=int, default=1,
                    help="modeled wire latency in scheduler steps before a "
                         "migration's signal is polled (streamed closes "
                         "scale it by the final installment's share)")
    ap.add_argument("--stream-chunks", type=int, default=None,
                    metavar="BLOCKS",
                    help="chunked prefill streaming: put BLOCKS filled "
                         "blocks on the wire per scheduler step mid-prefill "
                         "(0 = whole-prefill migration; --fleet defaults to "
                         "ISHMEM_FLEET_STREAM_CHUNKS)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="serve every request as a sample of one shared "
                         "prompt: prefix blocks are mapped (incref), not "
                         "re-staged, with copy-on-write on divergence")
    ap.add_argument("--fused-attn", action="store_true",
                    help="device-initiated fused decode protocol: per-block "
                         "migration signals, first-block admission, and "
                         "per-signal block consumption inside the decode "
                         "gather (DESIGN.md §12; excludes --stream-chunks)")
    ap.add_argument("--seq-parallel", type=int, default=0, metavar="N",
                    help="sequence-parallel ring attention demo over N PEs: "
                         "device-side K/V rotation per ring step, checked "
                         "against single-PE flash, plus the modeled "
                         "blocking-vs-overlap step pricing")
    ap.add_argument("--dense-rehydrate", action="store_true",
                    help="fall back to the PR-3 dense-cache admission "
                         "(gather+insert) instead of paged decode attention")
    ap.add_argument("--cross-pod", action="store_true",
                    help="decode PEs in a second pod: dcn tier, migrations "
                         "route through the host proxy ring")
    # --- cluster frontend (fleet) ----------------------------------------
    ap.add_argument("--fleet", action="store_true",
                    help="cluster frontend: open-loop traffic over N pods "
                         "with SLO admission + routing (DESIGN.md §10); "
                         "defaults come from the ISHMEM_FLEET_* env vars")
    ap.add_argument("--pods", type=int, default=fenv.pods)
    ap.add_argument("--pod-prefill", type=int, default=1,
                    help="prefill PEs per pod")
    ap.add_argument("--pod-decode", type=int, default=2,
                    help="decode PEs per pod")
    ap.add_argument("--fleet-steps", type=int, default=24,
                    help="open-loop arrival window in scheduler steps "
                         "(the run drains past it)")
    ap.add_argument("--rate", type=float, default=0.8,
                    help="offered load, requests per step fleet-wide")
    ap.add_argument("--traffic", choices=("poisson", "bursty"),
                    default="poisson", help="arrival process")
    ap.add_argument("--router", choices=("random", "round_robin",
                                         "least_loaded", "affinity"),
                    default=fenv.router)
    ap.add_argument("--admission", choices=("slo", "fcfs"),
                    default=fenv.admission,
                    help="SLO deadline-class policy vs the FCFS baseline")
    ap.add_argument("--queue-bound", type=int, default=fenv.queue_bound,
                    help="per-pod queue bound before the SLO policy sheds")
    ap.add_argument("--seed", type=int, default=fenv.seed)
    ap.add_argument("--chaos", nargs="?", const="", default=None,
                    metavar="PLAN",
                    help="fault injection against the fleet: a deterministic "
                         "kind=arg@step plan (kill_pe/kill_pod/partition/"
                         "drain/join — DESIGN.md §14), e.g. "
                         "'kill_pod=pod1@10,partition=3@14'; with no inline "
                         "plan, ISHMEM_FAULT_PLAN is used")
    # --- observability (repro.obs; defaults from ISHMEM_OBS_*) ------------
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="record causal spans and write a Chrome-trace/"
                         "Perfetto JSON (tracks = pods/PEs, async request "
                         "lifelines, migration flow arrows)")
    ap.add_argument("--metrics", metavar="OUT.json", default=None,
                    help="per-fleet-step metrics time series (heap "
                         "fragmentation, ring occupancy, pool residency, "
                         "per-class goodput)")
    ap.add_argument("--refit", type=int, default=None, metavar="STEPS",
                    help="online tuner re-fit period in fleet steps: re-run "
                         "the estimator over live telemetry and hot-swap "
                         "the cutover table mid-run (0 = off)")
    ap.add_argument("--refit-min-samples", type=int, default=None,
                    help="minimum retained telemetry samples before a due "
                         "re-fit runs")
    ap.add_argument("--audit", type=int, default=None, metavar="STEPS",
                    help="run the online invariant auditors (heap extents, "
                         "block refcounts, signal ledger, prefix residency, "
                         "slot banks) every STEPS fleet steps; any "
                         "violation aborts the run with an AuditError "
                         "(0 = off)")
    ap.add_argument("--recorder", type=int, default=None, metavar="STEPS",
                    help="arm the flight recorder: keep the last STEPS "
                         "steps of spans in a bounded ring and dump a "
                         "postmortem Chrome-trace on crash, audit "
                         "violation, or SLO alert (0 = off)")
    ap.add_argument("--alerts", action="store_true",
                    help="SLO burn-rate monitor: multi-window error-budget "
                         "burn per deadline class over the metrics series, "
                         "alerts carry the top offending requests by "
                         "critical-path segment (implies metrics sampling)")
    ap.add_argument("--profile", nargs="?", const="1", default=None,
                    metavar="OUT.json",
                    help="wall-clock profiler on the serve hot paths "
                         "(decode steps, paged-attention, prefill, "
                         "migration flushes); an argument also writes the "
                         "measured-sample JSON for "
                         "'python -m repro.obs.analyze --calibration'. "
                         "Deterministic outputs stay bitwise-identical")
    ap.add_argument("--calibration", nargs="?", const="1", default=None,
                    metavar="OUT.json",
                    help="measured-vs-modeled divergence report at shutdown "
                         "(ratio percentiles per (op, tier, size, "
                         "work-items) bucket, worst buckets, unmodeled "
                         "coverage); implies --profile; an argument also "
                         "writes the report JSON")
    args = ap.parse_args()
    if args.fleet and fenv_err is not None:
        raise fenv_err
    if args.stream_chunks is None:
        # fused admission and chunked streaming are mutually exclusive, so
        # --fused-attn suppresses the fleet's default streaming
        args.stream_chunks = (fenv.stream_chunks
                              if args.fleet and not args.fused_attn else 0)

    import jax
    from repro.configs import base as cfgbase
    from repro.models import model
    from repro.serve.engine import Engine, ServeConfig

    cfg = cfgbase.reduced(cfgbase.get_config(args.arch))
    params = model.init_params(jax.random.key(0), cfg)

    if args.fleet:
        _run_fleet(args, cfg, params)
    elif args.disagg:
        _run_disagg(args, cfg, params)
    else:
        eng = Engine(cfg, params, max_len=args.prompt_len + args.max_new)
        batch = _make_batch(cfg, jax.random.key(1), args.batch,
                            args.prompt_len)
        out = eng.generate(batch, ServeConfig(max_new_tokens=args.max_new,
                                              temperature=args.temperature))
        print(f"[serve] arch={cfg.name} generated {out.shape}:")
        print(out)

    if args.overlap_report:
        _overlap_report(args)
    if args.seq_parallel:
        _seq_parallel_report(args, cfg)


if __name__ == "__main__":
    main()
