"""Serving launcher: batched generation on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-2.7b --batch 4
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import base as cfgbase
    from repro.models import model
    from repro.serve.engine import Engine, ServeConfig

    cfg = cfgbase.reduced(cfgbase.get_config(args.arch))
    params = model.init_params(jax.random.key(0), cfg)
    eng = Engine(cfg, params, max_len=args.prompt_len + args.max_new)
    batch = {"tokens": jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.key(2), (args.batch, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.key(2), (args.batch, cfg.image_tokens, cfg.d_model))
    out = eng.generate(batch, ServeConfig(max_new_tokens=args.max_new,
                                          temperature=args.temperature))
    print(f"[serve] arch={cfg.name} generated {out.shape}:")
    print(out)


if __name__ == "__main__":
    main()
