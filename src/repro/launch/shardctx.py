"""Activation-sharding context.

The model code is mesh-agnostic; the launcher installs a rule table here and
``constrain(x, role)`` becomes ``with_sharding_constraint`` under a mesh, or a
no-op on a bare CPU.  Roles: "hidden" (B,S,d), "logits" (B,C,V).
"""
from __future__ import annotations

import contextlib

import jax

_RULES = None


@contextlib.contextmanager
def rules(rule_fn):
    """rule_fn(role, shape) -> PartitionSpec | None."""
    global _RULES
    prev = _RULES
    _RULES = rule_fn
    try:
        yield
    finally:
        _RULES = prev


def constrain(x, role: str):
    if _RULES is None:
        return x
    spec = _RULES(role, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
