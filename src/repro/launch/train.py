"""Training launcher.

CPU-scale (default): runs the real training loop on a reduced config.
Pod-scale (--dryrun): lowers/compiles the same step for the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 50
"""
from __future__ import annotations

import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full architecture (pods); default reduced")
    ap.add_argument("--comms-backend", default="none",
                    choices=["none", "shmem"],
                    help="shmem: model device-initiated gradient reduction "
                         "(nbi ring steps overlapping optimizer updates)")
    ap.add_argument("--comms-npes", type=int, default=8)
    ap.add_argument("--no-overlap-reduce", action="store_true",
                    help="disable the reduce/update pipeline "
                         "(PerfPolicy.overlap_grad_reduce=False)")
    args = ap.parse_args()

    from repro.configs import base as cfgbase
    from repro.launch import policy as policy_mod
    from repro.train import trainer

    cfg = cfgbase.get_config(args.arch)
    if not args.full_size:
        cfg = cfgbase.reduced(cfg)
    tcfg = trainer.TrainConfig(
        steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, grad_accum=args.grad_accum,
        lr=args.lr, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        comms_backend=args.comms_backend, comms_npes=args.comms_npes)
    pol = dataclasses.replace(policy_mod.get(),
                              overlap_grad_reduce=not args.no_overlap_reduce)
    with policy_mod.use(pol):
        trainer.train(cfg, tcfg, resume=args.resume)


if __name__ == "__main__":
    main()
