"""Performance policy knobs for the §Perf hillclimbing loop.

Every knob is consumed somewhere in the model/sharding stack; the dry-run
CLI can override any field (``--policy k=v``), so a hypothesis -> change ->
re-lower -> re-analyse cycle is one command.

Knobs (and the roofline term they target):

- ``attn_block_q/k``     : KV-block sizes of blockwise attention  [memory]
- ``attn_p_bf16``        : bf16 exp-score tensor (m/l stay f32)   [memory]
- ``logits_bf16``        : bf16 CE logits (f32 logsumexp)         [memory]
- ``ce_chunk``           : CE sequence chunk                      [memory]
- ``fsdp_gather_weights``: constrain scanned layer weights to a
  data-replicated spec inside the layer body, turning the data-axis
  *activation all-reduces* that GSPMD otherwise inserts for
  contraction-dim-sharded weights into per-layer *weight all-gathers*
  (ZeRO-3 style)                                                  [collective]
- ``moe_seq_shard``      : constrain MoE dispatch buffers to expert-
  sharded layout                                                  [collective]
- ``decode_replicate_small_cache``: replicate decode caches smaller than
  ``small_cache_bytes`` instead of sharding them (1-token decode over a
  windowed cache is latency-bound; gathers on sharded ring caches
  trigger involuntary full rematerialization)                     [collective]
"""
from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass(frozen=True)
class PerfPolicy:
    attn_impl: str = "blockwise"        # "blockwise" (XLA online-softmax
                                        # scan) or "flash" (fused Pallas
                                        # kernel, kernels/flash_attn.py;
                                        # interpret on CPU, Mosaic on TPU)
    attn_block_q: int = 512
    attn_block_k: int = 512
    attn_p_bf16: bool = False
    attn_qk_bf16: bool = False          # keep q/k bf16 into the score dot
                                        # (f32 via preferred_element_type,
                                        # MXU-native)  [memory+collective]
    logits_bf16: bool = False
    ce_chunk: int = 512
    fsdp_gather_weights: bool = False
    param_tp_only: bool = False         # block weights sharded on "model"
                                        # only (no ZeRO over "data"):
                                        # trades HBM for wire  [collective]
    attn_repeat_kv: bool = False        # replicate KV heads to nq so the
                                        # head axis (divisible by 16) shards
                                        # over "model" inside attention —
                                        # Megatron GQA-TP duplication
                                        # [collective]
    hidden_spec: str = "replicated"     # residual-stream constraint between
                                        # blocks: "replicated" (baseline:
                                        # P(b,None,None)), "dshard"
                                        # (P(b,None,model)), or "off" (let
                                        # GSPMD propagate)      [collective]
    seq_parallel_hidden: bool = False   # shard hidden seq over "model"
                                        # between blocks (Megatron SP):
                                        # all-reduce -> RS + AG   [collective]
    moe_expert_shard: bool = False
    decode_onehot_update: bool = False  # one-hot masked cache write instead
                                        # of scatter: shard-local on a
                                        # seq-sharded cache  [collective]
    decode_replicate_small_cache: bool = False
    small_cache_bytes: int = 1 << 30
    overlap_grad_reduce: bool = True    # pipeline per-leaf gradient reduce
                                        # (nbi ring step) under the previous
                                        # leaf's optimizer update; off =
                                        # reduce-all-then-update  [collective]


_CURRENT = PerfPolicy()


def get() -> PerfPolicy:
    return _CURRENT


@contextlib.contextmanager
def use(policy: PerfPolicy):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = policy
    try:
        yield
    finally:
        _CURRENT = prev


def parse_overrides(pairs) -> PerfPolicy:
    """['attn_p_bf16=1', 'attn_block_k=1024', ...] -> PerfPolicy."""
    kw = {}
    for pair in pairs or []:
        k, v = pair.split("=", 1)
        field = PerfPolicy.__dataclass_fields__[k]
        if field.type in ("bool", bool):
            kw[k] = v.lower() in ("1", "true", "yes")
        elif field.type in ("str", str):
            kw[k] = v
        else:
            kw[k] = int(v)
    return PerfPolicy(**kw)
