"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips of a
v5e pod; multi-pod adds a leading DCN "pod" axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices=None, *, multi_pod: bool = False):
    """Small mesh over however many host devices exist (tests)."""
    n = len(devices or jax.devices())
    if multi_pod and n >= 8:
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    if n >= 4:
        return jax.make_mesh((2, n // 2), ("data", "model"))
    return jax.make_mesh((1, n), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh) -> str:
    return "model"
