"""Sharding rules: parameters (FSDP x TP hybrid), optimizer state, caches,
batches, and activation constraints, for the production meshes.

Policy (see DESIGN.md §5):
  - weights: larger of the last two dims -> "model" (TP), the other -> "data"
    (ZeRO/FSDP); leading stack axes replicated; embeddings vocab -> "model".
  - MoE expert stacks: expert dim -> "model" (expert parallelism).
  - activations: batch -> ("pod","data"); logits vocab -> "model".
  - decode caches: batch -> ("pod","data") when divisible, sequence/window ->
    "model" (distributed flash-decode); SSM state heads -> "model".
All assignments are divisibility-checked; non-divisible dims replicate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_mod


def _sizes(mesh):
    ax = dict(mesh.shape)            # works for Mesh and AbstractMesh
    batch_axes = mesh_mod.batch_axes(mesh)
    bsize = 1
    for a in batch_axes:
        bsize *= ax[a]
    return ax.get("model", 1), bsize, batch_axes


def _div(n, k):
    return k > 0 and n % k == 0


# ---------------------------------------------------------------------------
# parameters / optimizer state
# ---------------------------------------------------------------------------


def _generic_matrix_spec(shape, msize, dsize):
    nd = len(shape)
    spec = [None] * nd
    if nd < 2:
        return P(*spec)
    a, b = nd - 2, nd - 1
    big, small = (a, b) if shape[a] >= shape[b] else (b, a)
    if _div(shape[big], msize):
        spec[big] = "model"
        if _div(shape[small], dsize):
            spec[small] = "data"
    elif _div(shape[small], msize):
        spec[small] = "model"
        if _div(shape[big], dsize):
            spec[big] = "data"
    elif _div(shape[big], dsize):
        spec[big] = "data"
    return P(*spec)


def spec_for_param(path: str, shape, mesh) -> P:
    from repro.launch import policy as policy_mod
    msize, _, _ = _sizes(mesh)
    dsize = dict(mesh.shape).get("data", 1)
    if policy_mod.get().param_tp_only and "blocks" in path:
        dsize = -1                       # never divisible -> no "data" shard
    nd = len(shape)
    if "embed" in path and nd == 2:
        v, d = shape
        return P("model" if _div(v, msize) else None,
                 "data" if _div(d, dsize) else None)
    if "lm_head" in path and nd == 2:
        d, v = shape
        if _div(v, msize):
            return P("data" if _div(d, dsize) else None, "model")
        return P("model" if _div(d, msize) else None, None)
    if "router" in path and nd == 3:
        return P(None, "data" if _div(shape[1], dsize) else None, None)
    if ("moe" in path and nd == 4
            and any(k in path for k in ("w_gate", "w_up", "w_down"))):
        e = shape[1]
        return P(None,
                 "model" if _div(e, msize) else None,
                 "data" if _div(shape[2], dsize) else None,
                 None)
    if nd >= 2:
        # strip leading stack axes; rule over the last two dims
        spec = _generic_matrix_spec(shape[-2:], msize, dsize)
        return P(*([None] * (nd - 2) + list(spec)))
    return P()


def param_shardings(cfg, mesh, params_struct):
    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        return NamedSharding(mesh, spec_for_param(pstr, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, params_struct)


def opt_shardings(cfg, mesh, opt_struct):
    """Optimizer state: same generic rules (m/v mirror params; adafactor
    vr/vc get the generic treatment of their reduced shapes)."""
    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, spec_for_param(pstr, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, opt_struct)


# ---------------------------------------------------------------------------
# batches / caches
# ---------------------------------------------------------------------------


def batch_shardings(cfg, mesh, batch_struct):
    msize, bsize, baxes = _sizes(mesh)
    baxes = tuple(baxes)

    def one(path, leaf):
        b = leaf.shape[0]
        first = baxes if (_div(b, bsize) and baxes) else None
        return NamedSharding(mesh, P(first, *([None] * (leaf.ndim - 1))))
    return jax.tree_util.tree_map_with_path(one, batch_struct)


def cache_shardings(cfg, mesh, cache_struct):
    from repro.launch import policy as policy_mod
    msize, bsize, baxes = _sizes(mesh)
    baxes = tuple(baxes)

    pol = policy_mod.get()
    if pol.decode_replicate_small_cache:
        total = sum(l.size * l.dtype.itemsize
                    for l in jax.tree.leaves(cache_struct))
        if total <= pol.small_cache_bytes:
            # latency-bound decode over a small (windowed/SSM) cache:
            # replicate rather than shard — removes gather-induced
            # involuntary full rematerialization
            return jax.tree.map(
                lambda l: NamedSharding(mesh, P(*([None] * l.ndim))),
                cache_struct)

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        nd = leaf.ndim
        spec = [None] * nd
        name = pstr.rsplit("'", 2)[-2] if "'" in pstr else pstr
        if nd >= 2:
            b = leaf.shape[1]            # (R, B, ...)
            batch_ok = _div(b, bsize) and baxes
            if batch_ok:
                spec[1] = baxes
        if name in ("k", "v", "ck", "cv") and nd == 5:
            seq, nkv = leaf.shape[2], leaf.shape[3]
            if spec[1] is None and _div(seq, bsize * msize):
                spec[2] = tuple(baxes) + ("model",)   # B=1: context parallel
            elif _div(seq, msize):
                spec[2] = "model"
            elif _div(nkv, msize):
                spec[3] = "model"
        elif name == "kpos" and nd == 3:
            seq = leaf.shape[2]
            if spec[1] is None and _div(seq, bsize * msize):
                spec[2] = tuple(baxes) + ("model",)
            elif _div(seq, msize):
                spec[2] = "model"
        elif name == "state" and nd == 5:
            if _div(leaf.shape[2], msize):
                spec[2] = "model"
        elif name == "conv" and nd == 4:
            if _div(leaf.shape[3], msize):
                spec[3] = "model"
        elif name in ("C", "n") and nd >= 4:
            if _div(leaf.shape[2], msize):
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_struct)


# ---------------------------------------------------------------------------
# activation constraint rules (installed via launch.shardctx)
# ---------------------------------------------------------------------------


def activation_rules(cfg, mesh):
    msize, bsize, baxes = _sizes(mesh)
    baxes = tuple(baxes)

    def rule(role, shape):
        if not baxes:
            return None
        if role == "gathered_weight":
            # ZeRO-3 weight gathering: inside the layer body the weight is
            # replicated across the data axis, sharded only on "model" —
            # GSPMD emits a per-layer weight all-gather instead of
            # contraction-dim activation all-reduces over "data".
            if len(shape) < 2:
                return P(*([None] * len(shape)))
            spec = list(_generic_matrix_spec(shape[-2:], msize, 1))
            spec = [s if s == "model" else None for s in spec]
            if len(shape) == 3 and _div(shape[0], msize):   # (E, d, ff) experts
                return P("model", None, None)
            return P(*([None] * (len(shape) - 2) + spec))
        b = shape[0]
        first = baxes if _div(b, bsize) else None
        if role == "hidden" and len(shape) == 3:
            from repro.launch import policy as policy_mod
            pol = policy_mod.get()
            if pol.hidden_spec == "off":
                return None
            if (pol.seq_parallel_hidden
                    and _div(shape[1], msize) and shape[1] > 1):
                return P(first, "model", None)   # sequence parallelism
            if pol.hidden_spec == "dshard" and _div(shape[2], msize):
                return P(first, None, "model")
            return P(first, None, None)
        if role == "logits" and len(shape) == 3:
            v = shape[-1]
            return P(first, None, "model" if _div(v, msize) else None)
        return None
    return rule
