"""Device-initiated ring collectives over ICI (paper §III-G2 -> TPU).

All kernels are issued from *inside* a running Pallas kernel (the paper's
"GPU-initiated" path) using ``make_async_remote_copy``; they run under
shard_map and are validated on CPU in TPU interpret mode, and compile to real
ICI RDMA on TPU.

- ``ring_allgather``     : fcollect — N-1 ring steps, each forwarding the
                           chunk received in the previous step.
- ``ring_reduce_scatter``: large-reduction building block ("split the work by
                           address across PEs and exchange results").
- ``push_broadcast``     : root *stores* to every destination — the paper's
                           push strategy with the inner loop over destinations.
- ``barrier_push``       : semaphore signal to every teammate + local wait —
                           the TPU analogue of the paper's pipelined remote
                           atomic-increment sync.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _interpret():
    if jax.default_backend() == "tpu":
        return False
    params = getattr(pltpu, "InterpretParams", None)  # absent pre-jax-0.5
    return params() if params is not None else True


def _wait_incoming(ref, sem):
    """Wait for an incoming DMA of ref's size (receiver-side recv wait)."""
    pltpu.make_async_copy(ref, ref, sem).wait()


# ---------------------------------------------------------------------------
# ring all-gather (fcollect)
# ---------------------------------------------------------------------------


def _ag_kernel(x_ref, o_ref, local_sem, send_sem, recv_sems, *, axis_name,
               npes):
    my = jax.lax.axis_index(axis_name)
    right = jax.lax.rem(my + 1, npes)
    # place own chunk
    cp = pltpu.make_async_copy(x_ref, o_ref.at[my], local_sem)
    cp.start()
    cp.wait()

    def step(s, _):
        src_slot = jax.lax.rem(my - s + npes, npes)
        copy = pltpu.make_async_remote_copy(
            o_ref.at[src_slot], o_ref.at[src_slot], send_sem,
            recv_sems.at[s], device_id={axis_name: right},
            device_id_type=pltpu.DeviceIdType.MESH)
        copy.start()
        copy.wait()          # sent my slot AND received left's slot for step s
        return 0

    jax.lax.fori_loop(0, npes - 1, step, 0)


def ring_allgather(x, *, axis_name: str, npes: int):
    """x: (chunk, ...) per PE -> (npes, chunk, ...): device-initiated fcollect.
    Call inside shard_map."""
    kernel = functools.partial(_ag_kernel, axis_name=axis_name, npes=npes)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((npes,) + x.shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA,
                        pltpu.SemaphoreType.DMA((npes - 1,))],
        interpret=_interpret(),
    )(x)


# ---------------------------------------------------------------------------
# ring reduce-scatter
# ---------------------------------------------------------------------------


def _rs_kernel(x_ref, o_ref, send_buf, recv_buf, acc_v, rcv_v, local_sem,
               send_sem, recv_sems, *, axis_name, npes):
    """Ring reduce-scatter step structure (TPU-idiomatic):

      VMEM acc --local DMA--> HBM send_buf --remote DMA--> right's HBM
      recv_buf[s] --local DMA--> VMEM, add next local addend, repeat.

    recv_buf has one landing slot per step: a fast upstream sub-ring may run
    arbitrarily far ahead of a slow PE (its progress is not gated on ours),
    so a single landing buffer would be overwritten — per-step slots + per-step
    recv semaphores make the pipeline race-free (same structure the all-gather
    uses with its per-slot output writes).
    """
    my = jax.lax.axis_index(axis_name)
    right = jax.lax.rem(my + 1, npes)
    first = jax.lax.rem(my - 1 + npes, npes)
    acc_v[...] = x_ref[first]

    def step(s, _):
        recv_idx = jax.lax.rem(my - 2 - s + 2 * npes, npes)
        cp = pltpu.make_async_copy(acc_v, send_buf, local_sem)
        cp.start()
        cp.wait()
        rcp = pltpu.make_async_remote_copy(
            send_buf, recv_buf.at[s], send_sem, recv_sems.at[s],
            device_id={axis_name: right}, device_id_type=pltpu.DeviceIdType.MESH)
        rcp.start()
        rcp.wait()                      # sent mine AND received left's partial
        cp = pltpu.make_async_copy(recv_buf.at[s], rcv_v, local_sem)
        cp.start()
        cp.wait()
        acc_v[...] = rcv_v[...] + x_ref[recv_idx]
        return 0

    jax.lax.fori_loop(0, npes - 1, step, 0)
    o_ref[...] = acc_v[...]


def ring_reduce_scatter(x, *, axis_name: str, npes: int):
    """x: (npes, chunk...) addends per PE -> (chunk...): PE i returns the full
    sum of chunk i.  Call inside shard_map."""
    chunk_shape = x.shape[1:]
    kernel = functools.partial(_rs_kernel, axis_name=axis_name, npes=npes)
    out = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct(chunk_shape, x.dtype),           # result
            jax.ShapeDtypeStruct(chunk_shape, x.dtype),           # send staging
            jax.ShapeDtypeStruct((npes - 1,) + chunk_shape, x.dtype),  # landings
        ),
        out_specs=(
            pl.BlockSpec(chunk_shape, lambda: (0,) * len(chunk_shape)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ),
        scratch_shapes=[
            pltpu.VMEM(chunk_shape, x.dtype),   # acc
            pltpu.VMEM(chunk_shape, x.dtype),   # recv (VMEM side)
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((npes - 1,)),
        ],
        interpret=_interpret(),
    )(x)
    return out[0]


# ---------------------------------------------------------------------------
# push broadcast
# ---------------------------------------------------------------------------


def _bcast_kernel(x_ref, o_ref, local_sem, send_sem, recv_sem, *, axis_name,
                  npes, root):
    my = jax.lax.axis_index(axis_name)

    @pl.when(my == root)
    def _():
        cp = pltpu.make_async_copy(x_ref, o_ref, local_sem)
        cp.start()
        cp.wait()

        # the paper's push: inner loop over destinations (stores beat loads)
        def send(i, _):
            dst = jax.lax.rem(root + 1 + i, npes)
            cp = pltpu.make_async_remote_copy(
                x_ref, o_ref, send_sem, recv_sem, device_id={axis_name: dst},
                device_id_type=pltpu.DeviceIdType.MESH)
            cp.start()
            cp.wait_send()
            return 0

        jax.lax.fori_loop(0, npes - 1, send, 0)

    @pl.when(my != root)
    def _():
        _wait_incoming(o_ref, recv_sem)


def push_broadcast(x, *, axis_name: str, npes: int, root: int = 0):
    kernel = functools.partial(_bcast_kernel, axis_name=axis_name, npes=npes,
                               root=root)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA,
                        pltpu.SemaphoreType.DMA],
        interpret=_interpret(),
    )(x)


# ---------------------------------------------------------------------------
# push-style barrier (sync)
# ---------------------------------------------------------------------------


def _barrier_kernel(o_ref, sem, *, axis_name, npes):
    my = jax.lax.axis_index(axis_name)

    def send(i, _):
        dst = jax.lax.rem(my + 1 + i, npes)
        pltpu.semaphore_signal(sem, 1, device_id={axis_name: dst},
                               device_id_type=pltpu.DeviceIdType.MESH)
        return 0

    jax.lax.fori_loop(0, npes - 1, send, 0)   # fire-and-forget increments
    pltpu.semaphore_wait(sem, npes - 1)       # local wait on own counter
    o_ref[0] = jnp.int32(1)


def barrier_push(*, axis_name: str, npes: int):
    """Returns 1 on every PE after all PEs arrive.  Call inside shard_map."""
    kernel = functools.partial(_barrier_kernel, axis_name=axis_name, npes=npes)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        scratch_shapes=[pltpu.SemaphoreType.REGULAR],
        interpret=_interpret(),
    )()
