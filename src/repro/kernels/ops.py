"""Jitted dispatch wrappers for the kernel package.

CPU: interpret mode (kernel bodies execute in Python) — used by tests and
benchmarks.  TPU: the same pallas_calls compile to real MXU/ICI programs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import reduce_tile as rt_mod, rma_copy, ring_collectives

LANE = 128


@functools.partial(jax.jit, static_argnames=("offset", "work_items"))
def wg_copy_local(dst_row, src, offset: int, work_items: int = 8):
    return rma_copy.wg_copy_local(dst_row, src, offset,
                                  work_items=work_items)


def copy_into(dst_row, value, offset: int):
    """core.rma direct-path data mover; falls back to .at[].set when the
    transfer is too small/unaligned for the DMA path (exactly the scalar
    store case on hardware)."""
    n = value.shape[0]
    if n % LANE or offset % LANE:
        return dst_row.at[offset:offset + n].set(value)
    g = 8
    while n % (g * LANE) and g > 1:
        g -= 1
    blk = n // g
    if offset % blk or dst_row.shape[0] % blk:
        # block grid must tile the destination row exactly
        return dst_row.at[offset:offset + n].set(value)
    return wg_copy_local(dst_row, value, offset, work_items=g)


@functools.partial(jax.jit, static_argnames=("op", "block"))
def reduce_tile(rows, op: str = "sum", block: int = 512):
    return rt_mod.reduce_tile(rows, op, block=block)


# shard_map-level collectives (call inside shard_map)
ring_allgather = ring_collectives.ring_allgather
ring_reduce_scatter = ring_collectives.ring_reduce_scatter
push_broadcast = ring_collectives.push_broadcast
barrier_push = ring_collectives.barrier_push
remote_put = rma_copy.remote_put


def __getattr__(name):
    # device-initiated attention entry points (lazy: ishmem_device pulls in
    # serve-layer types, and most kernel consumers never need it)
    _DEVICE = ("fused_paged_attn", "paged_gather", "flash_partial",
               "merge_partials", "ring_attention")
    if name in _DEVICE:
        from repro.kernels import ishmem_device
        return getattr(ishmem_device, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def flash_attention(q, k, v, *, block_q: int = 256, block_k: int = 256):
    """Fused causal attention with GQA support (repeats KV heads)."""
    from repro.kernels import flash_attn
    nq, nkv = q.shape[2], k.shape[2]
    if nq != nkv:
        rep = nq // nkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return flash_attn.flash_attention(q, k, v, block_q=block_q,
                                      block_k=block_k)


def ring_allreduce(x, *, axis_name: str, npes: int):
    """Allreduce = ring reduce-scatter + ring all-gather (engine-free,
    device-initiated end to end).  x: (npes, chunk...) addend rows."""
    mine = ring_reduce_scatter(x, axis_name=axis_name, npes=npes)
    return ring_allgather(mine, axis_name=axis_name, npes=npes)


def ring_step_nbi(x, *, axis_name: str, npes: int, work_items: int = 8):
    """One nbi ring step: put the local buffer to the right neighbor, return
    the buffer received from the left.  The building block of the overlapped
    allreduce — the returned value depends only on the *previous transfer*,
    never on local accumulation, so chained steps form a pure transfer chain
    the compiler can run concurrently with the compute hanging off it."""
    return remote_put(x, axis_name=axis_name, npes=npes, target_offset=1,
                      work_items=work_items)


def ring_allreduce_nbi(x, *, axis_name: str, npes: int, work_items: int = 8):
    """Pass-around ring allreduce with comm-compute overlap (paper §III-F).

    Each step issues the next neighbor transfer non-blocking and adds the
    chunk that just arrived: ``cur`` only ever flows transfer -> transfer
    (the critical path), while the adds accumulate off to the side.  The
    dependence graph therefore exposes every tile-add for execution UNDER the
    in-flight DMA of the next step — unlike RS+AG, where step k+1's send
    needs step k's reduced value.  Wire cost is npes*n vs RS+AG's 2n, so the
    cutover engine only routes small/medium messages here (see
    ``comms.ShmemOps.psum_overlap``)."""
    acc = x
    cur = x
    for _ in range(npes - 1):
        cur = ring_step_nbi(cur, axis_name=axis_name, npes=npes,
                            work_items=work_items)   # in flight...
        acc = acc + cur                              # ...while this computes
    return acc
