"""Device-initiated kernels: fused paged-attention gather + ring attention.

Two consumers of the work-group-collaborative op layer (``core/device.py``):

- :func:`paged_gather` / :func:`fused_paged_attn` — the decode-side fusion.
  The gather kernel walks a slot block table and copies each mapped pool
  block into the assembled payload (scalar-prefetch grid: the table rides
  in SMEM and steers the block index map, exactly how a TPU paged-attention
  kernel addresses its pages).  ``fused_paged_attn`` runs the device-side
  admission protocol in front of it: per-block ``signal_wait_until`` calls
  consume migrated KV blocks *as their put_signal_nbi signals land*, then
  the gathered K/V feeds the same fused flash kernel the dense path uses —
  so the fused output is bitwise-identical to ``assemble`` + flash.
- :func:`ring_attention` — sequence-parallel attention: the KV sequence is
  sharded across simulated PEs and rotated ring-wise, each step computing a
  partial flash (unnormalized accumulator + running max/denominator)
  against the resident shard; partials merge by the standard online-softmax
  combination.  Device-side rotation issue and overlap pricing live in
  ``core.device`` / ``cutover.t_ring_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import device as device_mod
from repro.kernels.flash_attn import NEG_INF, _interpret

# ---------------------------------------------------------------------------
# paged gather (table-steered block copy)
# ---------------------------------------------------------------------------


def _gather_kernel(table_ref, data_ref, o_ref):
    # one program copies one table-mapped block row; the index map already
    # pointed data_ref at row table[b, j]
    del table_ref
    o_ref[0, 0] = data_ref[0]


@functools.partial(jax.jit, static_argnames=())
def _paged_gather_pallas(data, table):
    R, W = data.shape
    B, nb = table.shape
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, nb),
            in_specs=[pl.BlockSpec((1, W), lambda b, j, t: (t[b, j], 0))],
            out_specs=pl.BlockSpec((1, 1, W), lambda b, j, t: (b, j, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, nb, W), data.dtype),
        interpret=_interpret(),
    )(table, data)


_GATHER_KERNEL_OK = None


def paged_gather(data, table):
    """Gather block rows through a block table: ``out[b, j] = data[table[b, j]]``.

    ``data``: (num_rows, block_words) — the pool row plus its trailing
    all-zeros page; ``table``: (num_slots, nb) int32.  Runs the scalar-
    prefetch Pallas kernel when the toolchain supports it (a pure copy, so
    bitwise-identical to the jnp gather it falls back to)."""
    global _GATHER_KERNEL_OK
    table = jnp.asarray(table, jnp.int32)
    if _GATHER_KERNEL_OK is None:
        try:
            probe = _paged_gather_pallas(
                jnp.arange(8, dtype=jnp.float32).reshape(2, 4),
                jnp.asarray([[1, 0]], jnp.int32))
            _GATHER_KERNEL_OK = bool(
                np.array_equal(np.asarray(probe[0, 0]), [4., 5., 6., 7.]))
        except Exception:
            _GATHER_KERNEL_OK = False
    if _GATHER_KERNEL_OK:
        return _paged_gather_pallas(data, table)
    return data[table]


# ---------------------------------------------------------------------------
# fused paged attention
# ---------------------------------------------------------------------------


def _leaf_offsets(lay):
    offs = {}
    off = 0
    for leaf in lay.paged:
        offs[(leaf.unit_idx, leaf.key)] = off
        off += leaf.words_per_token * lay.block_tokens
    return offs


def _extract_leaf(pay, lay, leaf, num_slots, off):
    """Rebuild one paged leaf from the gathered payload — the EXACT
    ``PagedDecodeView.assemble`` slicing, so the result is bitwise what the
    dense rehydrate would hold."""
    T = lay.block_tokens
    nb = lay.blocks_per_request
    n = leaf.words_per_token * T
    out = pay[:, :, off:off + n].reshape(
        num_slots, nb, leaf.reps, T, leaf.nkv, leaf.hd)
    return out.transpose(2, 0, 1, 3, 4, 5).reshape(
        leaf.reps, num_slots, nb * T, leaf.nkv, leaf.hd)[:, :, :leaf.width]


def fused_paged_attn(wg, heap, view, q, *, unit_idx=None, layer: int = 0,
                     waits=(), dtype=None):
    """Device-initiated fused gather + attention over the paged KV pool.

    ``wg`` is the calling work-group (``core.device.work_group``), ``view``
    a ``serve.paged_attn.PagedDecodeView``.  ``waits`` is a sequence of
    ``(sig_ptr, expected)`` pairs consumed via device ``signal_wait_until``
    BEFORE any block byte is read — the fusion protocol's per-block gates.
    ``q``: (num_slots, W, nq, hd) queries against the assembled width.

    Returns ``(heap, out)`` with ``out`` bitwise-identical to gathering the
    same leaves through ``view.assemble`` and running ``ops.flash_attention``
    (the A/B the tests and ``bench_device`` assert).
    """
    from repro.kernels import ops

    for sig_ptr, expected in waits:
        heap, _, ok = device_mod.signal_wait_until(
            wg, heap, sig_ptr, view.pe, "ge", expected)
        if not ok:
            raise RuntimeError(
                "fused_paged_attn: signal can never satisfy its wait — "
                "reading a block here would observe pre-signal bytes")
    lay = view.pool.layout
    if not lay.paged:
        raise ValueError("fused_paged_attn requires a paged layout")
    if unit_idx is None:
        unit_idx = lay.paged[0].unit_idx
    k_leaf = next(p for p in lay.paged
                  if p.unit_idx == unit_idx and p.key == "k")
    v_leaf = next(p for p in lay.paged
                  if p.unit_idx == unit_idx and p.key == "v")
    # collaborative local load of the pool row (device_get telemetry at the
    # group's width), then the table-steered gather kernel
    data = device_mod.get(wg, heap, view.pool.data, view.pe).reshape(
        view.pool.num_blocks, lay.block_words)
    data = jnp.concatenate(
        [data, jnp.zeros((1, lay.block_words), data.dtype)], axis=0)
    nb = lay.blocks_per_request
    table = np.full((view.num_slots, nb), view.pool.num_blocks, np.int32)
    for s, sm in view.slots.items():
        ids = view.pool.blocks_of(sm.req_id)
        table[s, :len(ids)] = ids
    pay = paged_gather(data, table)
    offs = _leaf_offsets(lay)
    k = _extract_leaf(pay, lay, k_leaf, view.num_slots,
                      offs[(unit_idx, "k")])[layer]
    v = _extract_leaf(pay, lay, v_leaf, view.num_slots,
                      offs[(unit_idx, "v")])[layer]
    if dtype is not None:
        k = k.astype(dtype)
        v = v.astype(dtype)
    return heap, ops.flash_attention(q, k, v)


# ---------------------------------------------------------------------------
# sequence-parallel ring attention
# ---------------------------------------------------------------------------


def _flash_partial_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                          bq, bk, scale, q_off, k_off):
    """Flash tile against ONE resident KV shard: emits the UNNORMALIZED
    accumulator plus running (max, denominator) so shard partials merge by
    the online-softmax combination.  ``q_off``/``k_off`` are the shards'
    absolute sequence positions — causality is global, not shard-local."""
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # (bq, hd)
    Skv = k_ref.shape[1]
    nkb = pl.cdiv(Skv, bk)

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(j * bk, bk)].astype(jnp.float32)
        v = v_ref[0, pl.ds(j * bk, bk)].astype(jnp.float32)
        s = q @ k.T
        qpos = q_off + qi * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        kpos = k_off + j * bk + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkb, body, (m0, l0, a0))
    o_ref[0] = acc
    m_ref[0] = m
    l_ref[0] = l


def flash_partial(q, k, v, *, q_off: int, k_off: int, block_q: int = 256,
                  block_k: int = 256):
    """One ring step's partial attention.  q: (B, Sq, H, hd) — the local
    query shard; k, v: (B, Skv, H, hd) — the KV shard currently resident.
    Returns (acc, m, l): unnormalized output (B, Sq, H, hd) f32 and the
    per-position softmax state (B, Sq, H) f32."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    while Sq % bq:
        bq //= 2
    bk = min(block_k, Skv)
    while Skv % bk:
        bk //= 2
    scale = hd ** -0.5

    def flat(t, S):
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    qf, kf, vf = flat(q, Sq), flat(k, Skv), flat(v, Skv)
    acc, m, l = pl.pallas_call(
        functools.partial(_flash_partial_kernel, bq=bq, bk=bk, scale=scale,
                          q_off=q_off, k_off=k_off),
        grid=(B * H, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Skv, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Skv, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, Sq), jnp.float32),
            jax.ShapeDtypeStruct((B * H, Sq), jnp.float32),
        ],
        interpret=_interpret(),
    )(qf, kf, vf)

    def unflat(t, trail):
        return t.reshape((B, H, Sq) + trail).transpose(
            (0, 2, 1) + tuple(range(3, 3 + len(trail))))

    return unflat(acc, (hd,)), unflat(m, ()), unflat(l, ())


def merge_partials(parts):
    """Combine per-shard (acc, m, l) partials into the softmax-correct
    output: ``m* = max m_i``, ``l* = sum l_i e^{m_i - m*}``,
    ``o = sum acc_i e^{m_i - m*} / l*``."""
    ms = jnp.stack([m for _, m, _ in parts])          # (n, B, Sq, H)
    m_tot = ms.max(axis=0)
    w = jnp.exp(ms - m_tot[None])                     # (n, B, Sq, H)
    l_tot = jnp.stack([l for _, _, l in parts])
    l_tot = (l_tot * w).sum(axis=0)
    acc = jnp.stack([a for a, _, _ in parts])         # (n, B, Sq, H, hd)
    out = (acc * w[..., None]).sum(axis=0)
    return out / jnp.maximum(l_tot, 1e-30)[..., None]


def ring_attention(q, k, v, *, npes: int, block_q: int = 256,
                   block_k: int = 256):
    """Sequence-parallel causal attention: the sequence is sharded across
    ``npes`` ring positions (PE i holds q/k/v shard i), and KV shards rotate
    around the ring — at step t, shard i computes a partial against KV shard
    ``(i - t) mod npes``.  Causality means only shards j <= i contribute, so
    the schedule is exactly the device-initiated ring the overlap model
    (``cutover.t_ring_attention``) prices: issue next rotation, compute
    resident partial, merge.

    q, k, v: (B, S, H, hd) with S % npes == 0 (GQA: equal head counts —
    callers repeat KV heads first, like ``flash_attn.flash_attention``).
    Returns (B, S, H, hd) matching full-sequence causal attention up to
    float associativity (the partial merge reorders the softmax sums).
    """
    B, S, H, hd = q.shape
    assert S % npes == 0, "sequence must shard evenly over ring PEs"
    Sh = S // npes
    shards_q = [q[:, i * Sh:(i + 1) * Sh] for i in range(npes)]
    shards_k = [k[:, i * Sh:(i + 1) * Sh] for i in range(npes)]
    shards_v = [v[:, i * Sh:(i + 1) * Sh] for i in range(npes)]
    outs = []
    for i in range(npes):
        parts = []
        for t in range(npes):
            j = (i - t) % npes
            if j > i:                    # future shard: fully masked, skip
                continue
            parts.append(flash_partial(
                shards_q[i], shards_k[j], shards_v[j],
                q_off=i * Sh, k_off=j * Sh,
                block_q=block_q, block_k=block_k))
        outs.append(merge_partials(parts))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)
