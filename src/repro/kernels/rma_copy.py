"""Work-group collaborative RMA copy kernels (paper §III-F, Fig. 4).

Two kernels:

- ``wg_copy_local``: the data-movement body of ``ishmemx_put_work_group`` —
  a tiled VMEM copy where the grid dimension plays the SYCL work-group role
  (more programs <=> more work-items <=> more outstanding bytes).  The target
  offset arrives by scalar prefetch, exactly how a TPU kernel computes DMA
  addresses from a symmetric-heap base.

- ``remote_put``: the device-initiated remote put — ``make_async_remote_copy``
  over ICI to a target PE, issued from inside a running kernel with
  ``work_items`` outstanding DMA slices (the TPU analogue of N work-items
  driving Xe-Link stores).  Runs under shard_map; validated in TPU interpret
  mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _interpret():
    if jax.default_backend() == "tpu":
        return False
    params = getattr(pltpu, "InterpretParams", None)  # absent pre-jax-0.5
    return params() if params is not None else True


# ---------------------------------------------------------------------------
# local tiled copy (the work-group put body)
# ---------------------------------------------------------------------------


def _copy_block_kernel(off_ref, src_ref, dst_in_ref, dst_ref):
    del off_ref, dst_in_ref
    dst_ref[...] = src_ref[...]


def wg_copy_local(dst_row, src, offset, *, work_items: int = 8):
    """Copy ``src`` (len multiple of 128) into ``dst_row`` at ``offset``
    (multiple of the block size).  Grid = work_items programs."""
    n = src.shape[0]
    assert n % LANE == 0, "RMA sizes are lane (128) aligned"
    g = max(1, min(work_items, n // LANE))
    while n % (g * LANE):
        g -= 1
    blk = n // g
    assert offset % blk == 0, "offset must be block aligned (ALIGN=128 heap)"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i, off: (i,)),
            pl.BlockSpec((blk,), lambda i, off: (off[0] // blk + i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i, off: (off[0] // blk + i,)),
    )
    return pl.pallas_call(
        _copy_block_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_row.shape, dst_row.dtype),
        input_output_aliases={2: 0},     # dst_in -> out (untouched blocks keep)
        interpret=_interpret(),
    )(jnp.asarray([offset], jnp.int32), src, dst_row)


# ---------------------------------------------------------------------------
# device-initiated remote put (inside shard_map)
# ---------------------------------------------------------------------------


def _remote_put_kernel(x_ref, o_ref, send_sem, recv_sem, *, axis_name,
                       target_offset, npes, work_items):
    my = jax.lax.axis_index(axis_name)
    tgt = jax.lax.rem(my + target_offset, npes)
    n = x_ref.shape[0]
    w = max(1, min(work_items, n // LANE))
    blk = n // w
    # issue `w` outstanding remote DMA slices — the work-item knob
    for i in range(w):
        sl = pl.ds(i * blk, blk)
        pltpu.make_async_remote_copy(
            x_ref.at[sl], o_ref.at[sl], send_sem, recv_sem,
            device_id={axis_name: tgt},
            device_id_type=pltpu.DeviceIdType.MESH,
        ).start()
    for i in range(w):
        sl = pl.ds(i * blk, blk)
        pltpu.make_async_remote_copy(
            x_ref.at[sl], o_ref.at[sl], send_sem, recv_sem,
            device_id={axis_name: tgt},
            device_id_type=pltpu.DeviceIdType.MESH,
        ).wait()


def remote_put(x, *, axis_name: str, npes: int, target_offset: int = 1,
               work_items: int = 1):
    """Every PE puts its buffer into PE (me+target_offset)'s output buffer.
    Call inside shard_map over ``axis_name``."""
    kernel = functools.partial(
        _remote_put_kernel, axis_name=axis_name,
        target_offset=target_offset, npes=npes, work_items=work_items)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
        interpret=_interpret(),
    )(x)
