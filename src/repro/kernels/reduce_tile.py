"""Vectorized tile reduction kernel (paper §III-G2 "Reduction"):

"split the reduction by address across threads, each thread uses vector loads
... vector binary operations ... vector stores" — on TPU the address split is
the grid, each program reduces a (T, block) VMEM tile over the team axis with
f32 accumulation.  This is the compute body of the engine-path reduce and of
the ring reduce-scatter step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import BINOPS

LANE = 128


def _interpret():
    if jax.default_backend() == "tpu":
        return False
    params = getattr(pltpu, "InterpretParams", None)  # absent pre-jax-0.5
    return params() if params is not None else True


def _reduce_kernel(rows_ref, o_ref, *, op):
    fn = BINOPS[op]
    rows = rows_ref[...]
    acc = rows[0].astype(jnp.float32)
    for i in range(1, rows.shape[0]):
        acc = fn(acc, rows[i].astype(jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


def reduce_tile(rows, op: str = "sum", *, block: int = 512):
    """(T, N) -> (N,), N a multiple of 128; grid over N/block tiles."""
    T, N = rows.shape
    assert N % LANE == 0
    blk = min(block, N)
    while N % blk:
        blk //= 2
    grid = (N // blk,)
    return pl.pallas_call(
        functools.partial(_reduce_kernel, op=op),
        grid=grid,
        in_specs=[pl.BlockSpec((T, blk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), rows.dtype),
        interpret=_interpret(),
    )(rows)
