"""Fused causal flash attention (Pallas, VMEM-resident scores).

The §Perf analysis shows the pure-XLA blockwise attention round-trips its
f32 exp-score tensors through HBM (and the rematerialized backward re-gathers
them) — the dominant memory/collective cost of every train/prefill dry-run.
This kernel keeps the (bq, bk) score tile in VMEM: HBM traffic is q/k/v/o
only.

Layout: inputs flattened to (BH, S, hd); grid = (BH, S/bq); each program
holds one q tile and streams kv tiles with an online softmax.  GQA callers
repeat KV heads first (`ops.flash_attention` handles that).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _interpret():
    if jax.default_backend() == "tpu":
        return False
    params = getattr(pltpu, "InterpretParams", None)  # absent pre-jax-0.5
    return params() if params is not None else True


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, scale):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    S = k_ref.shape[1]
    hd = q.shape[-1]
    hi = (qi + 1) * bq                                # causal kv limit
    nkb = pl.cdiv(hi, bk)

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(j * bk, bk)].astype(jnp.float32)   # (bk, hd)
        v = v_ref[0, pl.ds(j * bk, bk)].astype(jnp.float32)
        s = q @ k.T                                   # (bq, bk) — stays in VMEM
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkb, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, block_q: int = 256, block_k: int = 256):
    """Causal attention, equal head counts.  q,k,v: (B, S, H, hd)."""
    B, S, H, hd = q.shape
    assert k.shape == v.shape == (B, S, H, hd), "repeat KV heads first (GQA)"
    bq = min(block_q, S)
    while S % bq:
        bq //= 2
    bk = min(block_k, S)
    while S % bk:
        bk //= 2
    scale = hd ** -0.5

    def flat(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    qf, kf, vf = flat(q), flat(k), flat(v)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale),
        grid=(B * H, S // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=_interpret(),
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
