"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp

BINOPS = {
    "sum": jnp.add,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "prod": jnp.multiply,
}


def wg_copy(dst_row, src, offset: int):
    """Copy src into dst_row at offset (the work-group put data movement)."""
    return jnp.asarray(dst_row).at[offset:offset + src.size].set(src)


def reduce_tile(rows, op: str = "sum"):
    """(T, N) -> (N,): vector binary-op reduction over the team axis."""
    fn = BINOPS[op]
    acc = rows[0].astype(jnp.float32) if rows.dtype != jnp.int32 else rows[0]
    for i in range(1, rows.shape[0]):
        acc = fn(acc, rows[i].astype(acc.dtype))
    return acc.astype(rows.dtype)


def ring_allgather(shards):
    """(npes, chunk...) per-device inputs -> (npes, npes*chunk...) outputs:
    every device ends with every chunk, own chunk at slot == device index."""
    npes = shards.shape[0]
    full = shards.reshape((npes,) + shards.shape[1:])
    return jnp.broadcast_to(full[None], (npes,) + full.shape)


def ring_reduce_scatter(x):
    """x: (npes, npes, chunk...) — device i holds addend rows for all chunks.
    Returns (npes, chunk...): device i gets sum over devices of chunk i."""
    total = x.sum(axis=0)                  # (npes, chunk...)
    return total


def ring_allreduce(x):
    """x: (npes, n...) -> (npes, n...): every device gets the sum."""
    s = x.sum(axis=0)
    return jnp.broadcast_to(s[None], x.shape)


def push_broadcast(x, root: int):
    """x: (npes, n...) -> all rows replaced by row[root]."""
    return jnp.broadcast_to(x[root][None], x.shape)


def flash_attention(q, k, v):
    """Causal attention oracle, equal heads.  q,k,v: (B,S,H,hd)."""
    import jax
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
