"""Disaggregated continuous-batching scheduler.

Ties the serving subsystem together: a request queue feeding a fleet of
prefill PEs, SHMEM paged-KV migration to decode PEs (``serve/kvxfer.py``) —
whole-prefill or chunked-streaming — signal-threshold-gated admission into
decode slots, paged decode straight out of the block pool
(``serve/paged_attn.py``), shared-prefix block reuse with copy-on-write,
slot rotation mid-flight, and refcount-correct eviction back to the pool.

Request state machine (DESIGN.md §9):

    QUEUED --prefill+stage--> STAGED --migrate(nbi)-----------> MIGRATING
        |                       \\--open_stream--> STREAMING --close--/
        |                                            (chunk k flushes under
        |                                             chunk k+1's compute)
        --signal >= threshold--> DECODING --max_new/eos--> FINISHED
                                     \\--evict: refs dropped, slot re-armed

One ``step()`` advances every stage once — the order (stream, prefill,
admit, decode) means a migration issued this step stays *pending* (deferred
nbi traffic) while decode keeps stepping resident requests, and a streaming
request's previous chunk drains while its next chunk "computes": migration
overlaps prefill AND decode exactly the way the completion engine overlaps
any nbi transfer.  The admission flush only pays for what is still in
flight — under streaming that is just the final chunk, which is the
time-to-first-decode win ``stats.ttfd_model_s`` measures.

The scheduler is the control plane a real deployment runs host-side; the
data plane (block payloads, signals, headers) moves exclusively through the
symmetric heap via one-sided ops.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.serve import kvpool as kvpool_mod
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kvxfer import EXTRA_SIGNALS, KVMigrator, StreamState
from repro.serve.paged_attn import PagedDecodeView

QUEUED, STAGED, STREAMING, MIGRATING, DECODING, FINISHED = (
    "queued", "staged", "streaming", "migrating", "decoding", "finished")


@dataclasses.dataclass
class Request:
    rid: int
    batch: dict                     # {"tokens": (1,S)} + frontend embeds
    max_new: int
    state: str = QUEUED
    prefill_pe: int = -1
    decode_pe: int = -1
    slot: int = -1
    first_token: int = -1
    expected_sig: int = 0
    out: List[int] = dataclasses.field(default_factory=list)
    submit_step: int = -1
    migrate_step: int = -1
    admit_step: int = -1
    admit_ready_step: int = 0       # modeled wire latency gate
    # prefill result parked here while the request waits for pool blocks, so
    # a stall never re-runs the model
    prefill_cache: Optional[dict] = None
    # shared-prefix policy state
    prefix_len: int = 0
    prefix_key: Optional[tuple] = None
    shared_ids: List[int] = dataclasses.field(default_factory=list)
    cow_plan: Dict[int, int] = dataclasses.field(default_factory=dict)
    stream: Optional[StreamState] = None
    # modeled comm clock when the migration finished issuing (whole-prefill:
    # the staging step; streamed: stream close) — t_admit - t_submit is the
    # wire window admission still has to wait out
    t_submit: float = 0.0
    t_admit: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.batch["tokens"].shape[1])


@dataclasses.dataclass
class PrefixEntry:
    """One registered shareable prefix: the physical blocks, where their
    staged payload lives, and which of them each decode PE already holds.
    Residency is per (PE, block), not per PE: a shorter-prefix mapper only
    carries ``block_ids[:P//T]`` over the wire, so a whole-prompt mapper
    admitted to the same PE later must still send the boundary block."""
    key: tuple
    block_ids: List[int]
    whole_prompt: bool              # ids include the partial boundary block
    home_pe: int
    resident: Dict[int, set]        # decode PE -> entry block ids landed there
    refs: int = 0                   # live requests mapping these blocks


@dataclasses.dataclass
class SchedStats:
    prefills: int = 0
    migrations: int = 0
    admissions: int = 0
    evictions: int = 0
    decode_steps: int = 0
    decode_tokens: int = 0
    bytes_migrated: int = 0
    stalled_on_pool: int = 0        # prefills deferred because no free blocks
    stalled_on_slots: int = 0       # migrations deferred because no free slot
    stream_chunks: int = 0          # mid-prefill wire installments issued
    prefix_hits: int = 0            # requests that mapped an existing prefix
    blocks_prefix_shared: int = 0   # physical blocks reused via incref
    bytes_wire_saved: int = 0       # resident-at-dst blocks never re-sent
    cow_copies: int = 0             # divergent writes that copied a block
    ttfd_steps: List[int] = dataclasses.field(default_factory=list)
    ttfd_model_s: List[float] = dataclasses.field(default_factory=list)


class DisaggScheduler:
    """Drives prefill PEs, the migration engine, and decode slot banks."""

    def __init__(self, ctx, heap, engine: Engine, pool, migrator: KVMigrator,
                 *, prefill_pes: List[int], decode_pes: List[int],
                 num_slots: int, scfg: ServeConfig = ServeConfig(),
                 prefills_per_step: Optional[int] = None,
                 admit_delay_steps: int = 0, paged: bool = True,
                 stream_chunks: int = 0, shared_prefix: bool = False):
        if num_slots > pool.max_slots:
            raise ValueError(
                f"num_slots ({num_slots}) exceeds the pool's per-PE slot "
                f"regions (max_slots={pool.max_slots})")
        self.ctx = ctx
        self.heap = heap
        self.engine = engine
        self.pool = pool
        self.migrator = migrator
        self.prefill_pes = list(prefill_pes)
        self.decode_pes = list(decode_pes)
        self.scfg = scfg
        self.prefills_per_step = (len(self.prefill_pes)
                                  if prefills_per_step is None
                                  else prefills_per_step)
        # modeled wire latency in scheduler steps: a migration issued at
        # step N is only *polled* from step N + delay, so its nbi traffic
        # stays deferred while decode keeps stepping — migration overlapped
        # under decode.  Streamed migrations scale the delay by the final
        # installment's share of the wire (the rest already drained).
        self.admit_delay_steps = admit_delay_steps
        # paged decode: slots read K/V through block tables, no rehydrate;
        # False falls back to the PR-3 dense-copy admission (A/B baseline)
        self.paged = paged
        self.stream_chunks = stream_chunks      # blocks per installment; 0=off
        self.shared_prefix = shared_prefix
        self.views: Dict[int, PagedDecodeView] = (
            {pe: PagedDecodeView(pool, pe, num_slots) for pe in decode_pes}
            if paged else {})
        self.queue: deque = deque()
        self.requests: Dict[int, Request] = {}
        self.staged: deque = deque()            # blocks held, awaiting a slot
        self.streaming: List[Request] = []      # chunked migrations in flight
        self.migrating: List[Request] = []
        self.prefix_index: Dict[tuple, PrefixEntry] = {}
        # per-decode-PE slot banks (each decode PE owns num_slots slots)
        self.banks = {pe: engine.init_slots(num_slots) for pe in decode_pes}
        self.slot_req: Dict[int, List[Optional[int]]] = {
            pe: [None] * num_slots for pe in decode_pes}
        self.stats = SchedStats()
        self._rr_prefill = 0
        self._rr_decode = 0
        self._step = 0
        self._next_rid = 0
        self._key = jax.random.key(scfg.seed)

    # ------------------------------------------------------------- intake
    def submit(self, batch: dict, *, max_new: Optional[int] = None,
               prefix_len: int = 0) -> int:
        """Enqueue one request ({\"tokens\": (1,S)} [+ frontend embeds]).
        ``prefix_len`` declares the first N prompt tokens shareable with
        other requests declaring the same tokens (shared-prefix policy)."""
        if max_new is None:
            max_new = self.scfg.max_new_tokens
        S = int(batch["tokens"].shape[1])
        if S + max_new > self.engine.max_len + 1:
            raise ValueError(
                f"prompt ({S}) + max_new ({max_new}) exceeds the decode "
                f"cache (max_len={self.engine.max_len})")
        if not 0 <= prefix_len <= S:
            raise ValueError(f"prefix_len {prefix_len} outside [0, {S}]")
        lay = self.pool.layout
        need = (lay.blocks_for_decode(S, max_new) if self.paged
                else lay.blocks_for_prompt(S))
        if self._needs_boundary_cow(batch, prefix_len, S):
            need += 1
        if need > self.pool.num_blocks:
            raise ValueError(
                f"request needs {need} KV blocks but the pool holds only "
                f"{self.pool.num_blocks} — no schedule can ever admit it")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, batch=batch, max_new=max_new,
                      prefix_len=prefix_len if self.shared_prefix else 0)
        req.submit_step = self._step
        self.queue.append(req)
        self.requests[rid] = req
        return rid

    def _comm_clock(self) -> float:
        """Modeled comm seconds excluding the migrator's advisory per-block
        records (those price each block standalone for the tuner; the real
        wire cost lands at flush time and would otherwise double-count)."""
        advisory = sum(
            b.time_total for k, b in self.ctx.telemetry.buckets.items()
            if k[0] == "kvxfer_block")
        return self.ctx.total_time() - advisory

    # ------------------------------------------------------ prefix sharing
    def _sharable(self, batch: dict, prefix_len: int) -> bool:
        """Sharability gates (DESIGN.md §9.3).  Ring layouts never share:
        occupied slots wrap through every block, so no block is
        suffix-independent.  Batches carrying non-token inputs (frontend
        embeds) never share either: cross-attention makes K/V depend on
        them beyond the token prefix, so a token-keyed index cannot prove
        two requests' blocks equal."""
        return (self.shared_prefix and prefix_len > 0
                and not self.pool.layout.ring
                and not any(k != "tokens" for k in batch))

    def _needs_boundary_cow(self, batch: dict, prefix_len: int,
                            prompt_len: int) -> bool:
        """True when staging this request standalone reserves a private
        block for the whole-prompt boundary (R1) — the worst-case extra
        pool demand submit()'s feasibility check must charge, or _stage
        demands a block the check never counted and the request re-queues
        forever."""
        return (self.paged and self._sharable(batch, prefix_len)
                and prefix_len == prompt_len
                and prefix_len % self.pool.layout.block_tokens != 0)

    def _prefix_plan(self, req: Request):
        """(shared_ids, key, n_entry): which table prefix this request maps
        from the index (hit) or will register (miss).  Policy: only whole
        blocks inside the declared prefix are sharable, plus the partial
        boundary block when the prefix IS the whole prompt (the
        many-samples-one-prompt case — the first divergent decode write
        copy-on-writes it); see _sharable for the hard gates."""
        lay = self.pool.layout
        if not self._sharable(req.batch, req.prefix_len):
            return [], None, 0
        P, S, T = req.prefix_len, req.prompt_len, lay.block_tokens
        whole = P == S
        n_own = P // T + (1 if whole and P % T else 0)
        if n_own == 0:
            return [], None, 0      # prefix shorter than one block
        key = tuple(int(t) for t in np.asarray(req.batch["tokens"])[0, :P])
        entry = self.prefix_index.get(key)
        if entry is None:
            return [], key, n_own   # miss: register after staging
        usable = (entry.block_ids if (whole and entry.whole_prompt)
                  else entry.block_ids[:P // T])
        if not usable:
            return [], None, 0
        return list(usable), key, len(usable)

    def _cow_range(self, req: Request, n_entry: int):
        """Table indices decode will write that map prefix-entry blocks —
        at most the boundary block of a whole-prompt prefix."""
        lay = self.pool.layout
        if not self.paged or lay.ring or n_entry == 0:
            return range(0)
        return range(req.prompt_len // lay.block_tokens, n_entry)

    # -------------------------------------------------------------- phases
    def _next_prefill_pe(self) -> Optional[int]:
        """Round-robin over prefill PEs not occupied by a chunked stream
        (a streaming PE is still 'computing' its current request)."""
        busy = {r.prefill_pe for r in self.streaming}
        for _ in range(len(self.prefill_pes)):
            pe = self.prefill_pes[self._rr_prefill % len(self.prefill_pes)]
            self._rr_prefill += 1
            if pe not in busy:
                return pe
        return None

    def _phase_stream(self) -> None:
        """Advance every chunked migration one installment: drain the
        previous chunk's queue prefix (the wire works while this chunk's
        prefill compute runs), then either issue the next chunk or close
        the stream (remaining blocks + tail + header)."""
        for req in list(self.streaming):
            st = req.stream
            self.heap = self.migrator.stream_flush(self.heap, st)
            if len(st.pending) > self.stream_chunks:
                self.heap = self.migrator.stream_chunk(self.heap, st,
                                                       self.stream_chunks)
            else:
                self.heap, report = self.migrator.stream_close(self.heap, st)
                self.streaming.remove(req)
                total = st.sent + EXTRA_SIGNALS
                delay = -(-self.admit_delay_steps * st.final_wire // total)
                self._finish_migrate(req, report, delay=delay)

    def _phase_prefill(self) -> None:
        """Advance streams, retry slot assignment for already-staged
        requests, then pop queued requests onto free prefill PEs
        (round-robin), prefill each, stage + start its migration."""
        self._phase_stream()
        for _ in range(len(self.staged)):
            self._try_migrate(self.staged.popleft())
        for _ in range(self.prefills_per_step):
            if not self.queue:
                return
            req = self.queue.popleft()
            if req.prefill_cache is None:            # not prefilled yet
                pe = self._next_prefill_pe()
                if pe is None:                       # every PE mid-stream
                    self.queue.appendleft(req)
                    return
                req.prefill_pe = pe
                key = jax.random.fold_in(self._key, req.rid)
                tok, _, cache1 = self.engine.prefill_request(
                    req.batch, key, self.scfg.temperature)
                req.first_token = tok
                req.prefill_cache = cache1
                self.stats.prefills += 1
            if not self._stage(req):                 # pool exhausted: park
                self.stats.stalled_on_pool += 1      # the prefilled request
                self.queue.appendleft(req)
                return

    def _stage(self, req: Request) -> bool:
        """Stage a prefilled request into the pool: shared-prefix mapping,
        payload staging, prefix registration, and COW reservations — all or
        nothing against the free list, so a stall leaves no references."""
        lay = self.pool.layout
        shared_ids, key, n_entry = self._prefix_plan(req)
        max_new = req.max_new if self.paged else 0
        # the same formula stage() allocates with — the headroom check and
        # the allocation must agree, or reserve() below could come up empty
        n_table = lay.blocks_for_decode(req.prompt_len, max_new)
        n_cow = len(self._cow_range(req, n_entry))
        if n_table - len(shared_ids) + n_cow > self.pool.free_blocks():
            return False
        self.heap, ids = self.migrator.stage(
            self.heap, req.rid, req.prefill_cache,
            prompt_len=req.prompt_len, src_pe=req.prefill_pe,
            max_new=max_new, shared_ids=shared_ids)
        assert ids is not None       # free-list head-room checked above
        req.shared_ids = shared_ids
        if key is not None:
            if key not in self.prefix_index:
                self.prefix_index[key] = PrefixEntry(
                    key=key, block_ids=ids[:n_entry],
                    whole_prompt=req.prefix_len == req.prompt_len,
                    home_pe=req.prefill_pe, resident={})
                # the entry owns a reference on its blocks: mappers that
                # copy-on-write away drop THEIR ref, but the blocks must
                # outlive every mapper (and stay out of the free list) until
                # the entry itself dies — else a recycled block could be
                # zeroed as another request's growth while still mapped
                self.pool.incref(self.prefix_index[key].block_ids)
            entry = self.prefix_index[key]
            entry.refs += 1
            req.prefix_key = key
            if shared_ids:
                self.stats.prefix_hits += 1
                self.stats.blocks_prefix_shared += len(shared_ids)
        for b in self._cow_range(req, n_entry):
            req.cow_plan[b] = self.pool.reserve(1)[0]
        req.prefill_cache = None                 # staged in the pool now
        req.state = STAGED
        self._try_migrate(req)
        return True

    def _try_migrate(self, req: Request) -> None:
        """Assign a (decode PE, slot) and put the request on the wire —
        one shot, or as the first installment of a chunked stream."""
        pe, slot = self._pick_slot()
        if slot is None:
            self.stats.stalled_on_slots += 1
            self.staged.append(req)
            return
        req.decode_pe, req.slot = pe, slot
        self.slot_req[pe][slot] = req.rid
        skip = self._resident_skip(req, pe)
        if self.stream_chunks > 0:
            st = self.migrator.open_stream(
                req.rid, src_pe=req.prefill_pe, dst_pe=pe, slot=slot,
                prompt_len=req.prompt_len, first_token=req.first_token,
                skip=skip)
            if not st.pending:
                # fully resident prefix: no blocks to stream — close now
                # (tail + header only) instead of burning a scheduler step
                # on a phantom zero-block installment, matching the
                # whole-prefill path's admission timing
                self.heap, report = self.migrator.stream_close(self.heap, st)
                self._finish_migrate(req, report,
                                     delay=self.admit_delay_steps)
                return
            req.stream = st
            req.state = STREAMING
            self.streaming.append(req)
            # first installment leaves the same step its blocks "fill"
            self.heap = self.migrator.stream_chunk(self.heap, st,
                                                   self.stream_chunks)
            return
        self.heap, report = self.migrator.migrate(
            self.heap, req.rid, src_pe=req.prefill_pe, dst_pe=pe,
            slot=slot, prompt_len=req.prompt_len,
            first_token=req.first_token, skip=skip)
        self._finish_migrate(req, report, delay=self.admit_delay_steps)

    def _resident_skip(self, req: Request, dst_pe: int) -> frozenset:
        """Shared blocks already migrated to this decode PE by an earlier
        request never travel again (COW keeps them pristine there).  Skip
        only the intersection with the blocks recorded resident at this
        PE: an earlier mapper may have carried fewer entry blocks than
        this request maps (it skipped the whole-prompt boundary block),
        and skipping an absent block would admit stale pool-row bytes."""
        if req.prefix_key is None or not req.shared_ids:
            return frozenset()
        resident = self.prefix_index[req.prefix_key].resident.get(
            dst_pe, frozenset())
        return frozenset(req.shared_ids) & frozenset(resident)

    def _finish_migrate(self, req: Request, report, *, delay: int) -> None:
        req.expected_sig = report.expected_signal
        req.state = MIGRATING
        req.migrate_step = self._step
        req.admit_ready_step = self._step + delay
        req.t_submit = self._comm_clock()
        self.migrating.append(req)
        self.stats.migrations += 1
        self.stats.bytes_migrated += report.bytes_total
        self.stats.bytes_wire_saved += report.bytes_skipped
        if self.stream_chunks > 0:
            # report.chunks counts the stream's block-carrying installments
            # (a whole-prefill report never reaches here in streaming mode)
            self.stats.stream_chunks += report.chunks

    def _pick_slot(self):
        """Next (decode_pe, slot) with no resident request, round-robin."""
        n = len(self.decode_pes)
        for k in range(n):
            pe = self.decode_pes[(self._rr_decode + k) % n]
            for s, owner in enumerate(self.slot_req[pe]):
                if owner is None:
                    self._rr_decode += k + 1
                    return pe, s
        return None, None

    def _phase_admit(self) -> None:
        """Signal-threshold-gated admission: a MIGRATING request enters its
        decode slot only once ``signal_wait_until`` observes the threshold
        its closed stream (or whole migration) established."""
        still = []
        for req in self.migrating:
            if self._step < req.admit_ready_step:
                still.append(req)               # wire still "in flight"
                continue
            self.heap, hdr = self.migrator.try_admit(
                self.heap, req.slot, req.decode_pe, req.expected_sig)
            if hdr is None:
                still.append(req)
                continue
            assert hdr["req_id"] == req.rid, "slot/header mismatch"
            bank = self.banks[req.decode_pe]
            lay = self.pool.layout
            if self.paged:
                # no dense rehydrate: the pool row IS the decode KV cache;
                # only the (tiny) non-paged tail enters the slot bank
                tail = self.migrator.gather_tail(self.heap, req.slot,
                                                 req.decode_pe)
                cache = kvpool_mod.insert_tail(lay, bank.cache, req.slot,
                                               tail)
                bank = dataclasses.replace(bank, cache=cache)
                growth = [i for i in self.pool.blocks_of(req.rid)
                          if self.pool.home_of(i) is None]
                self.heap = self.views[req.decode_pe].attach(
                    self.heap, req.slot, req.rid, fresh_ids=growth,
                    cow=req.cow_plan)
                req.cow_plan = {}
            else:
                payloads, tail = self.migrator.gather(
                    self.heap, req.rid, req.slot, req.decode_pe)
                cache = kvpool_mod.insert_blocks(lay, bank.cache, req.slot,
                                                 payloads)
                cache = kvpool_mod.insert_tail(lay, cache, req.slot, tail)
                bank = dataclasses.replace(bank, cache=cache)
            bank = self.engine.activate_slot(
                bank, req.slot, pos=hdr["prompt_len"],
                token=hdr["first_token"])
            self.banks[req.decode_pe] = bank
            if req.prefix_key is not None:
                # the admission wait proved every block this request maps
                # landed at its decode PE (wire-carried or already skipped
                # as resident) — record exactly those entry blocks, not a
                # blanket PE flag.  COW has not fired yet (it triggers on
                # the first divergent decode write), so the table still
                # maps the shared ids.
                entry = self.prefix_index[req.prefix_key]
                entry.resident.setdefault(req.decode_pe, set()).update(
                    set(entry.block_ids) & set(self.pool.blocks_of(req.rid)))
            req.state = DECODING
            req.out.append(hdr["first_token"])
            req.admit_step = self._step
            req.t_admit = self._comm_clock()
            self.stats.admissions += 1
            self.stats.ttfd_steps.append(req.admit_step - req.submit_step)
            self.stats.ttfd_model_s.append(req.t_admit - req.t_submit)
            self._maybe_finish(req)
        self.migrating = still

    def _phase_decode(self) -> None:
        """One decode step over every decode PE that has an active slot
        (the PEs step in parallel on real hardware: one decode iteration)."""
        self._step_key = jax.random.fold_in(self._key, 10_000 + self._step)
        stepped = False
        for pe in self.decode_pes:
            bank = self.banks[pe]
            if not bank.active.any():
                continue
            # per-PE fold: decode PEs must not share sampling noise
            key = jax.random.fold_in(self._step_key, pe)
            if self.paged:
                bank, toks, self.heap = self.engine.decode_slots_paged(
                    bank, key, self.ctx, self.heap, self.views[pe],
                    self.scfg.temperature)
            else:
                bank, toks = self.engine.decode_slots(
                    bank, key, self.scfg.temperature)
            self.banks[pe] = bank
            stepped = True
            for s, rid in enumerate(self.slot_req[pe]):
                if rid is None:
                    continue
                req = self.requests[rid]
                if req.state != DECODING:
                    continue
                req.out.append(int(toks[s]))
                self.stats.decode_tokens += 1
                self._maybe_finish(req)
        if stepped:
            self.stats.decode_steps += 1

    def _maybe_finish(self, req: Request) -> None:
        eos_hit = (self.scfg.eos_id >= 0
                   and req.out and req.out[-1] == self.scfg.eos_id)
        if len(req.out) >= req.max_new or eos_hit:
            # same output contract as Engine.generate: eos is emitted, the
            # remainder zero-pads to max_new (bitwise-comparable rows)
            req.out = (req.out[:req.max_new]
                       + [0] * (req.max_new - len(req.out)))
            req.state = FINISHED
            self._evict(req)

    def _evict(self, req: Request) -> None:
        """Refcount-correct teardown: un-triggered COW reserves go first
        (view bookkeeping), then the table's references — a shared block
        returns to the free list only when its LAST mapper evicts — and the
        prefix-index entry dies with its last reference."""
        if self.paged:
            self.views[req.decode_pe].detach(req.slot)
            self.stats.cow_copies = sum(v.cow_copies
                                        for v in self.views.values())
        self.pool.release(req.rid)
        if req.prefix_key is not None:
            entry = self.prefix_index.get(req.prefix_key)
            if entry is not None:
                entry.refs -= 1
                if entry.refs <= 0:
                    self.pool.release_ids(entry.block_ids)
                    del self.prefix_index[req.prefix_key]
            req.prefix_key = None
        self.heap = self.migrator.reset_slot(self.heap, req.slot,
                                             req.decode_pe)
        bank = self.banks[req.decode_pe]
        self.banks[req.decode_pe] = self.engine.evict_slot(bank, req.slot)
        self.slot_req[req.decode_pe][req.slot] = None
        self.stats.evictions += 1

    # --------------------------------------------------------------- drive
    def step(self) -> None:
        """Advance every pipeline stage once (see module docstring)."""
        self._phase_prefill()
        self._phase_admit()
        self._phase_decode()
        self._step += 1

    def done(self) -> bool:
        return (not self.queue and not self.staged and not self.streaming
                and not self.migrating
                and all(r.state == FINISHED for r in self.requests.values()))

    def run(self, *, max_steps: int = 10_000) -> Dict[int, np.ndarray]:
        """Drive until every submitted request finishes; returns
        {rid: generated token ids}."""
        while not self.done():
            if self._step >= max_steps:
                raise RuntimeError(f"scheduler wedged after {max_steps} steps")
            self.step()
        return {rid: np.asarray(r.out, np.int32)
                for rid, r in self.requests.items()}
