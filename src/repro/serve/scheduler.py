"""Disaggregated continuous-batching scheduler.

Ties the serving subsystem together: a request queue feeding a fleet of
prefill PEs, SHMEM paged-KV migration to decode PEs (``serve/kvxfer.py``) —
whole-prefill or chunked-streaming — signal-threshold-gated admission into
decode slots, paged decode straight out of the block pool
(``serve/paged_attn.py``), shared-prefix block reuse with copy-on-write,
slot rotation mid-flight, and refcount-correct eviction back to the pool.

Request state machine (DESIGN.md §9, frontend extensions §10):

    QUEUED --prefill+stage--> STAGED --migrate(nbi)-----------> MIGRATING
        |                       \\--open_stream--> STREAMING --> PARKED
        |                                 (chunks drain slot-less;   |
        |                                  slot binds at close ------/
        |                                  tail+header -> MIGRATING)
        |--policy shed--> SHED
        --signal >= threshold--> DECODING --max_new/eos--> FINISHED
                 |   ^               \\--evict: refs dropped, slot re-armed
        policy   v   | slot frees
             PREEMPTED (KV parked in the pool, slot surrendered)

Admission is *pluggable*: every point where the scheduler chooses what to
run next — shed-at-submit, which queued request prefills, the order slot
waiters bind, and whether a decoding request is preempted to free a slot —
consults an :class:`AdmissionPolicy`.  The default is strict FCFS with no
shedding and no preemption (the A/B baseline); ``serve/frontend/slo.py``
implements deadline-class scheduling on the same hooks.

Preemption parks a DECODING request back into the pool: its paged KV
already lives there (decode writes back block-wise), so only the little
non-paged tail (SSM states, ring positions, cross-KV) is snapshotted
host-side; the slot is surrendered and the request re-binds a slot on the
same decode PE later, resuming at its exact cursor — under greedy decoding
the resumed stream is bitwise-identical to an uninterrupted run (property-
tested in ``tests/test_fleet.py``).

One ``step()`` advances every stage once — the order (stream, prefill,
admit, resume, decode) means a migration issued this step stays *pending*
(deferred nbi traffic) while decode keeps stepping resident requests, and a
streaming request's previous chunk drains while its next chunk "computes":
migration overlaps prefill AND decode exactly the way the completion engine
overlaps any nbi transfer.  Streams are slot-less while draining (blocks
park in the pool against a stream-signal word); the admission flush only
pays for what is still in flight — under streaming that is just the tail +
header of the close, which is the time-to-first-decode win
``stats.ttfd_model_s`` measures, now even at one slot per decode PE.

``fused_attn=True`` switches the whole migrate/admit/decode contract to the
device-initiated fused protocol (DESIGN.md §12): migrations send tail +
header first and then every block with its OWN signal
(``KVMigrator.migrate_fused``), admission gates on the FIRST resident block
instead of the ``sent + 2`` barrier (``try_admit_fused`` — the modeled comm
clock charges one block of wire, which is the ``ttfd_model_s`` win), and the
decode phase consumes the remaining blocks per-signal with minimal-prefix
device waits before the gather reads them — so the emitted tokens stay
bitwise-identical to the barrier baseline under any schedule.

The scheduler is the control plane a real deployment runs host-side; the
data plane (block payloads, signals, headers) moves exclusively through the
symmetric heap via one-sided ops.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.serve import kvpool as kvpool_mod
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kvxfer import (EXTRA_SIGNALS, KVMigrator, StreamState,
                                fused_admit_signal)
from repro.serve.paged_attn import PagedDecodeView

(QUEUED, STAGED, STREAMING, PARKED, MIGRATING, DECODING, PREEMPTED,
 FINISHED, SHED, RECOVERING, RECOVERED) = (
    "queued", "staged", "streaming", "parked", "migrating",
    "decoding", "preempted", "finished", "shed", "recovering", "recovered")

#: terminal request states (``done()`` waits for every request to reach one).
#: RECOVERED marks a record whose request was adopted by another pod after a
#: whole-pod failure — terminal here, live (as a new rid) over there.
TERMINAL = (FINISHED, SHED, RECOVERED)


@dataclasses.dataclass
class Request:
    rid: int
    batch: dict                     # {"tokens": (1,S)} + frontend embeds
    max_new: int
    state: str = QUEUED
    prefill_pe: int = -1
    decode_pe: int = -1
    slot: int = -1
    first_token: int = -1
    expected_sig: int = 0
    out: List[int] = dataclasses.field(default_factory=list)
    submit_step: int = -1
    arrival_step: int = -1          # frontend arrival (queue time counts)
    prefill_step: int = -1
    migrate_step: int = -1
    admit_step: int = -1
    finish_step: int = -1
    admit_ready_step: int = 0       # modeled wire latency gate
    slo: Optional[object] = None    # frontend deadline class (policy-owned)
    # prefill result parked here while the request waits for pool blocks, so
    # a stall never re-runs the model
    prefill_cache: Optional[dict] = None
    # shared-prefix policy state
    prefix_len: int = 0
    prefix_key: Optional[tuple] = None
    shared_ids: List[int] = dataclasses.field(default_factory=list)
    cow_plan: Dict[int, int] = dataclasses.field(default_factory=dict)
    stream: Optional[StreamState] = None
    park_sig: int = -1              # pool stream-signal id while slot-less
    # preemption snapshot: decode cursor + the non-paged tail (the paged KV
    # stays in the pool, written back block-wise every step)
    resume_pos: int = -1
    resume_tok: int = -1
    park_tail: Optional[object] = None
    preemptions: int = 0
    # recovery bookkeeping: tokens decoded before the fault are REPLAYED
    # (asserted equal, not appended) until ``replayed`` catches up to
    # ``replay_target`` — any surviving request's stream stays bitwise-
    # identical to the no-fault run (DESIGN.md §14)
    replay_target: int = 0
    replayed: int = 0
    recoveries: int = 0
    recover_step: int = -1          # fleet step of the fault (TTFD recovery)
    # fused-protocol bookkeeping (scheduler fused_attn=True): how many wire
    # blocks the migration sent, how many the decode side still has to
    # consume per-signal, and the first step the first block was observed
    # resident (the ttfd_first_block_steps stat; -1 = not yet observed)
    wire_blocks: int = 0
    fused_pending: int = 0
    first_block_step: int = -1
    # modeled comm clock at arrival / when the migration finished issuing
    # (whole-prefill: the staging step; streamed: stream close) — t_admit -
    # t_submit is the wire window admission still has to wait out, t_admit -
    # t_arrival the frontend-visible TTFD including queue time
    t_arrival: float = 0.0
    t_submit: float = 0.0
    t_admit: float = 0.0
    # currently open lifeline span (obs tracing; None = no span open)
    trace_phase: Optional[str] = None

    @property
    def prompt_len(self) -> int:
        return int(self.batch["tokens"].shape[1])


@dataclasses.dataclass
class PrefixEntry:
    """One registered shareable prefix: the physical blocks, where their
    staged payload lives, and which of them each decode PE already holds.
    Residency is per (PE, block), not per PE: a shorter-prefix mapper only
    carries ``block_ids[:P//T]`` over the wire, so a whole-prompt mapper
    admitted to the same PE later must still send the boundary block."""
    key: tuple
    block_ids: List[int]
    whole_prompt: bool              # ids include the partial boundary block
    home_pe: int
    resident: Dict[int, set]        # decode PE -> entry block ids landed there
    refs: int = 0                   # live requests mapping these blocks


class AdmissionPolicy:
    """Pluggable admission/scheduling policy — strict FCFS baseline.

    The scheduler calls these hooks at every choice point; overriding them
    (``serve/frontend/slo.py``) turns the same machinery into a deadline-
    class scheduler without touching the migration protocol.  The baseline
    never sheds, never reorders, never preempts — the A/B control.
    """

    def admit(self, req: Request, queue_len: int) -> bool:
        """Gate at submit time; False sheds the request (state SHED)."""
        return True

    def select(self, queue) -> int:
        """Index into the queue of the next request to prefill."""
        return 0

    def waiting_order(self, reqs: List[Request]) -> List[Request]:
        """Order in which slot waiters (parked streams, preempted
        requests) try to bind freed slots."""
        return list(reqs)

    def preempt_victim(self, req: Request,
                       decoding: List[Request]) -> Optional[Request]:
        """A slot-starved ``req`` may evict one of ``decoding``; return the
        victim or None.  Only paged decode can preempt (the KV must live in
        the pool, not the slot bank)."""
        return None


@dataclasses.dataclass
class SchedStats:
    prefills: int = 0
    migrations: int = 0
    admissions: int = 0
    evictions: int = 0
    decode_steps: int = 0
    decode_tokens: int = 0
    bytes_migrated: int = 0
    bytes_cross_pod: int = 0        # wire bytes that crossed pods (dcn tier)
    stalled_on_pool: int = 0        # prefills deferred because no free blocks
    stalled_on_slots: int = 0       # migrations deferred because no free slot
    stalled_on_streams: int = 0     # stream signals exhausted (parked storm)
    stream_chunks: int = 0          # mid-prefill wire installments issued
    prefix_hits: int = 0            # requests that mapped an existing prefix
    blocks_prefix_shared: int = 0   # physical blocks reused via incref
    bytes_wire_saved: int = 0       # resident-at-dst blocks never re-sent
    cow_copies: int = 0             # divergent writes that copied a block
    sheds: int = 0                  # requests rejected by the policy
    preempts: int = 0               # decoding requests parked back to pool
    resumes: int = 0                # preempted requests re-bound to a slot
    remigrated: int = 0             # recoveries served by re-sending staged KV
    recomputed: int = 0             # recoveries that re-ran prefill
    replayed_tokens: int = 0        # pre-fault tokens re-derived bitwise
    recovery_steps: List[int] = dataclasses.field(default_factory=list)
    ttfd_steps: List[int] = dataclasses.field(default_factory=list)
    ttfd_model_s: List[float] = dataclasses.field(default_factory=list)
    # time-to-first-resident-block, measured from arrival: the step the
    # FIRST wire block of a request was provably resident at its decode PE
    # (fused admission gates on exactly this; under the barrier protocol it
    # collapses to the admission step, which is the A/B comparison)
    ttfd_first_block_steps: List[int] = dataclasses.field(
        default_factory=list)
    # frontend-visible latencies: measured from *arrival*, so queue time
    # before prefill counts (the satellite fix — percentiles over these)
    queue_delay_steps: List[int] = dataclasses.field(default_factory=list)
    ttfd_arrival_steps: List[int] = dataclasses.field(default_factory=list)
    ttfd_arrival_model_s: List[float] = dataclasses.field(
        default_factory=list)
    e2e_steps: List[int] = dataclasses.field(default_factory=list)


class DisaggScheduler:
    """Drives prefill PEs, the migration engine, and decode slot banks."""

    def __init__(self, ctx, heap, engine: Engine, pool, migrator: KVMigrator,
                 *, prefill_pes: List[int], decode_pes: List[int],
                 num_slots: int, scfg: ServeConfig = ServeConfig(),
                 prefills_per_step: Optional[int] = None,
                 admit_delay_steps: int = 0, paged: bool = True,
                 stream_chunks: int = 0, fused_attn: bool = False,
                 shared_prefix: bool = False,
                 policy: Optional[AdmissionPolicy] = None,
                 prefix_index: Optional[Dict[tuple, PrefixEntry]] = None,
                 rid_base: int = 0):
        if num_slots > pool.max_slots:
            raise ValueError(
                f"num_slots ({num_slots}) exceeds the pool's per-PE slot "
                f"regions (max_slots={pool.max_slots})")
        self.ctx = ctx
        self.heap = heap
        self.engine = engine
        self.pool = pool
        self.migrator = migrator
        self.prefill_pes = list(prefill_pes)
        self.decode_pes = list(decode_pes)
        self.scfg = scfg
        self.prefills_per_step = (len(self.prefill_pes)
                                  if prefills_per_step is None
                                  else prefills_per_step)
        # modeled wire latency in scheduler steps: a migration issued at
        # step N is only *polled* from step N + delay, so its nbi traffic
        # stays deferred while decode keeps stepping — migration overlapped
        # under decode.  Streamed migrations scale the delay by the final
        # installment's share of the wire (the rest already drained).
        self.admit_delay_steps = admit_delay_steps
        # paged decode: slots read K/V through block tables, no rehydrate;
        # False falls back to the PR-3 dense-copy admission (A/B baseline)
        self.paged = paged
        self.stream_chunks = stream_chunks      # blocks per installment; 0=off
        # fused decode path: migrations use the per-block-signal protocol
        # (migrate_fused) and admission gates on the FIRST resident block
        # (try_admit_fused) instead of the whole-request barrier; the decode
        # phase consumes the remaining blocks per-signal before reading them
        if fused_attn and not paged:
            raise ValueError("fused_attn requires paged decode (the fused "
                             "kernel gathers K/V straight from the pool)")
        if fused_attn and stream_chunks > 0:
            raise ValueError(
                "fused_attn and chunked streaming are mutually exclusive — "
                "per-block signals already stream at block granularity")
        self.fused_attn = fused_attn
        self.shared_prefix = shared_prefix
        self.policy = policy if policy is not None else AdmissionPolicy()
        self.views: Dict[int, PagedDecodeView] = (
            {pe: PagedDecodeView(pool, pe, num_slots) for pe in decode_pes}
            if paged else {})
        self.queue: deque = deque()
        self.requests: Dict[int, Request] = {}
        self.staged: deque = deque()            # blocks held, awaiting a slot
        self.streaming: List[Request] = []      # chunked migrations in flight
        self.parked: List[Request] = []         # streams drained, no slot yet
        self.preempted: List[Request] = []      # evicted mid-decode, resumable
        self.migrating: List[Request] = []
        self.recovering: List[Request] = []     # fault victims awaiting redo
        # fleet mode shares ONE prefix index across every pod's scheduler, so
        # a request routed anywhere can map blocks staged by any pod (the
        # router's affinity policy tries to keep it on the home pod)
        self.prefix_index: Dict[tuple, PrefixEntry] = (
            {} if prefix_index is None else prefix_index)
        # per-decode-PE slot banks (each decode PE owns num_slots slots)
        self.banks = {pe: engine.init_slots(num_slots) for pe in decode_pes}
        self.slot_req: Dict[int, List[Optional[int]]] = {
            pe: [None] * num_slots for pe in decode_pes}
        self.stats = SchedStats()
        self._rr_prefill = 0
        self._rr_decode = 0
        self._step = 0
        self._next_rid = rid_base
        self._key = jax.random.key(scfg.seed)
        # trace process track: this scheduler's pod (fleet pods are nodes)
        self._trace_pid = f"pod{ctx.node_of(self.prefill_pes[0])}"
        # cached KV sizes for profiler scope labels (bytes moved/read per
        # token / per block) — computed once, consulted only when profiling
        lay = pool.layout
        self._block_bytes = lay.block_bytes
        self._token_bytes = lay.block_bytes // max(1, lay.block_tokens)

    # ------------------------------------------------------------- tracing
    def _tracer(self):
        """Context tracer when recording, else None (guard hot paths)."""
        tr = getattr(self.ctx, "tracer", None)
        return tr if tr is not None and tr.enabled else None

    def _prof(self):
        """Wall-clock profiler when measuring, else None (guard hot paths).
        Mirrors :meth:`_tracer`; the profiler's perf_counter values stay in
        its own samples/wallclock buckets, never in step-clocked state."""
        pf = getattr(self.ctx, "prof", None)
        return pf if pf is not None and pf.enabled else None

    def _trace_phase(self, req: Request, phase: Optional[str],
                     end_args: Optional[dict] = None, **begin_args) -> None:
        """Advance a request's causal lifeline: close the open phase span
        (attribution rides on ``end_args``) and open ``phase`` (None = the
        lifeline ends).  All phases are async spans keyed by rid on the
        pod's ``requests`` track, so overlapping requests never nest."""
        tr = self._tracer()
        if tr is None:
            return
        if req.trace_phase is not None:
            tr.async_end(req.trace_phase, "req", req.rid, self._trace_pid,
                         "requests", **(end_args or {}))
        req.trace_phase = phase
        if phase is not None:
            tr.async_begin(phase, "req", req.rid, self._trace_pid,
                           "requests", **begin_args)

    # ------------------------------------------------------------- intake
    def submit(self, batch: dict, *, max_new: Optional[int] = None,
               prefix_len: int = 0, arrival_step: Optional[int] = None,
               t_arrival: Optional[float] = None,
               slo: Optional[object] = None) -> int:
        """Enqueue one request ({\"tokens\": (1,S)} [+ frontend embeds]).
        ``prefix_len`` declares the first N prompt tokens shareable with
        other requests declaring the same tokens (shared-prefix policy).
        ``arrival_step``/``t_arrival`` carry the frontend arrival time so
        latency percentiles include queue delay (defaults: now); ``slo`` is
        an opaque deadline class the admission policy interprets."""
        if max_new is None:
            max_new = self.scfg.max_new_tokens
        S = int(batch["tokens"].shape[1])
        if S + max_new > self.engine.max_len + 1:
            raise ValueError(
                f"prompt ({S}) + max_new ({max_new}) exceeds the decode "
                f"cache (max_len={self.engine.max_len})")
        if not 0 <= prefix_len <= S:
            raise ValueError(f"prefix_len {prefix_len} outside [0, {S}]")
        lay = self.pool.layout
        need = (lay.blocks_for_decode(S, max_new) if self.paged
                else lay.blocks_for_prompt(S))
        if self._needs_boundary_cow(batch, prefix_len, S):
            need += 1
        if need > self.pool.num_blocks:
            raise ValueError(
                f"request needs {need} KV blocks but the pool holds only "
                f"{self.pool.num_blocks} — no schedule can ever admit it")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, batch=batch, max_new=max_new,
                      prefix_len=prefix_len if self.shared_prefix else 0,
                      slo=slo)
        req.submit_step = self._step
        req.arrival_step = (self._step if arrival_step is None
                            else arrival_step)
        req.t_arrival = (self._comm_clock() if t_arrival is None
                         else t_arrival)
        self.requests[rid] = req
        if not self.policy.admit(req, len(self.queue)):
            req.state = SHED
            req.finish_step = self._step
            self.stats.sheds += 1
            self._trace_phase(req, "shed", prompt_len=S,
                              queue_depth=len(self.queue))
            self._trace_phase(req, None, end_args={"outcome": "shed"})
            return rid
        self.queue.append(req)
        self._trace_phase(req, "queued", prompt_len=S, max_new=max_new,
                          arrival_step=req.arrival_step)
        return rid

    def _comm_clock(self) -> float:
        """Modeled comm seconds excluding the migrator's advisory per-block
        records (those price each block standalone for the tuner; the real
        wire cost lands at flush time and would otherwise double-count)."""
        advisory = sum(
            b.time_total for k, b in self.ctx.telemetry.buckets.items()
            if k[0] == "kvxfer_block")
        return self.ctx.total_time() - advisory

    # ------------------------------------------------------ prefix sharing
    def _sharable(self, batch: dict, prefix_len: int) -> bool:
        """Sharability gates (DESIGN.md §9.3).  Ring layouts never share:
        occupied slots wrap through every block, so no block is
        suffix-independent.  Batches carrying non-token inputs (frontend
        embeds) never share either: cross-attention makes K/V depend on
        them beyond the token prefix, so a token-keyed index cannot prove
        two requests' blocks equal."""
        return (self.shared_prefix and prefix_len > 0
                and not self.pool.layout.ring
                and not any(k != "tokens" for k in batch))

    def _needs_boundary_cow(self, batch: dict, prefix_len: int,
                            prompt_len: int) -> bool:
        """True when staging this request standalone reserves a private
        block for the whole-prompt boundary (R1) — the worst-case extra
        pool demand submit()'s feasibility check must charge, or _stage
        demands a block the check never counted and the request re-queues
        forever."""
        return (self.paged and self._sharable(batch, prefix_len)
                and prefix_len == prompt_len
                and prefix_len % self.pool.layout.block_tokens != 0)

    def _prefix_plan(self, req: Request):
        """(shared_ids, key, n_entry): which table prefix this request maps
        from the index (hit) or will register (miss).  Policy: only whole
        blocks inside the declared prefix are sharable, plus the partial
        boundary block when the prefix IS the whole prompt (the
        many-samples-one-prompt case — the first divergent decode write
        copy-on-writes it); see _sharable for the hard gates."""
        lay = self.pool.layout
        if not self._sharable(req.batch, req.prefix_len):
            return [], None, 0
        P, S, T = req.prefix_len, req.prompt_len, lay.block_tokens
        whole = P == S
        n_own = P // T + (1 if whole and P % T else 0)
        if n_own == 0:
            return [], None, 0      # prefix shorter than one block
        key = tuple(int(t) for t in np.asarray(req.batch["tokens"])[0, :P])
        entry = self.prefix_index.get(key)
        if entry is None:
            return [], key, n_own   # miss: register after staging
        usable = (entry.block_ids if (whole and entry.whole_prompt)
                  else entry.block_ids[:P // T])
        if not usable:
            return [], None, 0
        return list(usable), key, len(usable)

    def _cow_range(self, req: Request, n_entry: int):
        """Table indices decode will write that map prefix-entry blocks —
        at most the boundary block of a whole-prompt prefix."""
        lay = self.pool.layout
        if not self.paged or lay.ring or n_entry == 0:
            return range(0)
        return range(req.prompt_len // lay.block_tokens, n_entry)

    # -------------------------------------------------------------- phases
    def _next_prefill_pe(self) -> Optional[int]:
        """Round-robin over prefill PEs not occupied by a chunked stream
        (a streaming PE is still 'computing' its current request; parked
        streams have finished prefilling and free their PE)."""
        busy = {r.prefill_pe for r in self.streaming}
        for _ in range(len(self.prefill_pes)):
            pe = self.prefill_pes[self._rr_prefill % len(self.prefill_pes)]
            self._rr_prefill += 1
            if pe not in busy:
                return pe
        return None

    def _phase_stream(self) -> None:
        """Advance every chunked migration one installment: drain the
        previous chunk's queue prefix (the wire works while this chunk's
        prefill compute runs), then either issue the next chunk or park the
        stream — all blocks issued, waiting slot-less for a decode slot.
        Parked streams keep draining under other requests' compute and bind
        a slot the moment one frees (tail + header only)."""
        pf = self._prof()
        for req in list(self.streaming):
            st = req.stream
            if pf is not None:
                tier = self.ctx.tier(st.src_pe, st.dst_pe)
                with pf.scope("stream_flush",
                              nbytes=st.sent * self._block_bytes,
                              path="proxy" if tier == "dcn" else "direct",
                              tier=tier,
                              work_items=self.migrator.work_items) as ps:
                    self.heap = ps(self.migrator.stream_flush(self.heap, st))
            else:
                self.heap = self.migrator.stream_flush(self.heap, st)
            if st.pending:
                self.heap = self.migrator.stream_chunk(self.heap, st,
                                                       self.stream_chunks)
            if not st.pending:
                self.streaming.remove(req)
                req.state = PARKED
                self.parked.append(req)
                self._trace_phase(req, "parked",
                                  end_args={"chunks": st.chunks,
                                            "blocks_sent": st.sent})
        for req in self.policy.waiting_order(list(self.parked)):
            st = req.stream
            if pf is not None:
                tier = self.ctx.tier(st.src_pe, st.dst_pe)
                with pf.scope("stream_flush",
                              nbytes=st.sent * self._block_bytes,
                              path="proxy" if tier == "dcn" else "direct",
                              tier=tier,
                              work_items=self.migrator.work_items) as ps:
                    self.heap = ps(self.migrator.stream_flush(self.heap, st))
            else:
                self.heap = self.migrator.stream_flush(self.heap, st)
            self._try_bind(req)

    def _phase_prefill(self) -> None:
        """Advance streams, retry slot assignment for already-staged
        requests, then pop queued requests onto free prefill PEs
        (round-robin), prefill each, stage + start its migration.  The
        admission policy picks WHICH queued request runs next (FCFS
        baseline: the head)."""
        self._phase_stream()
        for _ in range(len(self.staged)):
            self._try_migrate(self.staged.popleft())
        for _ in range(self.prefills_per_step):
            if not self.queue:
                return
            idx = self.policy.select(self.queue)
            req = self.queue[idx]
            if req.prefill_cache is None:            # not prefilled yet
                pe = self._next_prefill_pe()
                if pe is None:                       # every PE mid-stream
                    return
                del self.queue[idx]
                req.prefill_pe = pe
                req.prefill_step = self._step
                self.stats.queue_delay_steps.append(
                    self._step - req.arrival_step)
                self._trace_phase(
                    req, "prefill",
                    end_args={"queue_steps": self._step - req.arrival_step},
                    pe=pe)
                tr = self._tracer()
                if tr is not None:
                    tr.begin("prefill", "sched", self._trace_pid, f"pe{pe}",
                             rid=req.rid, prompt_len=req.prompt_len)
                key = jax.random.fold_in(self._key, req.rid)
                pf = self._prof()
                if pf is not None:
                    with pf.scope("serve_prefill",
                                  nbytes=req.prompt_len * self._token_bytes,
                                  path="engine", tier="local") as ps:
                        tok, _, cache1 = self.engine.prefill_request(
                            req.batch, key, self.scfg.temperature)
                        tok = ps(tok)
                else:
                    tok, _, cache1 = self.engine.prefill_request(
                        req.batch, key, self.scfg.temperature)
                req.first_token = tok
                req.prefill_cache = cache1
                self.stats.prefills += 1
                if tr is not None:
                    tr.end("prefill", "sched", self._trace_pid, f"pe{pe}")
            else:
                del self.queue[idx]
            if not self._stage(req):                 # pool exhausted: park
                self.stats.stalled_on_pool += 1      # the prefilled request
                self.queue.appendleft(req)
                return

    def _stage(self, req: Request) -> bool:
        """Stage a prefilled request into the pool: shared-prefix mapping,
        payload staging, prefix registration, and COW reservations — all or
        nothing against the free list, so a stall leaves no references."""
        lay = self.pool.layout
        shared_ids, key, n_entry = self._prefix_plan(req)
        max_new = req.max_new if self.paged else 0
        # the same formula stage() allocates with — the headroom check and
        # the allocation must agree, or reserve() below could come up empty
        n_table = lay.blocks_for_decode(req.prompt_len, max_new)
        n_cow = len(self._cow_range(req, n_entry))
        if n_table - len(shared_ids) + n_cow > self.pool.free_blocks():
            return False
        self.heap, ids = self.migrator.stage(
            self.heap, req.rid, req.prefill_cache,
            prompt_len=req.prompt_len, src_pe=req.prefill_pe,
            max_new=max_new, shared_ids=shared_ids)
        assert ids is not None       # free-list head-room checked above
        req.shared_ids = shared_ids
        if key is not None:
            if key not in self.prefix_index:
                self.prefix_index[key] = PrefixEntry(
                    key=key, block_ids=ids[:n_entry],
                    whole_prompt=req.prefix_len == req.prompt_len,
                    home_pe=req.prefill_pe, resident={})
                # the entry owns a reference on its blocks: mappers that
                # copy-on-write away drop THEIR ref, but the blocks must
                # outlive every mapper (and stay out of the free list) until
                # the entry itself dies — else a recycled block could be
                # zeroed as another request's growth while still mapped
                self.pool.incref(self.prefix_index[key].block_ids)
            entry = self.prefix_index[key]
            entry.refs += 1
            req.prefix_key = key
            if shared_ids:
                self.stats.prefix_hits += 1
                self.stats.blocks_prefix_shared += len(shared_ids)
        for b in self._cow_range(req, n_entry):
            req.cow_plan[b] = self.pool.reserve(1)[0]
        req.prefill_cache = None                 # staged in the pool now
        req.state = STAGED
        self._trace_phase(req, "staged", pe=req.prefill_pe,
                          shared_blocks=len(shared_ids))
        self._try_migrate(req)
        return True

    def _try_migrate(self, req: Request) -> None:
        """Put a staged request on the wire: as a slot-less parked stream
        (streaming mode) or whole-prefill into an assigned (decode PE,
        slot) — preempting an over-budget victim if the policy offers one."""
        if self.stream_chunks > 0:
            self._open_stream(req)
            return
        pe, slot = self._pick_slot()
        if slot is None:
            pe, slot = self._preempt_for(req)
        if slot is None:
            self.stats.stalled_on_slots += 1
            self.staged.append(req)
            return
        req.decode_pe, req.slot = pe, slot
        self.slot_req[pe][slot] = req.rid
        skip = self._resident_skip(req, pe)
        send = (self.migrator.migrate_fused if self.fused_attn
                else self.migrator.migrate)
        self.heap, report = send(
            self.heap, req.rid, src_pe=req.prefill_pe, dst_pe=pe,
            slot=slot, prompt_len=req.prompt_len,
            first_token=req.first_token, skip=skip)
        delay = self.admit_delay_steps
        if self.fused_attn:
            # the modeled wire window only covers what admission waits for:
            # tail + header + the first block, not the whole request (same
            # scaling _try_bind applies to a parked stream's close)
            total = report.n_wire + EXTRA_SIGNALS
            delay = delay * fused_admit_signal(report.n_wire) // total
        self._finish_migrate(req, report, delay=delay)

    def _open_stream(self, req: Request) -> None:
        """Open a slot-less chunked stream: pick the decode PE now (the
        wire needs a destination), ramp a pool stream-signal word, and put
        the first installment out.  No decode slot is held while the
        blocks drain — the slot binds at close (``_try_bind``)."""
        sig_id = self.pool.alloc_stream_sig()
        if sig_id is None:                       # every stream word carried
            self.stats.stalled_on_streams += 1
            self.staged.append(req)
            return
        pe = self._pick_stream_pe()
        req.decode_pe = pe
        req.park_sig = sig_id
        skip = self._resident_skip(req, pe)
        st = self.migrator.open_stream(
            req.rid, src_pe=req.prefill_pe, dst_pe=pe, slot=-1,
            prompt_len=req.prompt_len, first_token=req.first_token,
            skip=skip, sig_ptr=self.pool.stream_sig_ptr(sig_id))
        req.stream = st
        if not st.pending:
            # fully resident prefix: nothing to stream — park immediately
            # and bind this same step if a slot is free (tail + header
            # only), matching the whole-prefill path's admission timing
            req.state = PARKED
            self._trace_phase(req, "parked", dst_pe=pe, resident=True)
            self.parked.append(req)
            self._try_bind(req)
            return
        req.state = STREAMING
        self._trace_phase(req, "streaming", dst_pe=pe,
                          blocks=len(st.pending))
        self.streaming.append(req)
        # first installment leaves the same step its blocks "fill"
        self.heap = self.migrator.stream_chunk(self.heap, st,
                                               self.stream_chunks)

    def _pick_stream_pe(self) -> int:
        """Decode PE for a new stream: most free slots wins (ties resolved
        round-robin) — slot-less streams pick their destination before any
        slot exists, so this is load balancing, not slot assignment."""
        n = len(self.decode_pes)
        best, best_free = None, -1
        for k in range(n):
            pe = self.decode_pes[(self._rr_decode + k) % n]
            free = sum(1 for o in self.slot_req[pe] if o is None)
            if free > best_free:
                best, best_free = pe, free
        self._rr_decode += 1
        return best

    def _try_bind(self, req: Request) -> None:
        """Bind a parked stream to a decode slot on its PE and close the
        stream (tail + header — the only wire left).  Preempts a policy-
        chosen victim when the PE is full."""
        pe = req.decode_pe
        slot = next((s for s, o in enumerate(self.slot_req[pe])
                     if o is None), None)
        if slot is None:
            _, slot = self._preempt_for(req, pe=pe)
        if slot is None:
            self.stats.stalled_on_slots += 1
            return
        st = req.stream
        st.slot = slot
        req.slot = slot
        self.slot_req[pe][slot] = req.rid
        self.parked.remove(req)
        self.heap, report = self.migrator.stream_close(self.heap, st)
        # modeled wire latency scaled by the close's share of the stream —
        # for a parked stream that is just tail + header (two words), which
        # rounds DOWN: the admission poll may run the same step the slot
        # binds, because the payload drained while the request was parked
        total = st.sent + EXTRA_SIGNALS
        delay = self.admit_delay_steps * st.final_wire // total
        self._finish_migrate(req, report, delay=delay)

    def _resident_skip(self, req: Request, dst_pe: int) -> frozenset:
        """Shared blocks already migrated to this decode PE by an earlier
        request never travel again (COW keeps them pristine there).  Skip
        only the intersection with the blocks recorded resident at this
        PE: an earlier mapper may have carried fewer entry blocks than
        this request maps (it skipped the whole-prompt boundary block),
        and skipping an absent block would admit stale pool-row bytes."""
        if req.prefix_key is None or not req.shared_ids:
            return frozenset()
        resident = self.prefix_index[req.prefix_key].resident.get(
            dst_pe, frozenset())
        return frozenset(req.shared_ids) & frozenset(resident)

    def _finish_migrate(self, req: Request, report, *, delay: int) -> None:
        req.expected_sig = report.expected_signal
        req.wire_blocks = report.n_wire
        req.state = MIGRATING
        req.migrate_step = self._step
        req.admit_ready_step = self._step + delay
        req.t_submit = self._comm_clock()
        self._trace_phase(req, "migrating", src_pe=report.src_pe,
                          dst_pe=report.dst_pe, tier=report.tier,
                          bytes=report.bytes_total,
                          bytes_dcn=report.bytes_dcn, chunks=report.chunks,
                          wire_steps=delay,
                          protocol=("stream" if req.park_sig >= 0
                                    else "fused" if self.fused_attn
                                    else "barrier"))
        self.migrating.append(req)
        self.stats.migrations += 1
        self.stats.bytes_migrated += report.bytes_total
        self.stats.bytes_cross_pod += report.bytes_dcn
        self.stats.bytes_wire_saved += report.bytes_skipped
        if self.stream_chunks > 0:
            # report.chunks counts the stream's block-carrying installments
            # (a whole-prefill report never reaches here in streaming mode)
            self.stats.stream_chunks += report.chunks

    def _pick_slot(self):
        """Next (decode_pe, slot) with no resident request, round-robin."""
        n = len(self.decode_pes)
        for k in range(n):
            pe = self.decode_pes[(self._rr_decode + k) % n]
            for s, owner in enumerate(self.slot_req[pe]):
                if owner is None:
                    self._rr_decode += k + 1
                    return pe, s
        return None, None

    # ---------------------------------------------------------- preemption
    def _preempt_for(self, req: Request, pe: Optional[int] = None):
        """Ask the policy for an over-budget victim (optionally pinned to
        one decode PE) and park it; returns the freed (pe, slot) or
        (None, None).  Dense-rehydrate mode cannot preempt: the victim's KV
        lives in the slot bank, not the pool."""
        if not self.paged:
            return None, None
        # candidates are exactly the slot owners (bounded by the slot
        # banks), not the ever-growing request history
        decoding = [self.requests[rid]
                    for p in ([pe] if pe is not None else self.decode_pes)
                    for rid in self.slot_req[p] if rid is not None]
        decoding = [r for r in decoding if r.state == DECODING]
        victim = self.policy.preempt_victim(req, decoding)
        if victim is None:
            return None, None
        assert victim.state == DECODING, "policy picked a non-decoding victim"
        vpe, vslot = victim.decode_pe, victim.slot
        self._preempt(victim)
        return vpe, vslot

    def _preempt(self, req: Request) -> None:
        """Park a DECODING request back into the pool: snapshot the decode
        cursor and the non-paged tail (the paged KV is already written back
        to pool blocks every step), surrender the slot, keep every block
        reference (including un-triggered COW reserves) so the KV survives
        until resume."""
        pe, slot = req.decode_pe, req.slot
        if self.fused_attn and req.fused_pending > 0:
            # admitted-but-not-yet-decoded victim: its fused blocks are
            # still on the wire — consume them before the slot signal is
            # re-armed, or the signals would land against the NEXT request
            have = req.wire_blocks - req.fused_pending
            self.heap, resident = self.migrator.consume_blocks(
                self.heap, slot, pe, have, req.wire_blocks, rid=req.rid)
            req.fused_pending = req.wire_blocks - resident
        bank = self.banks[pe]
        req.resume_pos = int(bank.pos[slot])
        req.resume_tok = int(bank.tok[slot])
        req.park_tail = kvpool_mod.pack_tail(self.pool.layout, bank.cache,
                                             batch_idx=slot)
        req.cow_plan = self.views[pe].detach_keep(slot)
        self.banks[pe] = self.engine.evict_slot(bank, slot)
        self.heap = self.migrator.reset_slot(self.heap, slot, pe)
        self.slot_req[pe][slot] = None
        req.slot = -1
        req.state = PREEMPTED
        req.preemptions += 1
        self._trace_phase(req, "preempted",
                          end_args={"decode_pos": req.resume_pos,
                                    "tokens_out": len(req.out)},
                          pe=pe)
        self.preempted.append(req)
        self.stats.preempts += 1

    def _phase_resume(self) -> None:
        """Re-bind preempted requests onto freed slots of their decode PE
        (their pool blocks never moved).  Runs AFTER admissions, so waiting
        higher-priority requests grab slots first."""
        for req in self.policy.waiting_order(list(self.preempted)):
            pe = req.decode_pe
            slot = next((s for s, o in enumerate(self.slot_req[pe])
                         if o is None), None)
            if slot is None:
                continue
            self.preempted.remove(req)
            self._resume(req, slot)

    def _resume(self, req: Request, slot: int) -> None:
        """Inverse of _preempt: restore the tail into the new slot, re-arm
        the view (no blocks are zeroed — they all carry live KV), and
        resume decoding at the exact saved cursor."""
        pe = req.decode_pe
        bank = self.banks[pe]
        cache = kvpool_mod.insert_tail(self.pool.layout, bank.cache, slot,
                                       req.park_tail)
        bank = dataclasses.replace(bank, cache=cache)
        self.heap = self.views[pe].attach(self.heap, slot, req.rid,
                                          fresh_ids=[], cow=req.cow_plan)
        req.cow_plan = {}
        req.park_tail = None
        bank = self.engine.activate_slot(bank, slot, pos=req.resume_pos,
                                         token=req.resume_tok)
        self.banks[pe] = bank
        self.slot_req[pe][slot] = req.rid
        req.slot = slot
        req.state = DECODING
        self._trace_phase(req, "decoding", pe=pe, slot=slot, resumed=True)
        self.stats.resumes += 1

    # ------------------------------------------------------------ recovery
    def _emit_token(self, req: Request, tok: int) -> None:
        """Append a decoded token — unless the request is replaying after a
        fault, in which case the token must MATCH the pre-fault stream
        (greedy decode over identical KV re-derives it bitwise) and is not
        appended again.  ``len(req.out)`` holds at ``replay_target`` through
        the replay, so ``_maybe_finish`` cannot fire early."""
        if req.replayed < req.replay_target:
            assert req.out[req.replayed] == tok, (
                f"rid {req.rid}: replay diverged at token {req.replayed} "
                f"({req.out[req.replayed]} != {tok}) — recovery is not "
                f"bitwise-identical")
            req.replayed += 1
            self.stats.replayed_tokens += 1
            return
        req.out.append(tok)

    def _phase_recover(self) -> None:
        """Dispatch fault victims parked by ``serve.recovery``: a victim
        whose pool blocks survived (prefill-side KV intact on live home
        rows) re-enters STAGED and re-migrates to a live decode PE; one
        whose KV died with its PE re-enters the queue head for a full
        recompute from the prompt.  Either way decoded-so-far tokens replay
        via ``_emit_token``."""
        if not self.recovering:
            return
        for req in self.recovering:
            if self.pool.block_tables.get(req.rid):
                req.state = STAGED
                self.staged.append(req)
                self.stats.remigrated += 1
                self._trace_phase(req, "staged", recovered=True,
                                  replay=req.replay_target)
            else:
                req.state = QUEUED
                self.queue.appendleft(req)
                self.stats.recomputed += 1
                self._trace_phase(req, "queued", recovered=True,
                                  replay=req.replay_target)
        self.recovering = []

    # ----------------------------------------------------------- admission
    def _poll_first_block(self, req: Request) -> None:
        """Record the first step the request's FIRST wire block is provably
        resident at its decode PE — a pure (non-forcing) read of the signal
        word, modeling the decode PE watching it ramp.  Another request's
        admission flush may have completed this request's early queue prefix,
        so the word can advance before this request admits.  Wire order sets
        the threshold: barrier migrations send blocks first (``sig >= 1``),
        fused ones send tail + header first (``sig >= EXTRA_SIGNALS + 1``)."""
        if req.first_block_step >= 0 or req.slot < 0 or req.wire_blocks == 0:
            return
        cur = int(np.asarray(self.heap.read(
            self.pool.sig_ptr(req.slot), req.decode_pe)).reshape(()))
        thr = (EXTRA_SIGNALS + 1) if self.fused_attn else 1
        if cur >= thr:
            req.first_block_step = self._step

    def _phase_admit(self) -> None:
        """Signal-threshold-gated admission: a MIGRATING request enters its
        decode slot only once ``signal_wait_until`` observes the threshold
        its closed stream (or whole migration) established.  In fused mode
        the threshold is the FIRST block's signal (``try_admit_fused``);
        the remaining blocks are consumed per-signal by ``_phase_decode``."""
        still = []
        for req in self.migrating:
            if req.park_sig < 0:
                self._poll_first_block(req)
            if self._step < req.admit_ready_step:
                still.append(req)               # wire still "in flight"
                continue
            if self.fused_attn:
                self.heap, hdr, resident = self.migrator.try_admit_fused(
                    self.heap, req.slot, req.decode_pe, req.wire_blocks)
                if hdr is not None:
                    req.fused_pending = req.wire_blocks - resident
            else:
                sig_ptr = (self.pool.stream_sig_ptr(req.park_sig)
                           if req.park_sig >= 0 else None)
                self.heap, hdr = self.migrator.try_admit(
                    self.heap, req.slot, req.decode_pe, req.expected_sig,
                    sig_ptr=sig_ptr)
            if hdr is None:
                still.append(req)
                continue
            assert hdr["req_id"] == req.rid, "slot/header mismatch"
            if req.park_sig >= 0:
                # admission observed the parked stream's signal; recycle the
                # word (zeroed on the decode PE row) for the next stream
                self.heap = self.migrator.reset_signal(
                    self.heap, self.pool.stream_sig_ptr(req.park_sig),
                    req.decode_pe)
                self.pool.free_stream_sig(req.park_sig)
                req.park_sig = -1
            bank = self.banks[req.decode_pe]
            lay = self.pool.layout
            if self.paged:
                # no dense rehydrate: the pool row IS the decode KV cache;
                # only the (tiny) non-paged tail enters the slot bank
                tail = self.migrator.gather_tail(self.heap, req.slot,
                                                 req.decode_pe)
                cache = kvpool_mod.insert_tail(lay, bank.cache, req.slot,
                                               tail)
                bank = dataclasses.replace(bank, cache=cache)
                growth = [i for i in self.pool.blocks_of(req.rid)
                          if self.pool.home_of(i) is None]
                self.heap = self.views[req.decode_pe].attach(
                    self.heap, req.slot, req.rid, fresh_ids=growth,
                    cow=req.cow_plan)
                req.cow_plan = {}
            else:
                payloads, tail = self.migrator.gather(
                    self.heap, req.rid, req.slot, req.decode_pe)
                cache = kvpool_mod.insert_blocks(lay, bank.cache, req.slot,
                                                 payloads)
                cache = kvpool_mod.insert_tail(lay, cache, req.slot, tail)
                bank = dataclasses.replace(bank, cache=cache)
            bank = self.engine.activate_slot(
                bank, req.slot, pos=hdr["prompt_len"],
                token=hdr["first_token"])
            self.banks[req.decode_pe] = bank
            if req.prefix_key is not None:
                # the admission wait proved every block this request maps
                # landed at its decode PE (wire-carried or already skipped
                # as resident) — record exactly those entry blocks, not a
                # blanket PE flag.  COW has not fired yet (it triggers on
                # the first divergent decode write), so the table still
                # maps the shared ids.
                entry = self.prefix_index[req.prefix_key]
                entry.resident.setdefault(req.decode_pe, set()).update(
                    set(entry.block_ids) & set(self.pool.blocks_of(req.rid)))
            req.state = DECODING
            self._emit_token(req, hdr["first_token"])
            if req.recoveries > 0 and req.recover_step >= 0:
                # recovery TTFD: fault step -> first (re-)decoded token
                self.stats.recovery_steps.append(
                    self._step - req.recover_step)
                req.recover_step = -1
            req.admit_step = self._step
            req.t_admit = self._comm_clock()
            # the admission wait itself proves the first block resident
            # (fused: by construction; barrier: everything landed), so the
            # poll's fallback is the admission step
            if req.first_block_step < 0:
                req.first_block_step = self._step
            self.stats.ttfd_first_block_steps.append(
                req.first_block_step - req.arrival_step)
            # lifeline attribution: queue = arrival->prefill, wire = the
            # modeled comm seconds between migration issue and admission,
            # compute = everything from here to finish (decode steps)
            self._trace_phase(
                req, "decoding",
                end_args={"wire_model_s": req.t_admit - req.t_submit,
                          "ttfd_steps": req.admit_step - req.arrival_step,
                          "ttfd_model_s": req.t_admit - req.t_arrival,
                          "first_block_step": req.first_block_step},
                pe=req.decode_pe, slot=req.slot)
            self.stats.admissions += 1
            self.stats.ttfd_steps.append(req.admit_step - req.submit_step)
            self.stats.ttfd_model_s.append(req.t_admit - req.t_submit)
            self.stats.ttfd_arrival_steps.append(
                req.admit_step - req.arrival_step)
            self.stats.ttfd_arrival_model_s.append(
                req.t_admit - req.t_arrival)
            self._maybe_finish(req)
        self.migrating = still

    def _consume_fused(self, pe: int) -> None:
        """Per-block device waits for every fused-admitted slot on this PE
        with blocks still on the wire.  Decode's first step attends over the
        WHOLE prompt (causal), so all pending blocks must be consumed before
        the gather reads them — fusion moved the admission barrier, not the
        read-after-signal invariant.  Each wait forces only the minimal
        queue prefix that delivers its block (``consume_blocks``)."""
        for s, rid in enumerate(self.slot_req[pe]):
            if rid is None:
                continue
            req = self.requests[rid]
            if req.state != DECODING or req.fused_pending <= 0:
                continue
            have = req.wire_blocks - req.fused_pending
            self.heap, resident = self.migrator.consume_blocks(
                self.heap, req.slot, pe, have, req.wire_blocks, rid=rid)
            req.fused_pending = req.wire_blocks - resident
            if req.fused_pending > 0:
                raise RuntimeError(
                    f"rid {rid}: {req.fused_pending} fused blocks never "
                    f"landed — decode would read unmigrated bytes")

    def _phase_decode(self) -> None:
        """One decode step over every decode PE that has an active slot
        (the PEs step in parallel on real hardware: one decode iteration)."""
        self._step_key = jax.random.fold_in(self._key, 10_000 + self._step)
        stepped = False
        tr = self._tracer()
        for pe in self.decode_pes:
            bank = self.banks[pe]
            if not bank.active.any():
                continue
            if self.fused_attn:
                self._consume_fused(pe)
            if tr is not None:
                tr.begin("decode", "sched", self._trace_pid, f"pe{pe}",
                         slots=int(bank.active.sum()))
            # per-PE fold: decode PEs must not share sampling noise
            key = jax.random.fold_in(self._step_key, pe)
            pf = self._prof()
            if pf is not None:
                # KV bytes the step reads: total context tokens across the
                # PE's active slots (positions) at per-token KV size
                ctx_tokens = int(bank.pos[bank.active].sum())
                with pf.scope("serve_decode",
                              nbytes=ctx_tokens * self._token_bytes,
                              path="engine", tier="local",
                              work_items=int(bank.active.sum())) as ps:
                    if self.paged:
                        bank, toks, self.heap = self.engine.decode_slots_paged(
                            bank, key, self.ctx, self.heap, self.views[pe],
                            self.scfg.temperature)
                    else:
                        bank, toks = self.engine.decode_slots(
                            bank, key, self.scfg.temperature)
                    toks = ps(toks)
            elif self.paged:
                bank, toks, self.heap = self.engine.decode_slots_paged(
                    bank, key, self.ctx, self.heap, self.views[pe],
                    self.scfg.temperature)
            else:
                bank, toks = self.engine.decode_slots(
                    bank, key, self.scfg.temperature)
            self.banks[pe] = bank
            stepped = True
            if tr is not None:
                tr.end("decode", "sched", self._trace_pid, f"pe{pe}")
            for s, rid in enumerate(self.slot_req[pe]):
                if rid is None:
                    continue
                req = self.requests[rid]
                if req.state != DECODING:
                    continue
                self._emit_token(req, int(toks[s]))
                self.stats.decode_tokens += 1
                self._maybe_finish(req)
        if stepped:
            self.stats.decode_steps += 1

    def _maybe_finish(self, req: Request) -> None:
        eos_hit = (self.scfg.eos_id >= 0
                   and req.out and req.out[-1] == self.scfg.eos_id)
        if len(req.out) >= req.max_new or eos_hit:
            # same output contract as Engine.generate: eos is emitted, the
            # remainder zero-pads to max_new (bitwise-comparable rows)
            req.out = (req.out[:req.max_new]
                       + [0] * (req.max_new - len(req.out)))
            req.state = FINISHED
            req.finish_step = self._step
            self.stats.e2e_steps.append(req.finish_step - req.arrival_step)
            # compute attribution: admission -> finish is pure decode
            self._trace_phase(
                req, None,
                end_args={"outcome": "finished",
                          "decode_steps": req.finish_step - req.admit_step,
                          "e2e_steps": req.finish_step - req.arrival_step,
                          "tokens": len(req.out),
                          "preemptions": req.preemptions})
            self._evict(req)

    def _evict(self, req: Request) -> None:
        """Refcount-correct teardown: un-triggered COW reserves go first
        (view bookkeeping), then the table's references — a shared block
        returns to the free list only when its LAST mapper evicts — and the
        prefix-index entry dies with its last reference."""
        if self.paged:
            self.views[req.decode_pe].detach(req.slot)
            self.stats.cow_copies = sum(v.cow_copies
                                        for v in self.views.values())
        self.pool.release(req.rid)
        if req.prefix_key is not None:
            entry = self.prefix_index.get(req.prefix_key)
            if entry is not None:
                entry.refs -= 1
                if entry.refs <= 0:
                    self.pool.release_ids(entry.block_ids)
                    del self.prefix_index[req.prefix_key]
            req.prefix_key = None
        self.heap = self.migrator.reset_slot(self.heap, req.slot,
                                             req.decode_pe)
        self.migrator.release_tail(req.rid)
        bank = self.banks[req.decode_pe]
        self.banks[req.decode_pe] = self.engine.evict_slot(bank, req.slot)
        self.slot_req[req.decode_pe][req.slot] = None
        self.stats.evictions += 1

    # --------------------------------------------------------------- drive
    def step(self) -> None:
        """Advance every pipeline stage once (see module docstring)."""
        tr = self._tracer()
        if tr is not None:
            # monotonic-max: in fleet mode the driver already advanced the
            # shared clock to this step, so this is a no-op there
            tr.clock.set_step(self._step)
        pf = self._prof()
        if pf is not None:
            pf.set_step(self._step)
        self._phase_recover()
        self._phase_prefill()
        self._phase_admit()
        self._phase_resume()
        self._phase_decode()
        self._step += 1

    def done(self) -> bool:
        return (not self.queue and not self.staged and not self.streaming
                and not self.parked and not self.preempted
                and not self.migrating and not self.recovering
                and all(r.state in TERMINAL for r in self.requests.values()))

    def run(self, *, max_steps: int = 10_000) -> Dict[int, np.ndarray]:
        """Drive until every submitted request finishes (or was shed);
        returns {rid: generated token ids} (shed requests: empty)."""
        while not self.done():
            if self._step >= max_steps:
                raise RuntimeError(f"scheduler wedged after {max_steps} steps")
            self.step()
        return {rid: np.asarray(r.out, np.int32)
                for rid, r in self.requests.items()}
