"""Disaggregated continuous-batching scheduler.

Ties the serving subsystem together: a request queue feeding a fleet of
prefill PEs, SHMEM paged-KV migration to decode PEs (``serve/kvxfer.py``),
signal-gated admission into decode slots, slot rotation mid-flight, and
eviction back to the block pool.

Request state machine (DESIGN.md §8):

    QUEUED --prefill+stage--> STAGED --migrate(nbi)--> MIGRATING
        --signal observed--> DECODING --max_new/eos--> FINISHED
                                 \\--evict: blocks freed, slot re-armed

One ``step()`` advances every stage once — the order (prefill, admit,
decode) means a migration issued this step stays *pending* (deferred nbi
traffic) while decode keeps stepping resident requests: migration overlaps
decode exactly the way the completion engine overlaps any nbi transfer, and
the flush cost is only paid at the admission completion point.

The scheduler is the control plane a real deployment runs host-side; the
data plane (block payloads, signals, headers) moves exclusively through the
symmetric heap via one-sided ops.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import kvpool as kvpool_mod
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kvxfer import KVMigrator

QUEUED, STAGED, MIGRATING, DECODING, FINISHED = (
    "queued", "staged", "migrating", "decoding", "finished")


@dataclasses.dataclass
class Request:
    rid: int
    batch: dict                     # {"tokens": (1,S)} + frontend embeds
    max_new: int
    state: str = QUEUED
    prefill_pe: int = -1
    decode_pe: int = -1
    slot: int = -1
    first_token: int = -1
    expected_sig: int = 0
    out: List[int] = dataclasses.field(default_factory=list)
    submit_step: int = -1
    migrate_step: int = -1
    admit_step: int = -1
    # prefill result parked here while the request waits for pool blocks, so
    # a stall never re-runs the model
    prefill_cache: Optional[dict] = None
    t_submit: float = 0.0           # modeled comm clock at prefill finish
    t_admit: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.batch["tokens"].shape[1])


@dataclasses.dataclass
class SchedStats:
    prefills: int = 0
    migrations: int = 0
    admissions: int = 0
    evictions: int = 0
    decode_steps: int = 0
    decode_tokens: int = 0
    bytes_migrated: int = 0
    stalled_on_pool: int = 0        # prefills deferred because no free blocks
    stalled_on_slots: int = 0       # migrations deferred because no free slot
    ttfd_steps: List[int] = dataclasses.field(default_factory=list)
    ttfd_model_s: List[float] = dataclasses.field(default_factory=list)


class DisaggScheduler:
    """Drives prefill PEs, the migration engine, and decode slot banks."""

    def __init__(self, ctx, heap, engine: Engine, pool, migrator: KVMigrator,
                 *, prefill_pes: List[int], decode_pes: List[int],
                 num_slots: int, scfg: ServeConfig = ServeConfig(),
                 prefills_per_step: Optional[int] = None,
                 admit_delay_steps: int = 0):
        if num_slots > pool.max_slots:
            raise ValueError(
                f"num_slots ({num_slots}) exceeds the pool's per-PE slot "
                f"regions (max_slots={pool.max_slots})")
        self.ctx = ctx
        self.heap = heap
        self.engine = engine
        self.pool = pool
        self.migrator = migrator
        self.prefill_pes = list(prefill_pes)
        self.decode_pes = list(decode_pes)
        self.scfg = scfg
        self.prefills_per_step = (len(self.prefill_pes)
                                  if prefills_per_step is None
                                  else prefills_per_step)
        # modeled wire latency in scheduler steps: a migration issued at
        # step N is only *polled* from step N + delay, so its nbi traffic
        # stays deferred while decode keeps stepping — migration overlapped
        # under decode
        self.admit_delay_steps = admit_delay_steps
        self.queue: deque = deque()
        self.requests: Dict[int, Request] = {}
        self.staged: deque = deque()            # blocks held, awaiting a slot
        self.migrating: List[Request] = []
        # per-decode-PE slot banks (each decode PE owns num_slots slots)
        self.banks = {pe: engine.init_slots(num_slots) for pe in decode_pes}
        self.slot_req: Dict[int, List[Optional[int]]] = {
            pe: [None] * num_slots for pe in decode_pes}
        self.stats = SchedStats()
        self._rr_prefill = 0
        self._rr_decode = 0
        self._step = 0
        self._next_rid = 0
        self._key = jax.random.key(scfg.seed)

    # ------------------------------------------------------------- intake
    def submit(self, batch: dict, *, max_new: Optional[int] = None) -> int:
        """Enqueue one request ({\"tokens\": (1,S)} [+ frontend embeds])."""
        if max_new is None:
            max_new = self.scfg.max_new_tokens
        S = int(batch["tokens"].shape[1])
        if S + max_new > self.engine.max_len + 1:
            raise ValueError(
                f"prompt ({S}) + max_new ({max_new}) exceeds the decode "
                f"cache (max_len={self.engine.max_len})")
        need = self.pool.layout.blocks_for_prompt(S)
        if need > self.pool.num_blocks:
            raise ValueError(
                f"prompt needs {need} KV blocks but the pool holds only "
                f"{self.pool.num_blocks} — no schedule can ever admit it")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, batch=batch, max_new=max_new)
        req.submit_step = self._step
        self.queue.append(req)
        self.requests[rid] = req
        return rid

    def _comm_clock(self) -> float:
        """Modeled comm seconds excluding the migrator's advisory per-block
        records (those price each block standalone for the tuner; the real
        wire cost lands at flush time and would otherwise double-count)."""
        advisory = sum(
            b.time_total for k, b in self.ctx.telemetry.buckets.items()
            if k[0] == "kvxfer_block")
        return self.ctx.total_time() - advisory

    # -------------------------------------------------------------- phases
    def _phase_prefill(self) -> None:
        """Retry slot assignment for already-staged requests, then pop up to
        prefills_per_step queued requests, prefill each on the next prefill
        PE (round-robin), stage + issue the nbi migration."""
        for _ in range(len(self.staged)):
            self._try_migrate(self.staged.popleft())
        for _ in range(self.prefills_per_step):
            if not self.queue:
                return
            req = self.queue.popleft()
            if req.prefill_cache is None:            # not prefilled yet
                pe = self.prefill_pes[self._rr_prefill
                                      % len(self.prefill_pes)]
                self._rr_prefill += 1
                req.prefill_pe = pe
                key = jax.random.fold_in(self._key, req.rid)
                tok, _, cache1 = self.engine.prefill_request(
                    req.batch, key, self.scfg.temperature)
                req.first_token = tok
                req.prefill_cache = cache1
                self.stats.prefills += 1
            self.heap, ids = self.migrator.stage(
                self.heap, req.rid, req.prefill_cache,
                prompt_len=req.prompt_len, src_pe=req.prefill_pe)
            if ids is None:                          # pool exhausted: park
                self.stats.stalled_on_pool += 1      # the prefilled request
                self.queue.appendleft(req)
                return
            req.prefill_cache = None                 # staged in the pool now
            req.state = STAGED
            req.t_submit = self._comm_clock()
            self._try_migrate(req)

    def _try_migrate(self, req: Request) -> None:
        """Assign a (decode PE, slot) and stream the request's blocks."""
        pe, slot = self._pick_slot()
        if slot is None:
            self.stats.stalled_on_slots += 1
            self.staged.append(req)
            return
        req.decode_pe, req.slot = pe, slot
        self.slot_req[pe][slot] = req.rid
        self.heap, report = self.migrator.migrate(
            self.heap, req.rid, src_pe=req.prefill_pe, dst_pe=pe,
            slot=slot, prompt_len=req.prompt_len,
            first_token=req.first_token)
        req.expected_sig = report.expected_signal
        req.state = MIGRATING
        req.migrate_step = self._step
        self.migrating.append(req)
        self.stats.migrations += 1
        self.stats.bytes_migrated += report.bytes_total

    def _pick_slot(self):
        """Next (decode_pe, slot) with no resident request, round-robin."""
        n = len(self.decode_pes)
        for k in range(n):
            pe = self.decode_pes[(self._rr_decode + k) % n]
            for s, owner in enumerate(self.slot_req[pe]):
                if owner is None:
                    self._rr_decode += k + 1
                    return pe, s
        return None, None

    def _phase_admit(self) -> None:
        """Signal-gated admission: a MIGRATING request enters its decode slot
        only once ``signal_wait_until`` observes the final signal."""
        still = []
        for req in self.migrating:
            if self._step < req.migrate_step + self.admit_delay_steps:
                still.append(req)               # wire still "in flight"
                continue
            self.heap, hdr = self.migrator.try_admit(
                self.heap, req.slot, req.decode_pe, req.expected_sig)
            if hdr is None:
                still.append(req)
                continue
            assert hdr["req_id"] == req.rid, "slot/header mismatch"
            payloads, tail = self.migrator.gather(
                self.heap, req.rid, req.slot, req.decode_pe)
            bank = self.banks[req.decode_pe]
            lay = self.pool.layout
            cache = kvpool_mod.insert_blocks(lay, bank.cache, req.slot,
                                             payloads)
            cache = kvpool_mod.insert_tail(lay, cache, req.slot, tail)
            bank = dataclasses.replace(bank, cache=cache)
            bank = self.engine.activate_slot(
                bank, req.slot, pos=hdr["prompt_len"],
                token=hdr["first_token"])
            self.banks[req.decode_pe] = bank
            req.state = DECODING
            req.out.append(hdr["first_token"])
            req.admit_step = self._step
            req.t_admit = self._comm_clock()
            self.stats.admissions += 1
            self.stats.ttfd_steps.append(req.admit_step - req.submit_step)
            self.stats.ttfd_model_s.append(req.t_admit - req.t_submit)
            self._maybe_finish(req)
        self.migrating = still

    def _phase_decode(self) -> None:
        """One decode step over every decode PE that has an active slot
        (the PEs step in parallel on real hardware: one decode iteration)."""
        self._step_key = jax.random.fold_in(self._key, 10_000 + self._step)
        stepped = False
        for pe in self.decode_pes:
            bank = self.banks[pe]
            if not bank.active.any():
                continue
            # per-PE fold: decode PEs must not share sampling noise
            bank, toks = self.engine.decode_slots(
                bank, jax.random.fold_in(self._step_key, pe),
                self.scfg.temperature)
            self.banks[pe] = bank
            stepped = True
            for s, rid in enumerate(self.slot_req[pe]):
                if rid is None:
                    continue
                req = self.requests[rid]
                if req.state != DECODING:
                    continue
                req.out.append(int(toks[s]))
                self.stats.decode_tokens += 1
                self._maybe_finish(req)
        if stepped:
            self.stats.decode_steps += 1

    def _maybe_finish(self, req: Request) -> None:
        eos_hit = (self.scfg.eos_id >= 0
                   and req.out and req.out[-1] == self.scfg.eos_id)
        if len(req.out) >= req.max_new or eos_hit:
            # same output contract as Engine.generate: eos is emitted, the
            # remainder zero-pads to max_new (bitwise-comparable rows)
            req.out = (req.out[:req.max_new]
                       + [0] * (req.max_new - len(req.out)))
            req.state = FINISHED
            self._evict(req)

    def _evict(self, req: Request) -> None:
        """Return the request's blocks to the pool and re-arm its slot."""
        self.pool.release(req.rid)
        self.heap = self.migrator.reset_slot(self.heap, req.slot,
                                             req.decode_pe)
        bank = self.banks[req.decode_pe]
        self.banks[req.decode_pe] = self.engine.evict_slot(bank, req.slot)
        self.slot_req[req.decode_pe][req.slot] = None
        self.stats.evictions += 1

    # --------------------------------------------------------------- drive
    def step(self) -> None:
        """Advance every pipeline stage once (see module docstring)."""
        self._phase_prefill()
        self._phase_admit()
        self._phase_decode()
        self._step += 1

    def done(self) -> bool:
        return (not self.queue and not self.staged and not self.migrating
                and all(r.state == FINISHED for r in self.requests.values()))

    def run(self, *, max_steps: int = 10_000) -> Dict[int, np.ndarray]:
        """Drive until every submitted request finishes; returns
        {rid: generated token ids}."""
        while not self.done():
            if self._step >= max_steps:
                raise RuntimeError(f"scheduler wedged after {max_steps} steps")
            self.step()
        return {rid: np.asarray(r.out, np.int32)
                for rid, r in self.requests.items()}
