"""Batched serving engine: prefill + decode loop with KV/recurrent caches.

Continuous-batching-lite: a request batch is prefetched together, decoded in
lockstep with per-request stop handling (a production engine would rotate
requests in/out of slots; the step functions here are exactly the ones the
pod launcher shards — decode_32k / long_500k dry-run lower these).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import kvcache, model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 = greedy
    eos_id: int = -1               # -1 = never stop early
    seed: int = 0


class Engine:
    def __init__(self, cfg_arch, params, *, max_len: int):
        self.cfg = cfg_arch
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, cfg_arch, b, c))
        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, cfg_arch, t, pos, c))

    def _sample(self, logits, key, temperature):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1) \
            .astype(jnp.int32)

    def generate(self, batch, scfg: ServeConfig = ServeConfig()):
        """batch: {tokens: (B, S_prompt) [+ frontend embeds]}.
        Returns (B, max_new_tokens) generated ids."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        assert S + scfg.max_new_tokens <= self.max_len + 1, \
            "cache too small for prompt + generation"
        cache = kvcache.init_cache(self.cfg, B, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)
        key = jax.random.key(scfg.seed)
        out = []
        done = jnp.zeros((B,), bool)
        tok = self._sample(logits, key, scfg.temperature)
        for i in range(scfg.max_new_tokens):
            out.append(jnp.where(done, 0, tok))
            done = done | (tok == scfg.eos_id)
            pos = jnp.full((B,), S + i, jnp.int32)
            logits, cache = self._decode(self.params, tok[:, None], pos,
                                         cache)
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, key, scfg.temperature)
        return jnp.stack(out, axis=1)
