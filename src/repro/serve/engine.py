"""Slot-based serving engine: prefill + decode over rotating request slots.

The decode cache is a fixed bank of ``num_slots`` request slots; requests
enter a slot mid-flight (continuous batching — from a local prefill or from
a migrated paged-KV hand-off, see ``serve/scheduler.py``) and leave it the
step they finish, freeing the slot for the next admission.  One decode step
always runs the full slot bank; inactive slots carry ``pos=0, tok=0``
padding whose cache writes are either masked by the per-slot validity rules
or overwritten at the next admission, so rotation never perturbs the active
slots' numerics.

``Engine.generate`` (the lockstep API the tests and examples drive) is a
thin orbit of the same machinery: admit the whole batch at once, decode
until done.  Disaggregated serving gets bitwise-identical decode because
both paths share ``decode_slots``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import kvcache, model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 = greedy
    eos_id: int = -1               # -1 = never stop early
    seed: int = 0


@dataclasses.dataclass
class SlotBatch:
    """State of one decode slot bank (functional: steps return a new one)."""
    cache: dict                    # batched decode cache, B = num_slots
    pos: jnp.ndarray               # (B,) int32 — next decode position
    tok: jnp.ndarray               # (B,) int32 — last sampled token
    active: np.ndarray             # (B,) bool, host-side occupancy mask

    @property
    def num_slots(self) -> int:
        return int(self.pos.shape[0])

    def free_slots(self) -> list:
        return [i for i in range(self.num_slots) if not self.active[i]]


class Engine:
    def __init__(self, cfg_arch, params, *, max_len: int):
        self.cfg = cfg_arch
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, cfg_arch, b, c))
        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, cfg_arch, t, pos, c))

    def _sample(self, logits, key, temperature):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1) \
            .astype(jnp.int32)

    # ------------------------------------------------------------ slot API
    def init_slots(self, num_slots: int) -> SlotBatch:
        return SlotBatch(
            cache=kvcache.init_cache(self.cfg, num_slots, self.max_len),
            pos=jnp.zeros((num_slots,), jnp.int32),
            tok=jnp.zeros((num_slots,), jnp.int32),
            active=np.zeros((num_slots,), bool))

    def prefill_request(self, request: dict, key, temperature: float = 0.0):
        """Prefill ONE request (batch axis 1).  Returns
        ``(first_token, logits, cache1)`` — the B=1 cache a migration packs
        from, and the first generated token sampled from the last-position
        logits (the token that travels in the migration header)."""
        S = request["tokens"].shape[1]
        assert S <= self.max_len, "prompt exceeds cache"
        cache = kvcache.init_cache(self.cfg, 1, self.max_len)
        logits, cache = self._prefill(self.params, request, cache)
        tok = self._sample(logits, key, temperature)
        return int(tok[0]), logits, cache

    def activate_slot(self, slots: SlotBatch, slot: int, *, pos: int,
                      token: int) -> SlotBatch:
        """Mark a slot occupied with its decode cursor and pending token.
        The slot's cache contents must already be in place (batched prefill,
        or `kvpool.insert_blocks`/`insert_tail` after a migration)."""
        active = slots.active.copy()
        active[slot] = True
        return SlotBatch(cache=slots.cache,
                         pos=slots.pos.at[slot].set(pos),
                         tok=slots.tok.at[slot].set(token),
                         active=active)

    def evict_slot(self, slots: SlotBatch, slot: int) -> SlotBatch:
        """Release a slot.  The cache rows keep their bytes (stale data is
        masked by pos-validity and fully overwritten on the next admission);
        pos/tok return to the inactive padding values."""
        active = slots.active.copy()
        active[slot] = False
        return SlotBatch(cache=slots.cache,
                         pos=slots.pos.at[slot].set(0),
                         tok=slots.tok.at[slot].set(0),
                         active=active)

    def decode_slots(self, slots: SlotBatch, key, temperature: float = 0.0):
        """ONE decode step over the whole slot bank.  Active slots advance
        their cursor; inactive slots hold at (pos=0, tok=0) padding.
        Returns ``(new_slots, tokens)`` with tokens the per-slot samples."""
        logits, cache = self._decode(self.params, slots.tok[:, None],
                                     slots.pos, slots.cache)
        tok = self._sample(logits, key, temperature)
        mask = jnp.asarray(slots.active)
        return SlotBatch(
            cache=cache,
            pos=jnp.where(mask, slots.pos + 1, 0).astype(jnp.int32),
            tok=jnp.where(mask, tok, 0).astype(jnp.int32),
            active=slots.active.copy()), tok

    def decode_slots_paged(self, slots: SlotBatch, key, ctx, heap, view,
                           temperature: float = 0.0):
        """ONE decode step consuming K/V straight from the symmetric-heap
        block pool: the view assembles every paged leaf through the slot
        block tables (byte-identical to what the dense rehydrate would have
        built, so the step itself is bitwise-identical to
        :meth:`decode_slots`), the exact same jitted decode runs, and each
        active slot's new K/V token is written back to its owning pool
        block — with copy-on-write if that block is shared.  The returned
        bank cache keeps only non-paged state; its paged leaves stay zero.
        Returns ``(new_slots, tokens, heap)``."""
        cache = view.assemble(heap, slots.cache)
        pf = getattr(ctx, "prof", None)
        if pf is not None and pf.enabled:
            # the paged-attention kernel region proper: assembled K/V in,
            # next-token logits out.  nbytes = assembled cache footprint
            # (static .nbytes attrs — no device sync to compute the label)
            import jax as _jax
            kv_bytes = sum(leaf.nbytes
                           for leaf in _jax.tree_util.tree_leaves(cache))
            with pf.scope("paged_attn", nbytes=kv_bytes, path="engine",
                          tier="local",
                          work_items=int(slots.active.sum())) as ps:
                logits, new_cache = self._decode(
                    self.params, slots.tok[:, None], slots.pos, cache)
                logits = ps(logits)
        else:
            logits, new_cache = self._decode(self.params, slots.tok[:, None],
                                             slots.pos, cache)
        tok = self._sample(logits, key, temperature)
        heap = view.writeback(ctx, heap, new_cache, slots.pos, slots.active)
        mask = jnp.asarray(slots.active)
        return SlotBatch(
            cache=view.strip(new_cache),
            pos=jnp.where(mask, slots.pos + 1, 0).astype(jnp.int32),
            tok=jnp.where(mask, tok, 0).astype(jnp.int32),
            active=slots.active.copy()), tok, heap

    # ------------------------------------------------------- lockstep API
    def generate(self, batch, scfg: ServeConfig = ServeConfig()):
        """batch: {tokens: (B, S_prompt) [+ frontend embeds]}.
        Returns (B, max_new_tokens) generated ids.

        Lockstep special case of the slot machinery: every request admitted
        at step 0 (one shared batched prefill), decoded until max_new.
        """
        tokens = batch["tokens"]
        B, S = tokens.shape
        assert S + scfg.max_new_tokens <= self.max_len + 1, \
            "cache too small for prompt + generation"
        slots = self.init_slots(B)
        logits, cache = self._prefill(self.params, batch, slots.cache)
        key = jax.random.key(scfg.seed)
        tok = self._sample(logits, key, scfg.temperature)
        slots = SlotBatch(cache=cache,
                          pos=jnp.full((B,), S, jnp.int32),
                          tok=tok,
                          active=np.ones((B,), bool))
        out = []
        done = jnp.zeros((B,), bool)
        for i in range(scfg.max_new_tokens):
            out.append(jnp.where(done, 0, slots.tok))
            done = done | (slots.tok == scfg.eos_id)
            key = jax.random.fold_in(key, i)
            slots, _ = self.decode_slots(slots, key, scfg.temperature)
        return jnp.stack(out, axis=1)
