"""``ISHMEM_FLEET_*`` environment knobs for the cluster frontend.

Mirrors the ``ISHMEM_*`` convention of ``repro.tune.env``: the launcher
(``repro.launch.serve --fleet``) consults these as its argument defaults,
so a deployment can retune the frontend with zero code changes.

==============================  ============================================
``ISHMEM_FLEET_PODS``           number of pods (default 2)
``ISHMEM_FLEET_ROUTER``         ``random`` | ``round_robin`` |
                                ``least_loaded`` | ``affinity`` (default)
``ISHMEM_FLEET_ADMISSION``      ``slo`` (default) | ``fcfs`` (A/B baseline)
``ISHMEM_FLEET_QUEUE_BOUND``    per-pod queue bound before the SLO policy
                                sheds best-effort traffic (default 12;
                                2x is the hard bound for everything)
``ISHMEM_FLEET_STREAM_CHUNKS``  blocks per mid-prefill wire installment
                                (0 = whole-prefill migration; default 1)
``ISHMEM_FLEET_SEED``           traffic/router determinism seed (default 0)
==============================  ============================================
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping, Optional

from repro.serve.frontend.router import POLICIES

PREFIX = "ISHMEM_FLEET_"
ADMISSIONS = ("slo", "fcfs")


@dataclasses.dataclass(frozen=True)
class FleetEnv:
    pods: int = 2
    router: str = "affinity"
    admission: str = "slo"
    queue_bound: int = 12
    stream_chunks: int = 1
    seed: int = 0


def load_fleet_env(environ: Optional[Mapping[str, str]] = None) -> FleetEnv:
    """Parse the ``ISHMEM_FLEET_*`` variables (defaults on empty env)."""
    env = os.environ if environ is None else environ

    def get(name: str) -> Optional[str]:
        val = env.get(PREFIX + name)
        return val if val not in (None, "") else None

    def get_int(name: str, default: int, *, minimum: int) -> int:
        raw = get(name)
        if raw is None:
            return default
        try:
            val = int(raw)
        except ValueError:
            raise ValueError(
                f"{PREFIX}{name}: expected an integer, got {raw!r}") from None
        if val < minimum:
            raise ValueError(f"{PREFIX}{name}: must be >= {minimum}, "
                             f"got {val}")
        return val

    router = get("ROUTER")
    if router is not None:
        router = router.strip().lower()
        if router not in POLICIES:
            raise ValueError(
                f"{PREFIX}ROUTER must be one of {POLICIES}, got {router!r}")
    admission = get("ADMISSION")
    if admission is not None:
        admission = admission.strip().lower()
        if admission not in ADMISSIONS:
            raise ValueError(f"{PREFIX}ADMISSION must be one of "
                             f"{ADMISSIONS}, got {admission!r}")
    return FleetEnv(
        pods=get_int("PODS", 2, minimum=1),
        router=router or "affinity",
        admission=admission or "slo",
        queue_bound=get_int("QUEUE_BOUND", 12, minimum=1),
        stream_chunks=get_int("STREAM_CHUNKS", 1, minimum=0),
        seed=get_int("SEED", 0, minimum=0),
    )
