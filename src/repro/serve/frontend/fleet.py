"""Cluster fleet driver: pods + router + SLO admission over one SHMEM world.

Topology: ``n_pods`` contiguous pods of ``prefill_per_pod + decode_per_pod``
PEs each.  ``node_size`` is set to the pod size, so intra-pod migration is
ici tier and anything crossing pods is dcn — routed through ONE shared
:class:`~repro.core.proxy.HostProxy` ring exactly like the paper's
reverse-offloaded inter-node ops.  All pods share:

- one symmetric heap and one :class:`~repro.serve.kvpool.KVPool` (block ids
  are cluster-wide addresses — the OpenSHMEM symmetric contract is what
  makes cross-pod prefix pulls possible at all);
- one prefix index (``DisaggScheduler.prefix_index``), so the router's
  affinity policy can see which pod staged a shared prompt;
- one :class:`~repro.serve.engine.Engine` (stateless params + jitted fns;
  per-pod slot banks live in each scheduler).

The driver is a straight open-loop clock: at every step it submits the
arrivals the traffic schedule put there (routing each through the
:class:`~repro.serve.frontend.router.Router`), then advances every pod's
scheduler one step.  After the schedule runs out it drains until every
request reaches a terminal state, then rolls the report up via
``frontend/metrics.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import context, teams
from repro.core.proxy import HostProxy
from repro.serve import fault as fault_mod
from repro.serve import recovery as recovery_mod
from repro.serve.engine import Engine, ServeConfig
from repro.serve.frontend import metrics as metrics_mod
from repro.serve.frontend import slo as slo_mod
from repro.serve.frontend.router import Pod, Router
from repro.serve.frontend.traffic import RequestSpec
from repro.serve.kvpool import KVPool
from repro.serve.kvxfer import KVMigrator
from repro.serve.scheduler import (RECOVERED, AdmissionPolicy,
                                   DisaggScheduler)

#: rid namespace stride per pod — block tables and request maps are fleet-
#: global (shared pool), so request ids must never collide across pods
RID_STRIDE = 1_000_000


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    arch: str = "qwen3-4b"
    n_pods: int = 2
    prefill_per_pod: int = 1
    decode_per_pod: int = 2
    num_slots: int = 2
    kv_blocks: int = 96
    block_tokens: int = 4
    max_streams: int = 32
    max_len: int = 24               # decode cache length (prompt + max_new)
    max_new: int = 4                # default decode budget
    temperature: float = 0.0
    stream_chunks: int = 1          # 0 = whole-prefill migration
    fused_attn: bool = False        # fused-admission decode (excl. streaming)
    shared_prefix: bool = True
    admit_delay: int = 1
    admission: str = "slo"          # "slo" | "fcfs"
    queue_bound: int = 12           # per-pod SLO shed bound
    router: str = "affinity"        # router.POLICIES
    proxy_slots: int = 128          # host-proxy ring capacity (power of 2)
    seed: int = 0

    @property
    def pod_size(self) -> int:
        return self.prefill_per_pod + self.decode_per_pod

    @property
    def npes(self) -> int:
        return self.n_pods * self.pod_size


class Fleet:
    """A running cluster frontend: build once, feed it arrival schedules."""

    def __init__(self, fcfg: FleetConfig, *, arch_cfg=None, params=None,
                 engine: Optional[Engine] = None,
                 classes: Optional[Dict[str, slo_mod.SLOClass]] = None,
                 obs=None, fault_plan=None):
        import jax
        from repro.configs import base as cfgbase
        from repro.models import model

        self.fcfg = fcfg
        self.classes = slo_mod.CLASSES if classes is None else classes
        if engine is not None:
            self.cfg = engine.cfg
            self.engine = engine
        else:
            self.cfg = (arch_cfg if arch_cfg is not None
                        else cfgbase.reduced(cfgbase.get_config(fcfg.arch)))
            if params is None:
                params = model.init_params(jax.random.key(0), self.cfg)
            self.engine = Engine(self.cfg, params, max_len=fcfg.max_len)
        # one world: pods are nodes, inter-pod traffic is dcn via the proxy
        self.ctx, self.heap = context.init(npes=fcfg.npes,
                                           node_size=fcfg.pod_size)
        # observability bundle (repro.obs.Obs): installs the span tracer on
        # the shared context and arms the online tuner re-fit loop
        self.obs = obs
        if obs is not None:
            obs.attach(self.ctx)
        self.pool = KVPool.create(
            self.heap, self.cfg, fcfg.max_len, num_blocks=fcfg.kv_blocks,
            max_slots=fcfg.num_slots, block_tokens=fcfg.block_tokens,
            max_streams=fcfg.max_streams)
        self.proxy = (HostProxy(self.ctx, slots=fcfg.proxy_slots)
                      if fcfg.n_pods > 1 else None)
        self.prefix_index: Dict = {}
        world = teams.world(fcfg.npes)
        pod_teams = teams.pods_partition(
            world, [fcfg.pod_size] * fcfg.n_pods)
        self.pods: List[Pod] = []
        for i, pod_team in enumerate(pod_teams):
            pre, dec = teams.disagg_partition(pod_team, fcfg.prefill_per_pod)
            mig = KVMigrator(self.ctx, self.pool, proxy=self.proxy)
            sched = DisaggScheduler(
                self.ctx, self.heap, self.engine, self.pool, mig,
                prefill_pes=pre.pes(), decode_pes=dec.pes(),
                num_slots=fcfg.num_slots,
                scfg=ServeConfig(max_new_tokens=fcfg.max_new,
                                 temperature=fcfg.temperature,
                                 seed=fcfg.seed),
                admit_delay_steps=fcfg.admit_delay,
                stream_chunks=fcfg.stream_chunks,
                fused_attn=fcfg.fused_attn,
                shared_prefix=fcfg.shared_prefix,
                policy=self._make_policy(),
                prefix_index=self.prefix_index,
                rid_base=i * RID_STRIDE)
            self.pods.append(Pod(name=f"pod{i}", team=pod_team, prefill=pre,
                                 decode=dec, sched=sched))
        self.router = Router(self.pods, policy=fcfg.router,
                             prefix_index=self.prefix_index, seed=fcfg.seed)
        self.placements: Dict[int, tuple] = {}   # spec.idx -> (pod name, rid)
        self.elapsed_steps = 0
        # fault machinery: a FaultPlan (or its spec string) arms an injector
        # that fires at the top of step(); dead pods leave self.pods but
        # stay here so report()/outputs() keep their pre-fault finishes
        if isinstance(fault_plan, str):
            fault_plan = fault_mod.FaultPlan.parse(fault_plan)
        self.injector = (fault_mod.FaultInjector(fault_plan)
                         if fault_plan is not None and fault_plan.events
                         else None)
        self.dead_pods: List[Pod] = []

    def _make_policy(self) -> AdmissionPolicy:
        if self.fcfg.admission == "slo":
            return slo_mod.SLOPolicy(queue_bound=self.fcfg.queue_bound,
                                     classes=self.classes)
        if self.fcfg.admission == "fcfs":
            return AdmissionPolicy()
        raise ValueError(
            f"unknown admission policy {self.fcfg.admission!r} "
            f"(one of 'slo', 'fcfs')")

    # ---------------------------------------------------------------- drive
    def _submit(self, spec: RequestSpec, step: int) -> None:
        pod = self.router.route(spec)
        tracer = self.ctx.tracer
        if tracer.enabled:
            tracer.instant("route", "fleet", "fleet", "router",
                           idx=spec.idx, pod=pod.name,
                           policy=self.fcfg.router, slo=str(spec.slo))
        rid = pod.sched.submit(
            {"tokens": spec.tokens}, max_new=spec.max_new,
            prefix_len=spec.prefix_len, arrival_step=step, slo=spec.slo)
        self.placements[spec.idx] = (pod.name, rid)

    def done(self) -> bool:
        return all(pod.sched.done() for pod in self.pods)

    def step(self, arrivals: Optional[List[RequestSpec]] = None) -> None:
        """One fleet step: submit this step's arrivals, advance every pod.

        The heap is threaded through the pods: there is ONE symmetric
        memory, but each scheduler evolves its ``heap`` functionally — and
        the completion queue is fleet-shared, so a flush driven by pod B
        may complete ops pod A submitted.  Handing each pod the canonical
        heap and taking its result back is what makes those cross-pod
        flushes land in the memory every other pod reads."""
        if self.obs is not None:
            self.obs.begin_step(self.elapsed_steps)
        if self.injector is not None:
            # faults fire before this step's arrivals, deterministically:
            # the same plan against the same traffic reproduces bit-for-bit
            self.injector.apply(self, self.elapsed_steps)
        for spec in arrivals or ():
            self._submit(spec, self.elapsed_steps)
        for pod in self.pods:
            pod.sched.heap = self.heap
            pod.sched.step()
            self.heap = pod.sched.heap
        self.elapsed_steps += 1
        if self.obs is not None:
            self.obs.end_step(self)

    def run(self, specs: List[RequestSpec], *,
            max_steps: int = 10_000) -> dict:
        """Open-loop drive: play the arrival schedule, drain, report."""
        specs = sorted(specs, key=lambda s: (s.step, s.idx))
        i = 0
        try:
            while i < len(specs) or not self.done():
                if self.elapsed_steps >= max_steps:
                    raise RuntimeError(
                        f"fleet wedged after {max_steps} steps "
                        f"({len(specs) - i} arrivals unplayed)")
                batch = []
                while i < len(specs) and specs[i].step <= self.elapsed_steps:
                    batch.append(specs[i])
                    i += 1
                self.step(batch)
        except Exception as exc:
            # flight recorder: the last window of spans becomes a postmortem
            # trace before the exception propagates.  An AuditError already
            # dumped at the violation site (Obs.end_step).
            from repro.obs.audit import AuditError
            if self.obs is not None and not isinstance(exc, AuditError):
                self.obs.crash_dump(type(exc).__name__)
            raise
        return self.report()

    # ------------------------------------------------------ fault surface
    def _pod(self, name: str) -> Pod:
        pod = next((p for p in self.pods if p.name == name), None)
        if pod is None:
            raise ValueError(f"no live pod named {name!r} "
                             f"(live: {[p.name for p in self.pods]})")
        return pod

    def _fault_dump(self, reason: str) -> None:
        """Postmortem at the fault site: when a FlightRecorder is armed the
        dump names the fault in ``otherData.postmortem.reason``."""
        rec = getattr(self.obs, "recorder", None) if self.obs else None
        if rec is not None:
            rec.dump(reason=reason, step=self.elapsed_steps)

    def kill_pe(self, pe: int) -> None:
        """Fail-stop one PE.  Pending ops touching it cancel with error,
        its requests recover (re-migrate or recompute — ``serve.recovery``)
        and its heap row is poisoned.  Killing a pod's only prefill or only
        decode PE escalates to whole-pod adoption: the pod cannot serve.
        Killing an already-dead PE (or a PE of a dead pod) is a no-op — a
        crashed machine cannot crash twice, and random chaos plans are
        allowed to draw the same victim repeatedly."""
        pe = int(pe)
        if not self.ctx.fault.alive(pe):
            return
        pod = next((p for p in self.pods if pe in p.team.pes()), None)
        if pod is None:
            if any(pe in p.team.pes() for p in self.dead_pods):
                return
            raise ValueError(f"pe {pe} is not a PE of any pod")
        s = pod.sched
        is_prefill = pe in s.prefill_pes
        lone = ((is_prefill and len(s.prefill_pes) == 1)
                or (not is_prefill and len(s.decode_pes) == 1))
        if lone:
            self.kill_pod(pod.name)
            return
        self.ctx.fault.kill(pe)
        self.ctx.pending.cancel_pe(self.ctx, pe)
        if is_prefill:
            recovery_mod.recover_prefill_pe(self, pod, pe,
                                            step=self.elapsed_steps)
        else:
            recovery_mod.recover_decode_pe(self, pod, pe,
                                           step=self.elapsed_steps)
        self.heap = fault_mod.scramble_rows(self.heap, [pe])
        self._fault_dump(f"fault:kill_pe:{pe}")

    def kill_pod(self, name: str) -> None:
        """Fail-stop a whole pod; its live requests are adopted by the
        surviving pods (full replay of decoded-so-far tokens).  Killing a
        pod that already died is a no-op (see :meth:`kill_pe`)."""
        if any(p.name == name for p in self.dead_pods):
            return
        pod = self._pod(name)
        dead_pes = [int(p) for p in pod.team.pes()]
        for pe in dead_pes:
            if self.ctx.fault.alive(pe):
                self.ctx.fault.kill(pe)
                self.ctx.pending.cancel_pe(self.ctx, pe)
        recovery_mod.adopt_pod(self, pod, step=self.elapsed_steps)
        self.heap = fault_mod.scramble_rows(self.heap, dead_pes)
        self._fault_dump(f"fault:kill_pod:{name}")

    def partition(self) -> None:
        """Partition the inter-pod (dcn) fabric: cross-pod ops stay queued
        — neither lost nor delivered — until :meth:`heal`."""
        self.ctx.fault.dcn_down = True
        self._fault_dump("fault:partition")

    def heal(self) -> None:
        """Heal a dcn partition; queued cross-pod traffic drains at the
        next completion point."""
        self.ctx.fault.dcn_down = False

    def drain(self, name: str) -> None:
        """Administratively drain a pod: the router stops placing arrivals
        there (affinity re-keys to surviving pods), queued-but-unstarted
        requests re-route, and in-flight work finishes in place — the pod
        keeps stepping until :meth:`join` or the run ends.  Draining a
        dead pod is a no-op: it already left the router at adoption."""
        if any(p.name == name for p in self.dead_pods):
            return
        pod = self._pod(name)
        if pod not in self.router.pods:
            return
        self.router.remove_pod(pod)
        sched = pod.sched
        back = {(pn, rid): idx for idx, (pn, rid) in self.placements.items()}
        for req in [r for r in list(sched.queue) if r.prefill_cache is None]:
            sched.queue.remove(req)
            req.state = RECOVERED
            req.finish_step = sched._step
            sched._trace_phase(req, None, end_args={"outcome": "rerouted"})
            target = self.router._least_loaded()
            new_rid = target.sched.submit(
                req.batch, max_new=req.max_new, prefix_len=req.prefix_len,
                arrival_step=req.arrival_step, t_arrival=req.t_arrival,
                slo=req.slo)
            idx = back.get((pod.name, req.rid))
            if idx is not None:
                self.placements[idx] = (target.name, new_rid)
        self._fault_dump(f"fault:drain:{name}")

    def join(self, name: str) -> None:
        """Re-admit a drained pod to the router rotation.  Dead pods
        cannot rejoin — joining one is a no-op."""
        if any(p.name == name for p in self.dead_pods):
            return
        pod = self._pod(name)
        if pod not in self.router.pods:
            self.router.add_pod(pod)

    def report(self) -> dict:
        doc = metrics_mod.collect(self.pods + self.dead_pods,
                                  classes=self.classes,
                                  elapsed_steps=self.elapsed_steps)
        doc["router"] = dict(self.router.stats)
        if self.proxy is not None:
            doc["proxy"] = {
                "ring_slots": self.proxy.ring.slots,
                "backpressure": self.proxy.backpressure,
                "delivered": len(self.proxy.ring.delivered),
            }
        if self.obs is not None:
            doc["obs"] = self.obs.summary()
        if (self.injector is not None or self.dead_pods
                or self.ctx.fault.dead_pes or self.ctx.pending.errors):
            doc["fault"] = {
                "dead_pes": sorted(self.ctx.fault.dead_pes),
                "dead_pods": [p.name for p in self.dead_pods],
                "dcn_down": self.ctx.fault.dcn_down,
                "events": (list(self.injector.fired)
                           if self.injector is not None else []),
                "cancelled_ops": self.ctx.pending.stats.cancelled,
            }
        return doc

    def outputs(self) -> Dict[int, object]:
        """spec.idx -> generated token list (shed requests: empty)."""
        out = {}
        by_pod = {pod.name: pod for pod in self.pods + self.dead_pods}
        for idx, (pod_name, rid) in self.placements.items():
            out[idx] = list(by_pod[pod_name].sched.requests[rid].out)
        return out
