"""Cluster fleet driver: pods + router + SLO admission over one SHMEM world.

Topology: ``n_pods`` contiguous pods of ``prefill_per_pod + decode_per_pod``
PEs each.  ``node_size`` is set to the pod size, so intra-pod migration is
ici tier and anything crossing pods is dcn — routed through ONE shared
:class:`~repro.core.proxy.HostProxy` ring exactly like the paper's
reverse-offloaded inter-node ops.  All pods share:

- one symmetric heap and one :class:`~repro.serve.kvpool.KVPool` (block ids
  are cluster-wide addresses — the OpenSHMEM symmetric contract is what
  makes cross-pod prefix pulls possible at all);
- one prefix index (``DisaggScheduler.prefix_index``), so the router's
  affinity policy can see which pod staged a shared prompt;
- one :class:`~repro.serve.engine.Engine` (stateless params + jitted fns;
  per-pod slot banks live in each scheduler).

The driver is a straight open-loop clock: at every step it submits the
arrivals the traffic schedule put there (routing each through the
:class:`~repro.serve.frontend.router.Router`), then advances every pod's
scheduler one step.  After the schedule runs out it drains until every
request reaches a terminal state, then rolls the report up via
``frontend/metrics.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import context, teams
from repro.core.proxy import HostProxy
from repro.serve.engine import Engine, ServeConfig
from repro.serve.frontend import metrics as metrics_mod
from repro.serve.frontend import slo as slo_mod
from repro.serve.frontend.router import Pod, Router
from repro.serve.frontend.traffic import RequestSpec
from repro.serve.kvpool import KVPool
from repro.serve.kvxfer import KVMigrator
from repro.serve.scheduler import AdmissionPolicy, DisaggScheduler

#: rid namespace stride per pod — block tables and request maps are fleet-
#: global (shared pool), so request ids must never collide across pods
RID_STRIDE = 1_000_000


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    arch: str = "qwen3-4b"
    n_pods: int = 2
    prefill_per_pod: int = 1
    decode_per_pod: int = 2
    num_slots: int = 2
    kv_blocks: int = 96
    block_tokens: int = 4
    max_streams: int = 32
    max_len: int = 24               # decode cache length (prompt + max_new)
    max_new: int = 4                # default decode budget
    temperature: float = 0.0
    stream_chunks: int = 1          # 0 = whole-prefill migration
    fused_attn: bool = False        # fused-admission decode (excl. streaming)
    shared_prefix: bool = True
    admit_delay: int = 1
    admission: str = "slo"          # "slo" | "fcfs"
    queue_bound: int = 12           # per-pod SLO shed bound
    router: str = "affinity"        # router.POLICIES
    proxy_slots: int = 128          # host-proxy ring capacity (power of 2)
    seed: int = 0

    @property
    def pod_size(self) -> int:
        return self.prefill_per_pod + self.decode_per_pod

    @property
    def npes(self) -> int:
        return self.n_pods * self.pod_size


class Fleet:
    """A running cluster frontend: build once, feed it arrival schedules."""

    def __init__(self, fcfg: FleetConfig, *, arch_cfg=None, params=None,
                 engine: Optional[Engine] = None,
                 classes: Optional[Dict[str, slo_mod.SLOClass]] = None,
                 obs=None):
        import jax
        from repro.configs import base as cfgbase
        from repro.models import model

        self.fcfg = fcfg
        self.classes = slo_mod.CLASSES if classes is None else classes
        if engine is not None:
            self.cfg = engine.cfg
            self.engine = engine
        else:
            self.cfg = (arch_cfg if arch_cfg is not None
                        else cfgbase.reduced(cfgbase.get_config(fcfg.arch)))
            if params is None:
                params = model.init_params(jax.random.key(0), self.cfg)
            self.engine = Engine(self.cfg, params, max_len=fcfg.max_len)
        # one world: pods are nodes, inter-pod traffic is dcn via the proxy
        self.ctx, self.heap = context.init(npes=fcfg.npes,
                                           node_size=fcfg.pod_size)
        # observability bundle (repro.obs.Obs): installs the span tracer on
        # the shared context and arms the online tuner re-fit loop
        self.obs = obs
        if obs is not None:
            obs.attach(self.ctx)
        self.pool = KVPool.create(
            self.heap, self.cfg, fcfg.max_len, num_blocks=fcfg.kv_blocks,
            max_slots=fcfg.num_slots, block_tokens=fcfg.block_tokens,
            max_streams=fcfg.max_streams)
        self.proxy = (HostProxy(self.ctx, slots=fcfg.proxy_slots)
                      if fcfg.n_pods > 1 else None)
        self.prefix_index: Dict = {}
        world = teams.world(fcfg.npes)
        pod_teams = teams.pods_partition(
            world, [fcfg.pod_size] * fcfg.n_pods)
        self.pods: List[Pod] = []
        for i, pod_team in enumerate(pod_teams):
            pre, dec = teams.disagg_partition(pod_team, fcfg.prefill_per_pod)
            mig = KVMigrator(self.ctx, self.pool, proxy=self.proxy)
            sched = DisaggScheduler(
                self.ctx, self.heap, self.engine, self.pool, mig,
                prefill_pes=pre.pes(), decode_pes=dec.pes(),
                num_slots=fcfg.num_slots,
                scfg=ServeConfig(max_new_tokens=fcfg.max_new,
                                 temperature=fcfg.temperature,
                                 seed=fcfg.seed),
                admit_delay_steps=fcfg.admit_delay,
                stream_chunks=fcfg.stream_chunks,
                fused_attn=fcfg.fused_attn,
                shared_prefix=fcfg.shared_prefix,
                policy=self._make_policy(),
                prefix_index=self.prefix_index,
                rid_base=i * RID_STRIDE)
            self.pods.append(Pod(name=f"pod{i}", team=pod_team, prefill=pre,
                                 decode=dec, sched=sched))
        self.router = Router(self.pods, policy=fcfg.router,
                             prefix_index=self.prefix_index, seed=fcfg.seed)
        self.placements: Dict[int, tuple] = {}   # spec.idx -> (pod name, rid)
        self.elapsed_steps = 0

    def _make_policy(self) -> AdmissionPolicy:
        if self.fcfg.admission == "slo":
            return slo_mod.SLOPolicy(queue_bound=self.fcfg.queue_bound,
                                     classes=self.classes)
        if self.fcfg.admission == "fcfs":
            return AdmissionPolicy()
        raise ValueError(
            f"unknown admission policy {self.fcfg.admission!r} "
            f"(one of 'slo', 'fcfs')")

    # ---------------------------------------------------------------- drive
    def _submit(self, spec: RequestSpec, step: int) -> None:
        pod = self.router.route(spec)
        tracer = self.ctx.tracer
        if tracer.enabled:
            tracer.instant("route", "fleet", "fleet", "router",
                           idx=spec.idx, pod=pod.name,
                           policy=self.fcfg.router, slo=str(spec.slo))
        rid = pod.sched.submit(
            {"tokens": spec.tokens}, max_new=spec.max_new,
            prefix_len=spec.prefix_len, arrival_step=step, slo=spec.slo)
        self.placements[spec.idx] = (pod.name, rid)

    def done(self) -> bool:
        return all(pod.sched.done() for pod in self.pods)

    def step(self, arrivals: Optional[List[RequestSpec]] = None) -> None:
        """One fleet step: submit this step's arrivals, advance every pod.

        The heap is threaded through the pods: there is ONE symmetric
        memory, but each scheduler evolves its ``heap`` functionally — and
        the completion queue is fleet-shared, so a flush driven by pod B
        may complete ops pod A submitted.  Handing each pod the canonical
        heap and taking its result back is what makes those cross-pod
        flushes land in the memory every other pod reads."""
        if self.obs is not None:
            self.obs.begin_step(self.elapsed_steps)
        for spec in arrivals or ():
            self._submit(spec, self.elapsed_steps)
        for pod in self.pods:
            pod.sched.heap = self.heap
            pod.sched.step()
            self.heap = pod.sched.heap
        self.elapsed_steps += 1
        if self.obs is not None:
            self.obs.end_step(self)

    def run(self, specs: List[RequestSpec], *,
            max_steps: int = 10_000) -> dict:
        """Open-loop drive: play the arrival schedule, drain, report."""
        specs = sorted(specs, key=lambda s: (s.step, s.idx))
        i = 0
        try:
            while i < len(specs) or not self.done():
                if self.elapsed_steps >= max_steps:
                    raise RuntimeError(
                        f"fleet wedged after {max_steps} steps "
                        f"({len(specs) - i} arrivals unplayed)")
                batch = []
                while i < len(specs) and specs[i].step <= self.elapsed_steps:
                    batch.append(specs[i])
                    i += 1
                self.step(batch)
        except Exception as exc:
            # flight recorder: the last window of spans becomes a postmortem
            # trace before the exception propagates.  An AuditError already
            # dumped at the violation site (Obs.end_step).
            from repro.obs.audit import AuditError
            if self.obs is not None and not isinstance(exc, AuditError):
                self.obs.crash_dump(type(exc).__name__)
            raise
        return self.report()

    def report(self) -> dict:
        doc = metrics_mod.collect(self.pods, classes=self.classes,
                                  elapsed_steps=self.elapsed_steps)
        doc["router"] = dict(self.router.stats)
        if self.proxy is not None:
            doc["proxy"] = {
                "ring_slots": self.proxy.ring.slots,
                "backpressure": self.proxy.backpressure,
                "delivered": len(self.proxy.ring.delivered),
            }
        if self.obs is not None:
            doc["obs"] = self.obs.summary()
        return doc

    def outputs(self) -> Dict[int, object]:
        """spec.idx -> generated token list (shed requests: empty)."""
        out = {}
        by_pod = {pod.name: pod for pod in self.pods}
        for idx, (pod_name, rid) in self.placements.items():
            out[idx] = list(by_pod[pod_name].sched.requests[rid].out)
        return out
