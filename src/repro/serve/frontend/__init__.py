"""Cluster serving frontend: open-loop traffic, multi-pod routing, SLO
admission, and fleet metrics over the disaggregated SHMEM serve stack.

See DESIGN.md §10 for the architecture; the pieces compose as

    TrafficEngine.schedule() --> Fleet.run() --> metrics report
                                   |-- Router (per arrival)
                                   |-- DisaggScheduler per pod
                                   |       '-- SLOPolicy / FCFS hooks
                                   '-- shared KVPool / prefix index / proxy
"""
from repro.serve.frontend.env import FleetEnv, load_fleet_env
from repro.serve.frontend.fleet import Fleet, FleetConfig
from repro.serve.frontend.metrics import collect, percentile
from repro.serve.frontend.router import POLICIES, Pod, Router
from repro.serve.frontend.slo import CLASSES, SLOClass, SLOPolicy, resolve
from repro.serve.frontend.traffic import (RequestSpec, TenantSpec,
                                          TrafficEngine)

__all__ = [
    "CLASSES", "Fleet", "FleetConfig", "FleetEnv", "POLICIES", "Pod",
    "RequestSpec", "Router", "SLOClass", "SLOPolicy", "TenantSpec",
    "TrafficEngine", "collect", "load_fleet_env", "percentile", "resolve",
]
