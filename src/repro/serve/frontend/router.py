"""Multi-pod request router: load balancing + shared-prefix affinity.

A *pod* is one (prefill fleet, decode fleet) pair — a contiguous PE slice
of the world, one shared-fabric node (so intra-pod migration is ici tier
and anything between pods is dcn, riding the host-proxy ring).  All pods
share ONE symmetric KV pool and ONE prefix index, so a block id names the
same physical page everywhere and a request routed to the "wrong" pod can
still map a prefix staged elsewhere — it just pays for pulling those
blocks across the pod boundary.

Routing policies (``Router(policy=...)``):

- ``random``       — seeded uniform choice (the control arm for the
  affinity CI gate: its cross-pod wire bytes are the baseline);
- ``round_robin``  — cycles pods regardless of load;
- ``least_loaded`` — minimizes live occupancy: waiting requests plus
  active decode slots over the pod's slot capacity, read live from the
  schedulers' slot banks (``KVPool.stats()`` rides along in
  :meth:`Pod.load` for shed/telemetry views);
- ``affinity``     — if the request declares a shared prefix that is
  already registered, route to the pod whose prefill PE staged it (the
  entry's ``home_pe``): every prefix block is then intra-pod (or already
  resident at the decode PE and skipped entirely), so the dcn wire bytes
  the random arm pays simply vanish.  Misses fall back to least-loaded.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serve.frontend.traffic import RequestSpec


@dataclasses.dataclass
class Pod:
    """One pod's control plane: teams + its DisaggScheduler."""
    name: str
    team: object                      # teams.Team covering the pod's PEs
    prefill: object                   # prefill sub-team
    decode: object                    # decode sub-team
    sched: object                     # DisaggScheduler

    def slot_capacity(self) -> int:
        return sum(len(v) for v in self.sched.slot_req.values())

    def free_slots(self) -> int:
        return sum(1 for v in self.sched.slot_req.values()
                   for owner in v if owner is None)

    def waiting(self) -> int:
        s = self.sched
        return (len(s.queue) + len(s.staged) + len(s.streaming)
                + len(s.parked) + len(s.preempted) + len(s.migrating))

    def occupancy(self) -> float:
        """Live load score: waiting requests + busy slots, normalized by
        slot capacity — the quantity least-loaded routing minimizes."""
        cap = max(1, self.slot_capacity())
        busy = cap - self.free_slots()
        return (self.waiting() + busy) / cap

    def load(self) -> dict:
        """Occupancy + pool view (the pool is fleet-shared, but surfacing
        it here keeps one stop for 'can this pod take more work')."""
        return {
            "waiting": self.waiting(),
            "free_slots": self.free_slots(),
            "slot_capacity": self.slot_capacity(),
            "occupancy": self.occupancy(),
            "pool": self.sched.pool.stats(),
        }


POLICIES = ("random", "round_robin", "least_loaded", "affinity")


class Router:
    """Maps arrivals onto pods; shares the fleet's prefix index read-only."""

    def __init__(self, pods: List[Pod], *, policy: str = "affinity",
                 prefix_index: Optional[Dict] = None, seed: int = 0):
        if not pods:
            raise ValueError("need at least one pod")
        if policy not in POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"one of {POLICIES}")
        self.pods = list(pods)
        self.policy = policy
        self.prefix_index = {} if prefix_index is None else prefix_index
        self._rng = np.random.default_rng(np.random.PCG64((seed, 0xF1EE7)))
        self._rr = 0
        self._pe_pod: Dict[int, Pod] = {}
        for pod in self.pods:
            for pe in pod.team.pes():
                self._pe_pod[pe] = pod
        self.stats = {"routed": 0, "affinity_hits": 0}

    # ------------------------------------------------------- drain / join
    def remove_pod(self, pod: Pod) -> None:
        """Stop routing to a pod (drain, or a dead pod leaving the fleet).
        Its PE -> pod affinity keys are dropped too, so a prefix homed
        there falls back to least-loaded instead of a drained target."""
        if pod not in self.pods:
            raise ValueError(f"pod {pod.name} is not routable")
        if len(self.pods) == 1:
            raise ValueError("cannot remove the last routable pod")
        self.pods.remove(pod)
        for pe in pod.team.pes():
            self._pe_pod.pop(pe, None)

    def add_pod(self, pod: Pod) -> None:
        """Re-admit a drained pod (rebuilds its affinity keys)."""
        if pod in self.pods:
            raise ValueError(f"pod {pod.name} is already routable")
        self.pods.append(pod)
        for pe in pod.team.pes():
            self._pe_pod[pe] = pod

    # ------------------------------------------------------------- scoring
    def _least_loaded(self) -> Pod:
        self._rr += 1
        n = len(self.pods)
        return min((self.pods[(self._rr + k) % n] for k in range(n)),
                   key=lambda p: p.occupancy())

    def _home_pod(self, spec: RequestSpec) -> Optional[Pod]:
        key = spec.prefix_key()
        if key is None:
            return None
        entry = self.prefix_index.get(key)
        if entry is None:
            return None
        return self._pe_pod.get(entry.home_pe)

    # --------------------------------------------------------------- route
    def route(self, spec: RequestSpec) -> Pod:
        self.stats["routed"] += 1
        if self.policy == "random":
            return self.pods[int(self._rng.integers(len(self.pods)))]
        if self.policy == "round_robin":
            pod = self.pods[self._rr % len(self.pods)]
            self._rr += 1
            return pod
        if self.policy == "affinity":
            pod = self._home_pod(spec)
            if pod is not None:
                self.stats["affinity_hits"] += 1
                return pod
        return self._least_loaded()
