"""SLO-aware admission: deadline classes, priority scheduling, shedding,
and preemptive eviction — on the scheduler's :class:`AdmissionPolicy` hooks.

The policy never touches the migration protocol; it only decides *what runs
next*, which is why the disagg bitwise guarantee survives any schedule it
produces (property-tested in ``tests/test_fleet.py``):

- **admit** — backpressure at submit: past ``queue_bound`` best-effort
  traffic is shed outright, and past ``hard_bound`` everything is (the
  queue must stay bounded or TTFD for *every* class collapses — shedding
  the overload is what keeps goodput from cratering past saturation).
- **select** — earliest-deadline-first within the highest waiting priority
  class: an interactive request never queues behind a batch scan.
- **waiting_order** — the same ordering applied to slot waiters (parked
  streams, preempted requests).
- **preempt_victim** — a slot-starved non-best-effort request may evict a
  best-effort request that is *over budget* (generated at least its class's
  ``decode_budget`` tokens) back to the pool; the victim's KV stays in its
  blocks and it resumes on the same decode PE when a slot frees.  A request
  preempted ``max_preemptions`` times becomes immune (no livelock).

Classes are plain frozen data: priority 0 is most urgent; ``ttfd_deadline``
is the arrival->first-decode-token budget in scheduler steps that goodput
accounting (``frontend/metrics.py``) checks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.serve.scheduler import AdmissionPolicy, Request


@dataclasses.dataclass(frozen=True)
class SLOClass:
    name: str
    priority: int                    # 0 = most urgent
    ttfd_deadline: int               # arrival -> first token, in sched steps
    e2e_deadline: int = 10_000       # arrival -> finish budget
    best_effort: bool = False        # sheddable + preemptible
    decode_budget: int = 0           # tokens before an over-budget preempt


#: default deadline-class catalog (override per deployment)
CLASSES: Dict[str, SLOClass] = {
    "interactive": SLOClass("interactive", 0, ttfd_deadline=8,
                            e2e_deadline=24),
    "standard": SLOClass("standard", 1, ttfd_deadline=16, e2e_deadline=48),
    "batch": SLOClass("batch", 2, ttfd_deadline=64, e2e_deadline=256,
                      best_effort=True, decode_budget=1),
}

DEFAULT_CLASS = "standard"


def resolve(slo, classes: Optional[Dict[str, SLOClass]] = None) -> SLOClass:
    """Map a request's opaque ``slo`` tag (name, class, or None) to a
    class.  Unknown names fall back to the default class rather than
    erroring — a frontend must not die on a mislabeled request."""
    classes = CLASSES if classes is None else classes
    if isinstance(slo, SLOClass):
        return slo
    return classes.get(slo, classes[DEFAULT_CLASS])


class SLOPolicy(AdmissionPolicy):
    """Deadline-class admission over the DisaggScheduler hooks."""

    def __init__(self, *, queue_bound: int = 16,
                 hard_bound: Optional[int] = None,
                 classes: Optional[Dict[str, SLOClass]] = None,
                 max_preemptions: int = 2):
        if queue_bound < 1:
            raise ValueError(f"queue_bound must be >= 1, got {queue_bound}")
        self.queue_bound = queue_bound
        self.hard_bound = (2 * queue_bound if hard_bound is None
                           else hard_bound)
        self.classes = CLASSES if classes is None else classes
        self.max_preemptions = max_preemptions

    # ------------------------------------------------------------- helpers
    def cls(self, req: Request) -> SLOClass:
        return resolve(req.slo, self.classes)

    def _deadline(self, req: Request) -> int:
        return req.arrival_step + self.cls(req).ttfd_deadline

    def _rank(self, req: Request) -> tuple:
        """Priority first, earliest TTFD deadline second, FIFO third."""
        return (self.cls(req).priority, self._deadline(req),
                req.arrival_step, req.rid)

    # --------------------------------------------------------------- hooks
    def admit(self, req: Request, queue_len: int) -> bool:
        c = self.cls(req)
        if queue_len >= self.hard_bound:
            return False
        if queue_len >= self.queue_bound and c.best_effort:
            return False
        return True

    def select(self, queue) -> int:
        return min(range(len(queue)), key=lambda i: self._rank(queue[i]))

    def waiting_order(self, reqs: List[Request]) -> List[Request]:
        return sorted(reqs, key=self._rank)

    def preempt_victim(self, req: Request,
                       decoding: List[Request]) -> Optional[Request]:
        c = self.cls(req)
        if c.best_effort:
            return None                  # best effort never preempts anyone
        cands = [r for r in decoding
                 if self.cls(r).best_effort
                 and self.cls(r).priority > c.priority
                 and len(r.out) >= max(1, self.cls(r).decode_budget)
                 and r.preemptions < self.max_preemptions]
        if not cands:
            return None
        # most decode progress first: it has consumed the most budget and
        # loses the least (its KV is banked in the pool either way)
        return max(cands, key=lambda r: (len(r.out), -r.rid))
