"""Fleet metrics: latency percentiles, goodput, wire accounting.

Everything here is derived from the schedulers' per-request records — no
separate measurement path, so the numbers cannot drift from what actually
ran.  All latencies are *frontend-visible*: measured from the request's
``arrival_step`` (the open-loop clock), so queue time before prefill counts
— the satellite fix that makes overload measurable at all (a queue-blind
TTFD looks great while requests rot in the queue).

Definitions:

- **TTFD** — arrival -> first decode token (``admit_step - arrival_step``
  in scheduler steps; ``t_admit - t_arrival`` on the modeled comm clock).
- **e2e** — arrival -> finish.
- **goodput** — requests that finished AND met their class's TTFD deadline,
  divided by everything *offered* (including shed requests).  Offered load
  is the denominator on purpose: shedding trades completed-late for
  rejected-fast, and goodput must show that trade, not hide it.
- **cross-pod wire bytes** — migration bytes whose block home and decode
  PE were in different pods (dcn tier, host-proxy ring): the quantity
  prefix-affinity routing exists to remove.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.serve.frontend import slo as slo_mod
from repro.serve.scheduler import FINISHED, RECOVERED, SHED


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank-with-interpolation percentile (q in [0, 100])."""
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    pos = (len(s) - 1) * q / 100.0
    lo = int(pos)
    frac = pos - lo
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] * (1 - frac) + s[hi] * frac)


def _latency_block(ttfd_steps, ttfd_model_s, e2e_steps) -> dict:
    return {
        "ttfd_p50_steps": percentile(ttfd_steps, 50),
        "ttfd_p99_steps": percentile(ttfd_steps, 99),
        "ttfd_p50_model_s": percentile(ttfd_model_s, 50),
        "ttfd_p99_model_s": percentile(ttfd_model_s, 99),
        "e2e_p50_steps": percentile(e2e_steps, 50),
        "e2e_p99_steps": percentile(e2e_steps, 99),
        "count": len(ttfd_steps),
    }


def collect(pods, *, classes: Optional[Dict] = None,
            elapsed_steps: Optional[int] = None) -> dict:
    """Roll every pod's request records up into one fleet report (plain
    JSON-able dict — benchmarks dump it verbatim)."""
    classes = slo_mod.CLASSES if classes is None else classes
    per_class: Dict[str, dict] = {}
    ttfd_all: List[float] = []
    ttfd_model_all: List[float] = []
    ttfd_first_block_all: List[float] = []
    e2e_all: List[float] = []
    offered = completed = shed = good = 0
    per_pod = {}
    for pod in pods:
        st = pod.sched.stats
        per_pod[pod.name] = {
            "prefills": st.prefills,
            "migrations": st.migrations,
            "admissions": st.admissions,
            "preempts": st.preempts,
            "resumes": st.resumes,
            "sheds": st.sheds,
            "bytes_migrated": st.bytes_migrated,
            "bytes_cross_pod": st.bytes_cross_pod,
            "bytes_wire_saved": st.bytes_wire_saved,
            "stream_chunks": st.stream_chunks,
            "prefix_hits": st.prefix_hits,
            "stalls": {"pool": st.stalled_on_pool,
                       "slots": st.stalled_on_slots,
                       "streams": st.stalled_on_streams},
            "recovery": {"remigrated": st.remigrated,
                         "recomputed": st.recomputed,
                         "replayed_tokens": st.replayed_tokens,
                         "recovery_p50_steps": percentile(
                             st.recovery_steps, 50),
                         "recovery_p99_steps": percentile(
                             st.recovery_steps, 99),
                         "recovered_requests": len(st.recovery_steps)},
            "load": pod.load(),
        }
        for req in pod.sched.requests.values():
            if req.state == RECOVERED:
                # the record lives on under a new rid on another pod (or
                # re-routed at drain) — counting it here would double-count
                # the request against offered load
                continue
            offered += 1
            cls = slo_mod.resolve(req.slo, classes)
            bucket = per_class.setdefault(
                cls.name, {"offered": 0, "completed": 0, "shed": 0,
                           "good": 0, "preempted": 0,
                           "_ttfd": [], "_ttfd_model": [], "_e2e": []})
            bucket["offered"] += 1
            bucket["preempted"] += req.preemptions
            if req.state == SHED:
                shed += 1
                bucket["shed"] += 1
                continue
            if req.state != FINISHED:
                continue                      # drained run: should not happen
            completed += 1
            bucket["completed"] += 1
            ttfd = req.admit_step - req.arrival_step
            ttfd_model = req.t_admit - req.t_arrival
            e2e = req.finish_step - req.arrival_step
            bucket["_ttfd"].append(ttfd)
            bucket["_ttfd_model"].append(ttfd_model)
            bucket["_e2e"].append(e2e)
            ttfd_all.append(ttfd)
            ttfd_model_all.append(ttfd_model)
            if req.first_block_step >= 0:
                ttfd_first_block_all.append(
                    req.first_block_step - req.arrival_step)
            e2e_all.append(e2e)
            if ttfd <= cls.ttfd_deadline:
                good += 1
                bucket["good"] += 1
    for name, b in per_class.items():
        b.update(_latency_block(b.pop("_ttfd"), b.pop("_ttfd_model"),
                                b.pop("_e2e")))
        b["goodput"] = b["good"] / b["offered"] if b["offered"] else 0.0
    report = {
        "offered": offered,
        "completed": completed,
        "shed": shed,
        "good": good,
        "goodput": good / offered if offered else 0.0,
        "latency": _latency_block(ttfd_all, ttfd_model_all, e2e_all),
        # time-to-first-resident-block percentiles (additive keys — the
        # device-op PR's satellite stat; equals admission under the barrier
        # protocol, strictly earlier under fused admission)
        "ttfd_first_block": {
            "p50_steps": percentile(ttfd_first_block_all, 50),
            "p99_steps": percentile(ttfd_first_block_all, 99),
            "count": len(ttfd_first_block_all),
        },
        "by_class": per_class,
        "by_pod": per_pod,
        "wire": {
            "bytes_migrated": sum(p["bytes_migrated"]
                                  for p in per_pod.values()),
            "bytes_cross_pod": sum(p["bytes_cross_pod"]
                                   for p in per_pod.values()),
            "bytes_wire_saved": sum(p["bytes_wire_saved"]
                                    for p in per_pod.values()),
        },
        "preempts": sum(p["preempts"] for p in per_pod.values()),
        "resumes": sum(p["resumes"] for p in per_pod.values()),
        "recovered": {
            "remigrated": sum(p["recovery"]["remigrated"]
                              for p in per_pod.values()),
            "recomputed": sum(p["recovery"]["recomputed"]
                              for p in per_pod.values()),
            "replayed_tokens": sum(p["recovery"]["replayed_tokens"]
                                   for p in per_pod.values()),
            "recovered_requests": sum(p["recovery"]["recovered_requests"]
                                      for p in per_pod.values()),
        },
    }
    if elapsed_steps:
        report["elapsed_steps"] = elapsed_steps
        report["goodput_per_step"] = good / elapsed_steps
    return report
