"""Open-loop traffic engine: reproducible multi-tenant arrival schedules.

A *closed-loop* driver (submit, wait, submit) can never overload the
system, so it can never measure the thing a cluster frontend exists for —
behavior past saturation.  This engine is open-loop: requests arrive on
their own clock (virtual scheduler steps, no wall time anywhere), whether
or not the fleet has capacity, exactly the methodology serving papers use
to sweep offered load.

Two arrival processes, both driven by one seeded ``numpy`` generator so a
(seed, tenants, rate, steps) tuple always produces the identical schedule:

- **poisson** — i.i.d. per-step arrival counts ``Poisson(rate)``, the
  classic open-loop baseline;
- **bursty**  — a two-state modulated Poisson process: the engine flips
  between a *hot* state (``rate * burst_factor``) and a *cold* state
  (``rate * cold_factor``) with switching probability ``1 / burst_len``
  per step — the "everyone pastes the same stack trace at 9am" shape that
  stresses shed/preempt paths far harder than the same mean rate spread
  evenly.

Each arrival is assigned a tenant by weighted choice; the tenant spec
decides prompt length, decode budget, SLO class, and whether the request
re-uses one of the tenant's *prefix groups* (a fixed prompt submitted with
``prefix_len == S``, the many-samples-one-prompt workload that exercises
shared-prefix block mapping and the router's affinity policy).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's request mix (distributions are finite choice sets so
    the schedule stays readable and the model jit-caches per shape)."""
    name: str
    weight: float = 1.0
    prompt_lens: Tuple[int, ...] = (12,)
    max_new: Tuple[int, ...] = (4,)
    slo: str = "standard"               # deadline class name (slo.CLASSES)
    shared_prefix_prob: float = 0.0     # P(request re-uses a prefix group)
    prefix_groups: int = 1              # distinct shared prompts per tenant


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """One scheduled arrival: everything the router/scheduler needs."""
    idx: int                            # schedule-order id
    step: int                           # arrival step (open-loop clock)
    tenant: str
    slo: str
    tokens: np.ndarray                  # (1, S) int32 prompt
    max_new: int
    prefix_len: int                     # 0 = private prompt

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[1])

    def prefix_key(self) -> Optional[tuple]:
        """The scheduler's token-tuple index key (None for private)."""
        if self.prefix_len <= 0:
            return None
        return tuple(int(t) for t in self.tokens[0, :self.prefix_len])


class TrafficEngine:
    """Deterministic open-loop arrival generator over a tenant mix."""

    def __init__(self, tenants: Sequence[TenantSpec], *, rate: float,
                 vocab: int, seed: int = 0, process: str = "poisson",
                 burst_len: int = 8, burst_factor: float = 4.0,
                 cold_factor: float = 0.25):
        if not tenants:
            raise ValueError("need at least one tenant")
        if rate <= 0:
            raise ValueError(f"offered rate must be positive, got {rate}")
        if process not in ("poisson", "bursty"):
            raise ValueError(f"unknown arrival process {process!r}")
        self.tenants = list(tenants)
        self.rate = rate
        self.vocab = vocab
        self.seed = seed
        self.process = process
        self.burst_len = max(1, burst_len)
        self.burst_factor = burst_factor
        self.cold_factor = cold_factor
        w = np.asarray([t.weight for t in self.tenants], np.float64)
        self._weights = w / w.sum()
        # prefix-group prompts are part of the schedule's identity: derive
        # them from the same seed, once, so every run (and the router's
        # affinity lookups) sees identical shared prompts
        rng = np.random.default_rng(np.random.PCG64(seed))
        self._group_prompts = {}
        for t in self.tenants:
            S = max(t.prompt_lens)
            for g in range(t.prefix_groups):
                self._group_prompts[(t.name, g)] = rng.integers(
                    0, vocab, (1, S), dtype=np.int64).astype(np.int32)

    def _tokens(self, rng, tenant: TenantSpec):
        """(tokens, prefix_len) for one arrival of this tenant."""
        if (tenant.shared_prefix_prob > 0
                and rng.random() < tenant.shared_prefix_prob):
            g = int(rng.integers(tenant.prefix_groups))
            tokens = self._group_prompts[(tenant.name, g)]
            return tokens, int(tokens.shape[1])     # whole-prompt prefix
        S = int(rng.choice(np.asarray(tenant.prompt_lens)))
        tokens = rng.integers(0, self.vocab, (1, S),
                              dtype=np.int64).astype(np.int32)
        return tokens, 0

    def schedule(self, n_steps: int) -> List[RequestSpec]:
        """The full arrival schedule for ``n_steps`` of open-loop traffic,
        sorted by arrival step.  Re-calling with the same arguments returns
        an identical schedule (fresh generator per call, no shared state)."""
        rng = np.random.default_rng(np.random.PCG64((self.seed, n_steps)))
        specs: List[RequestSpec] = []
        hot = False
        idx = 0
        for step in range(n_steps):
            if self.process == "bursty":
                if rng.random() < 1.0 / self.burst_len:
                    hot = not hot
                lam = self.rate * (self.burst_factor if hot
                                   else self.cold_factor)
            else:
                lam = self.rate
            for _ in range(int(rng.poisson(lam))):
                tenant = self.tenants[int(rng.choice(len(self.tenants),
                                                     p=self._weights))]
                tokens, prefix_len = self._tokens(rng, tenant)
                specs.append(RequestSpec(
                    idx=idx, step=step, tenant=tenant.name, slo=tenant.slo,
                    tokens=tokens,
                    max_new=int(rng.choice(np.asarray(tenant.max_new))),
                    prefix_len=prefix_len))
                idx += 1
        return specs

    def offered_load(self, specs: List[RequestSpec]) -> dict:
        """Summary of a schedule: totals per tenant/class, token volumes."""
        out = {"requests": len(specs), "by_tenant": {}, "by_slo": {},
               "prompt_tokens": sum(s.prompt_len for s in specs),
               "decode_tokens": sum(s.max_new for s in specs),
               "shared_prefix": sum(1 for s in specs if s.prefix_len > 0)}
        for s in specs:
            out["by_tenant"][s.tenant] = out["by_tenant"].get(s.tenant, 0) + 1
            out["by_slo"][s.slo] = out["by_slo"].get(s.slo, 0) + 1
        return out
